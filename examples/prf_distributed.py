"""Distributed PRF on a host-device mesh — the paper's §4 in miniature.

    python examples/prf_distributed.py --devices 8 --data 4 --model 2

Vertical partitioning: features shard over `model`, samples over `data`;
T_GR histogram psum crosses only the sample axis, T_NS winner selection
only the feature axis (paper Figs. 3-7).

Multi-process mode — the cluster topology on one machine:

    python examples/prf_distributed.py --multiproc 2 --local-devices 2

spawns N coordinator-connected ``jax.distributed`` processes, each
feeding only its own row range of a shared memmap through
``launch.multiproc.MultiHostMesh``; every process prints its per-host
feed bytes and the (identical) global forest hash.
"""
import argparse
import hashlib
import os
import subprocess
import sys
import tempfile


def _forest_hash(model) -> str:
    import jax
    import numpy as np

    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(model.forest):
        h.update(np.asarray(leaf).tobytes())
    h.update(np.asarray(model.bin_edges).tobytes())
    return h.hexdigest()


def run_multiproc_worker(args):
    """One coordinator-connected training process of the drill."""
    sys.path.insert(0, "src")
    from repro.launch import multiproc

    pid, nproc = multiproc.initialize(
        f"127.0.0.1:{args.port}", args.multiproc, args.worker,
        local_device_count=args.local_devices,
    )
    import numpy as np

    from repro.core import ForestConfig
    from repro.core.api import train_prf
    from repro.launch.multiproc import MultiHostMesh

    x = np.memmap(args.memmap, dtype=np.float32, mode="r",
                  shape=(args.rows, args.features))
    y = np.load(args.memmap + ".y.npy")
    cfg = ForestConfig(
        n_trees=args.trees, max_depth=6, n_bins=32, n_classes=4,
        feature_mode="importance", weighted_voting=True,
        sample_block=args.rows // 4,
    )
    runtime = MultiHostMesh()
    from repro.core.distributed import train_prf_multiproc

    model = train_prf_multiproc(x, y, cfg, seed=0, runtime=runtime)
    lo, hi = runtime.local_row_range(
        args.rows + runtime.pad(args.rows)
    )
    print(
        f"[proc {pid}/{nproc}] data shards [{runtime.shard_lo}, "
        f"{runtime.shard_hi}) rows ~[{lo}, {hi}) fed "
        f"{runtime.feed_bytes / 2**20:.2f} MiB host->device",
        flush=True,
    )
    print(f"[proc {pid}/{nproc}] forest sha256={_forest_hash(model)}",
          flush=True)


def run_multiproc(args):
    """Spawn the coordinator-connected process fleet and check parity."""
    sys.path.insert(0, "src")
    import numpy as np

    from repro.data.tabular import make_classification

    x, y = make_classification(
        n_samples=args.rows, n_features=args.features, n_classes=4, seed=1,
    )
    tmp = tempfile.mkdtemp(prefix="prf_multiproc_")
    path = os.path.join(tmp, "train.f32")
    mm = np.memmap(path, dtype=np.float32, mode="w+", shape=x.shape)
    mm[:] = x.astype(np.float32)
    mm.flush()
    np.save(path + ".y.npy", y)

    procs = [
        subprocess.Popen(
            [sys.executable, __file__, "--worker", str(i),
             "--multiproc", str(args.multiproc),
             "--local-devices", str(args.local_devices),
             "--port", str(args.port), "--memmap", path,
             "--rows", str(args.rows), "--features", str(args.features),
             "--trees", str(args.trees)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )
        for i in range(args.multiproc)
    ]
    hashes = []
    for i, p in enumerate(procs):
        out, _ = p.communicate(timeout=900)
        print(out, end="")
        if p.returncode != 0:
            raise SystemExit(f"worker {i} failed (rc={p.returncode})")
        hashes += [ln.rsplit("=", 1)[1] for ln in out.splitlines()
                   if "forest sha256=" in ln]
    if len(set(hashes)) != 1:
        raise SystemExit(f"forest hashes diverged across hosts: {hashes}")
    print(f"global forest hash agrees across {args.multiproc} processes: "
          f"{hashes[0][:16]}…")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--data", type=int, default=4)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--trees", type=int, default=16)
    ap.add_argument("--multiproc", type=int, default=0,
                    help="spawn N jax.distributed processes instead of the "
                         "single-process mesh demo")
    ap.add_argument("--local-devices", type=int, default=2)
    ap.add_argument("--port", type=int, default=12737)
    ap.add_argument("--rows", type=int, default=4096)
    ap.add_argument("--features", type=int, default=64)
    ap.add_argument("--worker", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--memmap", type=str, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.worker is not None:
        run_multiproc_worker(args)
        return
    if args.multiproc:
        run_multiproc(args)
        return

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}"
    )
    sys.path.insert(0, "src")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ForestConfig
    from repro.core.binning import apply_bins
    from repro.core.distributed import (
        fit_bins_sharded, make_prf_train_fn, predict_sharded,
    )
    from repro.data.tabular import make_classification, train_test_split
    from repro.launch.mesh import make_mesh
    from repro.roofline.analysis import analyze_hlo_text

    x, y = make_classification(n_samples=4096, n_features=64, n_classes=4, seed=1)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(n_trees=args.trees, max_depth=6, n_bins=32, n_classes=4)

    mesh = make_mesh((args.data, args.model), ("data", "model"))
    print(f"mesh: data={args.data} x model={args.model}")
    # Bin edges from per-shard quantile sketches merged over the mesh —
    # no single host ever takes a full pass over the raw source.
    edges = fit_bins_sharded(xtr, cfg.n_bins, mesh, sample_block=512)
    xb = np.asarray(apply_bins(jnp.asarray(xtr), jnp.asarray(edges)))
    train_fn, _ = make_prf_train_fn(cfg, mesh)

    n = (xb.shape[0] // args.data) * args.data
    lowered = train_fn.lower(
        jnp.asarray(xb[:n]), jnp.asarray(ytr[:n]), jax.random.PRNGKey(0)
    )
    compiled = lowered.compile()
    a = analyze_hlo_text(compiled.as_text())
    print("collectives (per device):",
          {k: int(v["count"]) for k, v in a["collectives"].items()},
          f"= {a['collective_bytes']/2**20:.1f} MiB on the wire")

    forest = train_fn(jnp.asarray(xb[:n]), jnp.asarray(ytr[:n]), jax.random.PRNGKey(0))
    xbte = apply_bins(jnp.asarray(xte), jnp.asarray(edges))
    m = (xbte.shape[0] // args.data) * args.data
    pred = predict_sharded(forest, xbte[:m], mesh)
    acc = float(np.mean(np.asarray(pred) == yte[:m]))
    print(f"distributed PRF accuracy: {acc:.4f}")


if __name__ == "__main__":
    main()
