"""Distributed PRF on a host-device mesh — the paper's §4 in miniature.

    python examples/prf_distributed.py --devices 8 --data 4 --model 2

Vertical partitioning: features shard over `model`, samples over `data`;
T_GR histogram psum crosses only the sample axis, T_NS winner selection
only the feature axis (paper Figs. 3-7).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--data", type=int, default=4)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--trees", type=int, default=16)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}"
    )
    sys.path.insert(0, "src")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ForestConfig
    from repro.core.binning import apply_bins
    from repro.core.distributed import (
        fit_bins_sharded, make_prf_train_fn, predict_sharded,
    )
    from repro.data.tabular import make_classification, train_test_split
    from repro.launch.mesh import make_mesh
    from repro.roofline.analysis import analyze_hlo_text

    x, y = make_classification(n_samples=4096, n_features=64, n_classes=4, seed=1)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(n_trees=args.trees, max_depth=6, n_bins=32, n_classes=4)

    mesh = make_mesh((args.data, args.model), ("data", "model"))
    print(f"mesh: data={args.data} x model={args.model}")
    # Bin edges from per-shard quantile sketches merged over the mesh —
    # no single host ever takes a full pass over the raw source.
    edges = fit_bins_sharded(xtr, cfg.n_bins, mesh, sample_block=512)
    xb = np.asarray(apply_bins(jnp.asarray(xtr), jnp.asarray(edges)))
    train_fn, _ = make_prf_train_fn(cfg, mesh)

    n = (xb.shape[0] // args.data) * args.data
    lowered = train_fn.lower(
        jnp.asarray(xb[:n]), jnp.asarray(ytr[:n]), jax.random.PRNGKey(0)
    )
    compiled = lowered.compile()
    a = analyze_hlo_text(compiled.as_text())
    print("collectives (per device):",
          {k: int(v["count"]) for k, v in a["collectives"].items()},
          f"= {a['collective_bytes']/2**20:.1f} MiB on the wire")

    forest = train_fn(jnp.asarray(xb[:n]), jnp.asarray(ytr[:n]), jax.random.PRNGKey(0))
    xbte = apply_bins(jnp.asarray(xte), jnp.asarray(edges))
    m = (xbte.shape[0] // args.data) * args.data
    pred = predict_sharded(forest, xbte[:m], mesh)
    acc = float(np.mean(np.asarray(pred) == yte[:m]))
    print(f"distributed PRF accuracy: {acc:.4f}")


if __name__ == "__main__":
    main()
