"""Batched serving demo: prefill a prompt batch, decode greedily.

    python examples/serve_lm.py --arch smollm-135m --batch 4 --prompt-len 32 --gen 16

Uses the same prefill/decode paths the dry-run lowers at 32k/500k scale
(rolling window caches for local-attention archs, SSM states for mamba).
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.serve_step import greedy_generate

    cfg = dataclasses.replace(
        get_config(args.arch),
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=4096, head_dim=64, compute_dtype="float32",
        local_window=16 if get_config(args.arch).local_window else 0,
        ssm_state=16 if get_config(args.arch).ssm_state else 0,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    s_max = args.prompt_len + args.gen + 1

    t0 = time.time()
    out = greedy_generate(model, params, prompts, steps=args.gen, s_max=s_max)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample continuations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}]", np.asarray(out[b]).tolist())


if __name__ == "__main__":
    main()
