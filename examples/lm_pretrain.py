"""End-to-end LM training driver with checkpoint/restart fault tolerance.

    python examples/lm_pretrain.py --arch smollm-135m --steps 200 --scale 0.25

``--scale 1.0`` trains the full 135M-parameter config (slow on 1 CPU
core); the default 0.25 width/depth scale (~10M params) runs a few
hundred steps in minutes and shows the loss dropping. The DSI-table data
pipeline (paper §4.1.2) feeds batches; checkpoints land in
``artifacts/lm_ckpt`` and the run RESUMES from the latest one.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/lm_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.checkpoint.checkpoint import latest_step
    from repro.configs import get_config
    from repro.data.pipeline import TokenPipeline
    from repro.models import build_model
    from repro.training import AdamWConfig
    from repro.training.train_step import init_state, make_train_step

    cfg = get_config(args.arch)
    if args.scale < 1.0:
        cfg = dataclasses.replace(
            cfg,
            n_layers=max(2, int(cfg.n_layers * args.scale)),
            d_model=max(64, int(cfg.d_model * args.scale) // 16 * 16),
            n_heads=max(2, int(cfg.n_heads * args.scale)),
            n_kv_heads=max(1, int(cfg.n_kv_heads * args.scale)),
            d_ff=max(128, int(cfg.d_ff * args.scale) // 16 * 16),
            vocab_size=min(cfg.vocab_size, 8192),
            compute_dtype="float32",
        )
    model = build_model(cfg)
    n_params = sum(
        int(np.prod(l.shape))
        for l in jax.tree_util.tree_leaves(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    )
    print(f"arch={args.arch} scale={args.scale} params={n_params/1e6:.1f}M")

    opt = AdamWConfig(lr=3e-3, warmup_steps=20, decay_steps=max(args.steps, 100))
    step_fn = jax.jit(make_train_step(model, opt))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         n_docs=4096, seed=0)

    mgr = CheckpointManager(args.ckpt_dir, keep=2, save_interval=args.save_every)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        state, start = mgr.restore_latest(state)
        print(f"resumed from checkpoint @ step {start}")

    t0 = time.time()
    for i, b in enumerate(pipe.batches(args.batch, args.steps, n_micro=args.accum)):
        if i < start:
            continue
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        mgr.maybe_save(state, i + 1)
        if (i + 1) % 10 == 0 or i == start:
            dt = (time.time() - t0) / max(i + 1 - start, 1)
            print(f"step {i+1:4d}  loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} lr={float(m['lr']):.2e} "
                  f"({dt:.2f}s/step)")
    print("done.")


if __name__ == "__main__":
    main()
