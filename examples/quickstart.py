"""Quickstart: the paper's end-to-end pipeline on synthetic tabular data.

    python examples/quickstart.py [--trees 32] [--depth 7]

Trains PRF (dimension reduction + DSI bootstrap + weighted voting) and
the paper's two comparison baselines, and prints a Fig. 8-style summary.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trees", type=int, default=32)
    ap.add_argument("--depth", type=int, default=7)
    ap.add_argument("--samples", type=int, default=6000)
    ap.add_argument("--features", type=int, default=400)
    args = ap.parse_args()

    from repro.core import ForestConfig, train_prf
    from repro.core.baselines import train_mlrf_like, train_rf
    from repro.data.tabular import make_classification, train_test_split

    print(f"dataset: N={args.samples} M={args.features} (high-dim, noisy)")
    x, y = make_classification(
        n_samples=args.samples, n_features=args.features, n_classes=3,
        n_informative=8, n_redundant=4, label_noise=0.1, class_sep=1.2, seed=7,
    )
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)

    cfg = ForestConfig(
        n_trees=args.trees, max_depth=args.depth, n_bins=32, n_classes=3
    )
    for name, fn in [
        ("PRF  (paper: dimred + weighted vote)", train_prf),
        ("RF   (random subspaces, plain vote)", train_rf),
        ("MLRF (sampled split candidates)",
         lambda a, b, c, seed: train_mlrf_like(a, b, c, seed, sample_budget=300)),
    ]:
        t0 = time.time()
        model = fn(xtr, ytr, cfg, seed=0)
        acc = model.accuracy(xte, yte)
        print(f"{name:42s} acc={acc:.4f}  ({time.time()-t0:.1f}s)")

    model = train_prf(xtr, ytr, cfg, seed=0)
    w = np.asarray(model.forest.tree_weight)
    print(f"\nOOB tree weights (Eq. 8): mean={w.mean():.3f} min={w.min():.3f} "
          f"max={w.max():.3f}")


if __name__ == "__main__":
    main()
