"""PRF end-to-end behaviour: growth, prediction, voting, dimred, baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig, train_prf
from repro.core.baselines import data_volume_bytes, train_mlrf_like, train_rf
from repro.data.tabular import make_classification, make_regression, train_test_split


def test_prf_beats_majority_baseline(class_data):
    xtr, ytr, xte, yte = class_data
    cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=32, n_classes=4)
    model = train_prf(xtr, ytr, cfg, seed=0)
    acc = model.accuracy(xte, yte)
    maj = np.bincount(yte).max() / len(yte)
    assert acc > maj + 0.25, (acc, maj)
    assert acc > 0.75


def test_tree_chunking_is_exact(class_data):
    xtr, ytr, xte, yte = class_data
    cfg = ForestConfig(n_trees=8, max_depth=5, n_bins=16, n_classes=4)
    m1 = train_prf(xtr, ytr, cfg, seed=3)
    m2 = train_prf(xtr, ytr, dataclasses.replace(cfg, tree_chunk=2), seed=3)
    np.testing.assert_array_equal(
        np.asarray(m1.forest.feature), np.asarray(m2.forest.feature)
    )
    np.testing.assert_array_equal(
        np.asarray(m1.forest.threshold), np.asarray(m2.forest.threshold)
    )


def test_beam_frontier_bounds_nodes(class_data):
    xtr, ytr, xte, yte = class_data
    cfg = ForestConfig(
        n_trees=4, max_depth=10, n_bins=16, n_classes=4, max_frontier=8
    )
    m = train_prf(xtr, ytr, cfg, seed=0)
    assert m.forest.feature.shape[1] == cfg.max_nodes + 1
    assert m.accuracy(xte, yte) > 0.6


def test_oob_weights_in_unit_interval(class_data):
    xtr, ytr, _, _ = class_data
    cfg = ForestConfig(n_trees=8, max_depth=5, n_bins=16, n_classes=4)
    m = train_prf(xtr, ytr, cfg, seed=1)
    w = np.asarray(m.forest.tree_weight)
    assert ((w >= 0) & (w <= 1)).all()
    assert w.std() > 0  # trees genuinely differ


def test_weighted_voting_improves_on_noisy_data():
    x, y = make_classification(
        n_samples=4000, n_features=120, n_classes=3, n_informative=8,
        label_noise=0.2, seed=11,
    )
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    base = ForestConfig(n_trees=24, max_depth=6, n_bins=16, n_classes=3)
    accs_w, accs_p = [], []
    for s in range(3):
        accs_w.append(train_prf(xtr, ytr, base, seed=s).accuracy(xte, yte))
        accs_p.append(
            train_prf(
                xtr, ytr, dataclasses.replace(base, weighted_voting=False), seed=s
            ).accuracy(xte, yte)
        )
    assert np.mean(accs_w) >= np.mean(accs_p) - 0.01   # weighting never hurts


def test_prf_beats_rf_in_high_dim_regime():
    """The paper's headline claim (Figs. 8-9): importance-guided dimension
    reduction beats random-subspace RF on high-dimensional noisy data."""
    x, y = make_classification(
        n_samples=3000, n_features=800, n_classes=3, n_informative=8,
        n_redundant=4, label_noise=0.1, class_sep=1.2, seed=7,
    )
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=16, n_classes=3)
    acc_prf = train_prf(xtr, ytr, cfg, seed=0).accuracy(xte, yte)
    acc_rf = train_rf(xtr, ytr, cfg, seed=0).accuracy(xte, yte)
    assert acc_prf > acc_rf + 0.1, (acc_prf, acc_rf)


def test_mlrf_sampling_degrades_with_small_budget(class_data):
    xtr, ytr, xte, yte = class_data
    cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=32, n_classes=4)
    acc_big = train_mlrf_like(xtr, ytr, cfg, seed=0, sample_budget=2000).accuracy(xte, yte)
    acc_tiny = train_mlrf_like(xtr, ytr, cfg, seed=0, sample_budget=40).accuracy(xte, yte)
    assert acc_big >= acc_tiny - 0.02


def test_regression_r2():
    x, y = make_regression(3000, 32, seed=5)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(
        n_trees=16, max_depth=6, n_bins=32, regression=True, feature_mode="all"
    )
    m = train_prf(xtr, ytr, cfg, seed=0)
    pred = m.predict(xte)
    r2 = 1 - np.mean((pred - yte) ** 2) / np.var(yte)
    assert r2 > 0.6


def test_data_volume_model_flat_in_k():
    """Fig. 14: PRF volume ~flat in ensemble scale, RF linear."""
    N, M = 100_000, 1000
    v_rf_10 = data_volume_bytes("rf", N, M, 10)
    v_rf_100 = data_volume_bytes("rf", N, M, 100)
    assert v_rf_100 == 10 * v_rf_10                      # linear in k
    v_paper_10 = data_volume_bytes("prf-paper", N, M, 10)
    v_paper_100 = data_volume_bytes("prf-paper", N, M, 100)
    assert v_paper_100 == v_paper_10                     # exactly flat (2NM)
    v_prf_10 = data_volume_bytes("prf-tpu", N, M, 10)
    v_prf_100 = data_volume_bytes("prf-tpu", N, M, 100)
    assert v_prf_100 < 2 * v_prf_10                      # k*N counts only
    assert v_prf_100 < v_rf_100 / 100                    # orders smaller than RF
