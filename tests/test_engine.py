"""Unified growth-engine parity matrix (core/engine.py).

The acceptance bar for the task-DAG engine as the one growth
implementation: {local, mesh} x {early-exit, fixed-depth} x
{streamed, resident} all produce bit-identical ``Forest`` arrays on the
small fixtures (DSI counts are integer-valued, so every histogram
accumulation order is exact f32 integer arithmetic), the ``tree_chunk``
remainder padding is exact, and ``GrowthState`` round-trips ``jax.jit``
as a pytree. The mesh cases run in a subprocess so the multi-device XLA
flag never leaks into other tests.
"""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig, GrowthState, grow_forest_streamed
from repro.core.binning import bin_dataset
from repro.core.dsi import bootstrap_counts
from repro.core.engine import init_growth_state, LocalPlane
from repro.core.forest import chunked_level_scores, grow_forest
from repro.data.tabular import make_classification, make_regression

FOREST_ARRAYS = ("feature", "threshold", "left_child", "class_counts", "value")


def _assert_forests_equal(a, b, msg=""):
    for n in FOREST_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, n)), np.asarray(getattr(b, n)),
            err_msg=f"{n} {msg}",
        )


@pytest.fixture(scope="module")
def grow_case():
    x, y = make_classification(n_samples=600, n_features=13, n_classes=3, seed=3)
    cfg = ForestConfig(
        n_trees=6, max_depth=4, n_bins=16, n_classes=3, feature_mode="all"
    )
    xb, _ = bin_dataset(x, cfg.n_bins)
    w = np.asarray(
        bootstrap_counts(jax.random.PRNGKey(0), cfg.n_trees, xb.shape[0])
    ).astype(np.float32)
    return xb, y, w, cfg


def _grow(xb, y, w, cfg):
    return grow_forest(jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w), cfg)


# ---------------------------------------------------------------------------
# Early-exit scheduling
# ---------------------------------------------------------------------------


def test_early_exit_matches_fixed_depth(grow_case):
    xb, y, w, cfg = grow_case
    f_ee = _grow(xb, y, w, dataclasses.replace(cfg, early_exit=True))
    f_fx = _grow(xb, y, w, dataclasses.replace(cfg, early_exit=False))
    _assert_forests_equal(f_ee, f_fx, "early_exit")


def test_early_exit_matches_on_depth_starved_forest(grow_case):
    """Deep budget, tiny data: every frontier dies well before max_depth,
    so the while_loop actually exits early — and still matches."""
    xb, y, w, cfg = grow_case
    deep = dataclasses.replace(cfg, max_depth=12, min_samples_split=64)
    f_ee = _grow(xb, y, w, dataclasses.replace(deep, early_exit=True))
    f_fx = _grow(xb, y, w, dataclasses.replace(deep, early_exit=False))
    _assert_forests_equal(f_ee, f_fx, "early_exit deep")


# ---------------------------------------------------------------------------
# Sample-block streaming
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefetch", [0, 2])
def test_streamed_blocks_match_resident(grow_case, prefetch):
    """>= 4 host-fed blocks -> the exact resident forest; no device call
    ever sees the full [N, F] matrix (the block list IS the feed API).
    prefetch=0 is the synchronous feed, prefetch=2 the async
    double-buffered BlockFeeder — both run the fused route+hist pass
    and must be bit-identical to the resident engine."""
    xb, y, w, cfg = grow_case
    blocks = np.array_split(xb, 5)
    assert len(blocks) >= 4 and max(b.shape[0] for b in blocks) < xb.shape[0]
    f_st = grow_forest_streamed(blocks, y, w, cfg, prefetch=prefetch)
    _assert_forests_equal(
        f_st, _grow(xb, y, w, cfg), f"streamed blocks prefetch={prefetch}"
    )


def test_streamed_rejects_empty_block_sequence(grow_case):
    """An empty block list must raise a clear ValueError, not IndexError
    on blocks[0]."""
    xb, y, w, cfg = grow_case
    with pytest.raises(ValueError, match="empty block sequence"):
        grow_forest_streamed([], y, w, cfg)
    with pytest.raises(ValueError, match="empty block sequence"):
        grow_forest_streamed(
            xb[:0], y[:0], w[:, :0],
            dataclasses.replace(cfg, sample_block=64),
        )


def test_streamed_array_source_uses_sample_block(grow_case):
    """Array/memmap source: config.sample_block slices the host views."""
    xb, y, w, cfg = grow_case
    cfg_sb = dataclasses.replace(cfg, sample_block=150)   # 600 -> 4 blocks
    f_st = grow_forest_streamed(xb, y, w, cfg_sb)
    _assert_forests_equal(f_st, _grow(xb, y, w, cfg), "streamed array")


def test_streamed_rejects_mismatched_blocks(grow_case):
    xb, y, w, cfg = grow_case
    with pytest.raises(ValueError):
        grow_forest_streamed([xb[:100]], y, w, cfg)


def test_streamed_rejects_array_without_sample_block(grow_case):
    """An array source with sample_block=0 would silently feed the whole
    [N, F] matrix as one device block — exactly what the out-of-core
    path exists to avoid, so it must refuse."""
    xb, y, w, cfg = grow_case
    assert cfg.sample_block == 0
    with pytest.raises(ValueError, match="sample_block"):
        grow_forest_streamed(xb, y, w, cfg)


def test_resident_sample_block_knob_is_exact(grow_case):
    """Device-side blocked histogram accumulation (non-divisible final
    block included) == the one-pass histogram, bitwise."""
    xb, y, w, cfg = grow_case
    for nb in (150, 256):     # divides N / leaves a remainder
        f_sb = _grow(xb, y, w, dataclasses.replace(cfg, sample_block=nb))
        _assert_forests_equal(f_sb, _grow(xb, y, w, cfg), f"sample_block={nb}")


def test_streamed_regression_close():
    """Regression channels are float sums — blocked accumulation agrees
    to rounding, not bitwise; predictions must still agree closely."""
    x, y = make_regression(500, 11, seed=4)
    cfg = ForestConfig(
        n_trees=5, max_depth=4, n_bins=16, regression=True, feature_mode="all"
    )
    xb, _ = bin_dataset(x, cfg.n_bins)
    w = np.asarray(
        bootstrap_counts(jax.random.PRNGKey(2), cfg.n_trees, xb.shape[0])
    ).astype(np.float32)
    yf = y.astype(np.float32)
    f_st = grow_forest_streamed(np.array_split(xb, 4), yf, w, cfg)
    f_rs = _grow(xb, yf, w, cfg)
    np.testing.assert_array_equal(
        np.asarray(f_st.feature), np.asarray(f_rs.feature)
    )
    np.testing.assert_allclose(
        np.asarray(f_st.value), np.asarray(f_rs.value), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Streamed OOB + prediction (the sample-block carriers)
# ---------------------------------------------------------------------------


def test_streamed_oob_and_predict_match_resident(grow_case):
    """Blocked OOB accuracy and prediction == resident, bitwise (OOB
    correct/total counts are exact f32 integer sums; labels are
    per-sample)."""
    from repro.core.voting import (
        oob_accuracy, oob_accuracy_streamed, predict, predict_scores,
        predict_scores_streamed, predict_streamed,
    )

    xb, y, w, cfg = grow_case
    forest = _grow(xb, y, w, cfg)
    blocks = np.array_split(xb, 5)
    xb_dev, y_dev, w_dev = jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w)

    np.testing.assert_array_equal(
        np.asarray(oob_accuracy_streamed(forest, blocks, y, w)),
        np.asarray(oob_accuracy(forest, xb_dev, y_dev, w_dev)),
    )
    # Array source + sample_block slicing, prefetch on and off.
    for prefetch in (0, 2):
        np.testing.assert_array_equal(
            np.asarray(oob_accuracy_streamed(
                forest, xb, y, w, sample_block=130, prefetch=prefetch,
            )),
            np.asarray(oob_accuracy(forest, xb_dev, y_dev, w_dev)),
        )
    np.testing.assert_array_equal(
        np.asarray(predict_streamed(forest, blocks)),
        np.asarray(predict(forest, xb_dev)),
    )
    np.testing.assert_array_equal(
        np.asarray(predict_scores_streamed(forest, xb, sample_block=200)),
        np.asarray(predict_scores(forest, xb_dev)),
    )
    with pytest.raises(ValueError, match="sample_block"):
        oob_accuracy_streamed(forest, xb, y, w)   # array source needs blocks


def test_streamed_oob_r2_bitwise():
    """Blocked OOB R^2 == resident, BITWISE: both paths compute the
    per-sample moment terms with one shared jitted kernel and reduce
    them in host float64 (the streamed side Neumaier-compensated per
    block), so the single f32 rounding at the end agrees exactly —
    across different block splits too."""
    from repro.core.voting import oob_r2, oob_r2_streamed

    x, y = make_regression(500, 11, seed=4)
    cfg = ForestConfig(
        n_trees=5, max_depth=4, n_bins=16, regression=True, feature_mode="all"
    )
    xb, _ = bin_dataset(x, cfg.n_bins)
    w = np.asarray(
        bootstrap_counts(jax.random.PRNGKey(2), cfg.n_trees, xb.shape[0])
    ).astype(np.float32)
    yf = y.astype(np.float32)
    forest = _grow(xb, yf, w, cfg)
    r_res = np.asarray(oob_r2(forest, jnp.asarray(xb), jnp.asarray(yf), jnp.asarray(w)))
    assert np.any(r_res > 0), "fixture should have informative trees"
    for n_blocks in (4, 7):
        r_st = np.asarray(
            oob_r2_streamed(forest, np.array_split(xb, n_blocks), yf, w)
        )
        np.testing.assert_array_equal(r_st, r_res, err_msg=f"{n_blocks} blocks")


def test_train_prf_sample_block_dispatches_streamed(grow_case):
    """The public entry point: config.sample_block > 0 routes the WHOLE
    pipeline (binning, dimred, growth, OOB weights, prediction) through
    the streaming data plane, bit-identical to the resident train_prf."""
    from repro.core import train_prf

    xb, y, w, cfg = grow_case
    x, _ = make_classification(n_samples=600, n_features=13, n_classes=3, seed=3)
    cfg_imp = dataclasses.replace(cfg, feature_mode="importance")
    m_res = train_prf(x, y, cfg_imp, seed=11)
    m_st = train_prf(
        x, y, dataclasses.replace(cfg_imp, sample_block=140), seed=11
    )
    _assert_forests_equal(m_st.forest, m_res.forest, "train_prf streamed")
    np.testing.assert_array_equal(
        np.asarray(m_st.forest.tree_weight), np.asarray(m_res.forest.tree_weight)
    )
    np.testing.assert_array_equal(m_st.predict(x), m_res.predict(x))
    np.testing.assert_array_equal(m_st.predict_scores(x), m_res.predict_scores(x))


# ---------------------------------------------------------------------------
# tree_chunk remainder padding
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tree_chunk", [4, 5])
def test_tree_chunk_remainder_is_exact(grow_case, tree_chunk):
    """n_trees=6 with tree_chunk=4/5: the final chunk is padded with
    zero-weight dummy trees instead of raising, and the forest is
    bit-identical to the unchunked run."""
    xb, y, w, cfg = grow_case
    f_c = _grow(xb, y, w, dataclasses.replace(cfg, tree_chunk=tree_chunk))
    _assert_forests_equal(f_c, _grow(xb, y, w, cfg), f"tree_chunk={tree_chunk}")


def test_chunked_level_scores_accepts_remainder(grow_case):
    """Direct call at the training/prediction-shared chunk size."""
    xb, y, w, cfg = grow_case
    cfg = dataclasses.replace(cfg, n_trees=7, tree_chunk=3)
    from repro.core.histograms import class_channels

    base = class_channels(jnp.asarray(y), cfg.n_classes)
    w7 = jnp.asarray(np.tile(w, (2, 1))[:7])
    slot = jnp.zeros((7, xb.shape[0]), jnp.int32)
    scores, n_node = chunked_level_scores(
        jnp.asarray(xb), base, w7, slot, None, cfg
    )
    cfg_full = dataclasses.replace(cfg, tree_chunk=0)
    scores_full, n_node_full = chunked_level_scores(
        jnp.asarray(xb), base, w7, slot, None, cfg_full
    )
    np.testing.assert_array_equal(np.asarray(n_node), np.asarray(n_node_full))
    # Winners and their (integer-valued) child counts are exact; the gain
    # ratio itself may move by 1 ulp — the lax.map chunk body is compiled
    # (FMA-contracted) while the single-chunk path runs op-by-op.
    for name in ("feature", "threshold", "left_counts", "right_counts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(scores, name)),
            np.asarray(getattr(scores_full, name)), err_msg=name,
        )
    np.testing.assert_allclose(
        np.asarray(scores.gain_ratio), np.asarray(scores_full.gain_ratio),
        rtol=1e-6, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# GrowthState — the engine's real carry
# ---------------------------------------------------------------------------


def test_growth_state_pytree_roundtrips_jit(grow_case):
    xb, y, w, cfg = grow_case
    from repro.core.histograms import class_channels

    base = class_channels(jnp.asarray(y), cfg.n_classes)
    state = init_growth_state(
        base, jnp.asarray(w), cfg, LocalPlane(), rng=jax.random.PRNGKey(7)
    )
    assert isinstance(state, GrowthState)
    out = jax.jit(lambda s: s)(state)
    assert isinstance(out, GrowthState)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(state)
    for a, b in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert out.forest.config == cfg          # static aux survives the boundary
    assert int(out.level) == 0
    assert int(out.slot_node[0, 0]) == 0 and int(out.slot_node[0, 1]) == -1


# ---------------------------------------------------------------------------
# Mesh plane (subprocess: 8 host devices)
# ---------------------------------------------------------------------------


def test_mesh_plane_matches_local_bitwise():
    """The full plane matrix: {psum, psum_scatter} x {early-exit,
    fixed-depth} sharded growth == single-host growth, bit-for-bit,
    given identical DSI weights — plus the mesh-STREAMED driver
    (host blocks fed into the collective plane), streamed-sharded OOB,
    and streamed-sharded prediction, all bitwise against the local
    resident references."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import ForestConfig
        from repro.core.binning import bin_dataset
        from repro.core.distributed import (
            _grow_sharded, _shard_map, grow_forest_streamed_sharded,
            oob_accuracy_streamed_sharded, predict_streamed_sharded,
        )
        from repro.core.dsi import bootstrap_counts
        from repro.core.forest import grow_forest
        from repro.core.histograms import class_channels
        from repro.core.voting import oob_accuracy, predict
        from repro.data.tabular import make_classification
        from repro.launch.mesh import make_mesh

        x, y = make_classification(n_samples=640, n_features=16, n_classes=3, seed=2)
        cfg0 = ForestConfig(n_trees=6, max_depth=4, n_bins=16, n_classes=3,
                            feature_mode="all")
        xb, _ = bin_dataset(x, cfg0.n_bins)
        y_np, w_np = np.asarray(y), None
        xb_dev, y_dev = jnp.asarray(xb), jnp.asarray(y)
        w = bootstrap_counts(jax.random.PRNGKey(1), cfg0.n_trees,
                             xb.shape[0]).astype(jnp.float32)
        w_np = np.asarray(w)
        mesh = make_mesh((4, 2), ("data", "model"))
        ARRS = ("feature", "threshold", "left_child", "class_counts", "value")
        # Ragged block sizes: exercises the parked-sample padding to the
        # data-axis multiple inside the mesh-streamed driver.
        blocks = [xb[:150], xb[150:290], xb[290:500], xb[500:]]

        for hist_reduce in ("psum", "psum_scatter"):
            for early in (True, False):
                cfg = dataclasses.replace(cfg0, hist_reduce=hist_reduce,
                                          early_exit=early)
                def kernel(xb_loc, y_loc, w_loc, _cfg=cfg):
                    base_loc = class_channels(y_loc, _cfg.n_classes)
                    return _grow_sharded(xb_loc, base_loc, w_loc, None, _cfg,
                                         sample_axes=("data",),
                                         feature_axis="model")
                f_mesh = jax.jit(_shard_map(
                    kernel, mesh=mesh,
                    in_specs=(P("data", "model"), P("data"), P(None, "data")),
                    out_specs=P(),
                ))(xb_dev, y_dev, w)
                f_loc = grow_forest(xb_dev, y_dev, w, cfg)
                for n in ARRS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(f_mesh, n)),
                        np.asarray(getattr(f_loc, n)),
                        err_msg=f"{n} {hist_reduce} early={early}")
            # Mesh x streaming: host blocks fed into the same collective
            # plane == the local resident forest, bit-for-bit.
            f_ms = grow_forest_streamed_sharded(
                blocks, y_np, w_np, dataclasses.replace(cfg0,
                hist_reduce=hist_reduce), mesh)
            f_loc = grow_forest(xb_dev, y_dev, w, cfg0)
            for n in ARRS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(f_ms, n)), np.asarray(getattr(f_loc, n)),
                    err_msg=f"{n} streamed {hist_reduce}")
        print("MESH_STREAM_GROW_OK")

        f_loc = grow_forest(xb_dev, y_dev, w, cfg0)
        np.testing.assert_array_equal(
            np.asarray(oob_accuracy_streamed_sharded(f_loc, blocks, y_np,
                                                     w_np, mesh)),
            np.asarray(oob_accuracy(f_loc, xb_dev, y_dev, w)))
        np.testing.assert_array_equal(
            predict_streamed_sharded(f_loc, blocks, mesh),
            np.asarray(predict(f_loc, xb_dev)))
        print("MESH_PARITY_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH_PARITY_OK" in out.stdout


# ---------------------------------------------------------------------------
# Property: early-exit never changes predictions
# ---------------------------------------------------------------------------


def test_early_exit_never_changes_predictions_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        seed=st.integers(0, 2 ** 10),
        depth=st.integers(2, 6),
        frontier=st.sampled_from([0, 4]),
        tree_chunk=st.sampled_from([0, 3]),
    )
    @settings(max_examples=10, deadline=None)
    def prop(seed, depth, frontier, tree_chunk):
        x, y = make_classification(
            n_samples=160, n_features=7, n_classes=3, seed=seed % 17
        )
        cfg = ForestConfig(
            n_trees=4, max_depth=depth, n_bins=8, n_classes=3,
            feature_mode="all", max_frontier=frontier, tree_chunk=tree_chunk,
            min_samples_split=8,
        )
        xb, _ = bin_dataset(x, cfg.n_bins)
        w = np.asarray(
            bootstrap_counts(jax.random.PRNGKey(seed), cfg.n_trees, xb.shape[0])
        ).astype(np.float32)
        f_ee = _grow(xb, y, w, dataclasses.replace(cfg, early_exit=True))
        f_fx = _grow(xb, y, w, dataclasses.replace(cfg, early_exit=False))
        from repro.core.voting import predict

        np.testing.assert_array_equal(
            np.asarray(predict(f_ee, jnp.asarray(xb))),
            np.asarray(predict(f_fx, jnp.asarray(xb))),
        )

    prop()
