"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see ONE
device; only tests that need a mesh spawn host devices via a subprocess
or the dedicated mesh fixtures below (which use the real single device
count and skip if unavailable)."""
import dataclasses

import numpy as np
import pytest


@pytest.fixture(scope="session")
def class_data():
    from repro.data.tabular import make_classification, train_test_split

    x, y = make_classification(
        n_samples=3000, n_features=48, n_classes=4, n_informative=10,
        label_noise=0.05, seed=7,
    )
    return train_test_split(x, y, 0.25, 0)


def reduce_cfg(cfg, **over):
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4) if not cfg.pattern else 2 * len(cfg.pattern),
        d_model=128, n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256 if cfg.d_ff else 0, vocab_size=512, head_dim=32,
        encoder_layers=2 if cfg.encoder_layers else 0, encoder_frames=16,
        vision_tokens=8 if cfg.vision_tokens else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        meta_tokens=4 if cfg.meta_tokens else 0,
        local_window=8 if cfg.local_window else 0,
        n_experts=8 if cfg.n_experts else 0,
        experts_per_token=2 if cfg.n_experts else 0,
        moe_d_ff=64 if cfg.n_experts else 0,
        n_dense_layers=1 if cfg.n_dense_layers else 0,
        dense_d_ff=256 if cfg.dense_d_ff else 0,
        q_lora_rank=32 if cfg.use_mla else 0,
        kv_lora_rank=16 if cfg.use_mla else 0,
        qk_rope_dim=16 if cfg.use_mla else 0,
        qk_nope_dim=16 if cfg.use_mla else 0,
        v_head_dim=32 if cfg.use_mla else 0,
        ssm_state=16 if cfg.ssm_state else 0, ssm_head_dim=32,
        compute_dtype="float32", remat="none", ep_mode="gspmd",
        capacity_factor=8.0,
    )
    kw.update(over)
    return dataclasses.replace(cfg, **kw)
