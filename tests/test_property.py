"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dsi import bootstrap_counts, dsi_counts, make_dsi
from repro.core.gain import (
    entropy_from_counts, multiway_gain_ratio, split_gain_ratios,
    variable_importance,
)
from repro.kernels.gain_ratio.ref import histogram_ref

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    counts=st.lists(st.floats(0.0, 1e4), min_size=2, max_size=8),
)
@settings(**SETTINGS)
def test_entropy_nonnegative_and_bounded(counts):
    c = jnp.asarray(counts, jnp.float32)
    if float(c.sum()) <= 0:
        return
    h = float(entropy_from_counts(c))
    assert -1e-5 <= h <= np.log(len(counts)) + 1e-4


@given(
    n=st.integers(2, 64), k=st.integers(1, 8),
    seed=st.integers(0, 2 ** 16),
)
@settings(**SETTINGS)
def test_dsi_counts_conserve_draws(n, k, seed):
    counts = bootstrap_counts(jax.random.PRNGKey(seed), k, n)
    s = np.asarray(counts).sum(axis=1)
    np.testing.assert_allclose(s, n)
    assert (np.asarray(counts) >= 0).all()


@given(
    seed=st.integers(0, 2 ** 16),
    b=st.integers(2, 8), c=st.integers(2, 4), f=st.integers(1, 4),
)
@settings(**SETTINGS)
def test_gain_ratio_invariant_to_count_scaling(seed, b, c, f):
    """GR is a function of distributions — scaling all counts is a no-op."""
    rng = np.random.default_rng(seed)
    hist = jnp.asarray(rng.random((f, b, c)).astype(np.float32)) + 0.01
    g1 = split_gain_ratios(hist)
    g2 = split_gain_ratios(hist * 7.5)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-3, atol=2e-5)


@given(
    seed=st.integers(0, 2 ** 16), f=st.integers(2, 6),
)
@settings(**SETTINGS)
def test_variable_importance_is_distribution(seed, f):
    rng = np.random.default_rng(seed)
    gr = jnp.asarray(rng.random((3, f)).astype(np.float32))
    vi = variable_importance(gr)
    v = np.asarray(vi)
    assert (v >= -1e-6).all()
    np.testing.assert_allclose(v.sum(-1), 1.0, rtol=1e-4)


@given(
    seed=st.integers(0, 2 ** 16),
    n=st.sampled_from([32, 64]), fdim=st.sampled_from([4, 8]),
    s=st.integers(1, 3), b=st.sampled_from([4, 8]), c=st.integers(2, 4),
)
@settings(**SETTINGS)
def test_histogram_mass_conservation(seed, n, fdim, s, b, c):
    """Total histogram mass == total (unparked) weight, for every feature."""
    rng = np.random.default_rng(seed)
    xb = rng.integers(0, b, (n, fdim)).astype(np.int32)
    w = rng.random(n).astype(np.float32)
    y = rng.integers(0, c, n)
    wch = w[:, None] * np.eye(c, dtype=np.float32)[y]
    slot = rng.integers(-1, s, n).astype(np.int32)
    hist = histogram_ref(
        jnp.asarray(xb), jnp.asarray(wch), jnp.asarray(slot), n_slots=s, n_bins=b
    )
    live = w[slot >= 0].sum()
    got = np.asarray(hist).sum(axis=(0, 2, 3))
    np.testing.assert_allclose(got, live, rtol=1e-4)


@given(seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_multiway_gr_nonnegative(seed):
    rng = np.random.default_rng(seed)
    hist = jnp.asarray(rng.random((3, 8, 3)).astype(np.float32))
    gr = np.asarray(multiway_gain_ratio(hist))
    assert (gr >= -1e-4).all()


@given(
    seed=st.integers(0, 2 ** 16),
    k=st.integers(1, 12), n=st.integers(1, 24), c=st.integers(2, 5),
    scale=st.floats(0.1, 10.0),
)
@settings(**SETTINGS)
def test_uniform_weighted_vote_equals_unweighted_majority(seed, k, n, c, scale):
    """Eq. (10) with uniform weights is plain majority voting. Where the
    majority is unique the winner matches exactly (rounding can't bridge
    a >= scale*1 score gap); where classes tie, XLA's order-dependent
    f32 sum may break the tie either way, so only membership in the
    tied set is asserted."""
    from repro.core.voting import weighted_vote

    rng = np.random.default_rng(seed)
    probs = jnp.asarray(rng.random((k, n, c)).astype(np.float32))
    scores = weighted_vote(probs, jnp.full((k,), scale, jnp.float32))
    pred = np.argmax(np.asarray(scores), axis=-1)

    votes = np.argmax(np.asarray(probs), axis=-1)            # [k, n]
    counts = np.zeros((n, c), np.int64)
    for t in range(k):
        counts[np.arange(n), votes[t]] += 1
    top = counts.max(axis=-1)
    unique = (counts == top[:, None]).sum(axis=-1) == 1
    majority = np.argmax(counts, axis=-1)
    np.testing.assert_array_equal(pred[unique], majority[unique])
    assert (counts[np.arange(n), pred] == top).all()         # ties: still a leader


@given(
    seed=st.integers(0, 2 ** 16),
    k=st.integers(1, 12), n=st.integers(1, 24),
)
@settings(**SETTINGS)
def test_faithful_eq9_matches_naive_sum(seed, k, n):
    """weighted_regression(faithful_eq9=True) is literally Eq. (9):
    (1/k) * sum_i w_i * h_i(x), computed naively in float64."""
    from repro.core.voting import weighted_regression

    rng = np.random.default_rng(seed)
    values = rng.standard_normal((k, n)).astype(np.float32)
    w = rng.random(k).astype(np.float32)
    got = np.asarray(
        weighted_regression(jnp.asarray(values), jnp.asarray(w), faithful_eq9=True)
    )
    naive = np.zeros(n, np.float64)
    for t in range(k):
        naive += np.float64(w[t]) * values[t].astype(np.float64)
    naive /= k
    np.testing.assert_allclose(got, naive, rtol=1e-5, atol=1e-6)
