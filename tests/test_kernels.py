"""Pallas kernel sweeps: shapes x dtypes vs pure-jnp oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import attention_pallas_call
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gain_ratio.kernel import hist_pallas_call
from repro.kernels.gain_ratio.ref import histogram_ref
from repro.kernels.ssd_scan.kernel import ssd_pallas_call
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n,f,s,b,c,n_blk,f_blk", [
    (256, 64, 2, 8, 2, 128, 32),
    (512, 128, 4, 16, 8, 256, 64),
    (512, 32, 1, 32, 4, 512, 32),
    (1024, 64, 8, 8, 3, 256, 64),
    (300, 40, 3, 8, 2, 128, 32),   # non-divisible N/F: padded inside the call
    (700, 24, 4, 16, 5, None, None),  # auto-chosen block sizes
])
def test_gain_ratio_histogram_sweep(n, f, s, b, c, n_blk, f_blk):
    xb = RNG.integers(0, b, (n, f)).astype(np.int32)
    w = RNG.random(n).astype(np.float32)
    y = RNG.integers(0, c, n)
    wch = w[:, None] * np.eye(c, dtype=np.float32)[y]
    slot = RNG.integers(-1, s, n).astype(np.int32)
    got = hist_pallas_call(
        jnp.asarray(xb), jnp.asarray(wch), jnp.asarray(slot),
        n_slots=s, n_bins=b, n_blk=n_blk, f_blk=f_blk, interpret=True,
    )
    want = histogram_ref(
        jnp.asarray(xb), jnp.asarray(wch), jnp.asarray(slot), n_slots=s, n_bins=b
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,lq,lk,d,causal,window,dtype", [
    (2, 4, 256, 256, 64, True, 0, np.float32),
    (1, 2, 128, 384, 64, True, 0, np.float32),
    (2, 2, 256, 256, 64, True, 128, np.float32),
    (1, 2, 256, 256, 32, False, 0, np.float32),
    (1, 2, 256, 256, 64, True, 0, np.dtype("bfloat16")),
])
def test_flash_attention_sweep(b, h, lq, lk, d, causal, window, dtype):
    q = RNG.standard_normal((b * h, lq, d)).astype(np.float32)
    k = RNG.standard_normal((b * h, lk, d)).astype(np.float32)
    v = RNG.standard_normal((b * h, lk, d)).astype(np.float32)
    qj = jnp.asarray(q).astype(dtype)
    kj = jnp.asarray(k).astype(dtype)
    vj = jnp.asarray(v).astype(dtype)
    got = attention_pallas_call(
        qj, kj, vj, causal=causal, window=window, bq=128, bkv=128, interpret=True
    )
    want = attention_ref(qj, kj, vj, causal=causal, window=window)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("bh,l,p,n,q", [
    (2, 256, 64, 16, 128),
    (3, 384, 32, 64, 128),
    (1, 128, 64, 32, 64),
    (2, 512, 32, 16, 128),
])
def test_ssd_scan_sweep(bh, l, p, n, q):
    x = RNG.standard_normal((bh, l, p)).astype(np.float32)
    loga = -np.abs(RNG.standard_normal((bh, l)).astype(np.float32)) * 0.5
    b = RNG.standard_normal((bh, l, n)).astype(np.float32) * 0.3
    c = RNG.standard_normal((bh, l, n)).astype(np.float32) * 0.3
    y1, h1 = ssd_pallas_call(*map(jnp.asarray, (x, loga, b, c)), q_blk=q, interpret=True)
    y2, h2 = ssd_ref(*map(jnp.asarray, (x, loga, b, c)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_chunked_path():
    """kernels/ssd_scan == models/mamba._ssd_chunked (same math)."""
    from repro.models.mamba import _ssd_chunked

    B, S, H, P, N = 1, 256, 2, 32, 16
    x = RNG.standard_normal((B, S, H, P)).astype(np.float32)
    loga = -np.abs(RNG.standard_normal((B, S, H)).astype(np.float32)) * 0.3
    b = RNG.standard_normal((B, S, N)).astype(np.float32) * 0.3
    c = RNG.standard_normal((B, S, N)).astype(np.float32) * 0.3
    h0 = np.zeros((B, H, N, P), np.float32)
    y_model, _ = _ssd_chunked(*map(jnp.asarray, (x, loga, b, c, h0)), chunk=128)
    # kernel path: flatten (B, H) -> BH
    xk = jnp.asarray(np.moveaxis(x, 2, 1).reshape(B * H, S, P))
    lk = jnp.asarray(np.moveaxis(loga, 2, 1).reshape(B * H, S))
    bk = jnp.asarray(np.repeat(b[:, None], H, 1).reshape(B * H, S, N))
    ck = jnp.asarray(np.repeat(c[:, None], H, 1).reshape(B * H, S, N))
    y_kern, _ = ssd_pallas_call(xk, lk, bk, ck, q_blk=128, interpret=True)
    y_kern = np.moveaxis(np.asarray(y_kern).reshape(B, H, S, P), 1, 2)
    np.testing.assert_allclose(np.asarray(y_model), y_kern, rtol=2e-4, atol=2e-4)
