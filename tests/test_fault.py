"""Fault-tolerance parity suite (ISSUE 6 tentpole).

The resilience contract: crashes at ANY level boundary, retried feed
failures, and killed feeder threads must never change the model.

* **Kill-and-resume, every boundary** — growth is killed after each
  completed level's checkpoint and resumed from disk; the resumed
  forest, tree weights, and predictions must be bit-identical to an
  uninterrupted run, on {local, mesh} x {resident, streamed} (the mesh
  half runs in a subprocess so the 8-device XLA flag never leaks).
* **Retrying block feeds** — a deterministic ``FaultInjector`` makes
  ``BlockFeeder`` device puts fail transiently; bounded retry +
  backoff must absorb every injected fault bit-invisibly (hypothesis
  property over rates/seeds), exhaustion must surface ``FeedError``
  with the feeder thread joined, and early close / context-manager
  exit must never leak the thread.
"""
import dataclasses
import subprocess
import sys
import textwrap
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import ForestConfig, train_prf
from repro.data.pipeline import BlockFeeder, FeedError
from repro.launch.fault import FaultInjector, SimulatedFailure
from repro.data.tabular import make_classification

FOREST_ARRAYS = (
    "feature", "threshold", "left_child", "class_counts", "value",
    "tree_weight",
)


def _assert_models_equal(a, b, msg=""):
    for n in FOREST_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.forest, n)), np.asarray(getattr(b.forest, n)),
            err_msg=f"{n} {msg}",
        )


class _Kill(Exception):
    """The simulated crash: raised from on_level AFTER the level's
    checkpoint is durable — a crash at the level boundary."""


@pytest.fixture(scope="module")
def fault_case():
    x, y = make_classification(n_samples=600, n_features=13, n_classes=3, seed=3)
    cfg = ForestConfig(
        n_trees=6, max_depth=4, n_bins=16, n_classes=3, feature_mode="all"
    )
    return x, y, cfg


@pytest.fixture(scope="module")
def baseline(fault_case):
    x, y, cfg = fault_case
    return train_prf(x, y, cfg, seed=0)


# ---------------------------------------------------------------------------
# Kill-and-resume parity: local planes, every level boundary
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("streamed", [False, True], ids=["resident", "streamed"])
def test_resume_after_crash_bit_identical_local(
    tmp_path, fault_case, baseline, streamed
):
    x, y, cfg = fault_case
    if streamed:
        cfg = dataclasses.replace(cfg, sample_block=170)
    for kill_at in range(1, cfg.max_depth):
        d = str(tmp_path / f"{'st' if streamed else 'rs'}{kill_at}")

        def boom(level, _):
            if level == kill_at:
                raise _Kill

        with pytest.raises(_Kill):
            train_prf(x, y, cfg, seed=0, checkpoint_dir=d, on_level=boom)

        resumed_levels = []
        m = train_prf(
            x, y, cfg, seed=0, checkpoint_dir=d, resume_from=d,
            on_level=lambda level, _: resumed_levels.append(level),
        )
        # The resumed run really starts AFTER the crash level — it must
        # not silently regrow from scratch.
        assert min(resumed_levels) == kill_at + 1, resumed_levels
        _assert_models_equal(m, baseline, f"kill@{kill_at} streamed={streamed}")
        np.testing.assert_array_equal(m.predict(x), baseline.predict(x))


def test_resume_from_empty_dir_is_fresh_start(tmp_path, fault_case, baseline):
    """The ElasticRunner convention: an empty resume directory means
    'no progress yet' — train from scratch, don't raise."""
    x, y, cfg = fault_case
    m = train_prf(x, y, cfg, seed=0, resume_from=str(tmp_path / "nothing"))
    _assert_models_equal(m, baseline, "empty resume dir")


def test_checkpoint_every_gates_saves(tmp_path, fault_case):
    """checkpoint_every=2 writes only even-level checkpoints; resume
    from the latest one still converges to the same model."""
    from repro.checkpoint.checkpoint import latest_step

    x, y, cfg = fault_case
    d = str(tmp_path / "every2")
    base = train_prf(x, y, cfg, seed=0, checkpoint_dir=d, checkpoint_every=2)
    assert latest_step(d) == 4
    m = train_prf(x, y, cfg, seed=0, resume_from=d)
    _assert_models_equal(m, base, "resume from every-2 checkpoint")


# ---------------------------------------------------------------------------
# Kill-and-resume parity: mesh planes (subprocess, 8 host devices)
# ---------------------------------------------------------------------------


def test_resume_after_crash_bit_identical_mesh():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from repro.core import ForestConfig
        from repro.core.binning import bin_dataset
        from repro.core.distributed import (
            grow_forest_streamed_sharded, grow_sharded_checkpointed,
        )
        from repro.core.dsi import bootstrap_counts
        from repro.core.forest import grow_forest
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.data.tabular import make_classification
        from repro.launch.mesh import make_mesh

        x, y = make_classification(n_samples=640, n_features=16, n_classes=3,
                                   seed=2)
        cfg = ForestConfig(n_trees=6, max_depth=4, n_bins=16, n_classes=3,
                           feature_mode="all").resolved(16)
        xb, _ = bin_dataset(x, cfg.n_bins)
        w = np.asarray(bootstrap_counts(jax.random.PRNGKey(1), cfg.n_trees,
                                        xb.shape[0])).astype(np.float32)
        y_np = np.asarray(y)
        mesh = make_mesh((4, 2), ("data", "model"))
        local = grow_forest(jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w), cfg)
        ARRS = ("feature", "threshold", "left_child", "class_counts", "value")

        class Kill(Exception):
            pass

        def drill(grow, tag):
            for kill_at in (1, 3):
                d = tempfile.mkdtemp()

                def boom(level, _):
                    if level == kill_at:
                        raise Kill

                try:
                    grow(manager=CheckpointManager(d, keep=3, save_interval=1),
                         resume_from=None, on_level=boom)
                    raise AssertionError("kill did not fire")
                except Kill:
                    pass
                resumed = []
                f = grow(manager=None, resume_from=d,
                         on_level=lambda level, _: resumed.append(level))
                assert min(resumed) == kill_at + 1, (tag, kill_at, resumed)
                for n in ARRS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(f, n)),
                        np.asarray(getattr(local, n)),
                        err_msg=f"{n} {tag} kill@{kill_at}")

        drill(lambda **kw: grow_sharded_checkpointed(
            xb, y_np, w, cfg, mesh, **kw), "mesh-resident")
        cfgs = ForestConfig(n_trees=6, max_depth=4, n_bins=16, n_classes=3,
                            feature_mode="all", sample_block=170).resolved(16)
        drill(lambda **kw: grow_forest_streamed_sharded(
            xb, y_np, w, cfgs, mesh, **kw), "mesh-streamed")
        print("MESH_RESUME_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH_RESUME_OK" in out.stdout


# ---------------------------------------------------------------------------
# Retrying block feeds
# ---------------------------------------------------------------------------


def test_fault_injector_is_deterministic_and_bounded():
    a = FaultInjector(0.5, seed=9, max_consecutive=2)
    b = FaultInjector(0.5, seed=9, max_consecutive=2)
    for _ in range(200):
        ra = rb = None
        try:
            a("s")
        except SimulatedFailure as e:
            ra = str(e)
        try:
            b("s")
        except SimulatedFailure as e:
            rb = str(e)
        assert ra == rb
    assert a.injected > 0
    # The streak cap: never more than max_consecutive faults in a row,
    # so a feeder with max_retries > max_consecutive ALWAYS progresses.
    c = FaultInjector(1.0, seed=0, max_consecutive=2)
    streak, worst = 0, 0
    for _ in range(100):
        try:
            c("s")
            streak = 0
        except SimulatedFailure:
            streak += 1
            worst = max(worst, streak)
    assert worst == 2


def test_feeder_retries_transient_faults(fault_case, baseline):
    """Injected feed failures under bounded retry never change the
    trained model — and the retries actually happened."""
    x, y, cfg = fault_case
    cfg = dataclasses.replace(cfg, sample_block=170)
    inj = FaultInjector(0.3, seed=7, max_consecutive=2)
    m = train_prf(
        x, y, cfg, seed=0,
        feeder_opts=dict(fault_hook=inj, max_retries=3, backoff=1e-4),
    )
    assert inj.injected > 0
    _assert_models_equal(m, baseline, "faulted feed")


def test_feeder_exhausted_retries_raise_feed_error_and_join_thread():
    blocks = [np.zeros((32, 4), np.uint8) for _ in range(3)]

    def always_fail(site):
        raise SimulatedFailure(f"permanent @ {site}")

    feeder = BlockFeeder(
        blocks, prefetch=2, fault_hook=always_fail, max_retries=2,
        backoff=1e-4,
    )
    with pytest.raises(FeedError, match="failed permanently after 2 retries"):
        list(feeder.sweep())
    feeder.close()
    assert not any(
        t.name == "prf-block-feeder" and t.is_alive()
        for t in threading.enumerate()
    ), "feeder thread leaked after FeedError"


def test_feeder_sweep_close_and_context_manager_join_thread():
    blocks = [np.zeros((32, 4), np.uint8) for _ in range(6)]
    feeder = BlockFeeder(blocks, prefetch=2)
    sweep = feeder.sweep()
    next(sweep)
    sweep.close()                       # abandon mid-sweep
    with BlockFeeder(blocks, prefetch=2) as f2:
        assert sum(1 for _ in f2.sweep()) == len(blocks)
    assert not any(
        t.name == "prf-block-feeder" and t.is_alive()
        for t in threading.enumerate()
    ), "feeder thread leaked after close"


def test_sweep_close_escalates_stuck_thread_to_feed_error():
    """A producer thread that outlives ``join(join_timeout)`` is a
    wedged device transfer — ``close()`` must escalate to ``FeedError``
    naming the stuck feed site, never silently leak a live thread."""
    blocks = [np.zeros((8, 2), np.uint8) for _ in range(3)]
    feeder = BlockFeeder(blocks, prefetch=1, join_timeout=0.05)
    sweep = feeder.sweep()
    next(sweep)
    # Swap in a producer that ignores cancellation (a hung device_put).
    stuck = threading.Thread(
        target=lambda: time.sleep(0.5), daemon=True, name="prf-block-feeder"
    )
    stuck.start()
    sweep._thread = stuck
    feeder._last_site = "block[1]"
    with pytest.raises(FeedError, match=r"wedged at site 'block\[1\]'"):
        sweep.close()
    # The sweep deregistered itself before raising: feeder.close() is
    # still safe, and once the transfer unwedges the thread is gone.
    feeder.close()
    stuck.join()
    assert not stuck.is_alive()


def test_feeder_retry_knobs_validated():
    blocks = [np.zeros((8, 2), np.uint8)]
    with pytest.raises(ValueError):
        BlockFeeder(blocks, max_retries=-1)
    with pytest.raises(ValueError):
        FaultInjector(1.5)
    with pytest.raises(ValueError):
        FaultInjector(0.5, max_consecutive=0)


def test_injected_feed_failures_never_change_model_property(
    fault_case, baseline
):
    """Property: for ANY fault rate/seed, growth through a
    faulty-but-retried feed is bit-identical to the clean run.

    Runs under hypothesis when it is installed; otherwise (the CI chaos
    job is gated skip-free) a deterministic seeded sweep over the same
    (rate, seed) space checks the property directly."""
    x, y, cfg = fault_case
    cfg = dataclasses.replace(cfg, sample_block=200)

    def prop(rate, seed):
        inj = FaultInjector(rate, seed=seed, max_consecutive=2)
        m = train_prf(
            x, y, cfg, seed=0,
            feeder_opts=dict(fault_hook=inj, max_retries=3, backoff=1e-4),
        )
        _assert_models_equal(m, baseline, f"rate={rate} seed={seed}")

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for rate, seed in [(0.05, 1), (0.2, 17), (0.4, 4242), (0.6, 65535)]:
            prop(rate, seed)
        return

    settings(max_examples=5, deadline=None)(
        given(rate=st.floats(0.05, 0.6), seed=st.integers(0, 2 ** 16))(prop)
    )()
