"""T_NS split-backend parity: pallas split-scan (interpret) vs XLA oracle.

The acceptance bar for the fused split-scoring kernel as the production
backend (mirrors test_hist_backends.py for T_GR):

* identical winners (feature/threshold ints) and matching gains/counts
  on the full matrix — classification/regression x feature-masked x
  all-invalid-gain slots x non-divisible F x multi-block carry;
* ``grow_forest`` builds *bit-identical* forests whichever backend
  scores the splits (integer DSI weights make histograms and their
  prefix sums exact, so only argmax order matters — and both backends
  implement first-occurrence semantics);
* the fully-fused single-host path never materializes the
  ``[tc, S, F, B, C]`` histogram in HBM (jaxpr inspection).

Float gains agree to rounding only (XLA fuses the two compiled contexts
differently), hence exact asserts on ints, allclose on floats.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.core.binning import bin_dataset
from repro.core.dsi import bootstrap_counts
from repro.core.forest import chunked_level_scores, grow_forest
from repro.core.gain import level_scores, resolve_split_backend
from repro.core.dimred import random_feature_mask
from repro.data.tabular import make_classification
from repro.kernels.split_scan.kernel import (
    choose_score_block, split_scan_block, split_scan_scores,
)
from repro.kernels.split_scan.ref import split_scan_ref

RNG = np.random.default_rng(11)


def _assert_scores_match(got, want, *, counts_exact=False):
    """Ints exact, floats to rounding (see module docstring)."""
    gr_g, f_g, thr_g, l_g, r_g = (np.asarray(a) for a in got)
    gr_w, f_w, thr_w, l_w, r_w = (np.asarray(a) for a in want)
    np.testing.assert_array_equal(f_g, f_w)
    np.testing.assert_array_equal(thr_g, thr_w)
    np.testing.assert_allclose(gr_g, gr_w, rtol=2e-5, atol=1e-6)
    if counts_exact:
        np.testing.assert_array_equal(l_g, l_w)
        np.testing.assert_array_equal(r_g, r_w)
    else:
        np.testing.assert_allclose(l_g, l_w, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(r_g, r_w, rtol=1e-6, atol=1e-6)


def _random_hist(tc, s, f, b, c, *, integer=False):
    if integer:
        h = RNG.integers(0, 5, (tc, s, f, b, c)).astype(np.float32)
    else:
        h = RNG.random((tc, s, f, b, c)).astype(np.float32)
    return jnp.asarray(h)


# (tc, S, F, B, C): block-aligned and deliberately-awkward shapes.
SHAPES = [
    (2, 4, 16, 8, 3),      # aligned, single feature block
    (3, 4, 13, 8, 3),      # F non-divisible (padded + masked in-kernel)
    (1, 1, 5, 4, 2),       # tiny single slot
    (2, 2, 33, 16, 4),     # F > 8-multiple with remainder
]


@pytest.mark.parametrize("tc,s,f,b,c", SHAPES)
@pytest.mark.parametrize("masked", [False, True])
def test_kernel_matches_ref_classification(tc, s, f, b, c, masked):
    hist = _random_hist(tc, s, f, b, c)
    mask = jnp.asarray(RNG.random((tc, f)) > 0.3) if masked else None
    got = split_scan_scores(hist, mask, interpret=True)
    want = split_scan_ref(hist, mask)
    _assert_scores_match(tuple(got), want)


@pytest.mark.parametrize("tc,s,f,b,c", SHAPES[:2])
def test_kernel_matches_ref_regression(tc, s, f, b, c):
    hist = _random_hist(tc, s, f, b, 3, integer=True)
    got = split_scan_scores(hist, None, regression=True, interpret=True)
    want = split_scan_ref(hist, None, regression=True)
    _assert_scores_match(tuple(got), want, counts_exact=True)


def test_kernel_integer_counts_bit_exact():
    """Integer-valued histograms (DSI weights) -> exact child counts."""
    hist = _random_hist(3, 4, 13, 8, 3, integer=True)
    got = split_scan_scores(hist, None, interpret=True)
    _assert_scores_match(tuple(got), split_scan_ref(hist, None), counts_exact=True)


def test_kernel_multiblock_internal_carry():
    """f_blk forced below F: the in-kernel running argmax must span blocks."""
    hist = _random_hist(2, 3, 24, 8, 3)
    got = split_scan_scores(hist, None, interpret=True, f_blk=8)
    _assert_scores_match(tuple(got), split_scan_ref(hist, None))


def test_chained_carry_matches_single_shot():
    """Slab-at-a-time with a threaded carry == one pass over the full hist
    — the contract the fused T_GR->T_NS loop relies on."""
    hist = _random_hist(2, 4, 20, 8, 3, integer=True)
    mask = jnp.asarray(RNG.random((2, 20)) > 0.2)
    carry = None
    for f0 in (0, 8, 16):
        hi = min(f0 + 8, 20)
        carry = split_scan_block(
            hist[:, :, f0:hi], mask[:, f0:hi], carry, f0, interpret=True
        )
    _assert_scores_match(carry, split_scan_ref(hist, mask), counts_exact=True)


def test_all_invalid_slots_match_oracle_convention():
    """Every split empty on one side -> gain -inf, winner (f=0, thr=0)."""
    hist = jnp.zeros((2, 2, 5, 4, 3)).at[:, :, :, 0, :].set(2.0)
    got = split_scan_scores(hist, None, interpret=True)
    _assert_scores_match(tuple(got), split_scan_ref(hist, None), counts_exact=True)
    assert np.all(np.isneginf(np.asarray(got.gain_ratio)))
    assert np.all(np.asarray(got.feature) == 0)
    assert np.all(np.asarray(got.threshold) == 0)


def test_all_features_masked():
    hist = _random_hist(2, 3, 7, 8, 3, integer=True)
    mask = jnp.zeros((2, 7), jnp.bool_)
    got = split_scan_scores(hist, mask, interpret=True)
    _assert_scores_match(tuple(got), split_scan_ref(hist, mask), counts_exact=True)
    assert np.all(np.isneginf(np.asarray(got.gain_ratio)))


@pytest.mark.parametrize("regression", [False, True])
def test_level_scores_backend_dispatch(regression):
    """backend='pallas' through the public API == the xla path."""
    hist = _random_hist(3, 4, 13, 8, 3, integer=True)
    mask = jnp.asarray(RNG.random((3, 13)) > 0.3)
    sc_x, nn_x = level_scores(hist, mask, regression=regression, backend="xla")
    sc_p, nn_p = level_scores(
        hist, mask, regression=regression, backend="pallas", interpret=True
    )
    _assert_scores_match(tuple(sc_p), tuple(sc_x), counts_exact=True)
    np.testing.assert_array_equal(np.asarray(nn_p), np.asarray(nn_x))


def test_ops_wrapper_matches_oracle():
    """The jit'd public wrapper: pallas path == its own ref dispatch."""
    from repro.kernels.split_scan.ops import fused_split_scores

    hist = _random_hist(2, 3, 10, 8, 3, integer=True)
    mask = jnp.asarray(RNG.random((2, 10)) > 0.3)
    got = fused_split_scores(hist, mask, interpret=True)
    want = fused_split_scores(hist, mask, use_pallas=False)
    _assert_scores_match(tuple(got), tuple(want), counts_exact=True)


def test_resolve_split_backend():
    assert resolve_split_backend("xla") == "xla"
    assert resolve_split_backend("pallas") == "pallas"
    assert resolve_split_backend("auto") in ("pallas", "xla")
    with pytest.raises(ValueError):
        resolve_split_backend("segment_sum")


def test_choose_score_block_fits_budget():
    from repro.kernels.gain_ratio.kernel import _VMEM_BUDGET

    for (s, b, c, f) in [(64, 64, 8, 500), (1, 4, 2, 3), (16, 16, 4, 1000)]:
        f_blk = choose_score_block(s, b, c, f)
        assert f_blk <= -(-min(f, 128) // 8) * 8      # never pads past one block
        if f_blk > 8:  # above the halving floor the budget MUST hold
            assert 6 * f_blk * s * b * c * 4 <= _VMEM_BUDGET
            # ...and f_blk is maximal up to its caps (128, or F itself):
            # doubling it would blow the budget.
            assert (
                f_blk == 128
                or f_blk == -(-f // 8) * 8
                or 6 * (2 * f_blk) * s * b * c * 4 > _VMEM_BUDGET
            )
    # The floor is only ever hit because even 8 features exceed the budget.
    assert choose_score_block(64, 64, 8, 500) == 8
    assert 6 * 16 * 64 * 64 * 8 * 4 > _VMEM_BUDGET


@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("tree_chunk", [0, 4])
def test_grow_forest_split_backend_equivalence(masked, tree_chunk):
    """Forests are bit-identical whichever backend scores the splits."""
    x, y = make_classification(n_samples=600, n_features=13, n_classes=3, seed=3)
    cfg0 = ForestConfig(
        n_trees=8, max_depth=4, n_bins=16, n_classes=3,
        feature_mode="all", tree_chunk=tree_chunk,
    )
    xb, _ = bin_dataset(x, cfg0.n_bins)
    xb, y = jnp.asarray(xb), jnp.asarray(y)
    w = bootstrap_counts(
        jax.random.PRNGKey(0), cfg0.n_trees, xb.shape[0]
    ).astype(jnp.float32)
    mask = (
        random_feature_mask(
            jax.random.PRNGKey(5), n_trees=8, n_features=13, n_selected=6
        )
        if masked
        else None
    )

    out = {}
    for be in ("xla", "pallas"):
        cfg = dataclasses.replace(cfg0, split_backend=be)
        out[be] = grow_forest(xb, y, w, cfg, mask)

    a, b = out["xla"], out["pallas"]
    np.testing.assert_array_equal(np.asarray(a.feature), np.asarray(b.feature))
    np.testing.assert_array_equal(np.asarray(a.threshold), np.asarray(b.threshold))
    np.testing.assert_array_equal(np.asarray(a.left_child), np.asarray(b.left_child))
    np.testing.assert_allclose(
        np.asarray(a.class_counts), np.asarray(b.class_counts), rtol=1e-6, atol=1e-6
    )


def _max_intermediate_size(jaxpr):
    """Largest eqn-output element count anywhere in the jaxpr (recursing
    into scan/pjit/pallas_call sub-jaxprs)."""
    m = 0
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "shape", None) is not None:
                m = max(m, int(np.prod(aval.shape)) if aval.shape else 1)
        for val in eqn.params.values():
            for sub in (val if isinstance(val, (list, tuple)) else [val]):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    m = max(m, _max_intermediate_size(inner))
    return m


def test_fused_path_never_materializes_full_histogram():
    """The acceptance criterion: with split_backend='pallas' the
    single-host path holds at most one feature slab of histogram; the
    xla path (sanity check for the detector) holds the full tensor."""
    tc, S, F, B, C, N = 2, 4, 320, 16, 3, 64
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.integers(0, B, (N, F)).astype(np.uint8))
    base = jnp.asarray(np.eye(C, dtype=np.float32)[rng.integers(0, C, N)])
    w = jnp.asarray(rng.integers(0, 3, (tc, N)).astype(np.float32))
    slot = jnp.asarray(rng.integers(-1, S, (tc, N)).astype(np.int32))

    full = tc * S * F * B * C
    sizes = {}
    for be in ("pallas", "xla"):
        cfg = ForestConfig(
            n_trees=tc, max_depth=2, n_bins=B, n_classes=C,
            max_frontier=S, feature_mode="all", split_backend=be,
        )
        jaxpr = jax.make_jaxpr(
            lambda a, b_, c, d, _cfg=cfg: chunked_level_scores(a, b_, c, d, None, _cfg)
        )(xb, base, w, slot)
        sizes[be] = _max_intermediate_size(jaxpr.jaxpr)

    assert sizes["xla"] >= full          # detector sees the full histogram
    assert sizes["pallas"] < 0.75 * full  # fused path: one slab, never the tensor
