"""End-to-end behaviour tests: the paper's full pipeline + the LM driver
+ distributed PRF on a host-device mesh (run in a subprocess so the
multi-device XLA flag never leaks into other tests)."""
import json
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import ForestConfig, train_prf
from repro.data.tabular import make_classification, train_test_split


def test_paper_pipeline_end_to_end(class_data):
    """bin -> DSI -> dimred -> grow -> OOB weights -> weighted vote."""
    xtr, ytr, xte, yte = class_data
    cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=32, n_classes=4)
    model = train_prf(xtr, ytr, cfg, seed=0)
    acc = model.accuracy(xte, yte)
    assert acc > 0.75
    w = np.asarray(model.forest.tree_weight)
    assert (w > 0.4).all() and (w < 1.0).all()


def test_distributed_prf_matches_quality():
    """Vertical-partition PRF on an 8-device host mesh reaches the same
    accuracy band as the single-device trainer (stratified bootstrap)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ForestConfig
        from repro.core.binning import bin_dataset, apply_bins
        from repro.core.distributed import make_prf_train_fn, predict_sharded
        from repro.data.tabular import make_classification, train_test_split
        from repro.launch.mesh import make_mesh

        x, y = make_classification(n_samples=2048, n_features=64, n_classes=4, seed=1)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
        cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=32, n_classes=4)
        xb, edges = bin_dataset(xtr, cfg.n_bins)
        mesh = make_mesh((4, 2), ("data", "model"))
        train_fn, _ = make_prf_train_fn(cfg, mesh)
        forest = train_fn(jnp.asarray(xb[:1536]), jnp.asarray(ytr[:1536]),
                          jax.random.PRNGKey(0))
        xbte = apply_bins(jnp.asarray(xte), jnp.asarray(edges))
        pred = predict_sharded(forest, xbte[:496], mesh)
        acc = float(np.mean(np.asarray(pred) == yte[:496]))
        print(json.dumps({"acc": acc}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    acc = json.loads(out.stdout.strip().splitlines()[-1])["acc"]
    assert acc > 0.75, acc


def test_dryrun_single_cell_subprocess():
    """The dry-run machinery itself (512 virtual devices) on a small
    cell — proves lower+compile+roofline runs green end to end."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm-135m", "--shape", "decode_32k",
         "--mesh", "single", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
    data = json.load(open("/tmp/dryrun_test/smollm-135m__decode_32k__16x16.json"))
    assert data["status"] == "OK"
    assert data["flops_per_device"] > 0
    assert data["fits_hbm"]
