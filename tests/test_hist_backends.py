"""T_GR backend parity: pallas (interpret) vs segment_sum vs oracle.

The acceptance bar for the fused kernel as the production backend:
identical histograms on the full layout matrix (packed/unpacked,
classification/regression channels, non-divisible N/F, parked samples)
and identical *forests* end to end across ``hist_backend`` settings.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.core.binning import bin_dataset
from repro.core.dsi import bootstrap_counts
from repro.core.forest import grow_forest
from repro.core.histograms import (
    class_channels, level_histograms, regression_channels, resolve_backend,
)
from repro.data.tabular import make_classification
from repro.kernels.gain_ratio.kernel import choose_blocks, multi_tree_hist_pallas
from repro.kernels.gain_ratio.ref import level_histogram_ref

RNG = np.random.default_rng(7)


def _random_case(tc, n, f, s, b, c, channels):
    xb = RNG.integers(0, b, (n, f)).astype(np.int32)
    if channels == "classification":
        base = np.eye(c, dtype=np.float32)[RNG.integers(0, c, n)]
    else:
        base = np.asarray(regression_channels(jnp.asarray(
            RNG.standard_normal(n).astype(np.float32))))
    w = (RNG.integers(0, 4, (tc, n))).astype(np.float32)    # DSI-like counts
    slot = RNG.integers(-1, s, (tc, n)).astype(np.int32)    # incl. parked
    return jnp.asarray(xb), jnp.asarray(base), jnp.asarray(w), jnp.asarray(slot)


# (tc, N, F, S, B, C): divisible and deliberately-awkward shapes.
SHAPES = [
    (2, 256, 32, 4, 8, 3),     # block-aligned
    (3, 300, 17, 4, 8, 3),     # N and F both non-divisible
    (1, 65, 5, 1, 4, 2),       # single tree, single slot, tiny
    (4, 1030, 33, 2, 16, 4),   # N > n_blk with remainder
]


@pytest.mark.parametrize("tc,n,f,s,b,c", SHAPES)
@pytest.mark.parametrize("packed", [False, True])
def test_pallas_matches_oracles_classification(tc, n, f, s, b, c, packed):
    xb, base, w, slot = _random_case(tc, n, f, s, b, c, "classification")
    got = multi_tree_hist_pallas(
        xb, base, w, slot, n_slots=s, n_bins=b, packed=packed, interpret=True
    )
    want_seg = level_histograms(
        xb, base, w, slot, n_slots=s, n_bins=b, packed=packed,
        backend="segment_sum",
    )
    want_ref = level_histogram_ref(xb, base, w, slot, n_slots=s, n_bins=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_seg),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("tc,n,f,s,b,c", SHAPES[:2])
def test_pallas_matches_oracles_regression(tc, n, f, s, b, c):
    """Regression channels [1, y, y^2] — unpacked layout only."""
    xb, base, w, slot = _random_case(tc, n, f, s, b, 3, "regression")
    got = multi_tree_hist_pallas(
        xb, base, w, slot, n_slots=s, n_bins=b, packed=False, interpret=True
    )
    want = level_histograms(
        xb, base, w, slot, n_slots=s, n_bins=b, backend="segment_sum"
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_all_parked_contributes_nothing():
    xb, base, w, _ = _random_case(2, 100, 7, 3, 8, 2, "classification")
    slot = jnp.full((2, 100), -1, jnp.int32)
    got = multi_tree_hist_pallas(
        xb, base, w, slot, n_slots=3, n_bins=8, interpret=True
    )
    assert float(jnp.abs(got).max()) == 0.0


def test_level_histograms_backend_dispatch():
    """backend='pallas' through the public API == segment_sum, both packings."""
    xb, base, w, slot = _random_case(2, 300, 17, 4, 8, 3, "classification")
    for packed in (False, True):
        a = level_histograms(xb, base, w, slot, n_slots=4, n_bins=8,
                             packed=packed, backend="pallas", interpret=True)
        b = level_histograms(xb, base, w, slot, n_slots=4, n_bins=8,
                             packed=packed, backend="segment_sum")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_resolve_backend():
    assert resolve_backend("segment_sum") == "segment_sum"
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("auto") in ("pallas", "segment_sum")
    with pytest.raises(ValueError):
        resolve_backend("cuda")


def test_choose_blocks_fits_budget():
    for (n, f, s, b, c, packed) in [
        (10_000, 500, 64, 64, 8, False),
        (10_000, 500, 64, 64, 8, True),
        (100, 3, 1, 4, 2, False),
    ]:
        n_blk, f_blk = choose_blocks(n, f, s, b, c, packed=packed)
        width = s * b * c if packed else s * b
        out_bytes = f_blk * s * b * c * 4
        in_bytes = n_blk * (width + f_blk + c + 2) * 4
        assert out_bytes + in_bytes <= 16 * 2 ** 20, (n_blk, f_blk)
        assert n_blk >= 8 and f_blk >= 8


@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("tree_chunk", [0, 4])
def test_grow_forest_backend_equivalence(packed, tree_chunk):
    """Forests are identical whichever backend built the histograms."""
    x, y = make_classification(n_samples=600, n_features=13, n_classes=3, seed=3)
    cfg0 = ForestConfig(
        n_trees=8, max_depth=4, n_bins=16, n_classes=3,
        feature_mode="all", packed_hist=packed, tree_chunk=tree_chunk,
    )
    xb, _ = bin_dataset(x, cfg0.n_bins)
    xb, y = jnp.asarray(xb), jnp.asarray(y)
    w = bootstrap_counts(
        jax.random.PRNGKey(0), cfg0.n_trees, xb.shape[0]
    ).astype(jnp.float32)

    out = {}
    for be in ("segment_sum", "pallas"):
        cfg = dataclasses.replace(cfg0, hist_backend=be)
        out[be] = grow_forest(xb, y, w, cfg)

    a, b = out["segment_sum"], out["pallas"]
    np.testing.assert_array_equal(np.asarray(a.feature), np.asarray(b.feature))
    np.testing.assert_array_equal(np.asarray(a.threshold), np.asarray(b.threshold))
    np.testing.assert_array_equal(np.asarray(a.left_child), np.asarray(b.left_child))
    np.testing.assert_allclose(
        np.asarray(a.class_counts), np.asarray(b.class_counts), rtol=1e-6, atol=1e-6
    )
