"""Prediction-backend parity: fused traversal+voting kernel vs oracles.

The acceptance bar for the fused predict path as a production backend
(mirrors test_hist_backends.py / test_split_backends.py for training):

* kernel-vs-ref parity on the full matrix — synthetic node pools x
  non-divisible N (forced sample blocking) x non-divisible tree chunks
  chained through the resumable carry;
* trained forests predict **identical labels** whichever backend votes
  (classification/regression x hard/soft x weighted/unweighted), with
  scores matching to float rounding (the kernel accumulates trees
  sequentially, the xla path reduces over the stacked axis);
* the fused path never materializes the ``[k, N, C]`` per-tree tensor
  (jaxpr inspection);
* the OOB weight fallbacks (Eq. 8 and its R^2 analogue) are pinned:
  degenerate OOB sets get the neutral prior 0.5, never a confident
  0/0 artifact.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig, train_prf
from repro.core.binning import apply_bins, bin_dataset
from repro.core.dsi import bootstrap_counts
from repro.core.forest import grow_forest
from repro.core.voting import (
    leaf_value_payload, leaf_vote_payload, oob_accuracy, oob_r2, predict,
    predict_regression, predict_regression_scores, predict_scores,
    resolve_predict_backend,
)
from repro.data.tabular import make_classification, make_regression, train_test_split
from repro.kernels.tree_traverse.kernel import choose_traverse_block, traverse_block
from repro.kernels.tree_traverse.ref import traverse_ref

from test_split_backends import _max_intermediate_size

RNG = np.random.default_rng(23)


def _random_pool(k, P, F, C, *, depth):
    """A random (not necessarily tree-shaped) node pool — the kernel and
    ref share the exact traversal contract, so arbitrary pools are fair."""
    feature = RNG.integers(-1, F, (k, P)).astype(np.int32)
    feature[:, 0] = RNG.integers(0, F, k)          # root always splits
    threshold = RNG.integers(0, 6, (k, P)).astype(np.int32)
    left = RNG.integers(0, max(P - 1, 1), (k, P)).astype(np.int32)
    payload = RNG.random((k, P, C)).astype(np.float32)
    return jnp.asarray(feature), jnp.asarray(threshold), jnp.asarray(left), jnp.asarray(payload)


# (k, P, F, C, N): block-aligned and deliberately-awkward shapes.
SHAPES = [
    (4, 16, 8, 3, 64),      # aligned
    (3, 23, 11, 3, 70),     # everything non-divisible
    (1, 9, 5, 1, 17),       # single tree, C=1 (regression payload shape)
    (6, 33, 7, 4, 129),     # N one past a block boundary
]


@pytest.mark.parametrize("k,p,f,c,n", SHAPES)
def test_kernel_matches_ref(k, p, f, c, n):
    depth = 5
    feat, thr, left, payload = _random_pool(k, p, f, c, depth=depth)
    xb = jnp.asarray(RNG.integers(0, 8, (n, f)).astype(np.uint8))
    got = traverse_block(xb, feat, thr, left, payload, None, depth=depth, interpret=True)
    want = traverse_ref(xb, feat, thr, left, payload, depth=depth)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_kernel_forced_small_sample_blocks():
    """n_blk forced below N: the score tile must survive the N grid axis."""
    depth = 4
    feat, thr, left, payload = _random_pool(3, 17, 6, 2, depth=depth)
    xb = jnp.asarray(RNG.integers(0, 8, (100, 6)).astype(np.uint8))
    got = traverse_block(
        xb, feat, thr, left, payload, None, depth=depth, n_blk=16, interpret=True
    )
    want = traverse_ref(xb, feat, thr, left, payload, depth=depth)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_kernel_chained_carry_matches_single_shot():
    """Uneven tree chunks threaded through the carry == one pass —
    the contract the tree-chunked fused predict loop relies on."""
    depth = 5
    k = 7
    feat, thr, left, payload = _random_pool(k, 19, 9, 3, depth=depth)
    xb = jnp.asarray(RNG.integers(0, 8, (53, 9)).astype(np.uint8))
    carry = None
    for c0, c1 in ((0, 3), (3, 6), (6, 7)):       # deliberately non-divisible
        carry = traverse_block(
            xb, feat[c0:c1], thr[c0:c1], left[c0:c1], payload[c0:c1],
            carry, depth=depth, interpret=True,
        )
    want = traverse_ref(xb, feat, thr, left, payload, depth=depth)
    np.testing.assert_allclose(np.asarray(carry), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_kernel_seeds_from_carry():
    """A nonzero carry is the starting score, exactly (psum partial-vote
    contract of the serving layer)."""
    depth = 3
    feat, thr, left, payload = _random_pool(2, 11, 5, 3, depth=depth)
    xb = jnp.asarray(RNG.integers(0, 8, (24, 5)).astype(np.uint8))
    carry = jnp.asarray(RNG.random((24, 3)).astype(np.float32))
    got = traverse_block(xb, feat, thr, left, payload, carry, depth=depth, interpret=True)
    want = traverse_ref(xb, feat, thr, left, payload, carry, depth=depth)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_ops_wrapper_matches_oracle():
    from repro.kernels.tree_traverse.ops import fused_vote

    depth = 4
    feat, thr, left, payload = _random_pool(3, 15, 7, 2, depth=depth)
    xb = jnp.asarray(RNG.integers(0, 8, (40, 7)).astype(np.uint8))
    got = fused_vote(xb, feat, thr, left, payload, depth=depth)
    want = fused_vote(xb, feat, thr, left, payload, depth=depth, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_choose_traverse_block_fits_budget():
    from repro.kernels.gain_ratio.kernel import _VMEM_BUDGET

    for (p, f, c) in [(4097, 512, 8), (26, 16, 4), (1025, 64, 2)]:
        n_blk = choose_traverse_block(p, f, c)
        if n_blk > 8:   # above the halving floor the budget MUST hold
            assert n_blk * (6 * p + 2 * f + 2 * c) * 4 <= _VMEM_BUDGET
            assert (
                n_blk == 512
                or 2 * n_blk * (6 * p + 2 * f + 2 * c) * 4 > _VMEM_BUDGET
            )


# ---------------------------------------------------------------------------
# Trained-forest dispatch: labels bit-identical across backends
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def class_model():
    x, y = make_classification(n_samples=900, n_features=14, n_classes=3, seed=5)
    xtr, ytr, xte, _ = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(
        n_trees=8, max_depth=4, n_bins=16, n_classes=3, feature_mode="all"
    )
    model = train_prf(xtr, ytr, cfg, seed=0)
    xbte = apply_bins(jnp.asarray(xte), jnp.asarray(model.bin_edges))
    return model, xbte


@pytest.mark.parametrize("soft", [False, True])
@pytest.mark.parametrize("weighted", [False, True])
def test_classification_backend_parity(class_model, soft, weighted):
    model, xbte = class_model
    cfg = dataclasses.replace(
        model.forest.config, soft_voting=soft, weighted_voting=weighted
    )
    forest = dataclasses.replace(model.forest, config=cfg)
    lx = predict(forest, xbte, backend="xla")
    lp = predict(forest, xbte, backend="pallas")
    np.testing.assert_array_equal(np.asarray(lx), np.asarray(lp))
    sx = predict_scores(forest, xbte, backend="xla")
    sp = predict_scores(forest, xbte, backend="pallas")
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sp), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("weighted", [False, True])
def test_regression_backend_parity(weighted):
    x, y = make_regression(900, 10, seed=2)
    xtr, ytr, xte, _ = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(
        n_trees=6, max_depth=4, n_bins=16, regression=True,
        feature_mode="all", weighted_voting=weighted,
    )
    model = train_prf(xtr, ytr, cfg, seed=0)
    xbte = apply_bins(jnp.asarray(xte), jnp.asarray(model.bin_edges))
    vx = predict_regression(model.forest, xbte, backend="xla")
    vp = predict_regression(model.forest, xbte, backend="pallas")
    np.testing.assert_allclose(np.asarray(vx), np.asarray(vp), rtol=1e-5, atol=1e-6)
    nx = predict_regression_scores(model.forest, xbte, backend="xla")
    np_ = predict_regression_scores(model.forest, xbte, backend="pallas")
    np.testing.assert_allclose(np.asarray(nx), np.asarray(np_), rtol=1e-5, atol=1e-6)


def test_tree_chunked_fused_predict_is_exact(class_model):
    """tree_chunk (including a non-divisible remainder: 8 = 3+3+2)
    threads the carry across pallas_calls without changing labels."""
    model, xbte = class_model
    want = predict(model.forest, xbte, backend="pallas")
    for tc in (1, 3, 4):
        cfg = dataclasses.replace(model.forest.config, tree_chunk=tc)
        forest = dataclasses.replace(model.forest, config=cfg)
        got = predict(forest, xbte, backend="pallas")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prfmodel_predict_bit_identical_across_backends(class_model):
    model, _ = class_model
    x, y = make_classification(n_samples=300, n_features=14, n_classes=3, seed=9)
    out = {
        be: model.with_predict_backend(be).predict(x)
        for be in ("xla", "pallas", "auto")
    }
    np.testing.assert_array_equal(out["xla"], out["pallas"])
    np.testing.assert_array_equal(out["xla"], out["auto"])


def test_resolve_predict_backend():
    assert resolve_predict_backend("xla") == "xla"
    assert resolve_predict_backend("pallas") == "pallas"
    assert resolve_predict_backend("auto") in ("pallas", "xla")
    with pytest.raises(ValueError):
        resolve_predict_backend("segment_sum")


def test_payloads_are_finite_everywhere(class_model):
    """The fused kernel's one-hot matmul reads EVERY pool row — a NaN at
    the scatter-pad slot (0/0 under XLA's subnormal flush) would poison
    the scores through 0 * NaN."""
    model, _ = class_model
    w = model.forest.tree_weight
    assert bool(jnp.isfinite(leaf_vote_payload(model.forest, w, soft=True)).all())
    assert bool(jnp.isfinite(leaf_vote_payload(model.forest, w, soft=False)).all())

    x, y = make_regression(400, 8, seed=3)
    cfg = ForestConfig(
        n_trees=4, max_depth=3, n_bins=8, regression=True, feature_mode="all"
    )
    m = train_prf(x, y, cfg, seed=0)
    assert bool(jnp.isfinite(m.forest.value).all())          # _safe_mean at work
    assert bool(jnp.isfinite(leaf_value_payload(m.forest, m.forest.tree_weight)).all())


# ---------------------------------------------------------------------------
# No [k, N, C] intermediate on the fused path (jaxpr inspection)
# ---------------------------------------------------------------------------


def test_fused_predict_never_materializes_per_tree_tensor():
    x, y = make_classification(n_samples=700, n_features=12, n_classes=4, seed=1)
    cfg = ForestConfig(
        n_trees=16, max_depth=3, n_bins=8, n_classes=4,
        max_frontier=4, feature_mode="all",
    )
    xb_np, _ = bin_dataset(x, cfg.n_bins)
    xb = jnp.asarray(xb_np)
    w = bootstrap_counts(jax.random.PRNGKey(0), cfg.n_trees, xb.shape[0]).astype(jnp.float32)
    forest = grow_forest(xb, jnp.asarray(y), w, cfg, None)

    N = 512
    xq = xb[:N]
    full = cfg.n_trees * N * cfg.n_classes
    sizes = {}
    for be in ("pallas", "xla"):
        jaxpr = jax.make_jaxpr(
            lambda a, _be=be: predict_scores(forest, a, backend=_be)
        )(xq)
        sizes[be] = _max_intermediate_size(jaxpr.jaxpr)

    assert sizes["xla"] >= full           # detector sees the per-tree tensor
    assert sizes["pallas"] < 0.75 * full  # fused path: only blocks + payload


# ---------------------------------------------------------------------------
# OOB weight fallbacks (Eq. 8 / R^2) — degenerate cases pinned
# ---------------------------------------------------------------------------


def _tiny_class_forest():
    x, y = make_classification(n_samples=200, n_features=8, n_classes=2, seed=4)
    cfg = ForestConfig(
        n_trees=4, max_depth=3, n_bins=8, n_classes=2, feature_mode="all"
    )
    xb_np, _ = bin_dataset(x, cfg.n_bins)
    xb = jnp.asarray(xb_np)
    w = bootstrap_counts(jax.random.PRNGKey(1), cfg.n_trees, xb.shape[0]).astype(jnp.float32)
    forest = grow_forest(xb, jnp.asarray(y), w, cfg, None)
    return forest, xb, jnp.asarray(y), w


def _tiny_reg_forest():
    x, y = make_regression(200, 8, seed=4)
    cfg = ForestConfig(
        n_trees=4, max_depth=3, n_bins=8, regression=True, feature_mode="all"
    )
    xb_np, _ = bin_dataset(x, cfg.n_bins)
    xb = jnp.asarray(xb_np)
    w = bootstrap_counts(jax.random.PRNGKey(1), cfg.n_trees, xb.shape[0]).astype(jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    forest = grow_forest(xb, y, w, cfg, None)
    return forest, xb, y, w


def test_oob_accuracy_all_in_bag_is_neutral():
    """An all-in-bag forest (no OOB evidence at all) gets 0.5 everywhere."""
    forest, xb, y, w = _tiny_class_forest()
    all_in_bag = jnp.ones_like(w)
    np.testing.assert_array_equal(
        np.asarray(oob_accuracy(forest, xb, y, all_in_bag)), 0.5
    )


def test_oob_accuracy_single_degenerate_tree():
    forest, xb, y, w = _tiny_class_forest()
    w = w.at[2].set(jnp.ones_like(w[2]))          # tree 2: zero OOB samples
    acc = np.asarray(oob_accuracy(forest, xb, y, w))
    assert acc[2] == 0.5
    assert ((acc >= 0.0) & (acc <= 1.0)).all()


def test_oob_r2_all_in_bag_is_neutral():
    """Previously the empty-OOB 0/eps arithmetic produced a confident 1.0
    under clip; the documented fallback is the neutral prior 0.5."""
    forest, xb, y, w = _tiny_reg_forest()
    all_in_bag = jnp.ones_like(w)
    np.testing.assert_array_equal(np.asarray(oob_r2(forest, xb, y, all_in_bag)), 0.5)


def test_oob_r2_zero_variance_is_neutral():
    """Constant targets on the OOB set: R^2 undefined -> neutral 0.5,
    not a clip-masked garbage ratio."""
    forest, xb, y, w = _tiny_reg_forest()
    const_y = jnp.full_like(y, 3.25)
    r2 = np.asarray(oob_r2(forest, xb, const_y, w))
    np.testing.assert_array_equal(r2, 0.5)


def test_oob_r2_regular_case_in_unit_interval_and_finite():
    forest, xb, y, w = _tiny_reg_forest()
    r2 = np.asarray(oob_r2(forest, xb, y, w))
    assert np.isfinite(r2).all()
    assert ((r2 >= 0.0) & (r2 <= 1.0)).all()
    assert (r2 != 0.5).any()                       # real evidence used
