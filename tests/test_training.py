"""Training substrate: optimizer, grad-accum exactness, loss decrease,
checkpoint/restart fault tolerance, straggler detection."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import latest_step
from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.launch.fault import ElasticRunner, SimulatedFailure, StragglerMonitor
from repro.models import build_model
from repro.training import AdamWConfig
from repro.training.optimizer import adamw_init, adamw_update, schedule
from repro.training.train_step import TrainState, init_state, make_train_step

from conftest import reduce_cfg


def tiny_model():
    cfg = reduce_cfg(
        get_config("smollm-135m"), n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    )
    return build_model(cfg), cfg


def test_adamw_reduces_quadratic():
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, decay_steps=1000)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, opt)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_factored_second_moment_shapes():
    opt = AdamWConfig(factored=True)
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    st = adamw_init(params, opt)
    assert st["v"]["w"]["vr"].shape == (8,)
    assert st["v"]["w"]["vc"].shape == (16,)
    assert st["v"]["b"].shape == (16,)
    grads = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
    p2, st2, _ = adamw_update(params, grads, st, opt)
    assert p2["w"].shape == (8, 16)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_schedule_warmup_and_decay():
    opt = AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    assert float(schedule(opt, jnp.int32(5))) == pytest.approx(5e-4)
    assert float(schedule(opt, jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(schedule(opt, jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_grad_accum_matches_single_batch():
    """2 microbatches of 8 == 1 microbatch of 16 (exact in fp32)."""
    model, cfg = tiny_model()
    opt = AdamWConfig(lr=1e-3)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = make_train_step(model, opt)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (16, 17)).astype(np.int32)
    b1 = {"tokens": jnp.asarray(toks[None, :, :-1]),
          "targets": jnp.asarray(toks[None, :, 1:])}
    b2 = {"tokens": jnp.asarray(toks[:, :-1].reshape(2, 8, 16)),
          "targets": jnp.asarray(toks[:, 1:].reshape(2, 8, 16))}
    s1, m1 = step(state, b1)
    s2, m2 = step(state, b2)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    l1 = jax.tree_util.tree_leaves(s1.params)
    l2 = jax.tree_util.tree_leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)


def test_loss_decreases_end_to_end():
    model, cfg = tiny_model()
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=100)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32, n_docs=256, seed=0)
    losses = []
    for b in pipe.batches(batch=16, steps=25, n_micro=2):
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_checkpoint_roundtrip_and_gc():
    model, cfg = tiny_model()
    opt = AdamWConfig()
    state = init_state(model, jax.random.PRNGKey(0), opt)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, save_interval=1)
        for s in range(5):
            mgr.maybe_save(state, s)
        assert latest_step(d) == 4
        restored, step = mgr.restore_latest(state)
        assert step == 4
        np.testing.assert_array_equal(
            np.asarray(state.params["embed"]["table"]),
            np.asarray(restored.params["embed"]["table"]),
        )
        # gc kept only 2
        import os
        assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2


def test_elastic_runner_recovers_from_failure():
    """Inject a failure mid-training; the runner must resume from the
    checkpoint and finish all steps with optimizer state intact."""
    model, cfg = tiny_model()
    opt = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(model, opt))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16, n_docs=64, seed=1)
    batches = [
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in pipe.batches(batch=8, steps=12, n_micro=1)
    ]
    failed = {"done": False}

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, save_interval=2)
        runner = ElasticRunner(mgr, max_restarts=2)

        def init_fn():
            return init_state(model, jax.random.PRNGKey(0), opt)

        def loop(state, start, n_steps, on_step):
            for s in range(start, n_steps):
                if s == 6 and not failed["done"]:
                    failed["done"] = True
                    raise SimulatedFailure("node died")
                state, m = step_fn(state, batches[s])
                on_step(s + 1, state, m)
            return state

        state, monitor, restarts = runner.run(init_fn, loop, 12)
        assert restarts == 1
        assert int(state.step) >= 10   # resumed from step<=6 checkpoint, reached 12


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(factor=3.0, warmup=3)
    for i in range(6):
        mon.record(i, 1.0)
    assert mon.record(6, 10.0) is True
    assert mon.flagged == [6]
    assert mon.record(7, 1.1) is False


def test_dsi_pipeline_no_copy_and_determinism():
    pipe = TokenPipeline(vocab_size=64, seq_len=8, n_docs=32, seed=5)
    t1 = pipe.dsi_epoch(0, 4, 10)
    t2 = pipe.dsi_epoch(0, 4, 10)
    np.testing.assert_array_equal(t1, t2)          # deterministic replay
    t3 = pipe.dsi_epoch(1, 4, 10)
    assert not np.array_equal(t1, t3)              # epochs differ
    b = pipe.batch(t1[0])
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
