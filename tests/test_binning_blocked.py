"""Blocked/mergeable quantile-sketch binning (core/binning.py).

The out-of-core contract: `fit_bins_blocked` over per-block views is
bitwise identical to the resident `fit_bins` while summaries stay
uncompressed, deterministic always, block-bounded in memory (proved
against a memmap with tracemalloc), and composable — sketch merges,
validator exclusion masks, and the mesh exchange all reproduce the same
edges. Plus the uint8 bin-count guard and the float32 edge-boundary
contract of `apply_bins`.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig, train_prf
from repro.core.binning import (
    MAX_BINS,
    BinCountError,
    StreamingQuantileSketch,
    apply_bins,
    fit_bins,
    fit_bins_blocked,
    host_digitize,
)
from repro.data.pipeline import sample_blocks

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # CI installs hypothesis; the property test skips without
    HAVE_HYPOTHESIS = False

    def given(**kw):  # no-op decorators so the module still imports
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    settings = given

    class st:  # noqa: N801 - stand-in namespace
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def sampled_from(*a, **kw):
            return None

        @staticmethod
        def booleans(*a, **kw):
            return None

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Exact-merge parity: blocked == resident, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize(
    "n_rows,block",
    [(600, 600), (600, 170), (601, 64), (601, 601), (37, 5), (4000, 333)],
)
def test_blocked_equals_exact_bitwise(dtype, n_rows, block):
    """Uncompressed sketch == np.quantile, to the last bit — single block,
    even blocks, and a ragged last block; both float dtypes (the lerp is
    evaluated in the source dtype, exactly as numpy does)."""
    x = (_rng(1).standard_normal((n_rows, 7))
         * 10.0 ** _rng(2).integers(-6, 6, (n_rows, 7))).astype(dtype)
    blocks = [x[i:i + block] for i in range(0, n_rows, block)]
    exact = fit_bins(x, 32)
    blocked = fit_bins_blocked(blocks, 32)
    assert blocked.dtype == exact.dtype == np.float64
    np.testing.assert_array_equal(blocked, exact)


@given(
    n_rows=st.integers(1, 400),
    block=st.integers(1, 400),
    n_bins=st.sampled_from([2, 8, 32]),
    wide=st.booleans(),
    ties=st.booleans(),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_blocked_equals_exact_property(n_rows, block, n_bins, wide, ties, seed):
    """Hypothesis sweep of the bitwise pin: any N, any block size (ragged
    last block / block > N / block == 1), heavy ties, wide exponents."""
    r = _rng(seed)
    x = r.standard_normal((n_rows, 3))
    if ties:
        x = np.round(x, 1)  # collapse to few distinct values
    if wide:
        x = x * 10.0 ** r.integers(-12, 12, x.shape)
    x = x.astype(np.float32)
    blocks = [x[i:i + block] for i in range(0, n_rows, block)]
    np.testing.assert_array_equal(
        fit_bins_blocked(blocks, n_bins), fit_bins(x, n_bins)
    )


def test_compressed_is_deterministic_and_close():
    """Past the compression threshold the sketch is no longer bitwise —
    but it is run-to-run deterministic and rank error stays bounded
    (< 2% of mass per edge at max_size=512 over 60k rows)."""
    x = _rng(3).standard_normal((60_000, 5)).astype(np.float32)
    blocks = [x[i:i + 4096] for i in range(0, x.shape[0], 4096)]
    a = fit_bins_blocked(blocks, 64, max_size=512)
    b = fit_bins_blocked(blocks, 64, max_size=512)
    np.testing.assert_array_equal(a, b)
    exact = fit_bins(x, 64)
    for f in range(x.shape[1]):
        for j in range(exact.shape[1]):
            lo, hi = sorted((exact[f, j], a[f, j]))
            frac = np.mean((x[:, f] > lo) & (x[:, f] <= hi))
            assert frac < 0.02, (f, j, frac)
    sk = StreamingQuantileSketch(5, max_size=512)
    for blk in blocks:
        sk.update(blk)
    assert not sk.exact
    assert int(sk.summary_sizes().max()) <= 2 * 512


def test_merge_matches_single_pass_and_roundtrips():
    """Sketch merge == one sketch over all blocks (bitwise, uncompressed),
    and the dense `state()` snapshot round-trips exactly — the mesh
    exchange depends on both."""
    x = _rng(4).standard_normal((500, 6)).astype(np.float32)
    left = StreamingQuantileSketch(6).update(x[:180])
    right = StreamingQuantileSketch(6).update(x[180:])
    merged = left.merge(right)
    single = StreamingQuantileSketch(6).update(x)
    np.testing.assert_array_equal(merged.edges(16), single.edges(16))
    np.testing.assert_array_equal(merged.edges(16), fit_bins(x, 16))
    assert merged.exact and int(merged.count.sum()) == 500 * 6

    back = StreamingQuantileSketch.from_state(merged.state(pad_to=1024))
    assert back.value_dtype == np.float32
    np.testing.assert_array_equal(back.edges(16), merged.edges(16))

    # Merging an empty sketch is a strict no-op (no dtype widening).
    merged.merge(StreamingQuantileSketch(6))
    assert merged.value_dtype == np.float32
    np.testing.assert_array_equal(merged.edges(16), single.edges(16))


def test_constant_and_empty_features():
    x = np.full((100, 2), 3.25, np.float32)
    x[:, 1] = 7.0
    blocked = fit_bins_blocked([x[:33], x[33:]], 8)
    np.testing.assert_array_equal(blocked, fit_bins(x, 8))
    assert np.all(blocked[0] == 3.25) and np.all(blocked[1] == 7.0)
    # A fully-excluded feature degrades to constant-0 edges, not a crash.
    mask = np.zeros_like(x, bool)
    mask[:, 0] = True
    e = fit_bins_blocked([x[:33], x[33:]], 8,
                         exclude_masks=[mask[:33], mask[33:]])
    assert np.all(e[0] == 0.0) and np.all(e[1] == 7.0)


def test_screened_cells_excluded_from_edges():
    """The validator's imputed-cell masks fold into the sketch: edges come
    from the surviving finite values only — bitwise equal to np.quantile
    over exactly those values — and bare NaN cells are dropped."""
    x = _rng(5).standard_normal((300, 4)).astype(np.float32)
    mask = _rng(6).random((300, 4)) < 0.1
    blocks = [x[:110], x[110:220], x[220:]]
    masks = {0: mask[:110], 2: mask[220:]}  # sparse, dict-keyed like api.py
    full_mask = np.zeros_like(mask)
    full_mask[:110] = mask[:110]
    full_mask[220:] = mask[220:]
    edges = fit_bins_blocked(blocks, 16, exclude_masks=masks)
    qs = np.linspace(0, 1, 17)[1:-1]
    for f in range(4):
        ref = np.quantile(x[~full_mask[:, f], f], qs)
        np.testing.assert_array_equal(edges[f], np.maximum.accumulate(ref))

    xn = x.copy()
    xn[full_mask] = np.nan  # same cells as NaN, no mask
    np.testing.assert_array_equal(fit_bins_blocked([xn], 16), edges)


# ---------------------------------------------------------------------------
# uint8 bin-count guard
# ---------------------------------------------------------------------------


def test_n_bins_validation_typed_error():
    x = _rng(7).standard_normal((64, 3)).astype(np.float32)
    for bad in (1, 0, -4, 257, 300, 2.5, "64", True):
        with pytest.raises(BinCountError):
            fit_bins(x, bad)
        with pytest.raises(BinCountError):
            fit_bins_blocked([x], bad)
        with pytest.raises(BinCountError):
            ForestConfig(n_bins=bad)
    with pytest.raises(ValueError):
        ForestConfig(bin_fit="fancy")
    # The boundary case must still work and stay inside uint8.
    edges = fit_bins(_rng(8).standard_normal((1000, 2)), MAX_BINS)
    assert edges.shape == (2, MAX_BINS - 1)
    ids = np.asarray(apply_bins(jnp.asarray(x[:, :2]), jnp.asarray(edges)))
    assert ids.dtype == np.uint8 and ids.max() <= MAX_BINS - 1


def test_apply_bins_rejects_wrapping_edges():
    """Pre-fix, 300 bins silently wrapped ids through the uint8 cast;
    now an over-wide edges array is a trace-time BinCountError."""
    with pytest.raises(BinCountError):
        apply_bins(jnp.zeros((4, 2), jnp.float32),
                   jnp.zeros((2, MAX_BINS), jnp.float32))


# ---------------------------------------------------------------------------
# float32 edge-boundary contract
# ---------------------------------------------------------------------------


def test_boundary_samples_follow_f32_contract():
    """Samples exactly on fitted edges: `apply_bins` evaluates both sides
    in float32 (explicitly — not via jax's implicit downcast), a sample
    bit-equal to edge j lands in bin j+1, and `host_digitize` is the
    host reference of the same rule."""
    # 101 rows put the 0.25/0.5/0.75 quantile positions on exact indices,
    # so the fitted edges are the data values themselves — 0.1, 0.3, 0.7,
    # none of which is float32-representable (0.7 rounds DOWN in f32).
    base = np.array([0.1] * 26 + [0.3] * 25 + [0.7] * 25 + [0.9] * 25)
    x = base[:, None].astype(np.float64)
    edges = fit_bins(x, 4)  # float64 edges, landing on data values
    np.testing.assert_array_equal(edges, [[0.1, 0.3, 0.7]])
    on_edge = edges.T.astype(np.float32)  # samples bit-equal (f32) to edges
    got = np.asarray(apply_bins(jnp.asarray(on_edge), jnp.asarray(edges)))
    np.testing.assert_array_equal(got, host_digitize(on_edge, edges))
    ef32 = edges.astype(np.float32)
    for j in range(edges.shape[1]):
        assert got[j, 0] == np.searchsorted(ef32[0], ef32[0, j], side="right")
    # The pin matters: comparing the same samples against the float64
    # edges lands at least one of them in a different bin (0.7's f32
    # rounding is below its f64 edge), which is the pre-fix ambiguity.
    f64_bins = np.stack(
        [np.searchsorted(edges[f], on_edge[:, f].astype(np.float64),
                         side="right") for f in range(edges.shape[0])], axis=1
    )
    assert not np.array_equal(got, f64_bins)


# ---------------------------------------------------------------------------
# sample_blocks: views, not copies
# ---------------------------------------------------------------------------


def test_sample_blocks_keeps_ndarray_identity_and_views(tmp_path):
    arr_blocks = [np.arange(6, dtype=np.float32).reshape(3, 2),
                  np.ones((2, 2), np.float32)]
    out = sample_blocks(arr_blocks)
    assert out[0] is arr_blocks[0] and out[1] is arr_blocks[1]
    # Non-array entries are materialized (once), arrays pass by identity.
    mixed = sample_blocks([arr_blocks[0], [[1.0, 2.0]]])
    assert mixed[0] is arr_blocks[0]
    assert isinstance(mixed[1], np.ndarray)

    p = tmp_path / "src.f32"
    mm = np.memmap(p, np.float32, "w+", shape=(10, 2))
    mm[:] = np.arange(20).reshape(10, 2)
    mm.flush()
    src = np.memmap(p, np.float32, "r", shape=(10, 2))
    views = sample_blocks(src, 4)
    assert len(views) == 3 and views[-1].shape == (2, 2)
    for v in views:
        assert np.shares_memory(v, src)


# ---------------------------------------------------------------------------
# Out-of-core: block-bounded memory against a memmap
# ---------------------------------------------------------------------------


def _fill_memmap(path, n_rows, n_features, seed=0):
    mm = np.memmap(path, np.float32, "w+", shape=(n_rows, n_features))
    r = _rng(seed)
    step = 100_000
    for i in range(0, n_rows, step):
        mm[i:i + step] = r.standard_normal(
            (min(step, n_rows - i), n_features), dtype=np.float32)
    mm.flush()
    del mm
    return np.memmap(path, np.float32, "r", shape=(n_rows, n_features))


def test_fit_bins_blocked_memmap_peak_memory(tmp_path):
    """The tentpole's memory bound: fitting edges over a 96MB memmap
    allocates O(block) + O(F * sketch) — a small fraction of the raw
    size — while the exact path demonstrably allocates the full copy
    (which also proves this measurement *can* detect materialization).

    tracemalloc is the meter (numpy registers its buffers with it);
    process RSS would be polluted by resident file pages, which the
    kernel reclaims lazily even though they are not allocations.
    """
    import tracemalloc

    n_rows, n_features = 1_000_000, 24
    src = _fill_memmap(tmp_path / "big.f32", n_rows, n_features)
    raw_bytes = n_rows * n_features * 4

    tracemalloc.start()
    blocked = fit_bins_blocked(sample_blocks(src, 65_536), 64)
    _, peak_blocked = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak_blocked < raw_bytes // 4, (
        f"blocked fit allocated {peak_blocked/1e6:.1f}MB against a "
        f"{raw_bytes/1e6:.0f}MB source — not block-bounded")

    tracemalloc.start()
    exact = fit_bins(src, 64)
    _, peak_exact = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak_exact >= raw_bytes, "meter failed to see the full-pass copy"
    assert peak_blocked < peak_exact // 8

    # Same source, same edges (uncompressed region is bitwise; this
    # scale compresses, so bound the rank error instead).
    sample = np.asarray(src[:4096])
    for f in range(0, n_features, 8):
        for j in range(0, 63, 16):
            lo, hi = sorted((exact[f, j], blocked[f, j]))
            frac = np.mean((sample[:, f] > lo) & (sample[:, f] <= hi))
            assert frac < 0.02


def test_streamed_train_memmap_peak_memory_and_determinism(tmp_path):
    """Acceptance: `train_prf(sample_block > 0)` on an np.memmap fits bin
    edges without materializing the raw source (host allocations stay
    far under the raw size; pre-fix, np.quantile copied all of it), and
    the model is bit-identical across reruns."""
    import tracemalloc

    n_rows, n_features = 250_000, 32
    src = _fill_memmap(tmp_path / "train.f32", n_rows, n_features, seed=1)
    raw_bytes = n_rows * n_features * 4
    y = _rng(2).integers(0, 3, n_rows).astype(np.int32)
    cfg = ForestConfig(n_trees=4, max_depth=2, n_bins=32, n_classes=3,
                       sample_block=50_000, feature_mode="all",
                       weighted_voting=False)
    assert cfg.resolved_bin_fit() == "blocked"

    tracemalloc.start()
    model = train_prf(src, y, cfg, seed=0)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < raw_bytes // 2, (
        f"streamed training allocated {peak/1e6:.1f}MB host-side against "
        f"a {raw_bytes/1e6:.0f}MB memmap — the raw source leaked into a "
        f"full-pass allocation")

    rerun = train_prf(src, y, cfg, seed=0)
    np.testing.assert_array_equal(model.bin_edges, rerun.bin_edges)
    for name in ("feature", "threshold", "left_child", "class_counts",
                 "value", "tree_weight"):
        np.testing.assert_array_equal(
            np.asarray(getattr(model.forest, name)),
            np.asarray(getattr(rerun.forest, name)), err_msg=name)


# ---------------------------------------------------------------------------
# Mesh plane: per-shard sketches merged over the collective gather
# ---------------------------------------------------------------------------


def test_fit_bins_sharded_matches_blocked_and_exact():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        from repro.core.binning import fit_bins, fit_bins_blocked
        from repro.core.distributed import fit_bins_sharded
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(2)
        x = rng.standard_normal((1000, 6)).astype(np.float32)
        mesh = make_mesh((4, 2), ("data", "model"))
        blocks = [x[i:i + 170] for i in range(0, 1000, 170)]

        e_sh = fit_bins_sharded(x, 32, mesh, sample_block=170)
        assert np.array_equal(e_sh, fit_bins_blocked(blocks, 32))
        assert np.array_equal(e_sh, fit_bins(x, 32))

        # Fewer blocks than data shards: the empty shard merges as a no-op.
        e1 = fit_bins_sharded(x, 16, mesh, sample_block=400)
        b1 = fit_bins_blocked([x[i:i + 400] for i in range(0, 1000, 400)], 16)
        assert np.array_equal(e1, b1)

        # Validator masks thread through, dict-keyed by global block index.
        m = {0: rng.random((170, 6)) < 0.05}
        e2 = fit_bins_sharded(x, 16, mesh, sample_block=170, exclude_masks=m)
        b2 = fit_bins_blocked(blocks, 16, exclude_masks=m)
        assert np.array_equal(e2, b2)

        # Samples sharded over BOTH mesh axes still merge in shard order.
        e3 = fit_bins_sharded(x, 16, mesh, sample_block=100,
                              sample_axes=("data", "model"))
        b3 = fit_bins_blocked([x[i:i + 100] for i in range(0, 1000, 100)], 16)
        assert np.array_equal(e3, b3)
        print("SHARDED_BINNING_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_BINNING_OK" in out.stdout
