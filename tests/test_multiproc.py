"""Multi-process training plane drills (launch.multiproc).

Every drill runs 2 coordinator-connected processes x 2 CPU devices each
in subprocesses (jax.distributed must own the process from its first jax
import, so none of this can run in the pytest process), and compares
against single-process references:

* bitwise forest/edge parity: 2x2 multi-process == 1-process runtime
  mesh == LocalPlane ``train_prf``, clean and dirty (sanitize /
  quarantine), and with sibling-subtraction ``hist_reuse="on"``;
* kill-and-resume through the multi-process checkpoint protocol lands
  bit-identical to an uninterrupted run;
* resuming across a *changed* process count raises
  ``CheckpointTopologyError`` in both directions (2->1 and 1->2);
* per-process host memory for the streamed fit+growth stays bounded by
  the local shard (tracemalloc peak < raw_bytes / (2 * n_data_shards)).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

WORKER = textwrap.dedent("""
    import json, os, sys, traceback

    SRC = sys.argv[1]
    role = sys.argv[2]            # single | mesh1 | mp
    pid = int(sys.argv[3])
    nproc = int(sys.argv[4])
    port = int(sys.argv[5])
    scenario = sys.argv[6]
    workdir = sys.argv[7]

    sys.path.insert(0, SRC)
    os.environ["JAX_PLATFORMS"] = "cpu"
    if role == "single":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    elif role == "mesh1":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    else:
        os.environ["XLA_FLAGS"] = ""
        from repro.launch import multiproc
        multiproc.initialize(
            f"127.0.0.1:{port}", nproc, pid, local_device_count=2
        )

    import hashlib
    import numpy as np
    from repro.core.types import ForestConfig

    def model_hash(model):
        import jax
        h = hashlib.sha256()
        for leaf in jax.tree_util.tree_leaves(model.forest):
            h.update(np.asarray(leaf).tobytes())
        h.update(np.asarray(model.bin_edges).tobytes())
        return h.hexdigest()

    def make_data(n, f, dirty=False, nb=100):
        rng = np.random.default_rng(7)
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = ((x[:, 0] + 0.5 * x[:, 1] > 0).astype(np.int32)
             + (x[:, 2] > 0.5).astype(np.int32))
        if dirty:
            x[nb + 3, 2] = np.nan          # block 1: non-finite cells
            x[nb + 7, 5] = np.inf
            y[2 * nb + 1] = 99             # block 2: out-of-range label
        return x, y

    def base_cfg(**over):
        kw = dict(n_trees=5, max_depth=4, n_bins=8, n_classes=3,
                  feature_mode="importance", weighted_voting=True,
                  sample_block=100)
        kw.update(over)
        return ForestConfig(**kw)

    out = {}
    try:
        kw = {}
        if scenario == "clean":
            x, y = make_data(250, 13)
            cfg = base_cfg()
        elif scenario == "reuse":
            x, y = make_data(250, 13)
            cfg = base_cfg(hist_reuse="on")
        elif scenario in ("sanitize", "quarantine"):
            x, y = make_data(250, 13, dirty=True)
            cfg = base_cfg()
            kw = {"bad_block_policy": scenario}
        elif scenario in ("ckpt_crash", "ckpt_resume", "topo"):
            x, y = make_data(250, 13)
            cfg = base_cfg()
            d = os.path.join(workdir, "ckpt")
            if scenario == "ckpt_crash":
                def boom(level, _):
                    if level >= 2:
                        raise RuntimeError("simulated crash")
                kw = {"checkpoint_dir": d, "checkpoint_every": 1,
                      "on_level": boom}
            else:
                kw = {"resume_from": d}
        elif scenario == "mem":
            n, f = 160000, 128
            x = np.memmap(os.path.join(workdir, "mem.f64"),
                          dtype=np.float64, mode="r", shape=(n, f))
            y = np.load(os.path.join(workdir, "mem.y.npy"))
            cfg = ForestConfig(n_trees=2, max_depth=3, n_bins=16,
                               n_classes=2, weighted_voting=False,
                               sample_block=10000)
            kw = {"bad_block_policy": None, "sketch_max_size": 64}

        if role == "single":
            from repro.core.api import train_prf
            model = train_prf(x, y, cfg, seed=3, **kw)
        else:
            from repro.core.distributed import train_prf_multiproc
            if scenario == "mem":
                import tracemalloc
                # First run warms the jit caches: tracing/compile
                # allocations are one-time and shape-dependent, not
                # data-plane memory. The traced second run measures
                # what the streamed fit+growth actually holds per
                # process at steady state.
                train_prf_multiproc(x, y, cfg, seed=3, **kw)
                import gc
                gc.collect()
                tracemalloc.start()
                model = train_prf_multiproc(x, y, cfg, seed=3, **kw)
                out["peak"] = int(tracemalloc.get_traced_memory()[1])
                out["raw"] = int(n) * int(f) * 8
            else:
                model = train_prf_multiproc(x, y, cfg, seed=3, **kw)
        out["hash"] = model_hash(model)
        if model.quarantine is not None:
            out["counters"] = {k: int(v)
                               for k, v in model.quarantine.counters().items()}
            out["quarantined"] = [int(i)
                                  for i in model.quarantine.quarantined]
    except BaseException as e:
        out["error"] = type(e).__name__
        out["message"] = str(e)[:500]
        out["trace"] = traceback.format_exc()[-2000:]
    print("RESULT " + json.dumps(out), flush=True)
""")

_PORT = [13801]


@pytest.fixture(scope="session")
def worker_path(tmp_path_factory):
    p = tmp_path_factory.mktemp("mp") / "worker.py"
    p.write_text(WORKER)
    return str(p)


def _parse(out, rc, who):
    for ln in reversed(out.splitlines()):
        if ln.startswith("RESULT "):
            return json.loads(ln[len("RESULT "):])
    raise AssertionError(f"{who} produced no RESULT (rc={rc}):\n{out[-3000:]}")


def _run(worker, role, scenario, workdir, nproc=2, timeout=600):
    """Launch one drill; returns a list of per-process RESULT dicts."""
    if role == "mp":
        _PORT[0] += 1
        port = _PORT[0]
        procs = [
            subprocess.Popen(
                [sys.executable, worker, SRC, "mp", str(i), str(nproc),
                 str(port), scenario, workdir],
                stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
            )
            for i in range(nproc)
        ]
        outs = [p.communicate(timeout=timeout)[0] for p in procs]
        return [
            _parse(out, p.returncode, f"mp proc {i}")
            for i, (p, out) in enumerate(zip(procs, outs))
        ]
    p = subprocess.run(
        [sys.executable, worker, SRC, role, "0", "1", "0", scenario, workdir],
        capture_output=True, text=True, timeout=timeout,
    )
    return [_parse(p.stdout + p.stderr, p.returncode, role)]


def _ok(r):
    assert "error" not in r, f"{r.get('error')}: {r.get('message')}\n{r.get('trace', '')}"
    return r


@pytest.fixture(scope="session")
def clean_single_hash(worker_path, tmp_path_factory):
    wd = str(tmp_path_factory.mktemp("clean_ref"))
    return _ok(_run(worker_path, "single", "clean", wd)[0])["hash"]


def test_multiproc_bitwise_parity_clean(worker_path, tmp_path, clean_single_hash):
    """2 procs x 2 devices == 1-process runtime mesh == LocalPlane."""
    mesh1 = _ok(_run(worker_path, "mesh1", "clean", str(tmp_path))[0])
    mps = [_ok(r) for r in _run(worker_path, "mp", "clean", str(tmp_path))]
    assert mesh1["hash"] == clean_single_hash
    assert [r["hash"] for r in mps] == [clean_single_hash] * 2


def test_multiproc_parity_hist_reuse(worker_path, tmp_path):
    """Sibling-subtraction reuse stays bitwise on the multi-process plane."""
    ref = _ok(_run(worker_path, "single", "reuse", str(tmp_path))[0])
    mps = [_ok(r) for r in _run(worker_path, "mp", "reuse", str(tmp_path))]
    assert [r["hash"] for r in mps] == [ref["hash"]] * 2


@pytest.mark.parametrize("policy", ["sanitize", "quarantine"])
def test_multiproc_parity_dirty(worker_path, tmp_path, policy):
    """The union-reduced validator reaches the single-host verdicts and
    the downstream model bitwise."""
    ref = _ok(_run(worker_path, "single", policy, str(tmp_path))[0])
    mps = [_ok(r) for r in _run(worker_path, "mp", policy, str(tmp_path))]
    for r in mps:
        assert r["hash"] == ref["hash"]
        assert r["counters"] == ref["counters"]
        assert r["quarantined"] == ref["quarantined"]


def test_multiproc_checkpoint_kill_and_resume(worker_path, tmp_path,
                                              clean_single_hash):
    """Both processes die after level 2; a fresh 2-process fleet resumes
    from the multi-process checkpoint and lands bit-identical."""
    crash = _run(worker_path, "mp", "ckpt_crash", str(tmp_path))
    for r in crash:
        assert r.get("error") == "RuntimeError", r
        assert "simulated crash" in r.get("message", "")
    steps = sorted(os.listdir(tmp_path / "ckpt"))
    assert any(s.startswith("step_") for s in steps), steps
    resumed = [_ok(r) for r in _run(worker_path, "mp", "ckpt_resume",
                                    str(tmp_path))]
    assert [r["hash"] for r in resumed] == [clean_single_hash] * 2


def test_multiproc_checkpoint_topology_change(worker_path, tmp_path_factory):
    """Resume across a changed process count is a typed refusal — never a
    silently wrong forest — in both directions."""
    # 2-process checkpoint -> 1-process resume
    wd2 = str(tmp_path_factory.mktemp("topo2to1"))
    crash = _run(worker_path, "mp", "ckpt_crash", wd2)
    assert all(r.get("error") == "RuntimeError" for r in crash), crash
    r = _run(worker_path, "mesh1", "topo", wd2)[0]
    assert r.get("error") == "CheckpointTopologyError", r

    # 1-process checkpoint -> 2-process resume
    wd1 = str(tmp_path_factory.mktemp("topo1to2"))
    crash = _run(worker_path, "mesh1", "ckpt_crash", wd1)
    assert crash[0].get("error") == "RuntimeError", crash
    rs = _run(worker_path, "mp", "topo", wd1)
    assert all(r.get("error") == "CheckpointTopologyError" for r in rs), rs


def test_multiproc_memory_bounded_by_local_shard(worker_path,
                                                 tmp_path_factory):
    """Streamed fit+growth peak host memory per process stays under
    raw_bytes / (2 * n_data_shards) on a memmap source — each process
    only ever materializes its own slice."""
    wd = tmp_path_factory.mktemp("mem")
    n, f = 160000, 128
    rng = np.random.default_rng(11)
    mm = np.memmap(wd / "mem.f64", dtype=np.float64, mode="w+", shape=(n, f))
    for o in range(0, n, 10000):
        mm[o:o + 10000] = rng.normal(size=(10000, f))
    mm.flush()
    del mm
    np.save(wd / "mem.y.npy",
            rng.integers(0, 2, size=n).astype(np.int32))
    results = [_ok(r) for r in _run(worker_path, "mp", "mem", str(wd))]
    assert len({r["hash"] for r in results}) == 1
    bound = results[0]["raw"] / (2 * 4)            # D = 4 data shards
    for i, r in enumerate(results):
        assert r["peak"] < bound, (
            f"proc {i} peak {r['peak'] / 2**20:.1f} MiB >= bound "
            f"{bound / 2**20:.1f} MiB"
        )
