"""Sibling-subtraction histogram reuse (``ForestConfig.hist_reuse``).

The acceptance bar for the reuse plane: classification forests grown
with ``hist_reuse="on"`` are BIT-IDENTICAL to ``"off"`` across
{local, mesh} x {resident, streamed} x {early-exit, fixed-depth} —
histogram counts are integer-valued f32, so ``parent - small_sibling``
is exact — including checkpoint kill/resume on both data planes. The
regression channels ([1, y, y^2]) only agree to float rounding, so
regression reuse is tolerance-gated and opt-in (``auto`` resolves to
off). A jaxpr walk proves the perf claim structurally: the reuse path
never scatters into the full ``S``-slot segment space, only the
``R = S/2`` small-child ranks. Mesh cases run in a subprocess so the
multi-device XLA flag never leaks.
"""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig, grow_forest_streamed
from repro.core.binning import bin_dataset
from repro.core.dsi import bootstrap_counts
from repro.core.engine import (
    LocalPlane, init_hist_cache, level_task_group, resolve_hist_reuse,
    reuse_level_task_group,
)
from repro.core.forest import grow_forest, grow_forest_checkpointed
from repro.core.histograms import class_channels, level_histograms
from repro.data.tabular import make_classification, make_regression

FOREST_ARRAYS = ("feature", "threshold", "left_child", "class_counts", "value")


def _assert_forests_equal(a, b, msg=""):
    for n in FOREST_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, n)), np.asarray(getattr(b, n)),
            err_msg=f"{n} {msg}",
        )


@pytest.fixture(scope="module")
def reuse_case():
    x, y = make_classification(n_samples=600, n_features=13, n_classes=3, seed=3)
    cfg = ForestConfig(
        n_trees=6, max_depth=4, n_bins=16, n_classes=3, feature_mode="all"
    )
    xb, _ = bin_dataset(x, cfg.n_bins)
    w = np.asarray(
        bootstrap_counts(jax.random.PRNGKey(0), cfg.n_trees, xb.shape[0])
    ).astype(np.float32)
    return xb, y, w, cfg


def _grow(xb, y, w, cfg):
    return grow_forest(jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w), cfg)


# ---------------------------------------------------------------------------
# Knob resolution & budget fallback
# ---------------------------------------------------------------------------


def test_knob_resolution_auto_is_classification_only():
    cls = ForestConfig(n_trees=2, max_depth=3, n_bins=8, n_classes=3)
    reg = dataclasses.replace(cls, regression=True, n_classes=0)
    assert cls.resolved_hist_reuse() == "on"
    assert reg.resolved_hist_reuse() == "off"
    assert dataclasses.replace(reg, hist_reuse="on").resolved_hist_reuse() == "on"
    assert dataclasses.replace(cls, hist_reuse="off").resolved_hist_reuse() == "off"
    with pytest.raises(ValueError, match="hist_reuse"):
        dataclasses.replace(cls, hist_reuse="maybe")


def test_budget_gate_falls_back_to_off(reuse_case):
    xb, y, w, cfg = reuse_case
    F = xb.shape[1]
    assert resolve_hist_reuse(cfg, F)
    tiny = dataclasses.replace(cfg, hist_reuse_budget_mb=0)
    assert not resolve_hist_reuse(tiny, F)
    # The fallback must be a silent-but-correct off run, not an error.
    _assert_forests_equal(
        _grow(xb, y, w, tiny),
        _grow(xb, y, w, dataclasses.replace(cfg, hist_reuse="off")),
        "budget fallback",
    )


# ---------------------------------------------------------------------------
# Local plane parity: resident (unfused + fused) and streamed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("early", [True, False])
@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_resident_reuse_bitwise(reuse_case, early, backend):
    xb, y, w, cfg = reuse_case
    base = dataclasses.replace(cfg, early_exit=early, hist_backend=backend)
    f_on = _grow(xb, y, w, dataclasses.replace(base, hist_reuse="on"))
    f_off = _grow(xb, y, w, dataclasses.replace(base, hist_reuse="off"))
    _assert_forests_equal(f_on, f_off, f"resident early={early} {backend}")


def test_streamed_reuse_bitwise(reuse_case):
    xb, y, w, cfg = reuse_case
    blocks = np.array_split(xb, 5)
    f_on = grow_forest_streamed(
        blocks, y, w, dataclasses.replace(cfg, hist_reuse="on")
    )
    f_off = grow_forest_streamed(
        blocks, y, w, dataclasses.replace(cfg, hist_reuse="off")
    )
    _assert_forests_equal(f_on, f_off, "streamed on-vs-off")
    _assert_forests_equal(f_on, _grow(xb, y, w, cfg), "streamed-vs-resident")


def test_checkpoint_resume_bitwise_with_reuse(reuse_case, tmp_path):
    """Kill at a level boundary, resume: the cache is a GrowthState leaf
    so the resumed run re-subtracts from the restored histograms and
    finishes bit-identical — on both local data planes."""
    from repro.checkpoint.checkpoint import CheckpointManager

    xb, y, w, cfg = reuse_case
    cfg_on = dataclasses.replace(cfg, hist_reuse="on")
    ref = _grow(xb, y, w, cfg_on)

    class Kill(Exception):
        pass

    def boom(level, _):
        if level == 2:
            raise Kill

    d = str(tmp_path / "resident")
    with pytest.raises(Kill):
        grow_forest_checkpointed(
            jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w), cfg_on,
            manager=CheckpointManager(d, keep=3, save_interval=1),
            on_level=boom,
        )
    f = grow_forest_checkpointed(
        jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w), cfg_on, resume_from=d
    )
    _assert_forests_equal(f, ref, "resident resume")

    cfg_st = dataclasses.replace(cfg_on, sample_block=150)
    d = str(tmp_path / "streamed")
    with pytest.raises(Kill):
        grow_forest_streamed(
            xb, y, w, cfg_st,
            manager=CheckpointManager(d, keep=3, save_interval=1),
            on_level=boom,
        )
    f = grow_forest_streamed(xb, y, w, cfg_st, resume_from=d)
    _assert_forests_equal(f, ref, "streamed resume")


# ---------------------------------------------------------------------------
# Regression: tolerance-gated, opt-in
# ---------------------------------------------------------------------------


def test_regression_reuse_within_tolerance():
    """[1, y, y^2] channels are not integer-valued, so parent - small
    only matches the direct sum to float rounding. Opt-in "on" must
    give the same tree STRUCTURE on a fixture without razor-thin gain
    ties, and leaf values within float tolerance."""
    x, y = make_regression(n_samples=500, n_features=10, seed=5)
    cfg = ForestConfig(
        n_trees=4, max_depth=4, n_bins=16, regression=True, n_classes=0,
        feature_mode="all",
    )
    xb, _ = bin_dataset(x, cfg.n_bins)
    w = np.asarray(
        bootstrap_counts(jax.random.PRNGKey(2), cfg.n_trees, xb.shape[0])
    ).astype(np.float32)
    f_on = _grow(xb, y, w, dataclasses.replace(cfg, hist_reuse="on"))
    f_off = _grow(xb, y, w, dataclasses.replace(cfg, hist_reuse="off"))
    for n in ("feature", "threshold", "left_child"):
        np.testing.assert_array_equal(
            np.asarray(getattr(f_on, n)), np.asarray(getattr(f_off, n)),
            err_msg=f"regression structure {n}",
        )
    np.testing.assert_allclose(
        np.asarray(f_on.value), np.asarray(f_off.value), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Root-histogram audit: dimred's root sweep == growth's level-0 row
# ---------------------------------------------------------------------------


def test_root_hist_bitwise_across_slot_geometries(reuse_case):
    """The audit behind PERF.md's "shared values, separate passes"
    verdict: the dimred root-gain histogram (n_slots=1) and growth's
    level-0 histogram row 0 (n_slots=S off-path, n_slots=R packed
    reuse path) are the same segment_sum over the same sample order —
    bitwise equal, all three geometries."""
    xb, y, w, cfg = reuse_case
    cfg = cfg.resolved(xb.shape[1])
    base = class_channels(jnp.asarray(y), cfg.n_classes)
    slot0 = jnp.zeros_like(jnp.asarray(w), dtype=jnp.int32)
    rows = {
        n_slots: np.asarray(level_histograms(
            jnp.asarray(xb), base, jnp.asarray(w), slot0,
            n_slots=n_slots, n_bins=cfg.n_bins, backend="segment_sum",
        )[:, 0])
        for n_slots in (1, cfg.max_splits_per_level, cfg.frontier)
    }
    ref = rows.pop(1)
    for n_slots, row in rows.items():
        np.testing.assert_array_equal(ref, row, err_msg=f"n_slots={n_slots}")


# ---------------------------------------------------------------------------
# Structural perf proof: large children are never re-scattered
# ---------------------------------------------------------------------------


def _scatter_dims(jaxpr):
    """All leading output dims in a jaxpr tree — segment_sum lowers to
    scatter/jit-call shapes whose first dim is the segment count."""
    import jax.extend.core as jex

    dims = set()

    def walk(j):
        for eqn in j.eqns:
            for v in eqn.outvars:
                shp = getattr(getattr(v, "aval", None), "shape", ())
                if shp:
                    dims.add(int(shp[0]))
            for val in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                    val, is_leaf=lambda x: isinstance(
                        x, (jex.Jaxpr, jex.ClosedJaxpr))
                ):
                    if isinstance(sub, jex.ClosedJaxpr):
                        walk(sub.jaxpr)
                    elif isinstance(sub, jex.Jaxpr):
                        walk(sub)

    walk(jaxpr)
    return dims


def test_reuse_never_scatters_full_slot_segments(reuse_case):
    """Off-path T_GR scatters into S*B + B segments (S slots + dump);
    the reuse task group must only ever scatter into R*B + B (small
    children + dump) — the large-child half is reconstructed by
    subtraction, never re-scattered. S=32 vs R=16 here, so the segment
    counts (528 vs 272) cannot collide with any other dimension."""
    xb, y, w, _ = reuse_case
    cfg = ForestConfig(
        n_trees=6, max_depth=5, n_bins=16, n_classes=3, feature_mode="all",
        hist_backend="segment_sum",
    ).resolved(xb.shape[1])
    S, R, B = cfg.frontier, cfg.max_splits_per_level, cfg.n_bins
    assert (S, R) == (32, 16)
    full_seg, packed_seg = S * B + B, R * B + B
    xb_d, base = jnp.asarray(xb), class_channels(jnp.asarray(y), cfg.n_classes)
    w_d = jnp.asarray(w)
    slot = jnp.zeros_like(w_d, dtype=jnp.int32)
    slot_node = jnp.full((cfg.n_trees, S), -1, jnp.int32).at[:, 0].set(0)
    plane = LocalPlane(None)

    off = jax.make_jaxpr(
        lambda *a: level_task_group(*a, cfg, plane)
    )(xb_d, base, w_d, slot, slot_node)
    cache = init_hist_cache(cfg, xb.shape[1])
    on = jax.make_jaxpr(
        lambda *a: reuse_level_task_group(*a, cfg, plane)
    )(xb_d, base, w_d, slot, slot_node, cache)

    off_dims, on_dims = _scatter_dims(off.jaxpr), _scatter_dims(on.jaxpr)
    assert full_seg in off_dims, "off path should scatter all S slots"
    assert full_seg not in on_dims, "reuse path re-scattered large children"
    assert packed_seg in on_dims, "reuse path should scatter R ranks"


# ---------------------------------------------------------------------------
# Mesh plane (subprocess: 8 host devices)
# ---------------------------------------------------------------------------


def test_mesh_reuse_parity_and_resume():
    """Mesh resident (psum + psum_scatter) and mesh streamed forests
    with reuse on == the local off-mode forest bitwise; a mesh-streamed
    run killed at a level boundary resumes bit-identically (the cache
    rides the checkpoint, feature-sharded)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, tempfile
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import ForestConfig
        from repro.core.binning import bin_dataset
        from repro.core.distributed import (
            _grow_sharded, _shard_map, grow_forest_streamed_sharded,
        )
        from repro.core.dsi import bootstrap_counts
        from repro.core.forest import grow_forest
        from repro.core.histograms import class_channels
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.data.tabular import make_classification
        from repro.launch.mesh import make_mesh

        x, y = make_classification(n_samples=640, n_features=16, n_classes=3,
                                   seed=2)
        cfg0 = ForestConfig(n_trees=6, max_depth=4, n_bins=16, n_classes=3,
                            feature_mode="all", hist_reuse="on")
        xb, _ = bin_dataset(x, cfg0.n_bins)
        y_np = np.asarray(y)
        xb_dev, y_dev = jnp.asarray(xb), jnp.asarray(y)
        w = bootstrap_counts(jax.random.PRNGKey(1), cfg0.n_trees,
                             xb.shape[0]).astype(jnp.float32)
        w_np = np.asarray(w)
        mesh = make_mesh((4, 2), ("data", "model"))
        ARRS = ("feature", "threshold", "left_child", "class_counts", "value")
        f_ref = grow_forest(xb_dev, y_dev, w,
                            dataclasses.replace(cfg0, hist_reuse="off"))

        def check(f, tag):
            for n in ARRS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(f, n)), np.asarray(getattr(f_ref, n)),
                    err_msg=f"{n} {tag}")

        for hist_reduce in ("psum", "psum_scatter"):
            cfg = dataclasses.replace(cfg0, hist_reduce=hist_reduce)
            def kernel(xb_loc, y_loc, w_loc, _cfg=cfg):
                base_loc = class_channels(y_loc, _cfg.n_classes)
                return _grow_sharded(xb_loc, base_loc, w_loc, None, _cfg,
                                     sample_axes=("data",),
                                     feature_axis="model")
            f_mesh = jax.jit(_shard_map(
                kernel, mesh=mesh,
                in_specs=(P("data", "model"), P("data"), P(None, "data")),
                out_specs=P(),
            ))(xb_dev, y_dev, w)
            check(f_mesh, f"resident {hist_reduce}")
            cfg_st = dataclasses.replace(cfg, sample_block=170)
            check(grow_forest_streamed_sharded(xb, y_np, w_np, cfg_st, mesh),
                  f"streamed {hist_reduce}")
        print("MESH_REUSE_PARITY_OK")

        cfg_st = dataclasses.replace(cfg0, sample_block=170)

        class Kill(Exception):
            pass

        def boom(level, _):
            if level == 2:
                raise Kill

        d = tempfile.mkdtemp()
        try:
            grow_forest_streamed_sharded(
                xb, y_np, w_np, cfg_st, mesh,
                manager=CheckpointManager(d, keep=3, save_interval=1),
                on_level=boom)
            raise AssertionError("kill did not fire")
        except Kill:
            pass
        check(grow_forest_streamed_sharded(xb, y_np, w_np, cfg_st, mesh,
                                           resume_from=d),
              "streamed resume")
        print("MESH_REUSE_RESUME_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH_REUSE_RESUME_OK" in out.stdout
