"""PRF serving layer: bucketing, micro-batch queue, sharded voting,
and the hardening layer.

* bucketed prediction returns exactly the direct-model answer at every
  batch size 1..33 (padding rows can never leak into real scores);
* the jit cache is bounded by the power-of-two bucket set;
* the async queue preserves submission order and auto-drains at
  ``max_batch`` aggregated rows;
* overload sheds with typed errors at admission, the circuit breaker
  opens/half-open-probes/closes, ``shutdown`` settles every future
  deterministically, and ``ModelRegistry`` hot-swaps versions without
  dropping an in-flight future (bulkheaded per-version services);
* the tree-sharded ``psum`` vote combine matches single-host prediction
  bit-for-bit on a CPU mesh (subprocess, so the multi-device XLA flag
  never leaks into other tests).
"""
import json
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core import ForestConfig, train_prf
from repro.data.tabular import make_classification, make_regression, train_test_split
from repro.serving import (
    CircuitBreaker, CircuitOpenError, DeadlineExceeded, ModelRegistry,
    PRFService, RateLimited, RateLimiter, ServiceClosedError, ServiceError,
    ServiceOverloaded, bucket_size,
)


@pytest.fixture(scope="module")
def served_model():
    x, y = make_classification(n_samples=900, n_features=12, n_classes=3, seed=8)
    xtr, ytr, xte, _ = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(
        n_trees=8, max_depth=4, n_bins=16, n_classes=3, feature_mode="all"
    )
    model = train_prf(xtr, ytr, cfg, seed=0)
    return model, xte


def test_bucket_size():
    assert [bucket_size(n) for n in (1, 7, 8, 9, 16, 17)] == [8, 8, 8, 16, 16, 32]
    assert bucket_size(5000, max_batch=1024) == 1024
    assert bucket_size(3, min_bucket=4) == 4
    with pytest.raises(ValueError):
        bucket_size(0)


def test_service_rejects_non_power_of_two_buckets(served_model):
    model, _ = served_model
    with pytest.raises(ValueError):
        PRFService(model, max_batch=100)
    with pytest.raises(ValueError):
        PRFService(model, min_bucket=6)


def test_bucketing_correct_at_every_batch_size(served_model):
    """Batch sizes 1..33 — every bucket boundary and both sides of it.
    Results must equal the unpadded direct prediction exactly: the
    padding mask never leaks into real rows' scores."""
    model, xte = served_model
    svc = PRFService(model, max_batch=32, min_bucket=8)
    for n in range(1, 34):
        got = svc.predict(xte[:n])
        want = model.predict(xte[:n])
        np.testing.assert_array_equal(got, want, err_msg=f"batch size {n}")
    # bounded recompilation: only power-of-two buckets were compiled
    stats = svc.stats()
    assert set(stats["buckets_compiled"]) <= {8, 16, 32}
    assert len(stats["buckets_compiled"]) <= stats["max_buckets"]


def test_bucketing_correct_regression():
    x, y = make_regression(600, 8, seed=6)
    xtr, ytr, xte, _ = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(
        n_trees=6, max_depth=4, n_bins=16, regression=True, feature_mode="all"
    )
    model = train_prf(xtr, ytr, cfg, seed=0)
    svc = PRFService(model, max_batch=64, min_bucket=8)
    for n in (1, 5, 9, 33):
        # float values: XLA fuses the reduce differently per batch shape,
        # so regression agrees to rounding (labels above are exact).
        np.testing.assert_allclose(
            svc.predict(xte[:n]), model.predict(xte[:n]), rtol=1e-6, atol=1e-6
        )


def test_single_sample_shape(served_model):
    model, xte = served_model
    svc = PRFService(model)
    got = svc.predict(xte[0])
    assert np.ndim(got) == 0
    assert got == model.predict(xte[:1])[0]


def test_queue_drain_preserves_request_order(served_model):
    model, xte = served_model
    svc = PRFService(model, max_batch=256)
    sizes = [3, 1, 7, 2, 5]
    futs, offsets = [], []
    off = 0
    for n in sizes:
        futs.append(svc.submit(xte[off : off + n]))
        offsets.append(off)
        off += n
    assert svc.pending == len(sizes)
    assert all(not f.done() for f in futs)
    with pytest.raises(RuntimeError):
        futs[0].result()
    assert svc.drain() == len(sizes)
    assert svc.pending == 0
    want = model.predict(xte[:off])
    for n, off0, fut in zip(sizes, offsets, futs):
        np.testing.assert_array_equal(fut.result(), want[off0 : off0 + n])


def test_queue_auto_drains_at_max_batch(served_model):
    model, xte = served_model
    svc = PRFService(model, max_batch=8, min_bucket=8)
    futs = [svc.submit(xte[i : i + 4]) for i in range(0, 12, 4)]
    # second submit reached max_batch=8 rows -> those two auto-drained;
    # the third is still queued until an explicit drain.
    assert futs[0].done() and futs[1].done() and not futs[2].done()
    assert svc.pending == 1
    assert svc.drain() == 1
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(
            f.result(), model.predict(xte[4 * i : 4 * i + 4])
        )


def test_drain_empty_queue_is_noop(served_model):
    model, _ = served_model
    assert PRFService(model).drain() == 0


def test_submit_rejects_malformed_requests(served_model):
    """Validation happens at submit time, so a bad request fails its own
    call instead of poisoning the aggregated micro-batch."""
    model, xte = served_model
    svc = PRFService(model)
    with pytest.raises(ValueError):
        svc.submit(np.empty((0, 12)))              # empty
    with pytest.raises(ValueError):
        svc.submit(np.zeros((2, 99)))              # wrong feature width
    with pytest.raises(ValueError):
        svc.predict(np.zeros((2, 3, 4)))           # wrong rank
    assert svc.pending == 0                        # nothing was enqueued


def test_failed_drain_keeps_requests_queued(served_model, monkeypatch):
    """A forward-pass failure must not silently drop queued futures —
    the snapshot is re-prepended and a later drain serves it."""
    model, xte = served_model
    svc = PRFService(model, max_batch=256)
    good = svc.submit(xte[:3])
    calls = {"n": 0}
    real_predict = PRFService.predict

    def flaky(self, x):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient device failure")
        return real_predict(self, x)

    monkeypatch.setattr(PRFService, "predict", flaky)
    with pytest.raises(RuntimeError):
        svc.drain()
    assert svc.pending == 1 and not good.done()    # nothing lost
    assert svc.drain() == 1                        # retry succeeds
    np.testing.assert_array_equal(good.result(), model.predict(xte[:3]))


# ---------------------------------------------------------------------------
# Hardening: admission control, circuit breaker, shutdown, hot-swap
# ---------------------------------------------------------------------------


def _flaky_bucketed(monkeypatch, fail_when):
    """Patch the forward pass INSIDE the breaker bracket: ``fail_when()``
    True -> the model 'fails'; otherwise the real pass runs."""
    real = PRFService._predict_bucketed

    def patched(self, xb):
        if fail_when():
            raise RuntimeError("injected model failure")
        return real(self, xb)

    monkeypatch.setattr(PRFService, "_predict_bucketed", patched)


def test_overload_sheds_with_typed_error(served_model):
    model, xte = served_model
    svc = PRFService(model, max_batch=64, max_queue_rows=10)
    fut = svc.submit(xte[:6])
    with pytest.raises(ServiceOverloaded):
        svc.submit(xte[:6])                 # 6 + 6 > 10 -> shed at admission
    with pytest.raises(ServiceError):       # typed: one except for all sheds
        svc.submit(xte[:5])
    assert svc.pending == 1                 # accepted request unaffected
    svc.submit(xte[6:10])                   # 6 + 4 == 10 still admitted
    svc.drain()
    np.testing.assert_array_equal(fut.result(), model.predict(xte[:6]))
    assert svc.stats()["requests_shed"] == 2


def test_circuit_breaker_opens_sheds_and_recovers(served_model, monkeypatch):
    """failure_threshold consecutive model failures open the circuit
    (predict/submit shed with CircuitOpenError, no forward pass); after
    reset_timeout a single half-open probe closes it again. The clock is
    injected, so no sleeping."""
    model, xte = served_model
    now = [0.0]
    br = CircuitBreaker(failure_threshold=2, reset_timeout=5.0,
                        clock=lambda: now[0])
    svc = PRFService(model, max_batch=64, breaker=br)
    broken = [True]
    _flaky_bucketed(monkeypatch, lambda: broken[0])

    for _ in range(2):
        with pytest.raises(RuntimeError, match="injected model failure"):
            svc.predict(xte[:4])
    assert br.state == "open"
    with pytest.raises(CircuitOpenError):
        svc.predict(xte[:4])
    with pytest.raises(CircuitOpenError):
        svc.submit(xte[:4])
    assert svc.stats()["requests_shed"] == 1

    now[0] = 6.0                            # past reset_timeout
    assert br.state == "half_open"
    broken[0] = False
    out = svc.predict(xte[:4])              # the probe — succeeds, closes
    assert br.state == "closed"
    np.testing.assert_array_equal(out, model.predict(xte[:4]))


def test_circuit_breaker_failed_probe_reopens(served_model, monkeypatch):
    model, xte = served_model
    now = [0.0]
    br = CircuitBreaker(failure_threshold=1, reset_timeout=5.0,
                        clock=lambda: now[0])
    svc = PRFService(model, max_batch=64, breaker=br)
    _flaky_bucketed(monkeypatch, lambda: True)
    with pytest.raises(RuntimeError):
        svc.predict(xte[:4])
    assert br.state == "open"
    now[0] = 6.0
    with pytest.raises(RuntimeError):
        svc.predict(xte[:4])                # the probe fails ...
    assert br.state == "open"               # ... and re-opens immediately
    now[0] = 7.0
    with pytest.raises(CircuitOpenError):
        svc.predict(xte[:4])                # new timeout window, shed again


def test_drain_keeps_queue_while_circuit_open(served_model, monkeypatch):
    """An open circuit fails drain WITHOUT losing the queued futures —
    after recovery the same futures are served."""
    model, xte = served_model
    svc = PRFService(
        model, max_batch=64,
        breaker=CircuitBreaker(failure_threshold=1, reset_timeout=0.0),
    )
    fut = svc.submit(xte[:3])
    broken = [True]
    _flaky_bucketed(monkeypatch, lambda: broken[0])
    with pytest.raises(RuntimeError):
        svc.drain()                         # model failure opens the circuit
    assert svc.pending == 1 and not fut.done()
    broken[0] = False
    assert svc.drain() == 1                 # reset_timeout=0: probe now
    np.testing.assert_array_equal(fut.result(), model.predict(xte[:3]))


def test_shutdown_drains_pending_futures(served_model):
    model, xte = served_model
    svc = PRFService(model, max_batch=64)
    fa, fb = svc.submit(xte[0]), svc.submit(xte[1:4])
    assert svc.shutdown(drain=True) == 2
    assert fa.done() and fb.done()
    assert fa.exception() is None and fb.exception() is None
    np.testing.assert_array_equal(fb.result(), model.predict(xte[1:4]))
    with pytest.raises(ServiceClosedError):
        svc.submit(xte[:2])                 # admission closed
    assert svc.shutdown() == 0              # idempotent
    # the direct path holds no queue state and stays usable
    np.testing.assert_array_equal(svc.predict(xte[:2]), model.predict(xte[:2]))


def test_shutdown_cancel_rejects_futures_deterministically(served_model):
    model, xte = served_model
    svc = PRFService(model, max_batch=64)
    fut = svc.submit(xte[:3])
    assert svc.shutdown(drain=False) == 1
    assert fut.done()
    assert isinstance(fut.exception(), ServiceClosedError)
    with pytest.raises(ServiceClosedError):
        fut.result()
    assert svc.stats()["requests_cancelled"] == 1


def test_registry_hot_swap_drops_zero_futures(served_model):
    """The atomic pointer flip: futures submitted before a publish are
    drained against the model they were submitted to; requests after it
    hit the new version. Nothing is ever left pending."""
    model, xte = served_model
    x, y = make_classification(n_samples=900, n_features=12, n_classes=3, seed=9)
    model2 = train_prf(
        x, y,
        ForestConfig(n_trees=8, max_depth=4, n_bins=16, n_classes=3,
                     feature_mode="all"),
        seed=1,
    )
    reg = ModelRegistry(max_batch=256)
    with pytest.raises(ServiceClosedError):
        reg.predict(xte[:2])                # nothing published yet
    assert reg.publish(model) == 1 and reg.version == 1
    futs = [reg.submit(xte[i : i + 2]) for i in range(0, 10, 2)]
    assert reg.publish(model2) == 2 and reg.version == 2
    assert all(f.done() and f.exception() is None for f in futs), \
        "hot swap dropped in-flight futures"
    for i, f in enumerate(futs):            # answered by the OLD model
        np.testing.assert_array_equal(
            f.result(), model.predict(xte[2 * i : 2 * i + 2])
        )
    f_new = reg.submit(xte[:2])
    reg.drain()
    np.testing.assert_array_equal(f_new.result(), model2.predict(xte[:2]))


def test_registry_hot_swap_with_concurrent_submitter(served_model):
    """A submitter racing the publish: every future it gets back is
    settled (served by old or new version), and sheds are typed."""
    model, xte = served_model
    reg = ModelRegistry(max_batch=256)
    reg.publish(model)
    futs, stop = [], threading.Event()

    def submitter():
        i = 0
        while not stop.is_set():
            try:
                futs.append(reg.submit(xte[i % 64 : i % 64 + 2]))
            except ServiceClosedError:
                pass                        # raced the flip — typed, retried
            i += 1

    t = threading.Thread(target=submitter)
    t.start()
    try:
        for _ in range(3):
            reg.publish(model)
    finally:
        stop.set()
        t.join(timeout=30)
    assert not t.is_alive()
    reg.drain()
    assert all(f.done() for f in futs), "swap left futures pending"
    assert all(f.exception() is None for f in futs)


def test_registry_versions_are_bulkheaded(served_model):
    """An open breaker on one version never touches another version —
    each publish gets its own service, queue, and breaker."""
    model, xte = served_model
    reg = ModelRegistry(max_batch=64)
    reg.publish(model)
    old_breaker = reg.service.breaker
    for _ in range(5):
        old_breaker.record_failure()
    assert old_breaker.state == "open"
    reg.publish(model)                      # new version, fresh bulkhead
    assert reg.service.breaker.state == "closed"
    np.testing.assert_array_equal(reg.predict(xte[:4]), model.predict(xte[:4]))
    assert old_breaker.state == "open"      # untouched
    stats = reg.stats()
    assert stats["version"] == 2 and stats["breaker_state"] == "closed"


# ---------------------------------------------------------------------------
# Degraded mode: deadlines, rate limiting, stale fallback, health
# ---------------------------------------------------------------------------


def test_rate_limiter_refill_and_per_client_isolation():
    now = [0.0]
    rl = RateLimiter(rate=1.0, burst=2, clock=lambda: now[0])
    assert rl.allow("a", n=2)                  # full burst
    assert not rl.allow("a", n=1)              # bucket empty
    assert rl.allow("b", n=2)                  # other client isolated
    now[0] = 1.5                               # refill 1.5 tokens at 1/s
    assert rl.allow("a", n=1)
    assert not rl.allow("a", n=1)              # only 0.5 left
    snap = rl.snapshot()
    assert snap["granted"] == 3 and snap["rejected"] == 2
    assert snap["clients"] == 2
    with pytest.raises(ValueError):
        RateLimiter(rate=0, burst=2)
    with pytest.raises(ValueError):
        RateLimiter(rate=1, burst=0.5)


def test_submit_deadline_rejects_stale_requests(served_model):
    """A request that outlives its deadline in the queue is settled with
    DeadlineExceeded THROUGH its future at drain — never dropped, never
    served stale. The clock is injected, so no sleeping."""
    model, xte = served_model
    now = [0.0]
    svc = PRFService(model, max_batch=256, clock=lambda: now[0])
    stale = svc.submit(xte[:3], deadline=5.0)
    fresh = svc.submit(xte[3:6])               # no deadline: never expires
    now[0] = 10.0
    assert svc.drain() == 2                    # settled = served + expired
    assert isinstance(stale.exception(), DeadlineExceeded)
    with pytest.raises(DeadlineExceeded):
        stale.result()
    np.testing.assert_array_equal(fresh.result(), model.predict(xte[3:6]))
    h = svc.health()
    assert h["deadline_exceeded"] == 1 and h["served"] == 1
    with pytest.raises(ValueError):
        svc.submit(xte[:2], deadline=0)
    with pytest.raises(ValueError):
        PRFService(model, default_deadline=-1)


def test_default_deadline_applies_to_every_submit(served_model):
    model, xte = served_model
    now = [0.0]
    svc = PRFService(
        model, max_batch=256, default_deadline=1.0, clock=lambda: now[0]
    )
    fut = svc.submit(xte[:2])
    now[0] = 0.5
    ok = svc.submit(xte[2:4])
    now[0] = 1.2                               # first expired, second not
    svc.drain()
    assert isinstance(fut.exception(), DeadlineExceeded)
    np.testing.assert_array_equal(ok.result(), model.predict(xte[2:4]))


def test_rate_limited_submit_is_typed_and_counted(served_model):
    model, xte = served_model
    now = [0.0]
    rl = RateLimiter(rate=1.0, burst=4, clock=lambda: now[0])
    svc = PRFService(model, max_batch=256, rate_limiter=rl,
                     clock=lambda: now[0])
    fut = svc.submit(xte[:4], client="tenant-a")   # drains the burst
    with pytest.raises(RateLimited):
        svc.submit(xte[:1], client="tenant-a")     # shed BEFORE the queue
    other = svc.submit(xte[4:6], client="tenant-b")
    assert svc.pending == 2                        # shed request never queued
    svc.drain()
    np.testing.assert_array_equal(fut.result(), model.predict(xte[:4]))
    assert other.exception() is None
    h = svc.health()
    assert h["rate_limited"] == 1
    assert h["rate_limiter"]["rejected"] == 1
    assert svc.stats()["requests_rate_limited"] == 1


def test_health_snapshot_shape(served_model):
    import dataclasses

    from repro.data.pipeline import QuarantineReport

    model, xte = served_model
    svc = PRFService(model, max_batch=64, max_queue_rows=100)
    svc.submit(xte[:3])
    h = svc.health()
    assert h["queue_requests"] == 1 and h["queue_rows"] == 3
    assert h["max_queue_rows"] == 100
    assert h["breaker"] == "closed" and not h["closed"]
    assert h["quarantined_blocks"] == 0
    assert "rate_limiter" not in h             # none configured
    svc.drain()
    assert svc.health()["queue_requests"] == 0
    # a quarantine-trained model surfaces its report's block count
    report = QuarantineReport(
        policy="quarantine", blocks_checked=4, quarantined=[2]
    )
    qmodel = dataclasses.replace(model, quarantine=report)
    assert PRFService(qmodel).health()["quarantined_blocks"] == 1


def test_registry_falls_back_to_newest_healthy_retired(served_model):
    """Live breaker open -> predict answers from the newest retired
    version whose own breaker is healthy: stale-but-correct beats an
    error while the live model recovers."""
    model, xte = served_model
    reg = ModelRegistry(max_batch=64)
    reg.publish(model)                         # v1 -> retires
    reg.publish(model)                         # v2 live
    for _ in range(5):
        reg.service.breaker.record_failure()
    assert reg.service.breaker.state == "open"
    got = reg.predict(xte[:6])                 # no error surfaces
    np.testing.assert_array_equal(got, model.predict(xte[:6]))
    h = reg.health()
    assert h["fallback_served"] == 1
    assert h["version"] == 2
    assert h["retired"] == {1: "closed"}
    assert h["live"]["breaker"] == "open"


def test_registry_fallback_skips_open_retired_versions(served_model):
    model, xte = served_model
    reg = ModelRegistry(max_batch=64)
    reg.publish(model)
    svc1 = reg.service
    reg.publish(model)
    svc2 = reg.service
    reg.publish(model)                         # v3 live; retired: v1, v2
    for _ in range(5):
        reg.service.breaker.record_failure()
    for _ in range(5):
        svc2.breaker.record_failure()          # newest retired also open
    got = reg.predict(xte[:4])                 # falls through v2 to v1
    np.testing.assert_array_equal(got, model.predict(xte[:4]))
    assert reg.health()["retired"] == {1: "closed", 2: "open"}
    assert reg.health()["fallback_served"] == 1
    for _ in range(5):
        svc1.breaker.record_failure()
    with pytest.raises(CircuitOpenError):
        reg.predict(xte[:4])                   # no healthy fallback left


def test_registry_shutdown_releases_retired_versions(served_model):
    model, xte = served_model
    reg = ModelRegistry(max_batch=64)
    reg.publish(model)
    reg.publish(model)
    fut = reg.submit(xte[:3])
    assert reg.health()["retired"] == {1: "closed"}
    assert reg.shutdown(drain=True) == 1       # the live future settles
    assert fut.exception() is None
    np.testing.assert_array_equal(fut.result(), model.predict(xte[:3]))
    assert reg.health()["retired"] == {}       # retired released too
    with pytest.raises(ServiceClosedError):
        reg.submit(xte[:2])


def test_sharded_vote_matches_single_host_bit_for_bit():
    """Tree-sharded partial votes + one psum == single-host prediction,
    classification and regression, on an 8-device host mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ForestConfig, train_prf
        from repro.core.binning import apply_bins
        from repro.core.voting import predict, predict_regression
        from repro.data.tabular import (
            make_classification, make_regression, train_test_split,
        )
        from repro.launch.mesh import make_mesh
        from repro.serving import make_sharded_vote_fn

        mesh = make_mesh((8,), ("data",))

        x, y = make_classification(n_samples=800, n_features=12, n_classes=3, seed=0)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
        cfg = ForestConfig(n_trees=16, max_depth=4, n_bins=16, n_classes=3,
                           feature_mode="all")
        m = train_prf(xtr, ytr, cfg, seed=0)
        xbte = apply_bins(jnp.asarray(xte), jnp.asarray(m.bin_edges))
        got = np.asarray(make_sharded_vote_fn(m.forest, mesh, tree_axis="data")(xbte))
        want = np.asarray(predict(m.forest, xbte))
        cls_equal = bool((got == want).all())

        x, y = make_regression(800, 10, seed=1)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
        cfg = ForestConfig(n_trees=16, max_depth=4, n_bins=16, regression=True,
                           feature_mode="all")
        m = train_prf(xtr, ytr, cfg, seed=0)
        xbte = apply_bins(jnp.asarray(xte), jnp.asarray(m.bin_edges))
        got = np.asarray(make_sharded_vote_fn(m.forest, mesh, tree_axis="data")(xbte))
        want = np.asarray(predict_regression(m.forest, xbte))
        reg_close = bool(np.allclose(got, want, rtol=1e-5, atol=1e-6))

        print(json.dumps({"cls_equal": cls_equal, "reg_close": reg_close}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["cls_equal"], "sharded classification labels differ from single-host"
    assert res["reg_close"], "sharded regression values differ from single-host"


# ---------------------------------------------------------------------------
# Cache-aside result cache
# ---------------------------------------------------------------------------


def test_cache_hit_bitwise_identical_and_counted(served_model):
    model, xte = served_model
    svc = PRFService(model, cache_size=4)
    b = np.asarray(xte[:16])
    first = svc.predict(b)
    again = svc.predict(b.copy())            # same bytes, different buffer
    np.testing.assert_array_equal(first, again)
    h = svc.health()
    assert (h["cache_hits"], h["cache_misses"], h["cache_entries"]) == (1, 1, 1)
    # Different shape / different rows are distinct keys.
    svc.predict(b[:8])
    svc.predict(np.asarray(xte[16:32]))
    assert svc.health()["cache_entries"] == 3
    assert svc.stats()["cache_misses"] == 3


def test_cache_lru_evicts_oldest_and_refreshes_on_hit(served_model):
    model, xte = served_model
    svc = PRFService(model, cache_size=2)
    a, b, c = (np.asarray(xte[i : i + 8]) for i in (0, 8, 16))
    svc.predict(a)
    svc.predict(b)
    svc.predict(a)                           # hit: refreshes a's recency
    svc.predict(c)                           # evicts b (LRU), not a
    h = svc.health()
    assert (h["cache_evictions"], h["cache_entries"]) == (1, 2)
    svc.predict(a)
    assert svc.health()["cache_hits"] == 2   # a survived the eviction


def test_cache_disabled_by_default(served_model):
    model, xte = served_model
    svc = PRFService(model)
    svc.predict(np.asarray(xte[:8]))
    svc.predict(np.asarray(xte[:8]))
    h = svc.health()
    assert (h["cache_size"], h["cache_hits"], h["cache_misses"]) == (0, 0, 0)
    with pytest.raises(ValueError):
        PRFService(model, cache_size=-1)


def test_cache_serves_hot_rows_while_circuit_open(served_model):
    """The cache check runs before the breaker: a cached batch keeps
    answering (bitwise) while the model is failing, an uncached one
    sheds with CircuitOpenError."""
    model, xte = served_model
    svc = PRFService(model, cache_size=4,
                     breaker=CircuitBreaker(failure_threshold=1))
    hot = np.asarray(xte[:16])
    want = svc.predict(hot)
    svc.breaker.record_failure()             # opens the circuit
    assert svc.breaker.state == "open"
    np.testing.assert_array_equal(svc.predict(hot), want)
    with pytest.raises(CircuitOpenError):
        svc.predict(np.asarray(xte[16:32]))


def test_cache_immune_to_caller_mutation(served_model):
    """Entries are private copies: mutating a returned (or input) array
    must not poison later hits."""
    model, xte = served_model
    svc = PRFService(model, cache_size=4)
    b = np.asarray(xte[:16])
    want = svc.predict(b).copy()
    svc.predict(b)[:] = -7                   # scribble on a hit's output
    b_bytes = b.tobytes()
    np.testing.assert_array_equal(svc.predict(b), want)
    assert b.tobytes() == b_bytes


def test_registry_hot_swap_invalidates_old_cache(served_model):
    model, xte = served_model
    reg = ModelRegistry(cache_size=4)
    reg.publish(model)
    old = reg.service
    reg.predict(np.asarray(xte[:16]))
    assert old.health()["cache_entries"] == 1
    reg.publish(model)
    assert old.health()["cache_entries"] == 0
    # The new version starts cold and fills its own (bulkheaded) cache.
    reg.predict(np.asarray(xte[:16]))
    h = reg.health()["live"]
    assert (h["cache_entries"], h["cache_hits"]) == (1, 0)
