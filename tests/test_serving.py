"""PRF serving layer: bucketing, micro-batch queue, sharded voting.

* bucketed prediction returns exactly the direct-model answer at every
  batch size 1..33 (padding rows can never leak into real scores);
* the jit cache is bounded by the power-of-two bucket set;
* the async queue preserves submission order and auto-drains at
  ``max_batch`` aggregated rows;
* the tree-sharded ``psum`` vote combine matches single-host prediction
  bit-for-bit on a CPU mesh (subprocess, so the multi-device XLA flag
  never leaks into other tests).
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import ForestConfig, train_prf
from repro.data.tabular import make_classification, make_regression, train_test_split
from repro.serving import PRFService, bucket_size


@pytest.fixture(scope="module")
def served_model():
    x, y = make_classification(n_samples=900, n_features=12, n_classes=3, seed=8)
    xtr, ytr, xte, _ = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(
        n_trees=8, max_depth=4, n_bins=16, n_classes=3, feature_mode="all"
    )
    model = train_prf(xtr, ytr, cfg, seed=0)
    return model, xte


def test_bucket_size():
    assert [bucket_size(n) for n in (1, 7, 8, 9, 16, 17)] == [8, 8, 8, 16, 16, 32]
    assert bucket_size(5000, max_batch=1024) == 1024
    assert bucket_size(3, min_bucket=4) == 4
    with pytest.raises(ValueError):
        bucket_size(0)


def test_service_rejects_non_power_of_two_buckets(served_model):
    model, _ = served_model
    with pytest.raises(ValueError):
        PRFService(model, max_batch=100)
    with pytest.raises(ValueError):
        PRFService(model, min_bucket=6)


def test_bucketing_correct_at_every_batch_size(served_model):
    """Batch sizes 1..33 — every bucket boundary and both sides of it.
    Results must equal the unpadded direct prediction exactly: the
    padding mask never leaks into real rows' scores."""
    model, xte = served_model
    svc = PRFService(model, max_batch=32, min_bucket=8)
    for n in range(1, 34):
        got = svc.predict(xte[:n])
        want = model.predict(xte[:n])
        np.testing.assert_array_equal(got, want, err_msg=f"batch size {n}")
    # bounded recompilation: only power-of-two buckets were compiled
    stats = svc.stats()
    assert set(stats["buckets_compiled"]) <= {8, 16, 32}
    assert len(stats["buckets_compiled"]) <= stats["max_buckets"]


def test_bucketing_correct_regression():
    x, y = make_regression(600, 8, seed=6)
    xtr, ytr, xte, _ = train_test_split(x, y, 0.25, 0)
    cfg = ForestConfig(
        n_trees=6, max_depth=4, n_bins=16, regression=True, feature_mode="all"
    )
    model = train_prf(xtr, ytr, cfg, seed=0)
    svc = PRFService(model, max_batch=64, min_bucket=8)
    for n in (1, 5, 9, 33):
        # float values: XLA fuses the reduce differently per batch shape,
        # so regression agrees to rounding (labels above are exact).
        np.testing.assert_allclose(
            svc.predict(xte[:n]), model.predict(xte[:n]), rtol=1e-6, atol=1e-6
        )


def test_single_sample_shape(served_model):
    model, xte = served_model
    svc = PRFService(model)
    got = svc.predict(xte[0])
    assert np.ndim(got) == 0
    assert got == model.predict(xte[:1])[0]


def test_queue_drain_preserves_request_order(served_model):
    model, xte = served_model
    svc = PRFService(model, max_batch=256)
    sizes = [3, 1, 7, 2, 5]
    futs, offsets = [], []
    off = 0
    for n in sizes:
        futs.append(svc.submit(xte[off : off + n]))
        offsets.append(off)
        off += n
    assert svc.pending == len(sizes)
    assert all(not f.done() for f in futs)
    with pytest.raises(RuntimeError):
        futs[0].result()
    assert svc.drain() == len(sizes)
    assert svc.pending == 0
    want = model.predict(xte[:off])
    for n, off0, fut in zip(sizes, offsets, futs):
        np.testing.assert_array_equal(fut.result(), want[off0 : off0 + n])


def test_queue_auto_drains_at_max_batch(served_model):
    model, xte = served_model
    svc = PRFService(model, max_batch=8, min_bucket=8)
    futs = [svc.submit(xte[i : i + 4]) for i in range(0, 12, 4)]
    # second submit reached max_batch=8 rows -> those two auto-drained;
    # the third is still queued until an explicit drain.
    assert futs[0].done() and futs[1].done() and not futs[2].done()
    assert svc.pending == 1
    assert svc.drain() == 1
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(
            f.result(), model.predict(xte[4 * i : 4 * i + 4])
        )


def test_drain_empty_queue_is_noop(served_model):
    model, _ = served_model
    assert PRFService(model).drain() == 0


def test_submit_rejects_malformed_requests(served_model):
    """Validation happens at submit time, so a bad request fails its own
    call instead of poisoning the aggregated micro-batch."""
    model, xte = served_model
    svc = PRFService(model)
    with pytest.raises(ValueError):
        svc.submit(np.empty((0, 12)))              # empty
    with pytest.raises(ValueError):
        svc.submit(np.zeros((2, 99)))              # wrong feature width
    with pytest.raises(ValueError):
        svc.predict(np.zeros((2, 3, 4)))           # wrong rank
    assert svc.pending == 0                        # nothing was enqueued


def test_failed_drain_keeps_requests_queued(served_model, monkeypatch):
    """A forward-pass failure must not silently drop queued futures —
    the snapshot is re-prepended and a later drain serves it."""
    model, xte = served_model
    svc = PRFService(model, max_batch=256)
    good = svc.submit(xte[:3])
    calls = {"n": 0}
    real_predict = PRFService.predict

    def flaky(self, x):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient device failure")
        return real_predict(self, x)

    monkeypatch.setattr(PRFService, "predict", flaky)
    with pytest.raises(RuntimeError):
        svc.drain()
    assert svc.pending == 1 and not good.done()    # nothing lost
    assert svc.drain() == 1                        # retry succeeds
    np.testing.assert_array_equal(good.result(), model.predict(xte[:3]))


def test_sharded_vote_matches_single_host_bit_for_bit():
    """Tree-sharded partial votes + one psum == single-host prediction,
    classification and regression, on an 8-device host mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import ForestConfig, train_prf
        from repro.core.binning import apply_bins
        from repro.core.voting import predict, predict_regression
        from repro.data.tabular import (
            make_classification, make_regression, train_test_split,
        )
        from repro.launch.mesh import make_mesh
        from repro.serving import make_sharded_vote_fn

        mesh = make_mesh((8,), ("data",))

        x, y = make_classification(n_samples=800, n_features=12, n_classes=3, seed=0)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
        cfg = ForestConfig(n_trees=16, max_depth=4, n_bins=16, n_classes=3,
                           feature_mode="all")
        m = train_prf(xtr, ytr, cfg, seed=0)
        xbte = apply_bins(jnp.asarray(xte), jnp.asarray(m.bin_edges))
        got = np.asarray(make_sharded_vote_fn(m.forest, mesh, tree_axis="data")(xbte))
        want = np.asarray(predict(m.forest, xbte))
        cls_equal = bool((got == want).all())

        x, y = make_regression(800, 10, seed=1)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
        cfg = ForestConfig(n_trees=16, max_depth=4, n_bins=16, regression=True,
                           feature_mode="all")
        m = train_prf(xtr, ytr, cfg, seed=0)
        xbte = apply_bins(jnp.asarray(xte), jnp.asarray(m.bin_edges))
        got = np.asarray(make_sharded_vote_fn(m.forest, mesh, tree_axis="data")(xbte))
        want = np.asarray(predict_regression(m.forest, xbte))
        reg_close = bool(np.allclose(got, want, rtol=1e-5, atol=1e-6))

        print(json.dumps({"cls_equal": cls_equal, "reg_close": reg_close}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["cls_equal"], "sharded classification labels differ from single-host"
    assert res["reg_close"], "sharded regression values differ from single-host"
