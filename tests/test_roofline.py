"""Roofline HLO analysis: loop-aware accounting validated on closed forms."""
import numpy as np
import pytest

from repro.roofline.analysis import (
    analyze_hlo_text, parse_module, roofline_terms, _shape_bytes,
)


def test_shape_bytes():
    assert _shape_bytes("f32", "4,4") == 64
    assert _shape_bytes("bf16", "128") == 256
    assert _shape_bytes("pred", "2,3") == 6
    assert _shape_bytes("s32", "") == 4


SYNTH = """
HloModule jit_f, entry_computation_layout={(f32[32,64]{1,0})->f32[32,64]{1,0}}

%body.1 (p: (s32[], f32[32,64])) -> (s32[], f32[32,64]) {
  %p = (s32[], f32[32,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[32,64]{1,0} get-tuple-element(%p), index=1
  %w = f32[64,64]{1,0} constant({...})
  %dot.5 = f32[32,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[32,64]{1,0} all-reduce(%dot.5), replica_groups={}, to_apply=%add.9
  %t = (s32[], f32[32,64]) tuple(%i, %ar)
  ROOT %r = (s32[], f32[32,64]) copy(%t)
}

%cond.2 (p2: (s32[], f32[32,64])) -> pred[] {
  %p2 = (s32[], f32[32,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

%add.9 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[32,64]) -> f32[32,64] {
  %arg = f32[32,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[32,64]) tuple(%zero, %arg)
  %while.1 = (s32[], f32[32,64]) while(%tup), condition=%cond.2, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[32,64]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_synthetic_module_loop_accounting():
    a = analyze_hlo_text(SYNTH)
    # dot: 2*32*64*64 flops, x5 trips
    assert a["flops"] == pytest.approx(2 * 32 * 64 * 64 * 5)
    ar = a["collectives"]["all-reduce"]
    assert ar["count"] == 5
    assert ar["operand_bytes"] == 32 * 64 * 4 * 5
    # ring model: all-reduce moves ~2x its operand on the wire
    assert a["collective_bytes"] == 2 * 32 * 64 * 4 * 5


def test_roofline_terms_dominance():
    analysis = {
        "flops": 197e12,           # exactly 1 s of compute
        "bytes_accessed": 819e9 / 2,   # 0.5 s memory
        "collective_bytes": 50e9 / 4,  # 0.25 s collective
    }
    t = roofline_terms(analysis, model_flops_per_device=197e12 / 2)
    assert t["dominant"] == "compute_s"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(0.5)
    assert t["useful_flops_ratio"] == pytest.approx(0.5)


def test_parse_module_structure():
    comps, entry, shapes = parse_module(SYNTH)
    assert entry == "main"
    assert ("while", "body.1", 5) in comps["main"].edges
    assert shapes["dot.5"][0] == 32 * 64 * 4


def test_real_compiled_module_flops_match_closed_form():
    """End-to-end: scanned matmul module — parser must recover trip-count
    x per-iteration dot flops exactly."""
    import jax, jax.numpy as jnp

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y

    x = jnp.zeros((16, 32))
    w = jnp.zeros((32, 32))
    comp = jax.jit(f).lower(x, w).compile()
    a = analyze_hlo_text(comp.as_text())
    assert a["flops"] == pytest.approx(2 * 16 * 32 * 32 * 9)
