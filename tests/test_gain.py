"""Unit tests: entropy / gain ratio / variable importance (paper Eq. 2-7)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gain import (
    best_splits, entropy_from_counts, multiway_gain_ratio,
    split_gain_ratios, variable_importance, variance_gains,
)


def _entropy(counts):
    n = sum(counts)
    return -sum(c / n * math.log(c / n) for c in counts if c > 0)


def test_entropy_matches_closed_form():
    cases = [[10, 10], [1, 99], [25, 25, 25, 25], [5, 0, 5]]
    for c in cases:
        got = float(entropy_from_counts(jnp.asarray(c, jnp.float32)))
        assert got == pytest.approx(_entropy(c), abs=1e-5)


def test_entropy_bounds():
    c = jnp.asarray([3.0, 7.0, 11.0, 2.0])
    h = float(entropy_from_counts(c))
    assert 0.0 <= h <= math.log(4) + 1e-6


def test_split_gain_ratio_perfect_split():
    """A feature that perfectly separates classes wins with max gain."""
    # hist[F=2, B=2, C=2]; feature 0: bin0 -> class0, bin1 -> class1
    hist = jnp.asarray([
        [[10.0, 0.0], [0.0, 10.0]],   # perfect
        [[5.0, 5.0], [5.0, 5.0]],     # useless
    ])
    gr = split_gain_ratios(hist)       # [F, B-1]
    assert float(gr[0, 0]) == pytest.approx(math.log(2) / math.log(2), rel=1e-4)
    assert float(gr[1, 0]) == pytest.approx(0.0, abs=1e-5)


def test_split_gain_invalid_empty_side():
    hist = jnp.asarray([[[10.0, 10.0], [0.0, 0.0]]])   # all mass in bin 0
    gr = split_gain_ratios(hist)
    assert np.isneginf(np.asarray(gr)[0, 0])


def test_best_splits_respects_feature_mask():
    hist = jnp.zeros((1, 1, 2, 2, 2))
    hist = hist.at[0, 0, 0].set(jnp.asarray([[10.0, 0.0], [0.0, 10.0]]))
    hist = hist.at[0, 0, 1].set(jnp.asarray([[8.0, 2.0], [2.0, 8.0]]))
    mask = jnp.asarray([[False, True]])   # best feature masked out
    s = best_splits(hist, mask)
    assert int(s.feature[0, 0]) == 1


def test_best_splits_child_counts_consistent():
    rng = np.random.default_rng(0)
    hist = jnp.asarray(rng.random((2, 3, 4, 8, 3)).astype(np.float32))
    s = best_splits(hist, None)
    total = hist.sum(axis=(-2,))          # [k, S, F, C]
    for t in range(2):
        for sl in range(3):
            f = int(s.feature[t, sl])
            np.testing.assert_allclose(
                np.asarray(s.left_counts + s.right_counts)[t, sl],
                np.asarray(total)[t, sl, f], rtol=1e-5,
            )


def test_variance_gains_matches_bruteforce():
    """Regression split gain (SSE reduction) vs a per-split numpy loop."""
    rng = np.random.default_rng(2)
    F, B = 3, 6
    cnt = rng.integers(1, 4, (F, B)).astype(np.float64)
    s = rng.standard_normal((F, B)) * cnt
    ss = np.abs(rng.standard_normal((F, B))) * cnt + s * s / cnt

    got = np.asarray(variance_gains(
        jnp.asarray(s, jnp.float32), jnp.asarray(ss, jnp.float32),
        jnp.asarray(cnt, jnp.float32),
    ))

    def sse(s_, ss_, c_):
        return ss_ - s_ * s_ / c_

    for f in range(F):
        tot = sse(s[f].sum(), ss[f].sum(), cnt[f].sum())
        for b in range(B - 1):
            l = (s[f, : b + 1].sum(), ss[f, : b + 1].sum(), cnt[f, : b + 1].sum())
            r = (s[f, b + 1 :].sum(), ss[f, b + 1 :].sum(), cnt[f, b + 1 :].sum())
            want = tot - sse(*l) - sse(*r)
            assert got[f, b] == pytest.approx(want, rel=1e-3, abs=1e-3)


def test_variance_gains_invalid_empty_side():
    cnt = np.zeros((1, 4), np.float32)
    cnt[0, 0] = 5.0                       # all mass in bin 0
    z = jnp.zeros((1, 4), jnp.float32)
    gains = variance_gains(z, z, jnp.asarray(cnt))
    assert np.all(np.isneginf(np.asarray(gains)))


def test_multiway_gain_ratio_informative_feature_wins():
    rng = np.random.default_rng(1)
    N, B, C = 2000, 8, 3
    y = rng.integers(0, C, N)
    informative = (y * 2 + rng.integers(0, 2, N)) % B
    noise = rng.integers(0, B, N)
    hist = np.zeros((2, B, C), np.float32)
    for f, col in enumerate([informative, noise]):
        np.add.at(hist[f], (col, y), 1.0)
    gr = multiway_gain_ratio(jnp.asarray(hist))
    assert float(gr[0]) > float(gr[1]) + 0.1


def test_variable_importance_normalizes():
    gr = jnp.asarray([[0.5, 0.3, 0.2], [1.0, 0.0, 1.0]])
    vi = variable_importance(gr)
    np.testing.assert_allclose(np.asarray(vi).sum(-1), [1.0, 1.0], rtol=1e-5)
    assert float(vi[0, 0]) == pytest.approx(0.5, rel=1e-5)
