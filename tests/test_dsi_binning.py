"""DSI table (paper §4.1.2) + quantile binning unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binning import apply_bins, bin_dataset, fit_bins
from repro.core.dsi import bootstrap_counts, dsi_counts, make_dsi, oob_mask


def test_dsi_counts_match_table():
    key = jax.random.PRNGKey(0)
    dsi = make_dsi(key, 4, 100)
    counts = dsi_counts(dsi, 100)
    assert counts.shape == (4, 100)
    # each row redistributes exactly N draws
    np.testing.assert_allclose(np.asarray(counts).sum(1), 100.0)
    # manual bincount agreement
    row = np.asarray(dsi[0])
    np.testing.assert_allclose(np.asarray(counts[0]), np.bincount(row, minlength=100))


def test_oob_fraction_near_1_over_e():
    counts = bootstrap_counts(jax.random.PRNGKey(1), 16, 4000)
    frac = float(oob_mask(counts).mean())
    assert 0.33 < frac < 0.40     # 1/e = 0.3679


def test_bootstrap_counts_fused_equals_two_step():
    key = jax.random.PRNGKey(2)
    c1 = bootstrap_counts(key, 3, 50)
    c2 = dsi_counts(make_dsi(key, 3, 50), 50)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_binning_monotone_and_bounded():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((500, 6)).astype(np.float32)
    xb, edges = bin_dataset(x, 16)
    assert xb.dtype == np.uint8
    assert xb.max() <= 15
    # order preservation per feature
    f = 2
    order = np.argsort(x[:, f])
    assert (np.diff(xb[order, f].astype(int)) >= 0).all()


def test_binning_handles_constant_feature():
    x = np.ones((100, 2), np.float32)
    x[:, 1] = np.arange(100)
    xb, edges = bin_dataset(x, 8)
    assert (xb[:, 0] == xb[0, 0]).all()


def test_apply_bins_quantile_balance():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4000, 1)).astype(np.float32)
    xb, _ = bin_dataset(x, 8)
    counts = np.bincount(xb[:, 0], minlength=8)
    assert counts.min() > 4000 / 8 * 0.7
