"""Per-arch smoke tests (reduced configs): forward/train step + shapes + no NaNs,
plus the teacher-forced decode == full-forward consistency check."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import build_model

from conftest import reduce_cfg

ARCHS = sorted(all_configs().keys())
RNG = np.random.default_rng(0)


def _batch(r, B, S):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, r.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(RNG.integers(0, r.vocab_size, (B, S)), jnp.int32),
    }
    if r.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            RNG.standard_normal((B, r.vision_tokens, r.d_model)), jnp.float32
        ) * 0.1
    if r.family == "encdec":
        batch["frames"] = jnp.asarray(
            RNG.standard_normal((B, r.encoder_frames, r.d_model)), jnp.float32
        ) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_and_decode(arch):
    r = reduce_cfg(all_configs()[arch])
    model = build_model(r)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(r, B, S)
    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss)), arch
    # gradient flows through every phase
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0, arch

    extras = {k: v for k, v in batch.items() if k in ("vision_embeds", "frames")}
    logits, cache = model.prefill(params, batch["tokens"], extras, s_max=S + 4)
    assert logits.shape == (B, r.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = model.decode_step(params, cache, tok, jnp.int32(S))
    assert logits2.shape == (B, r.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    """Teacher-forced incremental decode == one-shot forward (cache
    correctness incl. rolling windows, SSM states, meta tokens)."""
    r = reduce_cfg(all_configs()[arch])
    model = build_model(r)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 20   # > local_window so rolling buffers engage
    toks = jnp.asarray(RNG.integers(0, r.vocab_size, (B, S + 3)), jnp.int32)
    extras = {
        k: v for k, v in _batch(r, B, S).items()
        if k in ("vision_embeds", "frames")
    }
    lg_full, _ = model.prefill(params, toks, extras, s_max=S + 8)
    lg, cache = model.prefill(params, toks[:, :S], extras, s_max=S + 8)
    for i in range(3):
        lg, cache = model.decode_step(params, cache, toks[:, S + i], jnp.int32(S + i))
    err = np.max(np.abs(np.asarray(lg) - np.asarray(lg_full)))
    scale = np.max(np.abs(np.asarray(lg_full))) + 1e-9
    assert err / scale < 5e-4, (arch, err / scale)


def test_param_count_formulas_match_init():
    """configs.param_count (used for roofline MODEL_FLOPS) ~ actual init."""
    for arch in ARCHS:
        r = reduce_cfg(all_configs()[arch])
        model = build_model(r)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(
            int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes)
        )
        predicted = r.param_count()
        assert abs(actual - predicted) / actual < 0.15, (
            arch, actual, predicted
        )


def test_full_configs_param_counts_sane():
    """Full (non-reduced) configs land near their nameplate sizes."""
    expect = {
        "smollm-135m": (0.10e9, 0.20e9),
        "qwen1.5-4b": (3.0e9, 5.0e9),
        "gemma3-12b": (9e9, 14e9),
        "gemma3-27b": (21e9, 32e9),
        "deepseek-moe-16b": (13e9, 20e9),
        "deepseek-v3-671b": (560e9, 760e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "hymba-1.5b": (1.1e9, 2.0e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "llama-3.2-vision-90b": (75e9, 105e9),
    }
    for arch, (lo, hi) in expect.items():
        n = all_configs()[arch].param_count()
        assert lo < n < hi, (arch, n)
