"""Defense-in-depth drills (ISSUE 7 tentpole): checksummed checkpoints
and poisoned-block quarantine.

* **Checkpoint integrity** — every leaf carries a CRC32 in the
  manifest; a seeded byte-flipper (``CheckpointCorruptor``) must be
  caught before deserialization, ``restore_latest_valid`` must walk
  back past corrupt AND torn steps to the newest verifiable one, and a
  fully-corrupt directory must degrade to a fresh start — never a
  poisoned model.
* **Corrupted-resume parity** — killing growth, corrupting the newest
  checkpoint, and resuming must produce the bit-identical model on
  {local, mesh} x {resident, streamed} (mesh in a subprocess so the
  8-device XLA flag never leaks).
* **Poisoned blocks** — NaN/Inf cells and out-of-range labels under
  ``bad_block_policy``: ``"raise"`` names the block and columns,
  ``"sanitize"`` / ``"quarantine"`` are deterministic run-to-run, and
  on clean data validation is a bitwise no-op.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptionError, CheckpointManager, latest_step, list_steps,
    restore_checkpoint, restore_latest_valid, save_checkpoint,
    verify_checkpoint,
)
from repro.core import ForestConfig, train_prf
from repro.data.pipeline import (
    BlockFeeder, BlockValidator, DataIntegrityError, screen_blocks,
)
from repro.data.tabular import make_classification
from repro.launch.fault import CheckpointCorruptor, SimulatedFailure

FOREST_ARRAYS = (
    "feature", "threshold", "left_child", "class_counts", "value",
    "tree_weight",
)


def _assert_models_equal(a, b, msg=""):
    for n in FOREST_ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.forest, n)), np.asarray(getattr(b.forest, n)),
            err_msg=f"{n} {msg}",
        )


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(7, 5)).astype(np.float32)),
        "slots": [jnp.asarray(rng.integers(0, 99, size=(11,), dtype=np.int32))],
        "step": jnp.asarray(seed, np.int32),
    }


def _trees_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for la, lb in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# Checksummed checkpoints: CRC manifest, byte flips, walk-back
# ---------------------------------------------------------------------------


def test_manifest_carries_crc_and_roundtrips(tmp_path):
    import msgpack

    d = str(tmp_path)
    path = save_checkpoint(_tree(1), d, 1)
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    assert all(isinstance(e["crc32"], int) for e in manifest["leaves"])
    verify_checkpoint(d, 1)                    # every leaf passes its CRC
    restored, step = restore_checkpoint(_tree(0), d, 1)
    assert step == 1
    _trees_equal(restored, _tree(1))


def test_byte_flip_caught_before_deserialization(tmp_path):
    d = str(tmp_path)
    save_checkpoint(_tree(1), d, 1)
    assert CheckpointCorruptor(seed=0).corrupt(d) == 1
    with pytest.raises(CheckpointCorruptionError):
        verify_checkpoint(d, 1)
    with pytest.raises(CheckpointCorruptionError):
        restore_checkpoint(_tree(0), d, 1)
    # verify=False is the escape hatch that shows WHY verification is
    # load-bearing: without it the flip may deserialize silently.
    assert restore_latest_valid(_tree(0), d) is None


def test_corruptor_is_deterministic():
    import tempfile

    def run():
        d = tempfile.mkdtemp()
        save_checkpoint(_tree(3), d, 1)
        CheckpointCorruptor(seed=7, n_bytes=8).corrupt(d)
        path = os.path.join(d, "step_00000001")
        return {
            f: open(os.path.join(path, f), "rb").read()
            for f in sorted(os.listdir(path)) if f.endswith(".npy")
        }

    assert run() == run()                      # same bytes flipped both runs


def test_restore_latest_valid_walks_back_past_corruption(tmp_path):
    d = str(tmp_path)
    save_checkpoint(_tree(1), d, 1)
    save_checkpoint(_tree(2), d, 2)
    CheckpointCorruptor(seed=0).corrupt(d)     # newest = step 2
    skipped = []
    with pytest.warns(RuntimeWarning, match="skipping corrupt checkpoint"):
        restored, step = restore_latest_valid(
            _tree(0), d, on_skip=lambda s, e: skipped.append(s)
        )
    assert step == 1 and skipped == [2]
    _trees_equal(restored, _tree(1))           # exact step-1 values


def test_fully_corrupt_directory_degrades_to_fresh_start(tmp_path):
    d = str(tmp_path)
    for s in (1, 2):
        save_checkpoint(_tree(s), d, s)
        CheckpointCorruptor(seed=s).corrupt(d, s)
    with pytest.warns(RuntimeWarning):
        assert restore_latest_valid(_tree(0), d) is None
    mgr = CheckpointManager(d)
    with pytest.warns(RuntimeWarning), pytest.raises(FileNotFoundError):
        mgr.restore_latest_valid(_tree(0))


def test_manifest_without_crc_still_restores(tmp_path):
    """Backward compat: pre-integrity manifests (no crc32 key) skip the
    CRC check but keep shape/dtype verification."""
    import msgpack

    d = str(tmp_path)
    path = save_checkpoint(_tree(4), d, 1)
    mpath = os.path.join(path, "manifest.msgpack")
    with open(mpath, "rb") as f:
        manifest = msgpack.unpackb(f.read())
    for e in manifest["leaves"]:
        del e["crc32"]
    with open(mpath, "wb") as f:
        f.write(msgpack.packb(manifest))
    restored, step = restore_checkpoint(_tree(0), d, 1)
    _trees_equal(restored, _tree(4))


def test_latest_step_ignores_stray_and_malformed_entries(tmp_path):
    """Step discovery over a dirty directory: stray files, a file
    masquerading as a step dir, orphaned tmp dirs — none may crash or
    miscount ``latest_step``."""
    d = str(tmp_path)
    save_checkpoint(_tree(1), d, 1)
    save_checkpoint(_tree(2), d, 7)
    (tmp_path / "step_garbage").write_text("not a step")
    (tmp_path / "step_00000099").write_text("a FILE, not a step dir")
    (tmp_path / "README").write_text("stray")
    (tmp_path / ".tmp_save_dead").mkdir()
    assert list_steps(d) == [1, 7]
    assert latest_step(d) == 7
    assert latest_step(str(tmp_path / "missing")) is None
    # manager init garbage-collects the orphaned tmp dir
    CheckpointManager(d)
    assert not (tmp_path / ".tmp_save_dead").exists()
    assert (tmp_path / "step_garbage").exists()     # strangers untouched


def test_torn_write_never_clobbers_previous_step(tmp_path):
    """Kill a save in the torn-write window (after the complete tmp
    write, before the atomic rename): the previous step must stay the
    restorable latest, and the orphan tmp dir must be GC'd on the next
    manager init."""
    d = str(tmp_path)
    save_checkpoint(_tree(1), d, 1)

    def tear(site):
        if site == "pre_rename":
            raise SimulatedFailure("killed before rename")

    with pytest.raises(SimulatedFailure):
        save_checkpoint(_tree(2), d, 2, fault_hook=tear)
    assert latest_step(d) == 1                 # step 2 never materialized
    assert any(f.startswith(".tmp_save_") for f in os.listdir(d))
    restored, step = restore_latest_valid(_tree(0), d)
    assert step == 1
    _trees_equal(restored, _tree(1))
    CheckpointManager(d)                       # crash-retry supervisor
    assert not any(f.startswith(".tmp_save_") for f in os.listdir(d))

    # Tear mid-leaf too: nothing durable may change either.
    def tear_leaf(site):
        if site == "leaf[1]":
            raise SimulatedFailure("killed mid-leaf")

    mgr = CheckpointManager(d, save_interval=1, fault_hook=tear_leaf)
    with pytest.raises(SimulatedFailure):
        mgr.maybe_save(_tree(3), 3)
    assert latest_step(d) == 1


# ---------------------------------------------------------------------------
# Corrupted-resume parity drills
# ---------------------------------------------------------------------------


class _Kill(Exception):
    pass


@pytest.fixture(scope="module")
def drill_case():
    x, y = make_classification(n_samples=600, n_features=13, n_classes=3, seed=3)
    cfg = ForestConfig(
        n_trees=6, max_depth=4, n_bins=16, n_classes=3, feature_mode="all"
    )
    return x, y, cfg


@pytest.fixture(scope="module")
def drill_baseline(drill_case):
    x, y, cfg = drill_case
    return train_prf(x, y, cfg, seed=0)


@pytest.mark.parametrize("streamed", [False, True], ids=["resident", "streamed"])
def test_corrupted_resume_bit_identical_local(
    tmp_path, drill_case, drill_baseline, streamed
):
    """The corruption drill: kill growth at a level boundary, flip bytes
    in the NEWEST checkpoint, resume. The walk-back restores the
    previous step, regrows one extra level, and the final model is
    bit-identical to an uninterrupted run."""
    x, y, cfg = drill_case
    if streamed:
        cfg = dataclasses.replace(cfg, sample_block=170)
    kill_at = 2
    d = str(tmp_path / ("st" if streamed else "rs"))

    def boom(level, _):
        if level == kill_at:
            raise _Kill

    with pytest.raises(_Kill):
        train_prf(x, y, cfg, seed=0, checkpoint_dir=d, on_level=boom)
    assert CheckpointCorruptor(seed=0).corrupt(d) == kill_at

    resumed = []
    with pytest.warns(RuntimeWarning, match="skipping corrupt checkpoint"):
        m = train_prf(
            x, y, cfg, seed=0, resume_from=d,
            on_level=lambda level, _: resumed.append(level),
        )
    # Walk-back landed on step kill_at-1, so the crash level regrows.
    assert min(resumed) == kill_at, resumed
    _assert_models_equal(m, drill_baseline, f"corrupt-resume streamed={streamed}")
    np.testing.assert_array_equal(m.predict(x), drill_baseline.predict(x))


def test_all_corrupt_resume_is_fresh_start(tmp_path, drill_case, drill_baseline):
    """Every checkpoint corrupt -> resume degrades to a from-scratch
    retrain (ElasticRunner convention), still bit-identical."""
    x, y, cfg = drill_case
    kill_at = 2
    d = str(tmp_path / "allbad")

    def boom(level, _):
        if level == kill_at:
            raise _Kill

    with pytest.raises(_Kill):
        train_prf(x, y, cfg, seed=0, checkpoint_dir=d, on_level=boom)
    for s in list_steps(d):
        CheckpointCorruptor(seed=s).corrupt(d, s)
    with pytest.warns(RuntimeWarning):
        m = train_prf(x, y, cfg, seed=0, resume_from=d)
    _assert_models_equal(m, drill_baseline, "all-corrupt fresh start")


def test_corrupted_resume_bit_identical_mesh():
    code = textwrap.dedent("""
        import os, warnings
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from repro.core import ForestConfig
        from repro.core.binning import bin_dataset
        from repro.core.distributed import (
            grow_forest_streamed_sharded, grow_sharded_checkpointed,
        )
        from repro.core.dsi import bootstrap_counts
        from repro.core.forest import grow_forest
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.data.tabular import make_classification
        from repro.launch.fault import CheckpointCorruptor
        from repro.launch.mesh import make_mesh

        x, y = make_classification(n_samples=640, n_features=16, n_classes=3,
                                   seed=2)
        cfg = ForestConfig(n_trees=6, max_depth=4, n_bins=16, n_classes=3,
                           feature_mode="all").resolved(16)
        xb, _ = bin_dataset(x, cfg.n_bins)
        w = np.asarray(bootstrap_counts(jax.random.PRNGKey(1), cfg.n_trees,
                                        xb.shape[0])).astype(np.float32)
        y_np = np.asarray(y)
        mesh = make_mesh((4, 2), ("data", "model"))
        local = grow_forest(jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w), cfg)
        ARRS = ("feature", "threshold", "left_child", "class_counts", "value")

        class Kill(Exception):
            pass

        def drill(grow, tag):
            kill_at = 2
            d = tempfile.mkdtemp()

            def boom(level, _):
                if level == kill_at:
                    raise Kill

            try:
                grow(manager=CheckpointManager(d, keep=5, save_interval=1),
                     resume_from=None, on_level=boom)
                raise AssertionError("kill did not fire")
            except Kill:
                pass
            assert CheckpointCorruptor(seed=0).corrupt(d) == kill_at
            resumed = []
            with warnings.catch_warnings(record=True) as wrec:
                warnings.simplefilter("always")
                f = grow(manager=None, resume_from=d,
                         on_level=lambda level, _: resumed.append(level))
            assert any("skipping corrupt checkpoint" in str(x.message)
                       for x in wrec), (tag, "walk-back never fired")
            assert min(resumed) == kill_at, (tag, resumed)
            for n in ARRS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(f, n)),
                    np.asarray(getattr(local, n)),
                    err_msg=f"{n} {tag}")

        drill(lambda **kw: grow_sharded_checkpointed(
            xb, y_np, w, cfg, mesh, **kw), "mesh-resident")
        cfgs = ForestConfig(n_trees=6, max_depth=4, n_bins=16, n_classes=3,
                            feature_mode="all", sample_block=170).resolved(16)
        drill(lambda **kw: grow_forest_streamed_sharded(
            xb, y_np, w, cfgs, mesh, **kw), "mesh-streamed")
        print("MESH_CORRUPT_RESUME_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH_CORRUPT_RESUME_OK" in out.stdout


# ---------------------------------------------------------------------------
# Poisoned-block drills: raise / sanitize / quarantine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def poison_case(drill_case):
    """Rows 310-320 of column 3 go NaN, row 330 of column 7 goes Inf —
    all inside block 2 of the sample_block=150 sweep."""
    x, y, cfg = drill_case
    xp = np.array(x, dtype=np.float64)
    xp[310:320, 3] = np.nan
    xp[330, 7] = np.inf
    return xp, np.asarray(y), dataclasses.replace(cfg, sample_block=150)


def test_clean_data_validation_is_bitwise_noop(drill_case):
    x, y, cfg = drill_case
    base_r = train_prf(x, y, cfg, seed=0, bad_block_policy=None)
    assert base_r.quarantine is None
    for policy in ("raise", "sanitize", "quarantine"):
        m = train_prf(x, y, cfg, seed=0, bad_block_policy=policy)
        assert m.quarantine is not None and m.quarantine.clean
        _assert_models_equal(m, base_r, f"clean resident {policy}")
    cfgs = dataclasses.replace(cfg, sample_block=170)
    base_s = train_prf(x, y, cfgs, seed=0, bad_block_policy=None)
    for policy in ("raise", "sanitize", "quarantine"):
        m = train_prf(x, y, cfgs, seed=0, bad_block_policy=policy)
        assert m.quarantine.counters()["blocks_quarantined"] == 0
        _assert_models_equal(m, base_s, f"clean streamed {policy}")


def test_raise_policy_names_block_and_columns(poison_case):
    x, y, cfg = poison_case
    with pytest.raises(DataIntegrityError) as ei:
        train_prf(x, y, cfg, seed=0, bad_block_policy="raise")
    err = ei.value
    assert err.block_index == 2                # rows 300-449
    assert err.columns == (3, 7)
    assert err.reason == "nonfinite"
    assert "block 2" in str(err) and "[3, 7]" in str(err)


def test_raise_is_the_default_policy(poison_case):
    x, y, cfg = poison_case
    with pytest.raises(DataIntegrityError):
        train_prf(x, y, cfg, seed=0)


def test_sanitize_policy_is_deterministic(poison_case):
    x, y, cfg = poison_case
    a = train_prf(x, y, cfg, seed=0, bad_block_policy="sanitize")
    b = train_prf(x, y, cfg, seed=0, bad_block_policy="sanitize")
    _assert_models_equal(a, b, "sanitize run-to-run")
    assert a.quarantine.sanitized_cells == 11  # 10 NaN + 1 Inf
    assert a.quarantine.quarantined == []
    assert not a.quarantine.clean


def test_quarantine_policy_drops_block_deterministically(poison_case):
    x, y, cfg = poison_case
    a = train_prf(x, y, cfg, seed=0, bad_block_policy="quarantine")
    b = train_prf(x, y, cfg, seed=0, bad_block_policy="quarantine")
    _assert_models_equal(a, b, "quarantine run-to-run")
    assert a.quarantine.quarantined == [2]
    assert a.quarantine.counters()["blocks_quarantined"] == 1
    # the report survives a predict-backend swap
    assert a.with_predict_backend("xla").quarantine is a.quarantine


def test_poisoned_labels_sanitized_and_counted(drill_case):
    x, y, cfg = drill_case
    yb = np.array(y)
    yb[5:10] = 7                               # out of range for 3 classes
    cfgs = dataclasses.replace(cfg, sample_block=170)
    with pytest.raises(DataIntegrityError) as ei:
        train_prf(x, yb, cfgs, seed=0, bad_block_policy="raise")
    assert ei.value.reason == "label" and ei.value.block_index == 0
    a = train_prf(x, yb, cfgs, seed=0, bad_block_policy="sanitize")
    b = train_prf(x, yb, cfgs, seed=0, bad_block_policy="sanitize")
    _assert_models_equal(a, b, "label sanitize run-to-run")
    assert a.quarantine.sanitized_labels == 5


def test_resident_path_policies(poison_case):
    """The resident dataset is ONE block: raise still names columns,
    sanitize still trains deterministically, quarantine is a typed
    refusal pointing at streaming."""
    x, y, cfg = poison_case
    resident = dataclasses.replace(cfg, sample_block=0)
    with pytest.raises(DataIntegrityError) as ei:
        train_prf(x, y, resident, seed=0, bad_block_policy="raise")
    assert ei.value.columns == (3, 7)
    a = train_prf(x, y, resident, seed=0, bad_block_policy="sanitize")
    b = train_prf(x, y, resident, seed=0, bad_block_policy="sanitize")
    _assert_models_equal(a, b, "resident sanitize run-to-run")
    with pytest.raises(DataIntegrityError, match="sample_block"):
        train_prf(x, y, resident, seed=0, bad_block_policy="quarantine")


def test_validator_unit_findings():
    v = BlockValidator("quarantine", n_features=4, n_classes=3)
    clean = np.zeros((8, 4), np.float32)
    assert v.check(clean, 0, np.zeros(8, np.int32)) is None
    bad = clean.copy()
    bad[2, 1] = np.nan
    issue = v.check(bad, 5)
    assert issue.reason == "nonfinite" and issue.columns == (1,)
    assert "block 5" in issue.describe()
    assert v.check(np.zeros((8, 9), np.float32), 1).reason == "shape"
    issue = v.check(clean, 2, np.array([0, 1, 2, 3, -1, 0, 0, 0]))
    assert issue.reason == "label" and issue.bad_labels == 2
    with pytest.raises(ValueError, match="bad_block_policy"):
        BlockValidator("retry")


def test_screen_raise_does_not_mutate_inputs():
    blocks = [np.zeros((4, 3), np.float32), np.full((4, 3), np.nan)]
    y = np.zeros(8, np.int32)
    with pytest.raises(DataIntegrityError):
        screen_blocks(blocks, y, policy="raise", n_classes=3)
    assert np.isnan(blocks[1]).all()           # untouched on raise


def test_feeder_quarantines_shape_drift_and_skips_blocks():
    blocks = [
        np.zeros((16, 4), np.float32),
        np.zeros((16, 9), np.float32),         # drifted width
        np.full((16, 4), np.inf),              # poisoned
        np.zeros((16, 4), np.float32),
    ]
    feeder = BlockFeeder(
        blocks, prefetch=2, validator=BlockValidator("quarantine")
    )
    assert feeder.quarantined == (1, 2)
    assert feeder.live_blocks == (0, 3)
    with feeder:
        got = list(feeder.sweep())
    assert len(got) == 2                       # quarantined never transferred
    assert feeder.report.counters()["blocks_quarantined"] == 2


def test_feeder_refuses_fully_quarantined_feed():
    blocks = [np.full((8, 2), np.nan) for _ in range(2)]
    with pytest.raises(DataIntegrityError, match="every block quarantined"):
        BlockFeeder(blocks, validator=BlockValidator("quarantine"))
    with pytest.raises(ValueError, match="out of range"):
        BlockFeeder([np.zeros((8, 2), np.float32)], quarantined=[5])
    with pytest.raises(ValueError, match="join_timeout"):
        BlockFeeder([np.zeros((8, 2), np.float32)], join_timeout=0)
