"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference.

On this CPU container interpret-mode timings measure Python emulation,
NOT TPU performance — reported for completeness; correctness sweeps live
in tests/test_kernels.py.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gain_ratio.ref import histogram_ref
from repro.kernels.ssd_scan.ref import ssd_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []

    N, F, S, B, C = 2048, 128, 4, 16, 4
    xb = jnp.asarray(rng.integers(0, B, (N, F)).astype(np.int32))
    w = rng.random(N).astype(np.float32)
    y = rng.integers(0, C, N)
    wch = jnp.asarray(w[:, None] * np.eye(C, dtype=np.float32)[y])
    slot = jnp.asarray(rng.integers(0, S, N).astype(np.int32))
    f = jax.jit(lambda a, b, c: histogram_ref(a, b, c, n_slots=S, n_bins=B))
    rows.append({"bench": "kernel_gain_ratio_ref",
                 "us_per_call": _time(f, xb, wch, slot),
                 "derived": f"N={N},F={F}"})

    q = jnp.asarray(rng.standard_normal((8, 512, 64)).astype(np.float32))
    f = jax.jit(lambda a: attention_ref(a, a, a, causal=True))
    rows.append({"bench": "kernel_attention_ref", "us_per_call": _time(f, q),
                 "derived": "BH=8,L=512,D=64"})

    x = jnp.asarray(rng.standard_normal((4, 512, 64)).astype(np.float32))
    loga = jnp.asarray(-np.abs(rng.standard_normal((4, 512))).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((4, 512, 32)).astype(np.float32) * 0.3)
    f = jax.jit(lambda x_, l_, b_: ssd_ref(x_, l_, b_, b_)[0])
    rows.append({"bench": "kernel_ssd_ref", "us_per_call": _time(f, x, loga, b),
                 "derived": "BH=4,L=512,P=64,N=32"})
    return rows
