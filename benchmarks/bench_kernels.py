"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference.

On this CPU container interpret-mode timings measure Python emulation,
NOT TPU performance — reported for completeness; correctness sweeps live
in tests/test_kernels.py. The ``level_hist_*`` rows time the T_GR
backend on the histogram shapes training actually builds (multi-tree,
both backends, packed and unpacked); ``level_scores_*`` times the T_NS
split-scoring backends on the same shapes, ``hist_score_fused_*`` the
end-to-end T_GR->T_NS chunk (fused no-HBM-histogram path vs the
two-tensor xla path), ``predict_*`` the Eq. 9/10 weighted-voting
backends on a trained forest, and ``serve_throughput`` the bucketed
serving layer end to end — the series BENCH_kernels.json tracks across
PRs (see PERF.md).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import chunked_level_scores
from repro.core.gain import level_scores
from repro.core.histograms import level_histograms
from repro.core.types import ForestConfig
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gain_ratio.ref import histogram_ref
from repro.kernels.ssd_scan.ref import ssd_ref

# The training shape every suite row below uses: a mid-level of
# grow_forest — tc trees, S live frontier slots.
TC, N, F, S, B, C = 4, 2048, 32, 4, 16, 4
SHAPE = f"tc={TC},N={N},F={F},S={S},B={B},C={C}"


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _training_batch(rng):
    xb = jnp.asarray(rng.integers(0, B, (N, F)).astype(np.uint8))
    base = jnp.asarray(np.eye(C, dtype=np.float32)[rng.integers(0, C, N)])
    w = jnp.asarray(rng.integers(0, 4, (TC, N)).astype(np.float32))
    slot = jnp.asarray(rng.integers(-1, S, (TC, N)).astype(np.int32))
    return xb, base, w, slot


def run_level_hist():
    """Training-shaped T_GR benchmark: one level of a tree chunk."""
    rng = np.random.default_rng(0)
    rows = []
    xb, base, w, slot = _training_batch(rng)
    for backend in ("segment_sum", "pallas"):
        for packed in (False, True):
            fn = jax.jit(
                lambda a, b, c, d, _be=backend, _pk=packed: level_histograms(
                    a, b, c, d, n_slots=S, n_bins=B,
                    packed=_pk, backend=_be,
                )
            )
            name = f"level_hist_{backend}" + ("_packed" if packed else "")
            rows.append({
                "bench": name,
                "us_per_call": _time(fn, xb, base, w, slot),
                "derived": SHAPE,
                "backend": backend,
                "packed": packed,
            })
    return rows


def run_level_hist_reuse():
    """Sibling-subtraction T_GR at a deep-forest shape (S=512 frontier
    slots over 2048 samples — the thin-deep-level regime where the
    scatter's output zeroing dominates). ``level_hist_reuse_off`` is
    the full S-slot scatter; ``level_hist_reuse_on`` the packed
    R=S/2-rank scatter the reuse plane runs instead (its headline
    ``speedup_vs_off`` is the level-histogram-phase saving the
    acceptance bar tracks). ``with_expand_us`` adds the
    ``sibling_expand`` reconstruction (gather parent rows, subtract,
    concat) that reuse folds into the scoring-prep step — the honest
    end-of-phase cost of producing the same [k, S, F, B, C] tensor.
    """
    from repro.core.histograms import sibling_expand, sibling_segments

    TCd, Nd, Fd, Sd = 4, 2048, 32, 512
    Rd = Sd // 2
    shape = f"tc={TCd},N={Nd},F={Fd},S={Sd},B={B},C={C}"
    rng = np.random.default_rng(4)
    xb = jnp.asarray(rng.integers(0, B, (Nd, Fd)).astype(np.uint8))
    base = jnp.asarray(np.eye(C, dtype=np.float32)[rng.integers(0, C, Nd)])
    w = jnp.asarray(rng.integers(0, 4, (TCd, Nd)).astype(np.float32))
    slot = jnp.asarray(rng.integers(-1, Sd, (TCd, Nd)).astype(np.int32))
    small_right = jnp.asarray(rng.integers(0, 2, (TCd, Rd)).astype(np.int32))
    parent = jnp.asarray(rng.integers(0, Sd, (TCd, Rd)).astype(np.int32))
    cache_hist = jnp.asarray(
        rng.integers(0, 8, (TCd, Sd, Fd, B, C)).astype(np.float32))
    cache_perm = jnp.tile(jnp.arange(Sd, dtype=jnp.int32)[None], (TCd, 1))

    f_off = jax.jit(lambda a, b, c, d: level_histograms(
        a, b, c, d, n_slots=Sd, n_bins=B, backend="segment_sum"))

    def packed_only(a, b, c, d, sr):
        seg = sibling_segments(d, sr)
        return level_histograms(
            a, b, c, seg, n_slots=Rd, n_bins=B, backend="segment_sum")

    def packed_expand(a, b, c, d, sr, ch, cp, par):
        h = packed_only(a, b, c, d, sr)
        return sibling_expand(h, ch, cp, par, Sd)

    f_on = jax.jit(packed_only)
    f_exp = jax.jit(packed_expand)
    us_off = _time(f_off, xb, base, w, slot)
    us_on = _time(f_on, xb, base, w, slot, small_right)
    us_exp = _time(
        f_exp, xb, base, w, slot, small_right, cache_hist, cache_perm, parent)
    return [
        {"bench": "level_hist_reuse_off", "us_per_call": us_off,
         "derived": shape, "backend": "segment_sum"},
        {"bench": "level_hist_reuse_on", "us_per_call": us_on,
         "derived": f"{shape},R={Rd}", "backend": "segment_sum",
         "speedup_vs_off": us_off / max(us_on, 1e-9),
         "with_expand_us": us_exp,
         "with_expand_speedup": us_off / max(us_exp, 1e-9)},
    ]


def run_comm_reuse():
    """Mesh psum volume with sibling-subtraction reuse on vs off: lower
    the distributed trainer under each, parse per-device collective
    bytes from the post-SPMD HLO (deterministic — no timing). The
    per-level histogram combine is the dominant collective at this
    shape, so ``on`` must move about half of ``off``'s bytes — CI
    asserts the ratio."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax, jax.numpy as jnp
        from repro.core import ForestConfig
        from repro.core.distributed import make_prf_train_fn
        from repro.launch.mesh import make_mesh
        from repro.roofline.analysis import analyze_hlo_text

        N, F, C = 1 << 12, 128, 4
        cfg0 = ForestConfig(n_trees=8, max_depth=5, n_bins=16, n_classes=C,
                            max_frontier=32, tree_chunk=4)
        mesh = make_mesh((2, 4), ("data", "model"))
        out = {}
        for mode in ("off", "on"):
            cfg = dataclasses.replace(cfg0, hist_reuse=mode)
            fn, _ = make_prf_train_fn(cfg, mesh)
            comp = fn.lower(
                jax.ShapeDtypeStruct((N, F), jnp.uint8),
                jax.ShapeDtypeStruct((N,), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            ).compile()
            a = analyze_hlo_text(comp.as_text())
            out[mode] = a["collective_bytes"] / 2**20
        print("RESULT" + json.dumps(out))
    """)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=1800)
    if p.returncode != 0:
        return [{"bench": "comm_psum_reuse", "error": p.stderr[-500:],
                 "us_per_call": 0.0}]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT")][-1]
    mb = json.loads(line[len("RESULT"):])
    return [{
        "bench": "comm_psum_reuse",
        "us_per_call": 0.0,
        "derived": "N=4096,F=128,k=8,depth=5,S=32,mesh=2x4,psum",
        "collective_mb_off": mb["off"],
        "collective_mb_on": mb["on"],
        "on_over_off": mb["on"] / max(mb["off"], 1e-9),
    }]


def run_comm_multiproc():
    """``comm_multiproc``: cross-host collective volume of the
    multi-process training plane (deterministic HLO byte counts, no
    timing). Two coordinator-connected processes x 2 devices AOT-lower
    the two collectives every level pays on that plane — the data-axis
    histogram combine and the int64-limbed verdict/barrier psum
    (``MultiHostMesh.psum_hosts``) — and parse per-device bytes from the
    post-SPMD HLO."""
    code = textwrap.dedent("""
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"
        os.environ["XLA_FLAGS"] = ""
        pid = int(os.environ["PRF_PID"])
        nproc = int(os.environ["PRF_NPROC"])
        from repro.launch import multiproc
        multiproc.initialize("127.0.0.1:" + os.environ["PRF_PORT"],
                             nproc, pid, local_device_count=2)
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.distributed import _shard_map
        from repro.launch.multiproc import MultiHostMesh
        from repro.roofline.analysis import analyze_hlo_text

        rt = MultiHostMesh()
        K, S, F, B, C = 8, 32, 32, 16, 3
        D = rt.n_data_shards
        # The per-level histogram combine: [D, k, S, F, B, C] carries
        # sharded over the data axis, summed across hosts.
        hist = jax.ShapeDtypeStruct((D, K, S, F, B, C), jnp.float32)
        fn = jax.jit(_shard_map(
            lambda h: jax.lax.psum(h[0], "data"),
            mesh=rt.mesh,
            in_specs=(P("data", None, None, "model"),),
            out_specs=P(None, None, "model"),
        ))
        a_hist = analyze_hlo_text(fn.lower(hist).compile().as_text())
        # The limbed int64 union (validation verdicts, barriers):
        # [D, n, 3] int32 over the same axis.
        vec = jax.ShapeDtypeStruct((D, 1024, 3), jnp.int32)
        fn2 = jax.jit(_shard_map(
            lambda x: jax.lax.psum(x[0], "data"),
            mesh=rt.mesh, in_specs=(P("data",),), out_specs=P(),
        ))
        a_vec = analyze_hlo_text(fn2.lower(vec).compile().as_text())
        rt.barrier()
        if pid == 0:
            print("RESULT" + json.dumps({
                "hist_mb": a_hist["collective_bytes"] / 2**20,
                "hist_ops": {k: int(v["count"])
                             for k, v in a_hist["collectives"].items()},
                "verdict_kb": a_vec["collective_bytes"] / 2**10,
            }), flush=True)
    """)
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", code],
            env={**os.environ, "PRF_PID": str(i), "PRF_NPROC": "2",
                 "PRF_PORT": "12963"},
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )
        for i in range(2)
    ]
    outs = [p.communicate(timeout=1800)[0] for p in procs]
    if any(p.returncode != 0 for p in procs):
        return [{"bench": "comm_multiproc",
                 "error": (outs[0] + outs[1])[-500:], "us_per_call": 0.0}]
    line = [ln for ln in outs[0].splitlines() if ln.startswith("RESULT")][-1]
    r = json.loads(line[len("RESULT"):])
    return [{
        "bench": "comm_multiproc",
        "us_per_call": 0.0,
        "derived": "k=8,S=32,F=32,B=16,C=3,procs=2x2dev,psum",
        "hist_collective_mb_per_device": r["hist_mb"],
        "hist_collective_ops": r["hist_ops"],
        "verdict_collective_kb_per_device": r["verdict_kb"],
    }]


def run_level_scores():
    """T_NS split-scoring backends on a pre-built training-shaped
    histogram, plus the end-to-end T_GR->T_NS chunk: the fused
    hist-kernel -> score-kernel path (no HBM histogram) vs the
    two-tensor xla path."""
    rng = np.random.default_rng(1)
    rows = []
    hist = jnp.asarray(rng.integers(0, 4, (TC, S, F, B, C)).astype(np.float32))
    mask = jnp.ones((TC, F), jnp.bool_)
    for be in ("xla", "pallas"):
        fn = jax.jit(
            lambda h, m, _be=be: level_scores(h, m, backend=_be)
        )
        rows.append({
            "bench": f"level_scores_{be}",
            "us_per_call": _time(fn, hist, mask),
            "derived": SHAPE,
            "backend": be,
        })

    xb, base, w, slot = _training_batch(rng)
    cfg0 = ForestConfig(
        n_trees=TC, max_depth=2, n_bins=B, n_classes=C,
        max_frontier=S, feature_mode="all",
    )
    for be in ("xla", "pallas"):
        cfg = dataclasses.replace(cfg0, split_backend=be)
        fn = jax.jit(
            lambda a, b, c, d, _cfg=cfg: chunked_level_scores(
                a, b, c, d, None, _cfg
            )
        )
        rows.append({
            "bench": f"hist_score_fused_{be}",
            "us_per_call": _time(fn, xb, base, w, slot),
            "derived": SHAPE,
            "backend": be,
        })
    return rows


def run_predict():
    """Prediction backends + serving throughput on a trained forest.

    ``predict_xla`` routes the full [k, N, C] per-tree tensor through
    HBM before voting; ``predict_pallas`` is the fused traversal+voting
    kernel (interpret-mode emulation off-TPU). ``serve_throughput``
    times PRFService.predict — binning, bucketing, padding and the
    jit'd bucket forward pass — on a full bucket of raw rows.
    """
    from repro.core.api import train_prf
    from repro.core.binning import apply_bins
    from repro.core.voting import predict
    from repro.data.tabular import make_classification
    from repro.serving import PRFService

    rows = []
    k, depth = 16, 6
    x, y = make_classification(n_samples=N, n_features=F, n_classes=C, seed=3)
    cfg = ForestConfig(
        n_trees=k, max_depth=depth, n_bins=B, n_classes=C, feature_mode="all",
    )
    model = train_prf(x, y, cfg, seed=0)
    xb = apply_bins(jnp.asarray(x), jnp.asarray(model.bin_edges))
    shape = f"k={k},depth={depth},N={N},F={F},B={B},C={C}"
    for be in ("xla", "pallas"):
        fn = jax.jit(lambda a, _be=be: predict(model.forest, a, backend=_be))
        rows.append({
            "bench": f"predict_{be}",
            "us_per_call": _time(fn, xb),
            "derived": shape,
            "backend": be,
        })

    svc = PRFService(model, max_batch=1024, min_bucket=8)
    batch = x[:1024]
    us = _time(lambda: svc.predict(batch))
    rows.append({
        "bench": "serve_throughput",
        "us_per_call": us,
        "derived": f"batch=1024,{shape}",
        "rows_per_s": 1024 / (us / 1e6),
    })

    # Resilience rows (PERF.md "Resilience"). serve_overload floods
    # submit() against a 128-row admission cap, draining every 24
    # requests — admitted requests report submit->resolve latency,
    # overflow is shed with typed errors, never queued. serve_hotswap
    # times a ModelRegistry publish under 32 in-flight futures; the
    # old version drains on retirement, so dropped_futures must be 0.
    from repro.serving import ModelRegistry, ServiceError

    ovl = PRFService(model, max_batch=1024, min_bucket=8, max_queue_rows=128)
    ovl.predict(batch[:8])  # warm the small-bucket forward pass
    lat, pending, shed, total = [], [], 0, 288
    for i in range(total):
        t0 = time.perf_counter()
        try:
            j = (i * 8) % (N - 8)
            pending.append((ovl.submit(x[j:j + 8]), t0))
        except ServiceError:
            shed += 1
        if i % 24 == 23:
            ovl.drain()
            now = time.perf_counter()
            lat += [now - t for _, t in pending]
            pending = []
    ovl.drain()
    now = time.perf_counter()
    lat += [now - t for _, t in pending]
    lat_us = sorted(v * 1e6 for v in lat)
    rows.append({
        "bench": "serve_overload",
        "us_per_call": lat_us[len(lat_us) // 2],
        "derived": f"req=8rows,queue_cap=128rows,drain_every=24,{shape}",
        "p99_us": lat_us[min(len(lat_us) - 1, int(len(lat_us) * 0.99))],
        "shed_fraction": shed / total,
        "admitted": len(lat_us),
    })

    reg = ModelRegistry(max_batch=1024, min_bucket=8)
    reg.publish(model)
    reg.predict(batch[:8])  # warm v1 so the retirement drain is pure serving
    futs = [reg.submit(x[j * 8:(j + 1) * 8]) for j in range(32)]
    t0 = time.perf_counter()
    reg.publish(model)  # hot-swap: pointer flip, then v1 drains its queue
    swap_us = (time.perf_counter() - t0) * 1e6
    rows.append({
        "bench": "serve_hotswap",
        "us_per_call": swap_us,
        "derived": f"inflight=32x8rows,{shape}",
        "dropped_futures": sum(1 for f in futs if not f.done()),
        "swapped_to_version": reg.version,
    })

    # serve_degraded: traffic during a live-version brownout. v2 is
    # published and its breaker forced open; predict falls back to the
    # retired-but-healthy v1 (us_per_call = that stale-fallback path).
    # Before the brownout, one deadline'd request goes stale in the
    # queue and one client overruns its token bucket — both rejected
    # typed and counted, every admitted future settled (dropped must
    # stay 0). The clock is injected: deadline/refill time is virtual.
    from repro.serving import DeadlineExceeded, RateLimited, RateLimiter

    tick = [0.0]
    rl = RateLimiter(rate=1.0, burst=64, clock=lambda: tick[0])
    reg2 = ModelRegistry(
        max_batch=1024, min_bucket=8, rate_limiter=rl,
        clock=lambda: tick[0],
    )
    reg2.publish(model)
    reg2.predict(batch[:8])             # warm v1 (the future fallback)
    reg2.publish(model)
    reg2.predict(batch[:8])             # warm v2 (the live version)
    futs2 = []
    stale = reg2.submit(x[:8], client="lat", deadline=1.0)
    futs2.append(stale)
    tick[0] = 2.0                       # the deadline'd request goes stale
    granted = rejected = 0
    for j in range(9):                  # 9 x 8 rows vs a 64-token bucket
        try:
            futs2.append(reg2.submit(x[j * 8:(j + 1) * 8], client="hog"))
            granted += 1
        except RateLimited:
            rejected += 1
    reg2.drain()                        # settles stale + granted futures
    assert isinstance(stale.exception(), DeadlineExceeded)
    for _ in range(5):
        reg2.service.breaker.record_failure()   # brownout: v2 opens
    us_fb = _time(lambda: reg2.predict(batch[:8]))
    h = reg2.health()
    rows.append({
        "bench": "serve_degraded",
        "us_per_call": us_fb,
        "derived": f"fallback=v1,live_breaker=open,req=8rows,{shape}",
        "fallback_served": h["fallback_served"],
        "deadline_exceeded": h["live"]["deadline_exceeded"],
        "rate_limited": h["live"]["rate_limited"],
        "rate_limit_granted": granted,
        "rate_limit_rejected": rejected,
        "dropped_futures": sum(1 for f in futs2 if not f.done()),
        "live_us": us,                  # healthy serve_throughput path
    })
    return rows


def run_binning():
    """Bin-edge fitting: full-pass np.quantile vs the blocked sketch.

    Tracks both wall time and **peak host allocation** (tracemalloc —
    numpy registers its buffers with it): the exact path copies + sorts
    the whole [N, F] source, the blocked path is O(block) + O(F * sketch)
    no matter how large N grows. Same seed -> the blocked row also
    reports its max edge deviation from exact.
    """
    import tracemalloc

    from repro.core.binning import fit_bins, fit_bins_blocked

    n_rows, n_feat, n_bins, block = 120_000, 64, 64, 8_192
    x = np.random.default_rng(7).standard_normal(
        (n_rows, n_feat), dtype=np.float32)
    derived = f"N={n_rows},F={n_feat},B={n_bins}"

    def _metered(fn):
        tracemalloc.start()
        t0 = time.time()
        out = fn()
        us = (time.time() - t0) * 1e6
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        return out, us, peak

    exact, us_exact, peak_exact = _metered(lambda: fit_bins(x, n_bins))
    blocks = [x[i:i + block] for i in range(0, n_rows, block)]
    blocked, us_blocked, peak_blocked = _metered(
        lambda: fit_bins_blocked(blocks, n_bins))
    return [
        {"bench": "fit_bins_exact", "us_per_call": us_exact,
         "peak_bytes": int(peak_exact), "derived": derived},
        {"bench": "fit_bins_blocked", "us_per_call": us_blocked,
         "peak_bytes": int(peak_blocked),
         "max_edge_err": float(np.max(np.abs(blocked - exact))),
         "derived": f"{derived},block={block}"},
    ]


def run():
    rng = np.random.default_rng(0)
    rows = (
        run_level_hist() + run_level_hist_reuse() + run_comm_reuse()
        + run_comm_multiproc() + run_level_scores() + run_predict()
        + run_binning()
    )

    N, F, S, B, C = 2048, 128, 4, 16, 4
    xb = jnp.asarray(rng.integers(0, B, (N, F)).astype(np.int32))
    w = rng.random(N).astype(np.float32)
    y = rng.integers(0, C, N)
    wch = jnp.asarray(w[:, None] * np.eye(C, dtype=np.float32)[y])
    slot = jnp.asarray(rng.integers(0, S, N).astype(np.int32))
    f = jax.jit(lambda a, b, c: histogram_ref(a, b, c, n_slots=S, n_bins=B))
    rows.append({"bench": "kernel_gain_ratio_ref",
                 "us_per_call": _time(f, xb, wch, slot),
                 "derived": f"N={N},F={F}"})

    q = jnp.asarray(rng.standard_normal((8, 512, 64)).astype(np.float32))
    f = jax.jit(lambda a: attention_ref(a, a, a, causal=True))
    rows.append({"bench": "kernel_attention_ref", "us_per_call": _time(f, q),
                 "derived": "BH=8,L=512,D=64"})

    x = jnp.asarray(rng.standard_normal((4, 512, 64)).astype(np.float32))
    loga = jnp.asarray(-np.abs(rng.standard_normal((4, 512))).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((4, 512, 32)).astype(np.float32) * 0.3)
    f = jax.jit(lambda x_, l_, b_: ssd_ref(x_, l_, b_, b_)[0])
    rows.append({"bench": "kernel_ssd_ref", "us_per_call": _time(f, x, loga, b),
                 "derived": "BH=4,L=512,P=64,N=32"})
    return rows
