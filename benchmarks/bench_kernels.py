"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference.

On this CPU container interpret-mode timings measure Python emulation,
NOT TPU performance — reported for completeness; correctness sweeps live
in tests/test_kernels.py. The ``level_hist_*`` rows time the T_GR
backend on the histogram shapes training actually builds (multi-tree,
both backends, packed and unpacked) — the series BENCH_kernels.json
tracks across PRs (see PERF.md).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.histograms import level_histograms
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gain_ratio.ref import histogram_ref
from repro.kernels.ssd_scan.ref import ssd_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run_level_hist():
    """Training-shaped T_GR benchmark: one level of a tree chunk."""
    rng = np.random.default_rng(0)
    rows = []
    # A mid-level of grow_forest: tc trees, S live frontier slots.
    tc, N, F, S, B, C = 4, 2048, 32, 4, 16, 4
    xb = jnp.asarray(rng.integers(0, B, (N, F)).astype(np.uint8))
    base = jnp.asarray(np.eye(C, dtype=np.float32)[rng.integers(0, C, N)])
    w = jnp.asarray(rng.integers(0, 4, (tc, N)).astype(np.float32))
    slot = jnp.asarray(rng.integers(-1, S, (tc, N)).astype(np.int32))
    shape = f"tc={tc},N={N},F={F},S={S},B={B},C={C}"
    for backend in ("segment_sum", "pallas"):
        for packed in (False, True):
            fn = jax.jit(
                lambda a, b, c, d, _be=backend, _pk=packed: level_histograms(
                    a, b, c, d, n_slots=S, n_bins=B,
                    packed=_pk, backend=_be,
                )
            )
            name = f"level_hist_{backend}" + ("_packed" if packed else "")
            rows.append({
                "bench": name,
                "us_per_call": _time(fn, xb, base, w, slot),
                "derived": shape,
                "backend": backend,
                "packed": packed,
            })
    return rows


def run():
    rng = np.random.default_rng(0)
    rows = run_level_hist()

    N, F, S, B, C = 2048, 128, 4, 16, 4
    xb = jnp.asarray(rng.integers(0, B, (N, F)).astype(np.int32))
    w = rng.random(N).astype(np.float32)
    y = rng.integers(0, C, N)
    wch = jnp.asarray(w[:, None] * np.eye(C, dtype=np.float32)[y])
    slot = jnp.asarray(rng.integers(0, S, N).astype(np.int32))
    f = jax.jit(lambda a, b, c: histogram_ref(a, b, c, n_slots=S, n_bins=B))
    rows.append({"bench": "kernel_gain_ratio_ref",
                 "us_per_call": _time(f, xb, wch, slot),
                 "derived": f"N={N},F={F}"})

    q = jnp.asarray(rng.standard_normal((8, 512, 64)).astype(np.float32))
    f = jax.jit(lambda a: attention_ref(a, a, a, causal=True))
    rows.append({"bench": "kernel_attention_ref", "us_per_call": _time(f, q),
                 "derived": "BH=8,L=512,D=64"})

    x = jnp.asarray(rng.standard_normal((4, 512, 64)).astype(np.float32))
    loga = jnp.asarray(-np.abs(rng.standard_normal((4, 512))).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((4, 512, 32)).astype(np.float32) * 0.3)
    f = jax.jit(lambda x_, l_, b_: ssd_ref(x_, l_, b_, b_)[0])
    rows.append({"bench": "kernel_ssd_ref", "us_per_call": _time(f, x, loga, b),
                 "derived": "BH=4,L=512,P=64,N=32"})
    return rows
