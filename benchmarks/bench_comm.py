"""Fig. 15 analogue: data-communication cost, vertical vs horizontal
partitioning, vs cluster scale.

Methodology matches the dry-run: lower + compile the distributed PRF
trainer under each partitioning, parse per-device collective bytes from
the post-SPMD HLO. "Horizontal" = all devices shard samples, features
replicated (Spark-MLRF's layout): every histogram psum moves full-F
stats across the whole cluster. "Vertical" (the paper's scheme) psums
F/m-sized stats across the sample axis only.

Runs in a subprocess (needs host-device mesh).
"""
import json
import subprocess
import sys
import textwrap

_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.core import ForestConfig
    from repro.core.distributed import make_prf_train_fn
    from repro.launch.mesh import make_mesh
    from repro.roofline.analysis import analyze_hlo_text

    N, F, C = 1 << 14, 256, 4
    cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=16, n_classes=C,
                       max_frontier=8, tree_chunk=8)
    out = []
    for n_dev, shape, axes in [
        (2, (2, 1), "h"), (4, (4, 1), "h"), (8, (8, 1), "h"),
        (2, (1, 2), "v"), (4, (2, 2), "v"), (8, (2, 4), "v"),
    ]:
        mesh = make_mesh(shape, ("data", "model"))
        fn, _ = make_prf_train_fn(cfg, mesh)
        xb = jax.ShapeDtypeStruct((N, F), jnp.uint8)
        y = jax.ShapeDtypeStruct((N,), jnp.int32)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        comp = fn.lower(xb, y, key).compile()
        a = analyze_hlo_text(comp.as_text())
        out.append({"layout": "horizontal" if axes == "h" else "vertical",
                    "devices": n_dev,
                    "collective_mb_per_device": a["collective_bytes"] / 2**20,
                    "collective_ops": {k: int(v["count"]) for k, v in a["collectives"].items()}})
    print("RESULT" + json.dumps(out))
""")


def run():
    p = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                       text=True, timeout=1800)
    if p.returncode != 0:
        return [{"bench": "fig15_comm", "error": p.stderr[-500:], "us_per_call": 0.0}]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT")][-1]
    rows = []
    for r in json.loads(line[len("RESULT"):]):
        rows.append({"bench": "fig15_comm", **r, "us_per_call": 0.0})
    return rows
