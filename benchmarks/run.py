"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus a JSON dump in
artifacts/bench.json for EXPERIMENTS.md).
"""
import json
import os
import sys
import time


def main() -> None:
    from . import bench_accuracy, bench_comm, bench_kernels, bench_oob, bench_time, bench_volume

    all_rows = []
    suites = [
        ("accuracy (Figs. 8-9)", bench_accuracy.run),
        ("oob (Fig. 10/Table 5)", bench_oob.run),
        ("volume (Fig. 14)", lambda: bench_volume.run() + bench_volume.run_measured()),
        ("comm (Fig. 15)", bench_comm.run),
        ("time/scaling (Figs. 11-13)", bench_time.run),
        ("kernels", bench_kernels.run),
    ]
    print("name,us_per_call,derived")
    for title, fn in suites:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # a suite failure must not hide the others
            rows = [{"bench": title, "error": str(e)[:200], "us_per_call": 0.0}]
        for r in rows:
            name = r.get("bench", title)
            us = r.get("us_per_call", 0.0)
            derived = {
                k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in r.items() if k not in ("bench", "us_per_call")
            }
            print(f"{name},{us:.1f},{json.dumps(derived)}")
        all_rows.extend(rows)
        print(f"# suite '{title}' done in {time.time()-t0:.1f}s", file=sys.stderr)

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench.json", "w") as f:
        json.dump(all_rows, f, indent=2, default=str)


if __name__ == "__main__":
    main()
