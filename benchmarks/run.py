"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus a JSON dump in
artifacts/bench.json for EXPERIMENTS.md). The kernels + train suites
are additionally written to ``BENCH_kernels.json`` at the repo root so
the kernel-backend AND growth-engine perf trajectories are tracked
across PRs (see PERF.md).

``--only SUITE[,SUITE...]`` runs a subset (e.g. ``--only kernels,train``).
"""
import argparse
import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> None:
    from . import (
        bench_accuracy, bench_comm, bench_kernels, bench_oob, bench_time,
        bench_train, bench_volume,
    )

    parser = argparse.ArgumentParser()
    parser.add_argument(
        "--only", default=None,
        help="comma-separated suite subset: "
             "accuracy|oob|volume|comm|time|kernels|train",
    )
    parser.add_argument(
        "--history", action="store_true",
        help="append a dated name->us_per_call row for the tracked "
             "(kernels+train) suites to BENCH_history.jsonl — the "
             "across-run perf series CI uploads as an artifact",
    )
    args = parser.parse_args(argv)

    all_rows = []
    suites = [
        ("accuracy", "accuracy (Figs. 8-9)", bench_accuracy.run),
        ("oob", "oob (Fig. 10/Table 5)", bench_oob.run),
        ("volume", "volume (Fig. 14)", lambda: bench_volume.run() + bench_volume.run_measured()),
        ("comm", "comm (Fig. 15)", bench_comm.run),
        ("time", "time/scaling (Figs. 11-13)", bench_time.run),
        ("kernels", "kernels", bench_kernels.run),
        ("train", "train (growth engine)", bench_train.run),
    ]
    if args.only is not None:
        wanted = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = set(wanted) - {s[0] for s in suites}
        if unknown:
            raise SystemExit(f"unknown suite(s) {sorted(unknown)!r}")
        suites = [s for s in suites if s[0] in wanted]

    tracked_rows = {}                    # suite key -> rows in BENCH_kernels.json
    print("name,us_per_call,derived")
    for key, title, fn in suites:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # a suite failure must not hide the others
            rows = [{"bench": title, "error": str(e)[:200], "us_per_call": 0.0}]
        for r in rows:
            name = r.get("bench", title)
            us = r.get("us_per_call", 0.0)
            derived = {
                k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in r.items() if k not in ("bench", "us_per_call")
            }
            print(f"{name},{us:.1f},{json.dumps(derived)}")
        all_rows.extend(rows)
        if key in ("kernels", "train"):
            tracked_rows[key] = rows
        print(f"# suite '{title}' done in {time.time()-t0:.1f}s", file=sys.stderr)

    # Only a full run may replace the aggregate dump EXPERIMENTS.md reads;
    # --only iterations must not clobber it with a partial row set.
    if args.only is None:
        os.makedirs("artifacts", exist_ok=True)
        with open("artifacts/bench.json", "w") as f:
            json.dump(all_rows, f, indent=2, default=str)

    # BENCH_kernels.json tracks the kernel + training-engine trajectory.
    # Only rewrite it when BOTH suites ran green, so a failed or partial
    # run (--only kernels) never wipes half the tracked series.
    if set(tracked_rows) == {"kernels", "train"} and not any(
        "error" in r for rows in tracked_rows.values() for r in rows
    ):
        import jax

        payload = {
            "jax_backend": jax.default_backend(),
            "note": "interpret-mode Pallas timings off-TPU measure "
                    "emulation, not hardware; track deltas per backend",
            "rows": tracked_rows["kernels"] + tracked_rows["train"],
        }
        with open(os.path.join(_REPO_ROOT, "BENCH_kernels.json"), "w") as f:
            json.dump(payload, f, indent=2, default=str)

        if args.history:
            # One JSON line per run: the perf series a plot can read
            # straight off the CI artifact without parsing full dumps.
            from datetime import date

            line = {
                "date": date.today().isoformat(),
                "jax_backend": payload["jax_backend"],
                "us_per_call": {
                    r["bench"]: round(float(r.get("us_per_call", 0.0)), 1)
                    for r in payload["rows"]
                },
            }
            with open(os.path.join(_REPO_ROOT, "BENCH_history.jsonl"), "a") as f:
                f.write(json.dumps(line) + "\n")


if __name__ == "__main__":
    main()
