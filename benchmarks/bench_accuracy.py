"""Fig. 8 + Fig. 9 analogues: classification accuracy vs tree scale and
vs data size, PRF vs RF vs Spark-MLRF-like.

The paper's datasets are UCI/medical; we use synthetic data with the
same qualitative traits (high dimensionality, sparse signal, label
noise) so results are exactly reproducible offline.
"""
import time

import numpy as np

from repro.core import ForestConfig, train_prf
from repro.core.baselines import train_mlrf_like, train_rf
from repro.data.tabular import make_classification, train_test_split


def fig8_accuracy_vs_trees(trees=(8, 16, 32, 64), seeds=(0, 1)):
    x, y = make_classification(
        n_samples=4000, n_features=600, n_classes=3, n_informative=8,
        n_redundant=4, label_noise=0.1, class_sep=1.2, seed=7,
    )
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    rows = []
    for k in trees:
        cfg = ForestConfig(n_trees=k, max_depth=6, n_bins=16, n_classes=3)
        for name, fn in [("prf", train_prf), ("rf", train_rf),
                         ("mlrf", lambda a, b, c, seed: train_mlrf_like(a, b, c, seed, sample_budget=300))]:
            t0 = time.time()
            accs = [fn(xtr, ytr, cfg, seed=s).accuracy(xte, yte) for s in seeds]
            rows.append({
                "bench": "fig8_accuracy_vs_trees", "algo": name, "n_trees": k,
                "accuracy": float(np.mean(accs)),
                "us_per_call": (time.time() - t0) / len(seeds) * 1e6,
            })
    return rows


def fig9_accuracy_vs_datasize(sizes=(1000, 2000, 4000, 8000), seed=0):
    rows = []
    for n in sizes:
        x, y = make_classification(
            n_samples=n + 1000, n_features=400, n_classes=3, n_informative=8,
            label_noise=0.1, class_sep=1.2, seed=11,
        )
        xtr, ytr = x[:n], y[:n]
        xte, yte = x[n:], y[n:]
        cfg = ForestConfig(n_trees=24, max_depth=6, n_bins=16, n_classes=3)
        for name, fn in [("prf", train_prf), ("rf", train_rf),
                         ("mlrf", lambda a, b, c, seed: train_mlrf_like(a, b, c, seed, sample_budget=300))]:
            t0 = time.time()
            acc = fn(xtr, ytr, cfg, seed=seed).accuracy(xte, yte)
            rows.append({
                "bench": "fig9_accuracy_vs_datasize", "algo": name, "n_samples": n,
                "accuracy": float(acc), "us_per_call": (time.time() - t0) * 1e6,
            })
    return rows


def run():
    return fig8_accuracy_vs_trees() + fig9_accuracy_vs_datasize()
