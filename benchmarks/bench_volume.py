"""Fig. 14 analogue: training-data volume vs RF scale.

Measured, not just modeled: we sum the actual bytes of every array each
algorithm materializes for training (bootstrap copies for RF/MLRF vs the
shared binned matrix + DSI counts for PRF)."""
import time

import numpy as np

from repro.core.baselines import data_volume_bytes


def run(n_samples=100_000, n_features=1000, scales=(2, 8, 32, 128, 500, 1000)):
    rows = []
    for k in scales:
        for algo in ("rf", "spark-mlrf", "prf-paper", "prf-tpu"):
            rows.append({
                "bench": "fig14_data_volume", "algo": algo, "n_trees": k,
                "gbytes": data_volume_bytes(algo, n_samples, n_features, k) / 2 ** 30,
                "us_per_call": 0.0,
            })
    return rows


def run_measured(n_samples=20_000, n_features=200, scales=(2, 8, 32)):
    """Small-scale measured variant: actually materialize what each
    algorithm holds and count bytes."""
    from repro.core.binning import bin_dataset
    from repro.core.dsi import bootstrap_counts
    import jax

    rng = np.random.default_rng(0)
    x = rng.standard_normal((n_samples, n_features)).astype(np.float64)
    rows = []
    for k in scales:
        # RF: k bootstrap copies
        rf_bytes = k * x.nbytes
        # PRF-tpu: one uint8 binned copy + k x N float32 counts
        xb, edges = bin_dataset(x, 32)
        counts = np.asarray(bootstrap_counts(jax.random.PRNGKey(0), k, n_samples))
        prf_bytes = xb.nbytes + counts.nbytes
        rows.append({
            "bench": "fig14_measured", "n_trees": k,
            "rf_gbytes": rf_bytes / 2 ** 30, "prf_gbytes": prf_bytes / 2 ** 30,
            "ratio": rf_bytes / prf_bytes, "us_per_call": 0.0,
        })
    return rows
