"""Format EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.make_tables [artifacts/dryrun]
"""
import glob
import json
import os
import sys


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def load(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def dryrun_table(rows):
    out = ["| arch | shape | mesh | status | compile | HBM/dev | collectives |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "OK":
            colls = " ".join(
                f"{k}:{int(v['count'])}" for k, v in sorted(r["collectives"].items())
            )
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
                f"| {r['compile_s']:.0f}s | {r['hbm_per_device_gb']:.2f} GB"
                f"{'' if r['fits_hbm'] else ' **OVER**'} | {colls} |"
            )
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status'].split(':')[0]} | | | |"
            )
    return "\n".join(out)


def roofline_table(rows, mesh="16x16"):
    out = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful-FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh or r["status"] != "OK":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| {r['dominant'].replace('_s','')} "
            f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} |"
        )
    return "\n".join(out)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    rows = load(d)
    print("## Dry-run table\n")
    print(dryrun_table(rows))
    print("\n## Roofline table (single-pod 16x16)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Roofline table (multi-pod 2x16x16)\n")
    print(roofline_table(rows, "2x16x16"))


if __name__ == "__main__":
    main()
