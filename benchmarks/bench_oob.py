"""Fig. 10 / Table 5 analogue: OOB error rate vs tree scale.

Paper observation: OOB error falls with ensemble size and converges
(their Patient data: ~0.138 @ 500 trees -> ~0.089 @ 1000)."""
import time

import jax
import numpy as np

from repro.core import ForestConfig, train_prf
from repro.core.dsi import bootstrap_counts
from repro.core.voting import oob_accuracy
from repro.core.binning import apply_bins
import jax.numpy as jnp

from repro.data.tabular import make_classification


def run(trees=(8, 16, 32, 64, 128)):
    x, y = make_classification(
        n_samples=3000, n_features=64, n_classes=2, n_informative=10,
        label_noise=0.12, seed=3,
    )
    rows = []
    for k in trees:
        cfg = ForestConfig(n_trees=k, max_depth=6, n_bins=16, n_classes=2)
        t0 = time.time()
        model = train_prf(x, y, cfg, seed=0)
        # ensemble OOB error: for each sample, vote using only trees
        # where it is OOB (standard Breiman OOB estimate)
        xb = apply_bins(jnp.asarray(x), jnp.asarray(model.bin_edges))
        from repro.core.forest import predict_proba_trees
        from repro.core.dsi import bootstrap_counts

        key = jax.random.PRNGKey(0)
        k_boot, _ = jax.random.split(key)
        weights = bootstrap_counts(k_boot, cfg.n_trees, x.shape[0])
        probs = predict_proba_trees(model.forest, xb)      # [k, N, C]
        votes = jax.nn.one_hot(jnp.argmax(probs, -1), cfg.n_classes)
        oob = (weights == 0).astype(jnp.float32)[:, :, None]
        scores = (votes * oob).sum(0)
        pred = np.asarray(jnp.argmax(scores, -1))
        err = float(np.mean(pred != y))
        rows.append({
            "bench": "fig10_oob_error", "n_trees": k, "oob_error": err,
            "us_per_call": (time.time() - t0) * 1e6,
        })
    return rows
