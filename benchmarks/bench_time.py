"""Fig. 11-13 analogues: execution time vs data size; parallel scaling.

Wall-clock numbers come from ONE CPU core, so absolute times are not
TPU-meaningful; the *trends* (PRF vs RF slope with data size, Fig. 11)
are. Parallel speedup (Fig. 12-13) is derived from the compiled
artifacts (per-device FLOPs ratio vs 1 device), consistent with the
dry-run methodology — a single host core cannot time 8 virtual devices
honestly.
"""
import json
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.core import ForestConfig, train_prf
from repro.core.baselines import train_rf
from repro.data.tabular import make_classification


def fig11_time_vs_datasize(sizes=(1000, 4000, 16000)):
    rows = []
    for n in sizes:
        x, y = make_classification(n_samples=n, n_features=100, n_classes=3, seed=0)
        cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=16, n_classes=3)
        for name, fn in [("prf", train_prf), ("rf", train_rf)]:
            fn(x, y, cfg, seed=0)              # warm the jit cache
            t0 = time.time()
            fn(x, y, cfg, seed=1)
            rows.append({
                "bench": "fig11_time_vs_datasize", "algo": name, "n_samples": n,
                "seconds": time.time() - t0,
                "us_per_call": (time.time() - t0) * 1e6,
            })
    return rows


_SCALING = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.core import ForestConfig
    from repro.core.distributed import make_prf_train_fn
    from repro.launch.mesh import make_mesh
    from repro.roofline.analysis import analyze_hlo_text

    N, F, C = 1 << 14, 256, 4
    cfg = ForestConfig(n_trees=16, max_depth=6, n_bins=16, n_classes=C,
                       max_frontier=8, tree_chunk=8)
    out = []
    for shape in [(1, 1), (2, 2), (4, 2), (4, 4) if False else (2, 4)]:
        n_dev = shape[0] * shape[1]
        mesh = make_mesh(shape, ("data", "model"))
        fn, _ = make_prf_train_fn(cfg, mesh)
        comp = fn.lower(jax.ShapeDtypeStruct((N, F), jnp.uint8),
                        jax.ShapeDtypeStruct((N,), jnp.int32),
                        jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
        a = analyze_hlo_text(comp.as_text())
        out.append({"devices": n_dev, "flops_per_device": a["flops"],
                    "collective_mb": a["collective_bytes"] / 2**20})
    print("RESULT" + json.dumps(out))
""")


def fig13_parallel_scaling():
    p = subprocess.run([sys.executable, "-c", _SCALING], capture_output=True,
                       text=True, timeout=1800)
    if p.returncode != 0:
        return [{"bench": "fig13_scaling", "error": p.stderr[-500:], "us_per_call": 0.0}]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT")][-1]
    rows = json.loads(line[len("RESULT"):])
    base = rows[0]["flops_per_device"]
    out = []
    for r in rows:
        speedup = base / r["flops_per_device"] if r["flops_per_device"] else 0.0
        out.append({
            "bench": "fig13_scaling", "devices": r["devices"],
            "flops_per_device": r["flops_per_device"],
            "derived_speedup": speedup,
            "parallel_efficiency": speedup / r["devices"],
            "us_per_call": 0.0,
        })
    return out


def run():
    return fig11_time_vs_datasize() + fig13_parallel_scaling()
