"""Training-path benchmarks — the growth-engine trajectory rows.

``train_e2e_resident`` times the unified engine's jitted
``grow_forest`` (early-exit while_loop, whole dataset device-resident);
``train_e2e_streamed`` the host-streaming ``grow_forest_streamed``
driver on the same data split into 4 sample blocks with the
synchronous feed (``prefetch=0`` — the fused route+hist pass reads
each block once per level, but block copies still serialize with
compute); ``train_e2e_streamed_prefetch`` the full async data plane
(``prefetch=2``: a ``BlockFeeder`` thread keeps the next block's
host->device copy in flight while the current block's histogram
runs). ``oob_streamed`` times the blocked Eq. 8 OOB sweep against the
resident call (``resident_us``). ``train_early_exit`` times a
cleanly-separable dataset under a generous depth budget (trees purify
and their frontiers die at ~1/4 of ``max_depth`` — the realistic
over-budgeted case), with the fixed-depth time of the bit-identical
forest in ``fixed_depth_us`` — the level-count saving the early-exit
scheduler buys. Rows land in BENCH_kernels.json next to the kernel
series (see PERF.md); CI fails the kernels-bench job if the streamed
rows go missing.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import grow_forest_streamed
from repro.core.binning import bin_dataset
from repro.core.dsi import bootstrap_counts
from repro.core.forest import grow_forest
from repro.core.types import ForestConfig
from repro.core.voting import oob_accuracy, oob_accuracy_streamed
from repro.data.tabular import make_classification

K, N, F, B, C, DEPTH = 8, 4096, 32, 16, 3, 6
N_BLOCKS = 4
SHAPE = f"k={K},N={N},F={F},B={B},C={C},depth={DEPTH}"

# Multi-process plane worker: one coordinator-connected jax.distributed
# process of the 2x2 drill, timing the full train_prf_multiproc pipeline
# (screen -> sharded sketch merge -> local binning -> growth) on the
# same global shape as train_e2e_streamed. Spawned twice by
# run_multiproc(); process 0 prints the warm-call RESULT line.
_MP_CODE = textwrap.dedent("""
    import os
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = ""
    pid = int(os.environ["PRF_PID"])
    nproc = int(os.environ["PRF_NPROC"])
    from repro.launch import multiproc
    multiproc.initialize("127.0.0.1:" + os.environ["PRF_PORT"],
                         nproc, pid, local_device_count=2)
    import json, time
    from repro.core.distributed import train_prf_multiproc
    from repro.core.types import ForestConfig
    from repro.data.tabular import make_classification
    from repro.launch.multiproc import MultiHostMesh

    K, N, F, B, C, DEPTH = 8, 4096, 32, 16, 3, 6
    x, y = make_classification(
        n_samples=N, n_features=F, n_classes=C, n_informative=8, seed=5
    )
    cfg = ForestConfig(n_trees=K, max_depth=DEPTH, n_bins=B, n_classes=C,
                       feature_mode="all", weighted_voting=False,
                       sample_block=N // 4)
    rt = MultiHostMesh()
    train_prf_multiproc(x, y, cfg, seed=0, runtime=rt)  # warm jit caches
    t0 = time.time()
    train_prf_multiproc(x, y, cfg, seed=0, runtime=rt)
    us = (time.time() - t0) * 1e6
    rt.barrier()
    if pid == 0:
        print("RESULT" + json.dumps(
            {"us_per_call": us, "feed_bytes": int(rt.feed_bytes)}
        ), flush=True)
""")


def run_multiproc(streamed_us):
    """``train_e2e_multiproc``: the 2-process x 2-device training plane
    end to end — each process feeds only its local half of the rows;
    ``single_process_streamed_us`` carries the single-process streamed
    growth time of the same global shape for the trajectory table."""
    port = "12961"
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", _MP_CODE],
            env={**os.environ, "PRF_PID": str(i), "PRF_NPROC": "2",
                 "PRF_PORT": port},
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )
        for i in range(2)
    ]
    outs = [p.communicate(timeout=1800)[0] for p in procs]
    if any(p.returncode != 0 for p in procs):
        return [{"bench": "train_e2e_multiproc",
                 "error": (outs[0] + outs[1])[-500:], "us_per_call": 0.0}]
    line = [ln for ln in outs[0].splitlines() if ln.startswith("RESULT")][-1]
    r = json.loads(line[len("RESULT"):])
    return [{
        "bench": "train_e2e_multiproc",
        "us_per_call": r["us_per_call"],
        "derived": f"{SHAPE},blocks={N_BLOCKS},procs=2x2dev,full_prf_path",
        "feed_mb_per_proc": r["feed_bytes"] / 2**20,
        "single_process_streamed_us": streamed_us,
    }]


def _time(fn, reps=3):
    fn()  # compile / warm the jit caches
    t0 = time.time()
    for _ in range(reps):
        out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return (time.time() - t0) / reps * 1e6


def _setup():
    x, y = make_classification(
        n_samples=N, n_features=F, n_classes=C, n_informative=8, seed=5
    )
    cfg = ForestConfig(
        n_trees=K, max_depth=DEPTH, n_bins=B, n_classes=C, feature_mode="all"
    )
    xb, _ = bin_dataset(x, cfg.n_bins)
    w = np.asarray(
        bootstrap_counts(jax.random.PRNGKey(0), K, N)
    ).astype(np.float32)
    return xb, y, w, cfg


def run():
    rows = []
    xb, y, w, cfg = _setup()
    xb_dev, y_dev, w_dev = jnp.asarray(xb), jnp.asarray(y), jnp.asarray(w)

    rows.append({
        "bench": "train_e2e_resident",
        "us_per_call": _time(lambda: grow_forest(xb_dev, y_dev, w_dev, cfg)),
        "derived": SHAPE,
    })

    blocks = np.array_split(xb, N_BLOCKS)
    rows.append({
        "bench": "train_e2e_streamed",
        "us_per_call": _time(
            lambda: grow_forest_streamed(blocks, y, w, cfg, prefetch=0)
        ),
        "derived": f"{SHAPE},blocks={N_BLOCKS},fused_route_hist,sync_feed",
    })
    us_streamed = _time(
        lambda: grow_forest_streamed(blocks, y, w, cfg, prefetch=2)
    )
    rows.append({
        "bench": "train_e2e_streamed_prefetch",
        "us_per_call": us_streamed,
        "derived": f"{SHAPE},blocks={N_BLOCKS},fused_route_hist,prefetch=2",
    })

    # Resilience rows (see PERF.md "Resilience"): what per-level
    # checkpointing costs over the resident while_loop engine, what a
    # crash resume costs (restore + the remaining levels), and what a
    # 5%-fault feed under bounded retry costs over the clean stream.
    import shutil
    import tempfile

    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.core.forest import grow_forest_checkpointed
    from repro.launch.fault import FaultInjector

    ckpt_dir = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        def ckpt_run():
            shutil.rmtree(ckpt_dir, ignore_errors=True)
            return grow_forest_checkpointed(
                xb_dev, y_dev, w_dev, cfg,
                manager=CheckpointManager(ckpt_dir, keep=2, save_interval=1),
            )

        us_ckpt = _time(ckpt_run)

        class _Kill(Exception):
            pass

        def killer(level, _):
            if level == DEPTH // 2:
                raise _Kill

        shutil.rmtree(ckpt_dir, ignore_errors=True)
        try:
            grow_forest_checkpointed(
                xb_dev, y_dev, w_dev, cfg,
                manager=CheckpointManager(ckpt_dir, keep=2, save_interval=1),
                on_level=killer,
            )
        except _Kill:
            pass
        us_resume = _time(lambda: grow_forest_checkpointed(
            xb_dev, y_dev, w_dev, cfg, resume_from=ckpt_dir,
        ))
        rows.append({
            "bench": "train_checkpoint_resume",
            "us_per_call": us_ckpt,
            "derived": f"{SHAPE},ckpt_every_level",
            "resume_from_midpoint_us": us_resume,
            "resident_us": rows[0]["us_per_call"],
        })
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    def faulted_run():
        inj = FaultInjector(0.05, seed=3, max_consecutive=2)
        return grow_forest_streamed(
            blocks, y, w, cfg, prefetch=2,
            feeder_opts=dict(fault_hook=inj, max_retries=3, backoff=1e-4),
        )

    rows.append({
        "bench": "train_faulted_feed",
        "us_per_call": _time(faulted_run),
        "derived": f"{SHAPE},blocks={N_BLOCKS},fault_rate=0.05,retries=3",
        "clean_us": us_streamed,
    })

    # Per-block integrity validation (bad_block_policy) on the full
    # streamed train_prf path: the numpy NaN/Inf/label screen runs once
    # per raw block before binning, so its cost is a host-side preamble
    # over the unvalidated run (``unvalidated_us``) — the price of
    # refusing to train on poisoned shards.
    from repro.core.api import train_prf

    x_raw, y_raw = make_classification(
        n_samples=N, n_features=F, n_classes=C, n_informative=8, seed=5
    )
    cfg_stream = dataclasses.replace(cfg, sample_block=N // N_BLOCKS)
    us_unval = _time(lambda: train_prf(
        x_raw, y_raw, cfg_stream, seed=0, bad_block_policy=None
    ))
    us_val = _time(lambda: train_prf(
        x_raw, y_raw, cfg_stream, seed=0, bad_block_policy="raise"
    ))
    rows.append({
        "bench": "train_validated_feed",
        "us_per_call": us_val,
        "derived": f"{SHAPE},blocks={N_BLOCKS},policy=raise",
        "unvalidated_us": us_unval,
        "overhead_frac": us_val / max(us_unval, 1e-9) - 1.0,
    })

    forest = grow_forest(xb_dev, y_dev, w_dev, cfg)
    us_oob_res = _time(
        lambda: oob_accuracy(forest, xb_dev, y_dev, w_dev)
    )
    rows.append({
        "bench": "oob_streamed",
        "us_per_call": _time(
            lambda: oob_accuracy_streamed(forest, blocks, y, w)
        ),
        "derived": f"{SHAPE},blocks={N_BLOCKS}",
        "resident_us": us_oob_res,
    })

    # Sibling-subtraction reuse end to end: the same deep-frontier
    # forest grown with hist_reuse on vs off (bit-identical trees —
    # tests/test_hist_reuse.py). The per-level saving is the halved
    # T_GR scatter (level_hist_reuse_* rows); this row records how much
    # of it survives whole-training amortization on this backend.
    deep_cfg = dataclasses.replace(
        cfg, max_depth=10, max_frontier=512, min_samples_split=4,
    )
    us_reuse_on = _time(lambda: grow_forest(
        xb_dev, y_dev, w_dev,
        dataclasses.replace(deep_cfg, hist_reuse="on")))
    us_reuse_off = _time(lambda: grow_forest(
        xb_dev, y_dev, w_dev,
        dataclasses.replace(deep_cfg, hist_reuse="off")))
    rows.append({
        "bench": "train_e2e_reuse",
        "us_per_call": us_reuse_on,
        "derived": f"{SHAPE.replace(f'depth={DEPTH}', 'depth=10')},S=512",
        "off_us": us_reuse_off,
        "speedup_vs_off": us_reuse_off / max(us_reuse_on, 1e-9),
    })

    # Over-budgeted depth on separable data: trees purify and every
    # frontier dies at ~level 4 of a 16-level budget, so the early-exit
    # while_loop skips ~3/4 of the level work; the fixed-depth run of
    # the *bit-identical* forest is the baseline the saving is measured
    # against. max_frontier bounds S (the default 2**16 frontier would
    # dominate the timing with dead-slot histogram work).
    x2, y2 = make_classification(
        n_samples=N, n_features=F, n_classes=C, n_informative=10,
        class_sep=3.0, label_noise=0.0, seed=5,
    )
    xb2, _ = bin_dataset(x2, B)
    deep = dataclasses.replace(
        cfg, max_depth=16, max_frontier=64, min_samples_split=32,
        early_exit=True,
    )
    fixed = dataclasses.replace(deep, early_exit=False)
    xb2_dev, y2_dev = jnp.asarray(xb2), jnp.asarray(y2)
    us_ee = _time(lambda: grow_forest(xb2_dev, y2_dev, w_dev, deep))
    us_fx = _time(lambda: grow_forest(xb2_dev, y2_dev, w_dev, fixed))
    rows.append({
        "bench": "train_early_exit",
        "us_per_call": us_ee,
        "derived": f"{SHAPE.replace(f'depth={DEPTH}', 'depth=16')},"
                   "S=64,separable",
        "fixed_depth_us": us_fx,
        "speedup_vs_fixed": us_fx / max(us_ee, 1e-9),
    })
    rows.extend(run_multiproc(us_streamed))
    return rows
