"""Sharded checkpointing with elastic restore (fault-tolerance substrate).

Format: one ``.npy`` file per pytree leaf inside a step directory, plus a
msgpack manifest of paths/dtypes/shapes **and per-leaf CRC32 checksums**.
Writes go to a temp dir and are atomically renamed — a crash mid-save
never corrupts the latest checkpoint (the RDD-lineage replacement; see
DESIGN.md §2).

Restore is *elastic* and *verified*: leaves are CRC/shape/dtype-checked
against the manifest before they are trusted (a silently byte-flipped
checkpoint raises :class:`CheckpointCorruptionError` instead of
restoring garbage), then ``device_put`` with the shardings derived for
the *current* mesh, so a job can resume on a different pod count / mesh
shape than it saved from. ``restore_latest_valid`` walks back past
corrupt or torn steps to the newest verifiable one — the resume paths
of every growth driver use it, so a corrupted newest checkpoint costs
one extra level of recompute, never a poisoned model. (At real scale
the per-leaf files would be per-shard OCDBT streams; the protocol —
checksummed manifest + atomic rename + reshard-on-load — is the same.)
"""
from __future__ import annotations

import os
import re
import shutil
import tempfile
import warnings
import zlib
from typing import Any, Callable, List, Optional, Tuple

import jax
import msgpack
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")
_TMP_PREFIX = ".tmp_save_"


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint failed integrity verification: CRC mismatch,
    shape/dtype drift, a missing or unreadable leaf file, or a torn
    manifest. Raised *before* any corrupt bytes are deserialized into a
    training state."""


class CheckpointTopologyError(RuntimeError):
    """A checkpoint was written by a different process topology than the
    one restoring it (e.g. a 2-process manifest restored into a single
    process, or vice versa). Deliberately NOT a
    :class:`CheckpointCorruptionError`: ``restore_latest_valid`` walks
    back past *corrupt* steps, but a topology mismatch applies to every
    step in the directory — walking back would silently retrain from an
    older carry, so this propagates instead. Resume on the topology that
    saved, or start fresh with a new checkpoint directory."""


def _check_topology(manifest: dict, path: str) -> None:
    """Refuse to restore across a changed process count.

    Single-process manifests carry no ``topology`` key (byte-compatible
    with every pre-multiproc checkpoint) and imply ``process_count=1``;
    multi-process manifests record the saving process count. Either
    direction of mismatch raises :class:`CheckpointTopologyError` —
    never a silently wrong forest."""
    saved = manifest.get("topology", {}).get("process_count", 1)
    now = jax.process_count()
    if int(saved) != now:
        raise CheckpointTopologyError(
            f"checkpoint {path} was saved by {saved} process(es) but this "
            f"runtime has {now} — per-host shard leaves do not transfer "
            "across process counts; resume on the saving topology or start "
            "a fresh checkpoint directory"
        )


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out, treedef


def _crc32(arr: np.ndarray) -> int:
    """CRC32 of an array's raw bytes (C-contiguous canonical form)."""
    return zlib.crc32(np.ascontiguousarray(arr).data)


def save_checkpoint(
    tree, directory: str, step: int,
    *,
    fault_hook: Optional[Callable[[str], None]] = None,
) -> str:
    """Atomic save with a checksummed manifest. Returns the final path.

    ``fault_hook`` is a deterministic chaos hook (see
    ``launch.fault.FaultInjector``) called at ``"leaf[i]"`` before each
    leaf write and at ``"pre_rename"`` between the complete tmp write
    and the atomic rename — the torn-write window the recovery drill in
    tests/test_integrity.py exercises.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=_TMP_PREFIX)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        if fault_hook is not None:
            fault_hook(f"leaf[{i}]")
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname, "dtype": str(arr.dtype),
            "shape": list(arr.shape), "crc32": _crc32(arr),
        })
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if fault_hook is not None:
        fault_hook("pre_rename")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(directory: str) -> List[int]:
    """All step numbers in ``directory``, ascending. Stray files,
    orphaned ``.tmp_save_*`` dirs from a killed atomic rename, and any
    other non-``step_NNNNNNNN`` entries are ignored — a dirty directory
    can never crash step discovery."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.isdir(os.path.join(directory, d)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def _load_manifest(path: str) -> dict:
    try:
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        if not isinstance(manifest, dict) or "leaves" not in manifest:
            raise ValueError("manifest has no leaves")
        return manifest
    except CheckpointCorruptionError:
        raise
    except Exception as e:
        raise CheckpointCorruptionError(
            f"torn or unreadable manifest in {path}: {e}"
        ) from e


def _load_leaf(path: str, entry: dict) -> np.ndarray:
    """Load + verify one leaf against its manifest entry."""
    fname = entry["file"]
    try:
        arr = np.load(os.path.join(path, fname))
    except Exception as e:
        raise CheckpointCorruptionError(
            f"leaf {entry['key']!r} ({fname}) in {path} is missing or "
            f"unreadable: {e}"
        ) from e
    if list(arr.shape) != list(entry["shape"]) or str(arr.dtype) != entry["dtype"]:
        raise CheckpointCorruptionError(
            f"leaf {entry['key']!r} ({fname}) in {path} drifted: manifest "
            f"says {entry['dtype']}{entry['shape']}, file holds "
            f"{arr.dtype}{list(arr.shape)}"
        )
    want = entry.get("crc32")          # pre-integrity manifests lack it
    if want is not None and _crc32(arr) != want:
        raise CheckpointCorruptionError(
            f"leaf {entry['key']!r} ({fname}) in {path} failed its CRC32 "
            f"check — the checkpoint is corrupt"
        )
    return arr


def verify_checkpoint(directory: str, step: int) -> None:
    """Verify every leaf of one step against its manifest (CRC + shape +
    dtype) without building a pytree. Raises
    :class:`CheckpointCorruptionError` on the first failure."""
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = _load_manifest(path)
    _check_topology(manifest, path)
    for entry in manifest["leaves"]:
        _load_leaf(path, entry)


def restore_checkpoint(
    tree_like, directory: str, step: Optional[int] = None,
    shardings=None, *, verify: bool = True,
):
    """Restore into the structure of `tree_like` (values ignored).

    `shardings`: optional matching pytree of Shardings — enables elastic
    resume onto any mesh. With ``verify`` (the default) every leaf is
    checked against the manifest's CRC32/shape/dtype before it is
    deserialized onto a device; a failed check raises
    :class:`CheckpointCorruptionError` (use ``restore_latest_valid`` to
    fall back past corrupt steps automatically).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = _load_manifest(path)
    _check_topology(manifest, path)

    flat, treedef = _flatten(tree_like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten(shardings)[0]]

    leaves = []
    for i, (key, like) in enumerate(flat):
        entry = by_key.get(key)
        if entry is None:
            raise CheckpointCorruptionError(
                f"leaf {key!r} missing from manifest in {path}"
            )
        if verify:
            arr = _load_leaf(path, entry)
        else:
            arr = np.load(os.path.join(path, entry["file"]))
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_latest_valid(
    tree_like, directory: str, shardings=None,
    *,
    on_skip: Optional[Callable[[int, Exception], None]] = None,
) -> Optional[Tuple[Any, int]]:
    """Restore the newest *verifiable* checkpoint, walking back past
    corrupt or torn steps.

    Steps are tried newest-first; one that fails verification (CRC
    mismatch, torn manifest, missing leaf, shape drift) is skipped with
    a warning (and ``on_skip(step, error)``, if given) and the next
    older step is tried. Returns ``(tree, step)`` of the first valid
    one, or ``None`` when the directory holds no restorable checkpoint
    at all — the resume paths treat that exactly like an empty
    directory (fresh start), so a fully-corrupt checkpoint dir degrades
    to a from-scratch retrain, never a crash loop or a poisoned model.
    """
    for step in reversed(list_steps(directory)):
        try:
            return restore_checkpoint(
                tree_like, directory, step, shardings, verify=True
            )
        except (CheckpointCorruptionError, OSError, ValueError, KeyError) as e:
            if on_skip is not None:
                on_skip(step, e)
            warnings.warn(
                f"skipping corrupt checkpoint step {step} in {directory}: {e}",
                RuntimeWarning,
                stacklevel=2,
            )
    return None


class CheckpointManager:
    """Rotating checkpoints + resume — the training loop's FT interface.

    Init garbage-collects orphaned ``.tmp_save_*`` dirs left behind by a
    save killed between its tmp write and the atomic rename, so a
    crash-retry supervisor never accumulates torn half-writes.
    ``fault_hook`` forwards to :func:`save_checkpoint` for deterministic
    torn-write drills.
    """

    def __init__(
        self, directory: str, keep: int = 3, save_interval: int = 100,
        *,
        fault_hook: Optional[Callable[[str], None]] = None,
    ):
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self.fault_hook = fault_hook
        if os.path.isdir(directory):
            for d in os.listdir(directory):
                if d.startswith(_TMP_PREFIX):
                    shutil.rmtree(
                        os.path.join(directory, d), ignore_errors=True
                    )

    def maybe_save(self, tree, step: int) -> Optional[str]:
        if step % self.save_interval != 0:
            return None
        path = save_checkpoint(
            tree, self.directory, step, fault_hook=self.fault_hook
        )
        self._gc()
        return path

    def _gc(self):
        for s in list_steps(self.directory)[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    def restore_latest(self, tree_like, shardings=None):
        return restore_checkpoint(tree_like, self.directory, shardings=shardings)

    def restore_latest_valid(self, tree_like, shardings=None):
        """Newest verifiable checkpoint as ``(tree, step)``; corrupt or
        torn steps are skipped (see module-level
        :func:`restore_latest_valid`). Raises ``FileNotFoundError`` when
        no step verifies."""
        out = restore_latest_valid(tree_like, self.directory, shardings)
        if out is None:
            raise FileNotFoundError(
                f"no valid checkpoint in {self.directory}"
            )
        return out
