"""Sharded checkpointing with elastic restore (fault-tolerance substrate).

Format: one ``.npy`` file per pytree leaf inside a step directory, plus a
msgpack manifest of paths/dtypes/shapes. Writes go to a temp dir and are
atomically renamed — a crash mid-save never corrupts the latest
checkpoint (the RDD-lineage replacement; see DESIGN.md §2).

Restore is *elastic*: leaves are loaded on host and ``device_put`` with
the shardings derived for the *current* mesh, so a job can resume on a
different pod count / mesh shape than it saved from. (At real scale the
per-leaf files would be per-shard OCDBT streams; the protocol — manifest
+ atomic rename + reshard-on-load — is the same.)
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any, Callable, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(tree, directory: str, step: int) -> str:
    """Atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isdir(os.path.join(directory, d))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    tree_like, directory: str, step: Optional[int] = None,
    shardings=None,
):
    """Restore into the structure of `tree_like` (values ignored).

    `shardings`: optional matching pytree of Shardings — enables elastic
    resume onto any mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())

    flat, treedef = _flatten(tree_like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten(shardings)[0]]

    leaves = []
    for i, (key, like) in enumerate(flat):
        entry = by_key[key]
        arr = np.load(os.path.join(path, entry["file"]))
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Rotating checkpoints + resume — the training loop's FT interface."""

    def __init__(self, directory: str, keep: int = 3, save_interval: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval

    def maybe_save(self, tree, step: int) -> Optional[str]:
        if step % self.save_interval != 0:
            return None
        path = save_checkpoint(tree, self.directory, step)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    def restore_latest(self, tree_like, shardings=None):
        return restore_checkpoint(tree_like, self.directory, shardings=shardings)
