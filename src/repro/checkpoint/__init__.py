from .checkpoint import (  # noqa: F401
    CheckpointCorruptionError,
    CheckpointManager,
    latest_step,
    list_steps,
    restore_checkpoint,
    restore_latest_valid,
    save_checkpoint,
    verify_checkpoint,
)
