"""Train-step factory: grad-accumulation scan + AdamW + pjit shardings.

The microbatch axis is a ``lax.scan`` (fp32 grad accumulators live across
iterations), so arbitrarily large global batches compile with bounded
activation memory — the knob that keeps the XXL dry-run cells inside
16 GB/chip. Gradients are averaged over microbatches; the optimizer step
happens once per global batch.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.model import Model
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .sharding import param_specs


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init_state(model: Model, key, opt_cfg: AdamWConfig) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves are shaped [n_micro, micro_batch, ...]; the leading axis
    is the grad-accumulation scan.
    """

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        n_micro = jax.tree_util.tree_leaves(batch)[0].shape[0]

        def micro_grads(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True
            )(state.params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads
            )
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        (grads, loss_sum), _ = jax.lax.scan(
            micro_grads, (g0, jnp.zeros((), jnp.float32)), batch
        )
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)

        new_params, new_opt, om = adamw_update(
            state.params, grads, state.opt, opt_cfg
        )
        metrics = {"loss": loss_sum / n_micro, **om}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_sharded_train_step(
    model: Model, opt_cfg: AdamWConfig, mesh: Mesh, *,
    dp_axes=("data",), donate: bool = True,
):
    """jit-compiled train step with explicit in/out shardings for `mesh`."""
    train_step = make_train_step(model, opt_cfg)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, mesh)
    state_specs = TrainState(
        params=pspecs,
        opt={"m": pspecs, "v": pspecs, "step": P()},
        step=P(),
    )
    state_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

    def batch_sharding(leaf):
        # [n_micro, micro, ...]: microbatch dim over DP axes.
        spec = [None, tuple(dp_axes)] + [None] * (leaf.ndim - 2)
        return NamedSharding(mesh, P(*spec))

    return train_step, state_shardings, batch_sharding
