"""Parameter sharding rules: FSDP(`data`) x TP(`model`) x pure-DP(`pod`).

Every rule is validated against the actual dim sizes: a mesh axis is only
assigned to a tensor dim it divides; otherwise that dim stays replicated
(the GQA case — kv_heads < tp — degrades gracefully). Params under
"stages"/"enc_stages" carry a leading layer-group axis that is never
sharded (it is the `lax.scan` axis; FSDP gathers one group per step).

This layout is the LM-training translation of the paper's vertical
partitioning: shard the axis along which compute is independent
(heads/ff/experts -> `model`), keep the reduction axis local, and let the
`pod` axis carry pure data parallelism so scaling out pods never
re-shards the model (elasticity).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fits(size: int, dim: int) -> bool:
    return dim % size == 0 and dim >= size


def param_spec(path: str, shape, mesh: Mesh, *, fsdp=None,
               tp: str = "model", uneven_heads: bool = False,
               fsdp_tables_only: bool = False) -> P:
    """PartitionSpec for one param, by path suffix + shape validation.

    ``fsdp`` defaults to ALL non-`model` axes (pods included): at 512+
    chips the optimizer state must shard across pods too (ZeRO-3 over
    DCN with prefetch; see DESIGN.md §4).

    ``uneven_heads``: shard head axes over `model` even when the head
    count does not divide it (GSPMD pads) — trades <=2x head-padding
    waste for zero sequence-reshard collectives (§Perf).
    """
    sz = _axis_sizes(mesh)
    if fsdp is None:
        fsdp = tuple(a for a in mesh.axis_names if a != tp)
    if isinstance(fsdp, str):
        fsdp = (fsdp,)
    fsdp_size = int(np.prod([sz[a] for a in fsdp]))
    stacked = ("stages" in path)           # leading scan axis
    dims = list(shape[1:] if stacked else shape)
    name = path.split("/")[-1]
    head_param = name in ("wq", "wk", "wv", "wo")
    if fsdp_tables_only and name != "table":
        fsdp = ()                          # weight-stationary layers (serving)
        fsdp_size = 1

    def maybe(axis, dim_idx):
        if not (0 <= dim_idx < len(dims)):
            return None
        if axis == fsdp:
            if not fsdp:                 # FSDP disabled: replicate over DP
                return None
            return fsdp if _fits(fsdp_size, dims[dim_idx]) else None
        if axis in sz and _fits(sz[axis], dims[dim_idx]):
            return axis
        if axis in sz and uneven_heads and head_param and dims[dim_idx] >= 2:
            return axis                  # padded sharding
        return None

    spec = [None] * len(dims)

    if name == "table":                    # embed/unembed [V, D]
        spec[0] = maybe(tp, 0)
        spec[1] = maybe(fsdp, 1)
    elif name in ("wq",):                  # [D, H, hd]
        spec[0] = maybe(fsdp, 0)
        spec[1] = maybe(tp, 1)
    elif name in ("wk", "wv"):             # [D, KV, hd]
        spec[0] = maybe(fsdp, 0)
        spec[1] = maybe(tp, 1)             # None when KV % tp != 0
    elif name == "wo":                     # [H, hd, D]
        spec[0] = maybe(tp, 0)
        spec[2] = maybe(fsdp, 2)
    elif name in ("w1", "w3") and len(dims) == 2:   # [D, F]
        spec[0] = maybe(fsdp, 0)
        spec[1] = maybe(tp, 1)
    elif name == "w2" and len(dims) == 2:  # [F, D]
        spec[0] = maybe(tp, 0)
        spec[1] = maybe(fsdp, 1)
    elif name in ("w1", "w3") and len(dims) == 3:   # experts [E, D, F]
        spec[0] = maybe(tp, 0)             # EP: experts over `model`
        spec[1] = maybe(fsdp, 1)
    elif name == "w2" and len(dims) == 3:  # experts [E, F, D]
        spec[0] = maybe(tp, 0)
        spec[2] = maybe(fsdp, 2)
    elif name == "router":                 # [D, E]
        spec[0] = maybe(fsdp, 0)
    elif name in ("wdq", "wdkv", "wkrope"):          # MLA down [D, r]
        spec[0] = maybe(fsdp, 0)
    elif name in ("wuq", "wuk", "wuv"):    # MLA up [r, H, k]
        spec[1] = maybe(tp, 1)
    elif name == "in_proj":                # mamba [D, X]
        spec[0] = maybe(fsdp, 0)
        spec[1] = maybe(tp, 1)
    elif name == "out_proj":               # mamba [d_inner, D]
        spec[0] = maybe(tp, 0)
        spec[1] = maybe(fsdp, 1)
    elif name == "conv_w":                 # [W, C]
        spec[1] = maybe(tp, 1)
    elif name == "conv_b":                 # [C]
        spec[0] = maybe(tp, 0)
    # everything else (norms, biases, gates, meta, a_log, ...) replicated

    if stacked:
        spec = [None] + spec
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, mesh: Mesh, **kw):
    """Pytree of PartitionSpecs matching `params`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_spec(_path_str(p), np.shape(v), mesh, **kw) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh: Mesh, **kw):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, **kw)
    )


def opt_state_specs(opt_shape, params_specs):
    """Moment shardings: m inherits param specs; v inherits them too
    except factored {vr, vc} leaves, which are small and replicated."""
    ptreedef = jax.tree_util.tree_structure(params_specs)
    pspecs_flat = jax.tree_util.tree_leaves(params_specs)
    v_flat = ptreedef.flatten_up_to(opt_shape["v"])
    v_specs = [
        {"vr": P(), "vc": P()} if isinstance(v, dict) else s
        for v, s in zip(v_flat, pspecs_flat)
    ]
    return {
        "m": params_specs,
        "v": jax.tree_util.tree_unflatten(ptreedef, v_specs),
        "step": P(),
    }


def cache_specs(cache, mesh: Mesh, *, batch_sharded: bool,
                dp_axes=("data",), tp: str = "model"):
    """KV/SSM cache shardings — the paper's vertical-partition insight
    applied to serving: shard the *independent* axis.

    batch_sharded (decode_32k): batch over DP axes, cache LENGTH over
    `model` (flash-decoding: GSPMD turns the softmax over the sharded
    length into a small max/sum all-reduce pair).

    batch=1 (long_500k): length shards over EVERY mesh axis; SSD states
    shard heads over `model` and the state dim over `data`.
    """
    sz = _axis_sizes(mesh)
    all_axes = tuple(mesh.axis_names)

    def spec_for(path, leaf):
        name = _path_str(path).split("/")[-1]
        dims = list(np.shape(leaf))   # [G, B, L, KV, hd] / [G, B, L, r] / conv / h
        spec = [None] * len(dims)
        dp_total = int(np.prod([sz[a] for a in dp_axes]))

        if name in ("k", "v", "ckv", "krope"):
            if batch_sharded and _fits(dp_total, dims[1]):
                spec[1] = dp_axes
                if _fits(sz.get(tp, 1), dims[2]):
                    spec[2] = tp
            else:  # batch too small: shard length over the whole mesh
                full = int(np.prod(list(sz.values())))
                if _fits(full, dims[2]):
                    spec[2] = all_axes
                elif _fits(sz.get(tp, 1), dims[2]):
                    spec[2] = tp
        elif name == "h":             # [G, B, H, N, P]
            if batch_sharded and _fits(dp_total, dims[1]):
                spec[1] = dp_axes
            elif _fits(sz.get("data", 1), dims[3]):
                spec[3] = "data"      # SSD state dim over data when B==1
            if _fits(sz.get(tp, 1), dims[2]):
                spec[2] = tp          # SSD heads over tp
        elif name == "conv":          # [G, B, W-1, C]
            if batch_sharded and _fits(dp_total, dims[1]):
                spec[1] = dp_axes
            if _fits(sz.get(tp, 1), dims[3]):
                spec[3] = tp
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, v) for p, v in flat]
    )
