"""AdamW with dtype-configurable moments (no optax in this environment).

Moments may be stored in bf16 (``moment_dtype``) for the XXL configs —
deepseek-v3-671b does not fit fp32 moments in 16 GB/chip on 512 chips
(see DESIGN.md §4). All arithmetic happens in fp32; storage dtype only
affects at-rest bytes. Optimizer state inherits parameter shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # Factor the second moment over the last two dims (Adafactor-style) —
    # the XXL configs (deepseek-v3-671b) cannot hold full AdamW state:
    # 3 x 1.34 TB on a 256-chip pod is the pod's entire HBM.
    factored: bool = False


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def _is_factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adamw_init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)

    def vinit(p):
        if cfg.factored and _is_factorable(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return jnp.zeros_like(p, dtype=dt)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(vinit, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(
    params, grads, state, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        mhat = m32 / bc1
        if isinstance(v, dict):  # factored second moment
            g2 = g * g + 1e-30
            vr = cfg.b2 * v["vr"] + (1 - cfg.b2) * g2.mean(axis=-1)
            vc = cfg.b2 * v["vc"] + (1 - cfg.b2) * g2.mean(axis=-2)
            vhat = (
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30)
            ) / bc2
            new_v = {"vr": vr, "vc": vc}
        else:
            v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
            vhat = v32 / bc2
            new_v = v32.astype(mdt)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), new_v

    out = _tree_map_with_v(upd, params, grads, state["m"], state["v"])
    is_out_leaf = lambda t: isinstance(t, tuple)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_out_leaf)
    new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_out_leaf)
    new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=is_out_leaf)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


def _tree_map_with_v(fn, params, grads, m, v):
    """tree_map where v leaves may be {'vr','vc'} dicts."""
    pl, treedef = jax.tree_util.tree_flatten(params)
    gl = treedef.flatten_up_to(grads)
    ml = treedef.flatten_up_to(m)
    vl = treedef.flatten_up_to(v)
    out = [fn(p, g, mm, vv) for p, g, mm, vv in zip(pl, gl, ml, vl)]
    return jax.tree_util.tree_unflatten(treedef, out)
