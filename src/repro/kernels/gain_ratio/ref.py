"""Pure-jnp oracle for the fused T_GR histogram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(
    x_bins: jnp.ndarray,   # [N, F] integer bin ids
    wch: jnp.ndarray,      # [N, C] weighted channels (w * onehot(y))
    slot: jnp.ndarray,     # [N] int32 frontier slot, -1 = parked
    *,
    n_slots: int,
    n_bins: int,
) -> jnp.ndarray:
    """hist[s, f, b, c] = sum_i wch[i, c] * [slot_i = s] * [x_bins[i, f] = b]."""
    S, B = n_slots, n_bins
    base = jnp.where(slot >= 0, slot, S) * B

    def per_feature(bins_f):
        seg = base + bins_f.astype(jnp.int32)
        out = jax.ops.segment_sum(wch, seg, num_segments=S * B + B)
        return out[: S * B].reshape(S, B, -1)

    return jnp.transpose(jax.vmap(per_feature, in_axes=1)(x_bins), (1, 0, 2, 3))
