"""Pure-jnp oracle for the fused T_GR histogram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(
    x_bins: jnp.ndarray,   # [N, F] integer bin ids
    wch: jnp.ndarray,      # [N, C] weighted channels (w * onehot(y))
    slot: jnp.ndarray,     # [N] int32 frontier slot, -1 = parked
    *,
    n_slots: int,
    n_bins: int,
) -> jnp.ndarray:
    """hist[s, f, b, c] = sum_i wch[i, c] * [slot_i = s] * [x_bins[i, f] = b]."""
    S, B = n_slots, n_bins
    base = jnp.where(slot >= 0, slot, S) * B

    def per_feature(bins_f):
        seg = base + bins_f.astype(jnp.int32)
        out = jax.ops.segment_sum(wch, seg, num_segments=S * B + B)
        return out[: S * B].reshape(S, B, -1)

    return jnp.transpose(jax.vmap(per_feature, in_axes=1)(x_bins), (1, 0, 2, 3))


def level_histogram_ref(
    x_bins: jnp.ndarray,   # [N, F] integer bin ids
    base: jnp.ndarray,     # [N, C] unweighted channels
    w: jnp.ndarray,        # [tc, N] per-tree weights
    slot: jnp.ndarray,     # [tc, N] int32 frontier slot, -1 = parked
    *,
    n_slots: int,
    n_bins: int,
) -> jnp.ndarray:
    """Multi-tree oracle: per-tree histogram_ref with the weight folded in.

    Returns [tc, S, F, B, C] — the same contract as the Pallas backend.
    """

    def per_tree(w_t, slot_t):
        return histogram_ref(
            x_bins, w_t[:, None] * base, slot_t, n_slots=n_slots, n_bins=n_bins
        )

    return jax.vmap(per_tree)(w, slot)
