"""jit'd public wrapper for the fused T_GR histogram kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import hist_pallas_call
from .ref import histogram_ref


@partial(
    jax.jit,
    static_argnames=("n_slots", "n_bins", "use_pallas", "interpret", "n_blk", "f_blk"),
)
def fused_histogram(
    x_bins: jnp.ndarray,
    wch: jnp.ndarray,
    slot: jnp.ndarray,
    *,
    n_slots: int,
    n_bins: int,
    use_pallas: bool = True,
    interpret: bool = True,     # CPU container: interpret; False on real TPU
    n_blk: int = 512,
    f_blk: int = 128,
) -> jnp.ndarray:
    """hist [S, F, B, C]; Pallas on TPU, jnp oracle otherwise."""
    if not use_pallas:
        return histogram_ref(x_bins, wch, slot, n_slots=n_slots, n_bins=n_bins)
    return hist_pallas_call(
        x_bins, wch, slot,
        n_slots=n_slots, n_bins=n_bins,
        n_blk=n_blk, f_blk=f_blk, interpret=interpret,
    )
