"""jit'd public wrappers for the fused T_GR histogram kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import hist_pallas_call
from .ref import histogram_ref

# The multi-tree production entry point is
# core/histograms.level_histograms(backend="pallas"), which calls
# kernel.multi_tree_hist_pallas directly and handles backend/interpret
# resolution — no second jit wrapper here to keep in lockstep.


@partial(
    jax.jit,
    static_argnames=("n_slots", "n_bins", "use_pallas", "interpret", "n_blk", "f_blk"),
)
def fused_histogram(
    x_bins: jnp.ndarray,
    wch: jnp.ndarray,
    slot: jnp.ndarray,
    *,
    n_slots: int,
    n_bins: int,
    use_pallas: bool = True,
    interpret: bool = True,
    n_blk: int | None = None,
    f_blk: int | None = None,
) -> jnp.ndarray:
    """Single-tree hist [S, F, B, C]; Pallas on TPU, jnp oracle otherwise."""
    if not use_pallas:
        return histogram_ref(x_bins, wch, slot, n_slots=n_slots, n_bins=n_bins)
    return hist_pallas_call(
        x_bins, wch, slot,
        n_slots=n_slots, n_bins=n_bins,
        n_blk=n_blk, f_blk=f_blk, interpret=interpret,
    )
