"""Pallas TPU kernel: fused weighted histograms for T_GR (paper §4.2.1).

This is the production backend of ``core/histograms.level_histograms``
(selected by ``ForestConfig.hist_backend``), not a single-tree demo. A
CPU worker scatters into histogram bins; TPUs have no fast scatter, so
the kernel builds the histograms as **one-hot matmuls on the MXU**, for
a whole *chunk of trees* per ``pallas_call``:

  unpacked (channels) layout::

      onehot(slot*B + bin_f)^T  [S*B, N_blk] @ (w_t * base) [N_blk, C]
                                                        -> [S*B, C]

  packed (classification) layout — class folded into the one-hot index,
  so the matmul reads the [N] weight *vector*, never an [N, C] channel
  matrix (a C-fold cut of T_GR's dominant memory traffic)::

      (w_t * wcls) [1, N_blk] @ onehot(slot*B*C + bin_f*C + cls)
                                     [N_blk, S*B*C] -> [1, S*B*C]

Grid: ``(tc, F_blocks, N_blocks)`` with the sample axis innermost
(sequential), so each (tree, feature-block) accumulator tile stays
resident in VMEM while sample blocks stream through — the classic
reduction-grid pattern. The per-tree DSI weight multiply
``w[t, i] * base[i, c]`` happens *inside* the kernel: the ``[tc, N, C]``
weighted-channel tensor is never materialized anywhere.

Arbitrary ``N``/``F`` are supported: inputs are padded up to the block
grid with parked samples (``slot = -1`` -> zero weight) and dummy
features (sliced off the output). Block sizes are auto-chosen from a
VMEM budget as a function of the ``(S*B, C)`` accumulator footprint —
see ``choose_blocks``. ``choose_blocks(...)[1]`` doubles as the
feature-slab width of the fused T_GR->T_NS loop
(``core/histograms.hist_feature_slab``): slabs that wide see the same
``(n_blk, f_blk)`` grid in the same order, so per-slab histograms are
bit-identical to slices of a one-shot call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Per-step VMEM working-set budget. ~16 MiB/core physical; half keeps
# headroom for Pallas' double-buffered input pipelining. Shared with the
# split-scan score kernel (kernels/split_scan/kernel.py) so the fused
# T_GR->T_NS pipeline sizes both stages against the same ceiling.
_VMEM_BUDGET = 8 * 2 ** 20


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def choose_blocks(
    N: int, F: int, S: int, B: int, C: int, *, packed: bool,
    n_blk: int | None = None, f_blk: int | None = None,
    vmem_budget: int = _VMEM_BUDGET,
) -> tuple[int, int]:
    """Pick (n_blk, f_blk) so the per-step working set fits the budget.

    Working set per grid step (f32 words):
      out tile      f_blk * S*B * C          (resident accumulator)
      one-hot       n_blk * W, W = S*B (unpacked) or S*B*C (packed)
      bins block    n_blk * f_blk
      channels      n_blk * C  (+ w, slot: 2 * n_blk)
    """
    width = S * B * C if packed else S * B
    if f_blk is None:
        f_blk = 128
        while f_blk > 8 and f_blk * S * B * C * 4 > vmem_budget // 2:
            f_blk //= 2
    if n_blk is None:
        n_blk = 512
        while n_blk > 64 and n_blk * (width + f_blk + C + 2) * 4 > vmem_budget // 2:
            n_blk //= 2
    # Never pad beyond one block of the actual problem size.
    n_blk = min(n_blk, _round_up(max(N, 1), 8))
    f_blk = min(f_blk, _round_up(max(F, 1), 8))
    return n_blk, f_blk


def _hist_kernel_channels(
    bins_ref, base_ref, w_ref, slot_ref, out_ref, *, n_slots, n_bins, f_blk
):
    """One (tree, feature-block, sample-block) grid step, [N, C] channels."""
    S, B = n_slots, n_bins
    SB = S * B
    n_idx = pl.program_id(2)

    @pl.when(n_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    slot = slot_ref[0, :]                                 # [N_blk]
    parked = slot < 0
    seg0 = jnp.where(parked, 0, slot) * B                 # [N_blk]
    # Fused DSI weight: parked/padded samples contribute zero weight, so
    # the one-hot matmul needs no dump segment.
    w = jnp.where(parked, 0.0, w_ref[0, :])               # [N_blk]
    wch = base_ref[...] * w[:, None].astype(base_ref.dtype)  # [N_blk, C]

    def body(f, _):
        idx = seg0 + bins_ref[:, f].astype(jnp.int32)     # [N_blk]
        onehot = (
            idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, SB), 1)
        ).astype(wch.dtype)                               # [N_blk, SB]
        acc = jax.lax.dot_general(
            onehot, wch,
            dimension_numbers=(((0,), (0,)), ((), ())),   # onehot^T @ wch
            preferred_element_type=jnp.float32,
        )                                                 # [SB, C]
        out_ref[0, f, :, :] += acc
        return 0

    jax.lax.fori_loop(0, f_blk, body, 0)


def _hist_kernel_packed(
    bins_ref, cls_ref, wcls_ref, w_ref, slot_ref, out_ref,
    *, n_slots, n_bins, n_classes, f_blk
):
    """Packed grid step: class index folded into the one-hot column."""
    S, B, C = n_slots, n_bins, n_classes
    SBC = S * B * C
    n_idx = pl.program_id(2)

    @pl.when(n_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    slot = slot_ref[0, :]                                 # [N_blk]
    parked = slot < 0
    seg0 = jnp.where(parked, 0, slot) * (B * C)
    wv = jnp.where(parked, 0.0, w_ref[0, :] * wcls_ref[...])  # [N_blk]
    cls = cls_ref[...].astype(jnp.int32)                  # [N_blk]

    def body(f, _):
        idx = seg0 + bins_ref[:, f].astype(jnp.int32) * C + cls
        onehot = (
            idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, SBC), 1)
        ).astype(wv.dtype)                                # [N_blk, SBC]
        acc = jax.lax.dot_general(
            wv[None, :], onehot,
            dimension_numbers=(((1,), (0,)), ((), ())),   # wv @ onehot
            preferred_element_type=jnp.float32,
        )                                                 # [1, SBC]
        out_ref[0, f, :] += acc[0]
        return 0

    jax.lax.fori_loop(0, f_blk, body, 0)


def multi_tree_hist_pallas(
    x_bins: jnp.ndarray,    # [N, F] int (any int dtype)
    base: jnp.ndarray,      # [N, C] float32 unweighted channels
    w: jnp.ndarray,         # [tc, N] float32 per-tree DSI weights
    slot: jnp.ndarray,      # [tc, N] int32 frontier slot, -1 = parked
    *,
    n_slots: int,
    n_bins: int,
    packed: bool = False,
    n_blk: int | None = None,
    f_blk: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused multi-tree histograms. Returns [tc, S, F, B, C] float32."""
    N, F = x_bins.shape
    tc = w.shape[0]
    C = base.shape[1]
    S, B = n_slots, n_bins
    n_blk, f_blk = choose_blocks(
        N, F, S, B, C, packed=packed, n_blk=n_blk, f_blk=f_blk
    )

    Np, Fp = _round_up(N, n_blk), _round_up(F, f_blk)
    if Np != N or Fp != F:
        # Pad samples as parked (zero weight) and features as dummies
        # (their histogram slabs are sliced off below).
        x_bins = jnp.pad(x_bins, ((0, Np - N), (0, Fp - F)))
        base = jnp.pad(base, ((0, Np - N), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, Np - N)))
        slot = jnp.pad(slot, ((0, 0), (0, Np - N)), constant_values=-1)

    grid = (tc, Fp // f_blk, Np // n_blk)
    bins_spec = pl.BlockSpec((n_blk, f_blk), lambda t, f, n: (n, f))
    w_spec = pl.BlockSpec((1, n_blk), lambda t, f, n: (t, n))

    if packed:
        # Classification-shaped channels: base is (scaled) one-hot, so it
        # is exactly (class index, per-sample scale) — computed once here,
        # outside the (tree x feature) grid.
        cls = jnp.argmax(base, axis=-1).astype(jnp.int32)   # [Np]
        wcls = base.max(axis=-1)                            # [Np]
        out = pl.pallas_call(
            functools.partial(
                _hist_kernel_packed,
                n_slots=S, n_bins=B, n_classes=C, f_blk=f_blk,
            ),
            grid=grid,
            in_specs=[
                bins_spec,
                pl.BlockSpec((n_blk,), lambda t, f, n: (n,)),   # cls
                pl.BlockSpec((n_blk,), lambda t, f, n: (n,)),   # wcls
                w_spec,                                         # w
                w_spec,                                         # slot
            ],
            out_specs=pl.BlockSpec((1, f_blk, S * B * C), lambda t, f, n: (t, f, 0)),
            out_shape=jax.ShapeDtypeStruct((tc, Fp, S * B * C), jnp.float32),
            interpret=interpret,
        )(x_bins.astype(jnp.int32), cls, wcls, w, slot)
    else:
        out = pl.pallas_call(
            functools.partial(
                _hist_kernel_channels, n_slots=S, n_bins=B, f_blk=f_blk
            ),
            grid=grid,
            in_specs=[
                bins_spec,
                pl.BlockSpec((n_blk, C), lambda t, f, n: (n, 0)),  # base
                w_spec,                                            # w
                w_spec,                                            # slot
            ],
            out_specs=pl.BlockSpec(
                (1, f_blk, S * B, C), lambda t, f, n: (t, f, 0, 0)
            ),
            out_shape=jax.ShapeDtypeStruct((tc, Fp, S * B, C), jnp.float32),
            interpret=interpret,
        )(x_bins.astype(jnp.int32), base, w, slot)

    # [tc, Fp, S*B(*C)] -> [tc, S, F, B, C], dummy features sliced off.
    hist = jnp.transpose(out.reshape(tc, Fp, S, B, C), (0, 2, 1, 3, 4))
    return hist[:, :, :F]


def hist_pallas_call(
    x_bins: jnp.ndarray,   # [N, F] int (any int dtype)
    wch: jnp.ndarray,      # [N, C] float32 pre-weighted channels
    slot: jnp.ndarray,     # [N] int32
    *,
    n_slots: int,
    n_bins: int,
    n_blk: int | None = None,
    f_blk: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-tree convenience wrapper. Returns hist [S, F, B, C] float32.

    ``wch`` carries the weights already folded in (the tree weight passed
    to the kernel is 1); the multi-tree entry point is
    ``multi_tree_hist_pallas``.
    """
    N = x_bins.shape[0]
    ones = jnp.ones((1, N), jnp.float32)
    return multi_tree_hist_pallas(
        x_bins, wch, ones, slot[None],
        n_slots=n_slots, n_bins=n_bins, packed=False,
        n_blk=n_blk, f_blk=f_blk, interpret=interpret,
    )[0]
