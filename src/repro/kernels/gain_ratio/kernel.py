"""Pallas TPU kernel: fused weighted histogram for T_GR (paper §4.2.1).

TPU adaptation of the paper's gain-ratio hot spot. A CPU worker scatters
into histogram bins; TPUs have no fast scatter, so the kernel builds the
histogram as **one-hot matmuls on the MXU**:

    onehot(slot*B + bin_f)^T  [S*B, N_blk]  @  wch [N_blk, C]  ->  [S*B, C]

Tiling:
  grid = (F_blocks, N_blocks); the N axis is the innermost (sequential)
  grid dimension, so the [S*B, C] accumulator tile for a feature block
  stays resident in VMEM while sample blocks stream through (classic
  reduction-grid pattern).

VMEM working set per step (defaults N_blk=512, F_blk=128, S*B <= 2048,
C <= 32):  bins 512x128 int32 (256 KiB) + wch 512x32 f32 (64 KiB)
+ out 2048x128? no — out tile is [S, F_blk, B, C] laid out as
[F_blk, S*B, C] scratch (128 * 2048 * 32 f32 = 32 MiB would NOT fit; we
therefore loop features *inside* the block with a fori_loop and keep the
out tile at [S*B, C] per feature, writing each feature's slab to the
output ref as it completes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(bins_ref, wch_ref, slot_ref, out_ref, *, n_slots, n_bins, f_blk):
    """One (feature-block, sample-block) grid step."""
    S, B = n_slots, n_bins
    SB = S * B
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    slot = slot_ref[...]                                  # [N_blk]
    parked = slot < 0
    base = jnp.where(parked, 0, slot) * B                 # [N_blk]
    # Parked samples contribute zero weight instead of a dump row so the
    # one-hot matmul needs no extra segment.
    wch = wch_ref[...] * (~parked)[:, None].astype(wch_ref.dtype)   # [N_blk, C]

    def body(f, _):
        idx = base + bins_ref[:, f].astype(jnp.int32)     # [N_blk]
        onehot = (
            idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, SB), 1)
        ).astype(wch.dtype)                               # [N_blk, SB]
        acc = jax.lax.dot_general(
            onehot, wch,
            dimension_numbers=(((0,), (0,)), ((), ())),   # onehot^T @ wch
            preferred_element_type=jnp.float32,
        )                                                 # [SB, C]
        out_ref[f, :, :] += acc
        return 0

    jax.lax.fori_loop(0, f_blk, body, 0)


def hist_pallas_call(
    x_bins: jnp.ndarray,   # [N, F] int (any int dtype)
    wch: jnp.ndarray,      # [N, C] float32
    slot: jnp.ndarray,     # [N] int32
    *,
    n_slots: int,
    n_bins: int,
    n_blk: int = 512,
    f_blk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns hist [S, F, B, C] float32."""
    N, F = x_bins.shape
    C = wch.shape[1]
    S, B = n_slots, n_bins
    n_blk = min(n_blk, N)
    f_blk = min(f_blk, F)
    if N % n_blk or F % f_blk:
        raise ValueError(f"N={N} % n_blk={n_blk} or F={F} % f_blk={f_blk} != 0")

    grid = (F // f_blk, N // n_blk)
    out = pl.pallas_call(
        functools.partial(
            _hist_kernel, n_slots=S, n_bins=B, f_blk=f_blk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, f_blk), lambda f, n: (n, f)),   # bins
            pl.BlockSpec((n_blk, C), lambda f, n: (n, 0)),       # wch
            pl.BlockSpec((n_blk,), lambda f, n: (n,)),           # slot
        ],
        out_specs=pl.BlockSpec((f_blk, S * B, C), lambda f, n: (f, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, S * B, C), jnp.float32),
        interpret=interpret,
    )(x_bins.astype(jnp.int32), wch, slot)
    # [F, S*B, C] -> [S, F, B, C]
    return jnp.transpose(out.reshape(F, S, B, C), (1, 0, 2, 3))
