from .kernel import multi_tree_hist_pallas  # noqa: F401
from .ops import fused_histogram  # noqa: F401
