from .ops import fused_histogram  # noqa: F401
