"""Pallas TPU kernel: fused T_NS split scoring (paper §4.2.1, Eq. 2-6).

PR 1 made T_GR (histogram construction) a Pallas kernel but still wrote
the full ``[tc, S, F, B, C]`` histogram to HBM for ``core/gain.py`` to
re-read — for training shapes that tensor is orders of magnitude larger
than the ``O(k*S)`` split descriptors that survive the level. This
kernel closes the loop: it consumes histogram tiles ``[1, S, f_blk, B,
C]`` in VMEM, computes the bin-cumsum, Eq. (2)-(6) gain ratios (or the
``variance_gains`` regression analogue) and the dim-reduction feature
mask in-register, and folds the T_NS argmax into the grid loop as a
running ``(best_gr, best_f, best_thr, left/right_counts)`` accumulator.
Only the per-(tree, slot) winners are ever written back.

Grid: ``(tc, F_blocks)`` with the feature axis innermost (sequential),
so each tree's [S]-shaped accumulator stays resident in VMEM while
feature blocks stream through — the same reduction-grid pattern as
``kernels/gain_ratio``. The carry is *resumable*: callers pass the
previous best as inputs (seeded into the output at the first feature
block) plus a global feature offset, which is how
``core/forest.fused_level_scores`` chains histogram-kernel -> score-
kernel per feature slab without ever materializing the full histogram,
and how ``core/distributed.py`` scores each shard's feature slice
post-combine.

Numerics are shared with the XLA backend (``core/gain.py``'s
``*_from_cumsum`` scorers) and carry updates are strictly-greater, so
first-occurrence argmax semantics match exactly. Gain *values* agree to
float rounding only (XLA fuses the two compiled contexts differently —
FMA/reassociation). Winners and child counts are bit-identical wherever
the backends share XLA numerics — interpret mode (the tested path) and
real training data, where integer DSI weights make every histogram and
its prefix sums exact — so ``grow_forest`` builds bit-identical forests
whichever backend scores the splits (tests/test_split_backends.py).
Caveat: on a real TPU (``interpret=False``) Mosaic may round the
log/division chain differently from XLA, so two *near-tied* candidate
splits could in principle flip order vs the xla backend; the forests
remain valid, but exact cross-backend identity is only asserted where
it can be tested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.gain import (
    SplitScores, split_gain_ratios_from_cumsum, variance_gains_from_cumsum,
)
from ..gain_ratio.kernel import _VMEM_BUDGET, _round_up


def choose_score_block(
    S: int, B: int, C: int, F: int, *,
    f_blk: int | None = None, vmem_budget: int = _VMEM_BUDGET,
) -> int:
    """Feature-block width for the score kernel, from the same VMEM
    budget as ``gain_ratio.choose_blocks``.

    Working set per grid step is ~6 slab-sized f32 arrays (hist tile,
    bin cumsum, right counts, scores, winner one-hot, scratch):
    ``6 * f_blk * S*B * C * 4`` bytes must fit the budget.
    """
    if f_blk is None:
        f_blk = 128
        while f_blk > 8 and 6 * f_blk * S * B * C * 4 > vmem_budget:
            f_blk //= 2
    return min(f_blk, _round_up(max(F, 1), 8))


def init_carry(tc: int, S: int, C: int) -> tuple:
    """Neutral running-best carry: no winner yet (feature = -1).

    The kernel force-accepts the first block's argmax while
    ``feature < 0``, which reproduces the XLA oracle's all-invalid
    semantics (gain -inf -> feature 0, threshold 0, counts of that
    split) without a special case.
    """
    return (
        jnp.full((tc, S), -jnp.inf, jnp.float32),   # best gain ratio
        jnp.full((tc, S), -1, jnp.int32),           # best feature (global id)
        jnp.zeros((tc, S), jnp.int32),              # best threshold
        jnp.zeros((tc, S, C), jnp.float32),         # left child counts
        jnp.zeros((tc, S, C), jnp.float32),         # right child counts
    )


def _split_scan_kernel(
    hist_ref, mask_ref, fbase_ref,
    gr0_ref, f0_ref, thr0_ref, l0_ref, r0_ref,
    gr_ref, f_ref, thr_ref, l_ref, r_ref,
    *, f_blk: int, regression: bool,
):
    """One (tree, feature-block) grid step: score the slab, fold argmax."""
    fj = pl.program_id(1)

    @pl.when(fj == 0)
    def _seed_from_carry():
        gr_ref[...] = gr0_ref[...]
        f_ref[...] = f0_ref[...]
        thr_ref[...] = thr0_ref[...]
        l_ref[...] = l0_ref[...]
        r_ref[...] = r0_ref[...]

    hist = hist_ref[0]                                # [S, f_blk, B, C]
    S, Fb, B, C = hist.shape
    cum = jnp.cumsum(hist, axis=-2)                   # the ONE bin scan
    total = cum[:, :, -1, :]                          # [S, f_blk, C]
    if regression:
        sc = variance_gains_from_cumsum(cum, total)   # [S, f_blk, B-1]
    else:
        sc = split_gain_ratios_from_cumsum(cum, total)
    admit = mask_ref[0, :] > 0                        # [f_blk]
    sc = jnp.where(admit[None, :, None], sc, -jnp.inf)

    # Block argmax with first-occurrence tie-break (== jnp.argmax).
    flat = sc.reshape(S, Fb * (B - 1))
    m = jnp.max(flat, axis=-1)                        # [S]
    col = jax.lax.broadcasted_iota(jnp.int32, flat.shape, 1)
    idx = jnp.min(jnp.where(flat == m[:, None], col, Fb * (B - 1)), axis=-1)

    # Winner child counts, gathered from the cumsum via a one-hot
    # multiply-reduce (TPUs have no fast gather; exact — all other
    # summands are literal zeros).
    one = (col == idx[:, None]).astype(hist.dtype).reshape(S, Fb, B - 1)
    left = cum[:, :, :-1, :]                          # [S, f_blk, B-1, C]
    lcnt = jnp.sum(one[..., None] * left, axis=(1, 2))                      # [S, C]
    rcnt = jnp.sum(one[..., None] * (total[:, :, None, :] - left), axis=(1, 2))

    fl = (idx // (B - 1)).astype(jnp.int32)
    thr = (idx % (B - 1)).astype(jnp.int32)
    f_glob = fbase_ref[0] + fj * f_blk + fl           # [S] global feature id

    # Strictly-greater keeps the earliest (lowest feature-id) winner on
    # ties; `feature < 0` force-accepts the very first block so the
    # neutral carry never survives.
    cur_gr = gr_ref[0]
    better = (m > cur_gr) | (f_ref[0] < 0)
    gr_ref[0] = jnp.where(better, m, cur_gr)
    thr_ref[0] = jnp.where(better, thr, thr_ref[0])
    l_ref[0] = jnp.where(better[:, None], lcnt, l_ref[0])
    r_ref[0] = jnp.where(better[:, None], rcnt, r_ref[0])
    f_ref[0] = jnp.where(better, f_glob, f_ref[0])


def split_scan_block(
    hist: jnp.ndarray,           # [tc, S, F, B, C] histogram slab
    mask: jnp.ndarray,           # [tc, F] bool/int feature mask
    carry: tuple | None,         # running best (init_carry or a prior result)
    f_base,                      # global feature id of hist[..., 0, :, :] (traced ok)
    *,
    regression: bool = False,
    f_blk: int | None = None,
    interpret: bool = False,
) -> tuple:
    """Fold one histogram slab into the running-best carry.

    Returns the updated carry ``(gain [tc,S] f32, feature [tc,S] i32,
    threshold [tc,S] i32, left_counts [tc,S,C] f32, right_counts)``.
    ``feature`` ids are global (``f_base`` + position in ``hist``).
    """
    tc, S, F, B, C = hist.shape
    f_blk = choose_score_block(S, B, C, F, f_blk=f_blk)
    Fp = _round_up(F, f_blk)
    if Fp != F:
        # Padded features are masked out; they can never win (the
        # force-accept lands on flat position 0, a real feature).
        hist = jnp.pad(hist, ((0, 0), (0, 0), (0, Fp - F), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, Fp - F)))
    if carry is None:
        carry = init_carry(tc, S, C)

    grid = (tc, Fp // f_blk)
    carry_specs = [
        pl.BlockSpec((1, S), lambda t, f: (t, 0)),
        pl.BlockSpec((1, S), lambda t, f: (t, 0)),
        pl.BlockSpec((1, S), lambda t, f: (t, 0)),
        pl.BlockSpec((1, S, C), lambda t, f: (t, 0, 0)),
        pl.BlockSpec((1, S, C), lambda t, f: (t, 0, 0)),
    ]
    outs = pl.pallas_call(
        functools.partial(
            _split_scan_kernel, f_blk=f_blk, regression=regression
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, f_blk, B, C), lambda t, f: (t, 0, f, 0, 0)),
            pl.BlockSpec((1, f_blk), lambda t, f: (t, f)),
            pl.BlockSpec((1,), lambda t, f: (0,)),
            *carry_specs,
        ],
        out_specs=carry_specs,
        out_shape=[
            jax.ShapeDtypeStruct((tc, S), jnp.float32),
            jax.ShapeDtypeStruct((tc, S), jnp.int32),
            jax.ShapeDtypeStruct((tc, S), jnp.int32),
            jax.ShapeDtypeStruct((tc, S, C), jnp.float32),
            jax.ShapeDtypeStruct((tc, S, C), jnp.float32),
        ],
        interpret=interpret,
    )(
        hist.astype(jnp.float32),
        mask.astype(jnp.int32),
        jnp.full((1,), f_base, jnp.int32),
        *carry,
    )
    return tuple(outs)


def split_scan_scores(
    hist: jnp.ndarray,
    mask: jnp.ndarray | None,
    *,
    regression: bool = False,
    f_blk: int | None = None,
    interpret: bool | None = None,
) -> SplitScores:
    """Score a full [tc, S, F, B, C] histogram in one pallas_call.

    This is the ``split_backend="pallas"`` entry point of
    ``core/gain.level_scores`` — used when a combined histogram already
    exists (e.g. post-psum on each shard's feature slice). The
    fully-fused no-HBM-histogram path is ``core/forest.
    fused_level_scores``, which chains ``split_scan_block`` per slab.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tc, S, F, B, C = hist.shape
    if mask is None:
        mask = jnp.ones((tc, F), jnp.bool_)
    return SplitScores(
        *split_scan_block(
            hist, mask, None, 0,
            regression=regression, f_blk=f_blk, interpret=interpret,
        )
    )
