"""jit'd public wrappers for the fused split-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from ...core.gain import SplitScores
from .kernel import split_scan_scores
from .ref import split_scan_ref

# The production entry points are core/gain.level_scores(backend="pallas")
# (full-histogram scoring) and core/forest.fused_level_scores (the
# chained histogram-kernel -> score-kernel path with no HBM histogram);
# both call kernel.split_scan_block / split_scan_scores directly and
# handle backend/interpret resolution.


@partial(
    jax.jit,
    static_argnames=("regression", "use_pallas", "interpret", "f_blk"),
)
def fused_split_scores(
    hist,
    mask=None,
    *,
    regression: bool = False,
    use_pallas: bool = True,
    interpret: bool = True,
    f_blk: int | None = None,
) -> SplitScores:
    """SplitScores from a [tc, S, F, B, C] histogram; Pallas or jnp oracle."""
    if not use_pallas:
        return SplitScores(*split_scan_ref(hist, mask, regression=regression))
    return split_scan_scores(
        hist, mask, regression=regression, f_blk=f_blk, interpret=interpret
    )
