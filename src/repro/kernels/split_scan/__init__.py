# Fused T_NS split scoring: consumes histogram slabs in VMEM, keeps a
# running-best (gain, feature, threshold, child counts) carry, and only
# the O(k*S) winners ever reach HBM. kernel.py is the Pallas backend,
# ref.py the pure-XLA oracle, ops.py the jit'd public wrapper.
