"""Pure-XLA oracle for the fused split-scan kernel.

Same contract as ``kernel.split_scan_block``: score a histogram slab,
fold the result into a running-best carry with first-occurrence argmax
semantics, report global feature ids via ``f_base``. Numerics come from
the same ``core/gain.py`` ``*_from_cumsum`` scorers the kernel uses, so
the two are bit-identical — the parity bar of
``tests/test_split_backends.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...core.gain import (
    _select_winners, split_gain_ratios_from_cumsum, variance_gains_from_cumsum,
)


def split_scan_ref(
    hist: jnp.ndarray,           # [tc, S, F, B, C]
    mask: jnp.ndarray | None,    # [tc, F] bool
    carry: tuple | None = None,
    f_base: int = 0,
    *,
    regression: bool = False,
) -> tuple:
    """Reference running-best update over one histogram slab.

    Returns ``(gain [tc,S], feature [tc,S] i32 global, threshold,
    left_counts [tc,S,C], right_counts)``.
    """
    cum = jnp.cumsum(hist, axis=-2)
    total = cum[..., -1, :]
    if regression:
        sc = variance_gains_from_cumsum(cum, total)
    else:
        sc = split_gain_ratios_from_cumsum(cum, total)
    if mask is not None:
        sc = jnp.where(mask[:, None, :, None], sc, -jnp.inf)

    w = _select_winners(sc, cum, total)
    f_glob = w.feature + jnp.int32(f_base)
    if carry is None:
        return (w.gain_ratio, f_glob, w.threshold, w.left_counts, w.right_counts)

    gr0, f0, thr0, l0, r0 = carry
    better = (w.gain_ratio > gr0) | (f0 < 0)
    return (
        jnp.where(better, w.gain_ratio, gr0),
        jnp.where(better, f_glob, f0),
        jnp.where(better, w.threshold, thr0),
        jnp.where(better[..., None], w.left_counts, l0),
        jnp.where(better[..., None], w.right_counts, r0),
    )
