"""jit'd public wrapper for blocked attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import attention_pallas_call
from .ref import attention_ref


@partial(
    jax.jit,
    static_argnames=("causal", "window", "use_pallas", "interpret", "bq", "bkv"),
)
def flash_attention(
    q: jnp.ndarray,   # [B, H, Lq, D]
    k: jnp.ndarray,   # [B, H, Lk, D]
    v: jnp.ndarray,   # [B, H, Lk, D]
    *,
    causal: bool = True,
    window: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,     # CPU container; set False on real TPU
    bq: int = 128,
    bkv: int = 128,
) -> jnp.ndarray:
    B, H, Lq, D = q.shape
    qf = q.reshape(B * H, Lq, D)
    kf = k.reshape(B * H, -1, D)
    vf = v.reshape(B * H, -1, D)
    if not use_pallas:
        out = attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        out = attention_pallas_call(
            qf, kf, vf, causal=causal, window=window, bq=bq, bkv=bkv,
            interpret=interpret,
        )
    return out.reshape(B, H, Lq, D)
