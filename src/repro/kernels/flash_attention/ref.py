"""Pure-jnp oracle for blocked attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # [BH, Lq, D]
    k: jnp.ndarray,  # [BH, Lk, D]
    v: jnp.ndarray,  # [BH, Lk, D]
    *,
    causal: bool = True,
    window: int = 0,   # 0 = unbounded; else only attend to last `window` keys
) -> jnp.ndarray:
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits *= scale
    Lq, Lk = q.shape[1], k.shape[1]
    qpos = jnp.arange(Lq)[:, None] + (Lk - Lq)   # align ends (decode-friendly)
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32)).astype(q.dtype)
