"""Pallas TPU kernel: blocked attention with online softmax (flash-style).

Serving/long-context hot spot. Grid = (BH, q_blocks, kv_blocks) with the
kv axis innermost; the running (m, l, acc) statistics live in VMEM scratch
and persist across kv steps — the classic reduction-grid pattern. Causal
and sliding-window masking are applied per tile.

Block shapes default to (128, 128): MXU-aligned on the (q, kv) matmul
dims; D (head dim) rides along unblocked (<= 256 for all our archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bq, bkv, causal, window, lq, lk,
):
    ikv = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # [bq, D]
    k = k_ref[0].astype(jnp.float32)                    # [bkv, D]
    v = v_ref[0].astype(jnp.float32)
    scale = q.shape[-1] ** -0.5

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                           # [bq, bkv]

    iq = pl.program_id(1)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + (lk - lq)
    kpos = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                 # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)                     # rescale old stats
    p = jnp.exp(s - m_cur[:, None])                     # [bq, bkv]
    l_cur = alpha * l_scr[...] + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur
    l_scr[...] = l_cur

    @pl.when(ikv == nkv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-38)[:, None]).astype(
            o_ref.dtype
        )


def attention_pallas_call(
    q: jnp.ndarray,   # [BH, Lq, D]
    k: jnp.ndarray,   # [BH, Lk, D]
    v: jnp.ndarray,   # [BH, Lk, D]
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, Lq, D = q.shape
    Lk = k.shape[1]
    bq = min(bq, Lq)
    bkv = min(bkv, Lk)
    if Lq % bq or Lk % bkv:
        raise ValueError(f"L ({Lq},{Lk}) not divisible by blocks ({bq},{bkv})")
    grid = (BH, Lq // bq, Lk // bkv)

    return pl.pallas_call(
        functools.partial(
            _attn_kernel, bq=bq, bkv=bkv, causal=causal, window=window,
            lq=Lq, lk=Lk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, iq, ikv: (b, iq, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, iq, ikv: (b, ikv, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, iq, ikv: (b, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, iq, ikv: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
