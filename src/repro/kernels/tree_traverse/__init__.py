# Fused prediction: level-synchronous tree traversal walked entirely in
# VMEM with the Eq. 9/10 weighted vote accumulated in-register across
# the tree grid axis — only [N, C] scores leave the kernel, the
# [k, N, C] per-tree tensor never exists. kernel.py is the Pallas
# backend, ref.py the pure-XLA oracle, ops.py the jit'd public wrapper.
from .kernel import choose_traverse_block, traverse_block  # noqa: F401
from .ops import fused_vote  # noqa: F401
from .ref import traverse_ref  # noqa: F401
