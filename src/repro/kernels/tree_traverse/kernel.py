"""Pallas TPU kernel: fused tree traversal + weighted voting (paper §3.3).

Training (PR 1-2) is kernel-fused end to end, but prediction still ran
as an unrolled per-depth gather loop (``core/forest.route_to_leaves``)
that materialized the full ``[k, N, C]`` per-tree probability tensor in
HBM before Eq. (9)/(10) voting — for serving shapes that tensor is the
dominant memory traffic, and none of it survives the vote. This kernel
closes the prediction loop the same way ``kernels/split_scan`` closed
T_NS: the level-synchronous depth walk runs entirely in VMEM (the
forest's ``feature/threshold/left_child`` rows and the per-node vote
payload resident per tree-block, sample bins streamed in N-blocks), and
the weighted vote accumulates in-register across the tree grid axis as
a resumable carry. Only the ``[N, C]`` scores ever leave the kernel —
the ``[k, N, C]`` tensor never exists (jaxpr-verified by
``tests/test_predict_backends.py``).

Hard vs soft voting and classification vs regression are unified by the
**payload** input: per-(tree, node) vote vectors with the tree weight
``w_i`` already folded in —

    hard (Eq. 10):   payload[t, p] = w_t * onehot(argmax_c counts[t, p])
    soft:            payload[t, p] = w_t * counts[t, p] / sum_c counts
    regression (Eq. 9, C=1): payload[t, p, 0] = w_t * value[t, p]

so the kernel is a pure traversal + payload-accumulate; the Eq. (9)
normalization (``/ sum_i w_i`` or ``/ k``) happens on the tiny [N]
result outside. Payload construction lives in ``core/voting.py``.

Grid: ``(N_blocks, k)`` with the tree axis innermost (sequential), so
each sample block's ``[n_blk, C]`` score tile stays resident in VMEM
while trees stream through — the same reduction-grid pattern as the
histogram kernel. TPUs have no fast gather, so the per-depth node
lookups are one-hot select-reduces over the node pool and the final
leaf-payload read is a one-hot matmul on the MXU (exact: all other
summands are literal zeros). The carry is resumable: callers seed the
score tile from a previous call's output (``core/forest.
fused_vote_scores`` chains tree chunks; ``serving/prf_service.py``
feeds each shard's partial votes into one ``psum``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..gain_ratio.kernel import _VMEM_BUDGET, _round_up


def default_interpret() -> bool:
    """Interpret-mode emulation off-TPU, compiled on TPU — the ONE
    resolution rule every traversal caller shares (ops.fused_vote,
    core/forest.fused_vote_scores)."""
    return jax.default_backend() != "tpu"


def choose_traverse_block(
    P: int, F: int, C: int, *,
    n_blk: int | None = None, vmem_budget: int = _VMEM_BUDGET,
) -> int:
    """Sample-block height for the traversal kernel, from the shared
    VMEM budget.

    Working set per grid step is dominated by the [n_blk, P] one-hot
    node selector and its ~4 gather temporaries, plus the [n_blk, F]
    bins tile and feature one-hot and the [n_blk, C] score tile:
    ``n_blk * (6P + 2F + 2C) * 4`` bytes must fit the budget.
    """
    if n_blk is None:
        n_blk = 512
        while n_blk > 8 and n_blk * (6 * P + 2 * F + 2 * C) * 4 > vmem_budget:
            n_blk //= 2
    return n_blk


def _traverse_kernel(
    xb_ref, feat_ref, thr_ref, left_ref, payload_ref, s0_ref, out_ref,
    *, depth: int,
):
    """One (sample-block, tree) grid step: walk the tree, add its vote."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _seed_from_carry():
        out_ref[...] = s0_ref[...]

    xb = xb_ref[...]                                    # [n_blk, Fp] i32
    feat = feat_ref[0]                                  # [Pp] i32
    thr = thr_ref[0]
    left = left_ref[0]
    n_blk, Fp = xb.shape
    Pp = feat.shape[0]
    pcol = jax.lax.broadcasted_iota(jnp.int32, (n_blk, Pp), 1)
    fcol = jax.lax.broadcasted_iota(jnp.int32, (n_blk, Fp), 1)

    def step(_, node):
        # Node-pool gathers as one-hot select-reduces (no TPU gather);
        # exact — every non-selected summand is a literal zero.
        onehot = pcol == node[:, None]                  # [n_blk, Pp]
        f = jnp.sum(jnp.where(onehot, feat[None, :], 0), axis=1)
        th = jnp.sum(jnp.where(onehot, thr[None, :], 0), axis=1)
        lc = jnp.sum(jnp.where(onehot, left[None, :], 0), axis=1)
        leaf = f < 0
        f_safe = jnp.where(leaf, 0, f)
        b = jnp.sum(jnp.where(fcol == f_safe[:, None], xb, 0), axis=1)
        nxt = lc + (b > th).astype(jnp.int32)
        return jnp.where(leaf, node, nxt)

    node = jax.lax.fori_loop(
        0, depth, step, jnp.zeros((n_blk,), jnp.int32)
    )

    # Leaf payload read as a one-hot matmul on the MXU (exact).
    onehot = (pcol == node[:, None]).astype(jnp.float32)
    votes = jax.lax.dot_general(
        onehot, payload_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),     # onehot @ payload
        preferred_element_type=jnp.float32,
    )                                                   # [n_blk, C]
    out_ref[...] += votes


def traverse_block(
    x_binned: jnp.ndarray,      # [N, F] int bins
    feature: jnp.ndarray,       # [tc, P] i32, -1 = leaf
    threshold: jnp.ndarray,     # [tc, P] i32
    left_child: jnp.ndarray,    # [tc, P] i32
    payload: jnp.ndarray,       # [tc, P, C] f32 weighted vote vectors
    carry: jnp.ndarray | None,  # [N, C] f32 running scores (None = zeros)
    *,
    depth: int,
    n_blk: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fold one tree chunk's weighted votes into the running scores.

    Returns the updated ``[N, C]`` scores. Resumable: pass the result
    back as ``carry`` for the next chunk (or psum partial scores across
    tree shards) — chunked accumulation is exact because each tree's
    contribution is an exact payload row.
    """
    N, F = x_binned.shape
    tc, P = feature.shape
    C = payload.shape[-1]
    n_blk = choose_traverse_block(P, F, C, n_blk=n_blk)
    n_blk = min(n_blk, _round_up(max(N, 1), 8))

    Np, Fp, Pp = _round_up(N, n_blk), _round_up(F, 8), _round_up(P, 8)
    xb = x_binned.astype(jnp.int32)
    if Np != N or Fp != F:
        # Padded samples traverse the tree like real ones but are
        # sliced off the output; padded feature columns are never
        # addressed (real feature ids < F).
        xb = jnp.pad(xb, ((0, Np - N), (0, Fp - F)))
    if Pp != P:
        # Padded pool slots are leaves with zero payload; unreachable
        # anyway (traversal starts at the root, slot 0).
        feature = jnp.pad(feature, ((0, 0), (0, Pp - P)), constant_values=-1)
        threshold = jnp.pad(threshold, ((0, 0), (0, Pp - P)))
        left_child = jnp.pad(left_child, ((0, 0), (0, Pp - P)))
        payload = jnp.pad(payload, ((0, 0), (0, Pp - P), (0, 0)))
    if carry is None:
        carry = jnp.zeros((N, C), jnp.float32)
    carry = jnp.pad(carry.astype(jnp.float32), ((0, Np - N), (0, 0)))

    grid = (Np // n_blk, tc)
    out = pl.pallas_call(
        functools.partial(_traverse_kernel, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, Fp), lambda n, t: (n, 0)),      # bins
            pl.BlockSpec((1, Pp), lambda n, t: (t, 0)),          # feature
            pl.BlockSpec((1, Pp), lambda n, t: (t, 0)),          # threshold
            pl.BlockSpec((1, Pp), lambda n, t: (t, 0)),          # left_child
            pl.BlockSpec((1, Pp, C), lambda n, t: (t, 0, 0)),    # payload
            pl.BlockSpec((n_blk, C), lambda n, t: (n, 0)),       # carry
        ],
        out_specs=pl.BlockSpec((n_blk, C), lambda n, t: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, C), jnp.float32),
        interpret=interpret,
    )(
        xb,
        feature.astype(jnp.int32),
        threshold.astype(jnp.int32),
        left_child.astype(jnp.int32),
        payload.astype(jnp.float32),
        carry,
    )
    return out[:N]
