"""jit'd public wrappers for the fused tree-traversal kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import traverse_block
from .ref import traverse_ref

# The production entry points are core/forest.fused_vote_scores (the
# tree-chunked carry loop behind ForestConfig.predict_backend) and the
# serving layer's sharded partial-vote path; both call
# kernel.traverse_block directly and handle backend/interpret
# resolution. This wrapper is the standalone kernel-vs-oracle surface.


@partial(
    jax.jit,
    static_argnames=("depth", "use_pallas", "interpret", "n_blk"),
)
def fused_vote(
    x_binned,
    feature,
    threshold,
    left_child,
    payload,
    carry=None,
    *,
    depth: int,
    use_pallas: bool = True,
    interpret: bool | None = None,
    n_blk: int | None = None,
):
    """Weighted-vote scores [N, C] from a node-pool forest; Pallas or oracle.

    ``interpret=None`` resolves via ``kernel.default_interpret`` (the
    shared rule: emulation off-TPU, compiled on TPU), so backend
    selection cannot diverge across callers — the serving layer's
    sharded path routes through here.
    """
    if not use_pallas:
        return traverse_ref(
            x_binned, feature, threshold, left_child, payload, carry,
            depth=depth,
        )
    if interpret is None:
        from .kernel import default_interpret

        interpret = default_interpret()
    return traverse_block(
        x_binned, feature, threshold, left_child, payload, carry,
        depth=depth, n_blk=n_blk, interpret=interpret,
    )
