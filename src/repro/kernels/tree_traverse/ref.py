"""Pure-XLA oracle for the fused tree-traversal kernel.

Same contract as ``kernel.traverse_block``: walk every tree for every
sample for ``depth`` level-synchronous steps, read the leaf's weighted
vote payload, and fold the per-tree votes into a running ``[N, C]``
score carry. This is the clarity reference the parity matrix in
``tests/test_predict_backends.py`` pins the kernel against — it
deliberately materializes the per-tree ``[k, N, C]`` payload gather
that the kernel exists to avoid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def traverse_ref(
    x_binned: jnp.ndarray,      # [N, F] int bins
    feature: jnp.ndarray,       # [k, P] i32, -1 = leaf
    threshold: jnp.ndarray,     # [k, P] i32
    left_child: jnp.ndarray,    # [k, P] i32
    payload: jnp.ndarray,       # [k, P, C] f32 weighted vote vectors
    carry: jnp.ndarray | None = None,   # [N, C] f32
    *,
    depth: int,
) -> jnp.ndarray:
    """Reference weighted-vote scores. Returns [N, C] float32."""
    k = feature.shape[0]
    N = x_binned.shape[0]
    xb = x_binned.astype(jnp.int32)
    row = jnp.arange(N)[None, :]

    def step(node, _):
        f = jnp.take_along_axis(feature, node, 1)            # [k, N]
        leaf = f < 0
        f_safe = jnp.where(leaf, 0, f)
        b = xb[row, f_safe]
        th = jnp.take_along_axis(threshold, node, 1)
        lc = jnp.take_along_axis(left_child, node, 1)
        nxt = lc + (b > th).astype(jnp.int32)
        return jnp.where(leaf, node, nxt), None

    node0 = jnp.zeros((k, N), jnp.int32)
    leaves, _ = jax.lax.scan(step, node0, None, length=depth)

    votes = jnp.take_along_axis(
        payload.astype(jnp.float32), leaves[..., None], axis=1
    )                                                        # [k, N, C]
    scores = jnp.sum(votes, axis=0)
    return scores if carry is None else scores + carry.astype(jnp.float32)
