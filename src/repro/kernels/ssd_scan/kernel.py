"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

The SSD insight: within a chunk of Q steps the recurrence is a masked
attention-like matmul (MXU work); across chunks only the [N, P] state is
carried. Grid = (BH, n_chunks) with chunks innermost-sequential; the
carried state lives in VMEM scratch. Chunk size 128 aligns the (Q x Q)
and (Q x N)x(N x P) matmuls to the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, hT_ref, h_scr, *, q_blk):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)      # [Q, P]
    la = la_ref[0].astype(jnp.float32)    # [Q]
    b = b_ref[0].astype(jnp.float32)      # [Q, N]
    c = c_ref[0].astype(jnp.float32)      # [Q, N]

    lc = jnp.cumsum(la)                   # [Q] chunk-local cumulative log decay

    # Intra-chunk: masked decay-weighted "attention" on the MXU.
    s = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                     # [Q, Q] = c_i . b_j
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (q_blk, q_blk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (q_blk, q_blk), 1)
    mask = j_idx <= i_idx
    # clamp exponent under the mask (j > i would overflow exp -> inf)
    decay = jnp.exp(jnp.where(mask, lc[:, None] - lc[None, :], 0.0))
    s = jnp.where(mask, s * decay, 0.0)
    y = jax.lax.dot_general(
        s, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                     # [Q, P]

    # Carried-state contribution: y_i += (c_i * exp(lc_i)) @ H_prev.
    h_prev = h_scr[...]                   # [N, P]
    y += jax.lax.dot_general(
        c * jnp.exp(lc)[:, None], h_prev,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    y_ref[0] = y.astype(y_ref.dtype)

    # State update: H = exp(lc_Q) * H_prev + sum_j exp(lc_Q - lc_j) b_j x_j^T.
    w = jnp.exp(lc[-1] - lc)              # [Q]
    h_new = jnp.exp(lc[-1]) * h_prev + jax.lax.dot_general(
        b * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h_scr[...] = h_new

    @pl.when(ic == nc - 1)
    def _finish():
        hT_ref[0] = h_new.astype(hT_ref.dtype)


def ssd_pallas_call(
    x: jnp.ndarray,     # [BH, L, P]
    loga: jnp.ndarray,  # [BH, L]
    b: jnp.ndarray,     # [BH, L, N]
    c: jnp.ndarray,     # [BH, L, N]
    *,
    q_blk: int = 128,
    interpret: bool = False,
):
    BH, L, P = x.shape
    N = b.shape[-1]
    q_blk = min(q_blk, L)
    if L % q_blk:
        raise ValueError(f"L={L} % q_blk={q_blk} != 0")
    grid = (BH, L // q_blk)

    y, hT = pl.pallas_call(
        functools.partial(_ssd_kernel, q_blk=q_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_blk, P), lambda s, ic: (s, ic, 0)),
            pl.BlockSpec((1, q_blk), lambda s, ic: (s, ic)),
            pl.BlockSpec((1, q_blk, N), lambda s, ic: (s, ic, 0)),
            pl.BlockSpec((1, q_blk, N), lambda s, ic: (s, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_blk, P), lambda s, ic: (s, ic, 0)),
            pl.BlockSpec((1, N, P), lambda s, ic: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, loga, b, c)
    return y, hT
