"""Pure-jnp oracle for the Mamba-2 SSD scan: sequential state recurrence.

    h_t = a_t * h_{t-1} + b_t (x) x_t         h in R^{N x P}
    y_t = c_t^T h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jnp.ndarray,     # [BH, L, P]
    loga: jnp.ndarray,  # [BH, L]   log decay (<= 0)
    b: jnp.ndarray,     # [BH, L, N]
    c: jnp.ndarray,     # [BH, L, N]
    h0: jnp.ndarray | None = None,   # [BH, N, P]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [BH, L, P], h_final [BH, N, P])."""
    BH, L, P = x.shape
    N = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((BH, N, P), jnp.float32)

    def per_seq(x_s, la_s, b_s, c_s, h_init):
        def step(h, inp):
            x_t, la_t, b_t, c_t = inp
            h = jnp.exp(la_t) * h + b_t[:, None] * x_t[None, :]
            y_t = c_t @ h
            return h, y_t

        h_fin, y = jax.lax.scan(step, h_init, (x_s, la_s, b_s, c_s))
        return y, h_fin

    y, h_fin = jax.vmap(per_seq)(
        x.astype(jnp.float32), loga.astype(jnp.float32),
        b.astype(jnp.float32), c.astype(jnp.float32), h0,
    )
    return y, h_fin
