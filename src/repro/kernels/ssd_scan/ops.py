"""jit'd public wrapper for the SSD chunked scan."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_pallas_call
from .ref import ssd_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "q_blk"))
def ssd_scan(
    x: jnp.ndarray,     # [BH, L, P]
    loga: jnp.ndarray,  # [BH, L]
    b: jnp.ndarray,     # [BH, L, N]
    c: jnp.ndarray,     # [BH, L, N]
    *,
    use_pallas: bool = True,
    interpret: bool = True,     # CPU container; set False on real TPU
    q_blk: int = 128,
):
    """Returns (y [BH, L, P], h_final [BH, N, P])."""
    if not use_pallas:
        return ssd_ref(x, loga, b, c)
    return ssd_pallas_call(x, loga, b, c, q_blk=q_blk, interpret=interpret)
