"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD formulation: intra-chunk work is dense matmuls (MXU), only an
[H, N, P] state crosses chunk boundaries. The pure-jnp chunked path below
is the jit/dry-run implementation; kernels/ssd_scan is the Pallas TPU
drop-in (same math, validated against the same sequential oracle).

Decode carries (conv window, SSD state) — O(1) per token, which is what
qualifies the SSM archs for the long_500k shape.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import Params, _dense_init, init_rmsnorm, rmsnorm


def _dims(cfg: ArchConfig, d_in: int):
    d_inner = cfg.ssm_expand * d_in
    H = max(d_inner // cfg.ssm_head_dim, 1)
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    return d_inner, H, P, N


def init_mamba(key, cfg: ArchConfig, d_in: Optional[int] = None) -> Params:
    d_in = d_in or cfg.d_model
    d_inner, H, P, N = _dims(cfg, d_in)
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense_init(ks[0], (d_in, 2 * d_inner + 2 * N + H)),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, conv_ch), scale=cfg.conv_width ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(d_inner),
        "out_proj": _dense_init(ks[2], (d_inner, d_in)),
    }


def _split_proj(p, x, cfg, d_in):
    d_inner, H, P, N = _dims(cfg, d_in)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N :]
    return z, xbc, dt


def _causal_conv(p, xbc):
    """Depthwise causal conv, width W. xbc [B, S, C]."""
    W = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i].astype(xbc.dtype)
        for i in range(W)
    )
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _ssd_chunked(x, loga, b, c, h0, chunk: int):
    """Chunked SSD: x [B,S,H,P], loga [B,S,H], b/c [B,S,N], h0 [B,H,N,P].

    Returns (y [B,S,H,P], h_final). Pure jnp; mirrors kernels/ssd_scan.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    G = S // chunk
    xg = x.reshape(B, G, chunk, H, P)
    lg = loga.reshape(B, G, chunk, H)
    bg = b.reshape(B, G, chunk, N)
    cg = c.reshape(B, G, chunk, N)

    lc = jnp.cumsum(lg, axis=2)                                   # [B,G,Q,H]
    # Intra-chunk masked attention-like term. The exponent is clamped
    # UNDER the mask: for j > i it is positive and exp() would overflow
    # to inf, turning masked 0*inf into NaN gradients.
    s = jnp.einsum("bgin,bgjn->bgij", cg.astype(jnp.float32), bg.astype(jnp.float32))
    ii = jnp.arange(chunk)
    mask = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    delta = lc[:, :, :, None, :] - lc[:, :, None, :, :]           # [B,G,i,j,H]
    decay = jnp.exp(jnp.where(mask, delta, 0.0))
    sd = jnp.where(mask, s[..., None] * decay, 0.0)               # [B,G,i,j,H]
    y = jnp.einsum("bgijh,bgjhp->bgihp", sd, xg.astype(jnp.float32))

    # Chunk summaries.
    w_end = jnp.exp(lc[:, :, -1:, :] - lc)                        # [B,G,Q,H]
    summ = jnp.einsum(
        "bgjn,bgjh,bgjhp->bghnp", bg.astype(jnp.float32), w_end, xg.astype(jnp.float32)
    )                                                             # [B,G,H,N,P]
    chunk_decay = jnp.exp(lc[:, :, -1, :])                        # [B,G,H]

    # Inter-chunk recurrence over G (scan).
    def step(h, inp):
        summ_g, dec_g = inp                                       # [B,H,N,P], [B,H]
        h_out = h                                                 # state entering chunk
        h = dec_g[..., None, None] * h + summ_g
        return h, h_out

    h_fin, h_in = jax.lax.scan(
        step, h0, (jnp.moveaxis(summ, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                               # [B,G,H,N,P]

    # Carried-state contribution.
    y += jnp.einsum(
        "bgin,bgih,bghnp->bgihp", cg.astype(jnp.float32), jnp.exp(lc), h_in
    )
    return y.reshape(B, S, H, P).astype(x.dtype), h_fin


def mamba_train(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, *, d_in: Optional[int] = None,
    chunk: int = 128,
) -> jnp.ndarray:
    d_in = d_in or cfg.d_model
    d_inner, H, P, N = _dims(cfg, d_in)
    B, S, _ = x.shape
    z, xbc, dt = _split_proj(p, x, cfg, d_in)
    xbc = _causal_conv(p, xbc)
    xs = xbc[..., :d_inner].reshape(B, S, H, P)
    b = xbc[..., d_inner : d_inner + N]
    c = xbc[..., d_inner + N :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["a_log"])                                      # [H] < 0
    loga = dt * a                                                 # [B,S,H]
    xdt = xs * dt[..., None].astype(xs.dtype)

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    y, _ = _ssd_chunked(xdt, loga, b, c, h0, min(chunk, S))
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"].astype(y.dtype)


def mamba_prefill(p, x, cfg, *, d_in=None, chunk: int = 128):
    """Train-style pass that also returns (conv_state, ssd_state)."""
    d_in = d_in or cfg.d_model
    d_inner, H, P, N = _dims(cfg, d_in)
    B, S, _ = x.shape
    z, xbc_raw, dt = _split_proj(p, x, cfg, d_in)
    xbc = _causal_conv(p, xbc_raw)
    xs = xbc[..., :d_inner].reshape(B, S, H, P)
    b = xbc[..., d_inner : d_inner + N]
    c = xbc[..., d_inner + N :]
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    loga = dtf * a
    xdt = xs * dtf[..., None].astype(xs.dtype)
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    y, h_fin = _ssd_chunked(xdt, loga, b, c, h0, min(chunk, S))
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = rmsnorm(p["norm"], y.reshape(B, S, d_inner) * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(y.dtype)
    W = cfg.conv_width
    conv_state = xbc_raw[:, -(W - 1) :, :]                        # last raw inputs
    return out, {"conv": conv_state, "h": h_fin}


def mamba_decode(p, x, cache, cfg, *, d_in=None):
    """One token. x [B, 1, D]; cache conv [B, W-1, C], h [B, H, N, P]."""
    d_in = d_in or cfg.d_model
    d_inner, H, P, N = _dims(cfg, d_in)
    B = x.shape[0]
    z, xbc_raw, dt = _split_proj(p, x, cfg, d_in)                 # [B,1,...]
    W = cfg.conv_width
    window = jnp.concatenate([cache["conv"], xbc_raw], axis=1)    # [B, W, C]
    conv = sum(
        window[:, i, :] * p["conv_w"][i].astype(x.dtype) for i in range(W)
    )
    xbc = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))         # [B, C]
    xs = xbc[..., :d_inner].reshape(B, H, P)
    b = xbc[..., d_inner : d_inner + N]
    c = xbc[..., d_inner + N :]
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtf * a)                                      # [B,H]
    h = decay[..., None, None] * cache["h"] + jnp.einsum(
        "bn,bhp->bhnp", b.astype(jnp.float32),
        (xs * dtf[..., None].astype(xs.dtype)).astype(jnp.float32),
    )
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), h).astype(x.dtype)
    y = y + xs * p["d_skip"][None, :, None].astype(xs.dtype)
    y = rmsnorm(p["norm"], y.reshape(B, 1, d_inner) * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(y.dtype)
    return out, {"conv": window[:, 1:], "h": h}
