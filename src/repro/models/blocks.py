"""Per-layer-kind block assembly (pre-norm residual blocks).

Kinds:
  dense / local / global   self-attention (+window/theta variants) + MLP
  moe                      self-attention + MoE FFN (shared + routed)
  ssm                      Mamba-2 block (no MLP when d_ff == 0)
  hybrid                   parallel attention + Mamba heads (Hymba) + MLP
  cross                    cross-attention to vision embeddings + MLP
  enc / dec                whisper encoder / decoder blocks

``block_init(kind, key, cfg)`` builds params; ``block_apply`` runs one of
three modes: "train" (full seq, no cache), "prefill" (full seq -> cache),
"decode" (one token + cache). The ``Ctx`` carries everything modal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import mamba as mb
from . import mla
from . import moe as moe_mod
from .layers import (
    Params, attention_decode, attention_prefill, attention_train,
    causal_mask, gqa_attend, init_attention, init_mlp, init_rmsnorm,
    mlp_apply, rmsnorm, rope_apply, _qkv, _kv_for_cross, attn_out,
)


@dataclasses.dataclass
class Ctx:
    """Modal context threaded through block_apply."""
    cfg: ArchConfig
    mode: str                          # train | prefill | decode
    positions: Optional[jnp.ndarray] = None   # [S] or [B, S]
    pos: Optional[jnp.ndarray] = None         # decode: scalar position
    s_max: int = 0                            # cache capacity
    cross_src: Optional[jnp.ndarray] = None   # vision / encoder output
    mesh: Any = None                          # for shard_map EP
    meta: Optional[jnp.ndarray] = None        # hymba meta tokens [M, D]


def _kind_attn_args(kind: str, cfg: ArchConfig):
    window = cfg.local_window if kind in ("local", "hybrid") else 0
    theta = (
        cfg.rope_theta_global
        if (kind == "global" and cfg.rope_theta_global)
        else cfg.rope_theta
    )
    return window, theta


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def block_init(kind: str, key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    if kind in ("dense", "local", "global"):
        ff = cfg.dense_d_ff if (cfg.n_experts and cfg.dense_d_ff) else cfg.d_ff
        return {
            "ln1": init_rmsnorm(D), "attn": init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(D), "mlp": init_mlp(ks[1], D, ff, cfg.act),
        }
    if kind == "moe":
        p = {
            "ln1": init_rmsnorm(D),
            "ln2": init_rmsnorm(D),
            "moe": moe_mod.init_moe(ks[1], cfg),
        }
        p["attn"] = mla.init_mla(ks[0], cfg) if cfg.use_mla else init_attention(ks[0], cfg)
        return p
    if kind == "ssm":
        return {"ln1": init_rmsnorm(D), "ssm": mb.init_mamba(ks[0], cfg)}
    if kind == "hybrid":
        return {
            "ln1": init_rmsnorm(D),
            "attn": init_attention(ks[0], cfg),
            "ssm": mb.init_mamba(ks[1], cfg),
            "attn_norm": init_rmsnorm(D),
            "ssm_norm": init_rmsnorm(D),
            "gate_attn": jnp.ones((D,), jnp.float32) * 0.5,
            "gate_ssm": jnp.ones((D,), jnp.float32) * 0.5,
            "ln2": init_rmsnorm(D),
            "mlp": init_mlp(ks[2], D, cfg.d_ff, cfg.act),
        }
    if kind == "cross":
        return {
            "ln1": init_rmsnorm(D), "attn": init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(D), "mlp": init_mlp(ks[1], D, cfg.d_ff, cfg.act),
            "xgate": jnp.zeros((D,), jnp.float32),   # llama-vision gated x-attn
        }
    if kind == "enc":
        return {
            "ln1": init_rmsnorm(D), "attn": init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(D), "mlp": init_mlp(ks[1], D, cfg.d_ff, cfg.act),
        }
    if kind == "dec":
        return {
            "ln1": init_rmsnorm(D), "attn": init_attention(ks[0], cfg),
            "lnx": init_rmsnorm(D), "xattn": init_attention(ks[1], cfg),
            "ln2": init_rmsnorm(D), "mlp": init_mlp(ks[2], D, cfg.d_ff, cfg.act),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# attention with optional meta-token prefix (hymba)
# ---------------------------------------------------------------------------


def _self_attn(p, x, ctx: Ctx, kind: str, cache=None):
    """Returns (out, new_cache or None)."""
    cfg = ctx.cfg
    window, theta = _kind_attn_args(kind, cfg)
    M = cfg.meta_tokens if kind == "hybrid" else 0

    if ctx.mode == "decode":
        out, new_cache = attention_decode(
            p, x, ctx.pos + M, cache, cfg, window=window, theta=theta, prefix=M,
            mesh=ctx.mesh,
        )
        return out, new_cache

    if M:
        from .layers import MaskSpec, _auto_q_chunk, roll_to_window

        meta = jnp.broadcast_to(
            ctx.meta[None].astype(x.dtype), (x.shape[0],) + ctx.meta.shape
        )
        src = jnp.concatenate([meta, x], axis=1)
        positions = jnp.arange(src.shape[1])
        q, _, _ = _qkv(p, x, cfg)
        q = rope_apply(q, positions[M:], theta)
        _, k, v = _qkv(p, src, cfg)
        k = rope_apply(k, positions, theta)
        from .layers import seq_shard_qkv

        qs, ks, vs = seq_shard_qkv(q, k, v, ctx.mesh, cfg.n_heads, enabled=cfg.seq_shard_attn)
        S = x.shape[1]
        spec = MaskSpec(causal=True, window=window, prefix=M, offset=M)
        o = gqa_attend(qs, ks, vs, mask_spec=spec, q_chunk=_auto_q_chunk(S))
        out = attn_out(p, o)
        if ctx.mode == "prefill":
            if window > 0:  # meta prefix + rolling window buffer
                k = jnp.concatenate(
                    [k[:, :M], roll_to_window(k[:, M:], window)], axis=1
                )
                v = jnp.concatenate(
                    [v[:, :M], roll_to_window(v[:, M:], window)], axis=1
                )
            else:
                pad = ctx.s_max + M - k.shape[1]
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return out, {"k": k, "v": v}
        return out, None

    if ctx.mode == "train":
        return attention_train(
            p, x, ctx.positions, cfg, window=window, theta=theta, mesh=ctx.mesh
        ), None
    out, kv = attention_prefill(
        p, x, ctx.positions, cfg, window=window, theta=theta, s_max=ctx.s_max,
        mesh=ctx.mesh,
    )
    return out, kv


def _cross_attn(p, x, ctx: Ctx, cache=None):
    """Cross attention; KV from ctx.cross_src (train/prefill) or cache."""
    cfg = ctx.cfg
    if ctx.mode == "decode":
        out, _ = attention_decode(p, x, ctx.pos, cache, cfg, cross=True)
        return out, cache
    out = attention_train(
        p, x, ctx.positions, cfg, cross_src=ctx.cross_src, mesh=ctx.mesh
    )
    if ctx.mode == "prefill":
        k, v = _kv_for_cross(p, ctx.cross_src, cfg)
        return out, {"k": k, "v": v}
    return out, None


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def block_apply(kind: str, p: Params, x, ctx: Ctx, cache=None):
    """Returns (x, new_cache, aux_loss)."""
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)

    if kind in ("dense", "local", "global"):
        a, kv = _self_attn(p["attn"], rmsnorm(p["ln1"], x), ctx, kind, cache)
        x = x + a
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg.act)
        return x, kv, aux

    if kind == "moe":
        h = rmsnorm(p["ln1"], x)
        if cfg.use_mla:
            if ctx.mode == "train":
                a, kv = mla.mla_train(p["attn"], h, ctx.positions, cfg), None
            elif ctx.mode == "prefill":
                a, kv = mla.mla_prefill(p["attn"], h, ctx.positions, cfg, s_max=ctx.s_max)
            else:
                a, kv = mla.mla_decode(p["attn"], h, ctx.pos, cache, cfg)
        else:
            a, kv = _self_attn(p["attn"], h, ctx, "dense", cache)
        x = x + a
        h2 = rmsnorm(p["ln2"], x)
        dp_ok = False
        if ctx.mesh is not None:
            _dp = [ctx.mesh.shape[a] for a in ctx.mesh.axis_names if a != "model"]
            _dpt = 1
            for s in _dp:
                _dpt *= s
            dp_ok = h2.shape[0] % _dpt == 0
        if cfg.ep_mode == "shard_map" and ctx.mesh is not None and dp_ok:
            from jax.sharding import PartitionSpec as P

            dp = tuple(a for a in ctx.mesh.axis_names if a != "model")

            def _moe_kernel(px, hx):
                yk, auxk = moe_mod.moe_apply_shard_map(px, hx, cfg)
                return yk, jax.lax.pmean(auxk, dp)   # replicate across DP shards

            y, aux = jax.shard_map(
                _moe_kernel,
                mesh=ctx.mesh,
                in_specs=(_moe_param_specs(p["moe"]), P(dp, None, None)),
                out_specs=(P(dp, None, None), P()),
                check_vma=False,
            )(p["moe"], h2)
        else:
            y, aux = moe_mod.moe_apply_gspmd(p["moe"], h2, cfg)
        return x + y, kv, aux

    if kind == "ssm":
        h = rmsnorm(p["ln1"], x)
        if ctx.mode == "train":
            y, st = mb.mamba_train(p["ssm"], h, cfg), None
        elif ctx.mode == "prefill":
            y, st = mb.mamba_prefill(p["ssm"], h, cfg)
        else:
            y, st = mb.mamba_decode(p["ssm"], h, cache, cfg)
        return x + y, st, aux

    if kind == "hybrid":
        h = rmsnorm(p["ln1"], x)
        c_attn = cache["attn"] if cache is not None else None
        c_ssm = cache["ssm"] if cache is not None else None
        a, kv = _self_attn(p["attn"], h, ctx, "hybrid", c_attn)
        if ctx.mode == "train":
            s, st = mb.mamba_train(p["ssm"], h, cfg), None
        elif ctx.mode == "prefill":
            s, st = mb.mamba_prefill(p["ssm"], h, cfg)
        else:
            s, st = mb.mamba_decode(p["ssm"], h, c_ssm, cfg)
        y = (
            p["gate_attn"].astype(x.dtype) * rmsnorm(p["attn_norm"], a)
            + p["gate_ssm"].astype(x.dtype) * rmsnorm(p["ssm_norm"], s)
        )
        x = x + y
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg.act)
        new_cache = None
        if kv is not None or st is not None:
            new_cache = {"attn": kv, "ssm": st}
        return x, new_cache, aux

    if kind == "cross":
        a, kv = _cross_attn(p["attn"], rmsnorm(p["ln1"], x), ctx, cache)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * a
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg.act)
        return x, kv, aux

    if kind == "enc":
        h = rmsnorm(p["ln1"], x)
        q, k, v = _qkv(p["attn"], h, cfg)
        o = gqa_attend(q, k, v, mask=None)                      # bidirectional
        x = x + attn_out(p["attn"], o)
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg.act)
        return x, None, aux

    if kind == "dec":
        c_self = cache["self"] if cache is not None else None
        c_cross = cache["cross"] if cache is not None else None
        a, kv = _self_attn(p["attn"], rmsnorm(p["ln1"], x), ctx, "dense", c_self)
        x = x + a
        a2, xkv = _cross_attn(p["xattn"], rmsnorm(p["lnx"], x), ctx, c_cross)
        x = x + a2
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg.act)
        new_cache = None
        if kv is not None or xkv is not None:
            new_cache = {"self": kv, "cross": xkv}
        return x, new_cache, aux

    raise ValueError(kind)


def _moe_param_specs(moe_params):
    """PartitionSpecs for the inner-shard_map MoE call: experts sharded on
    their leading axis over `model`, router/shared replicated."""
    from jax.sharding import PartitionSpec as P

    def spec(path_leaf):
        return path_leaf

    specs = {}
    for name, sub in moe_params.items():
        if name == "experts":
            specs[name] = {k: P("model") for k in sub}
        elif name == "shared":
            specs[name] = {k: P() for k in sub}
        else:
            specs[name] = P()
    return specs
