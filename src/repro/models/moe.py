"""Fine-grained Mixture-of-Experts (DeepSeek family) with expert parallelism.

Two dispatch modes (the §Perf hillclimb compares them):

* ``gspmd``     — dense one-hot combine einsums; XLA's SPMD partitioner
  chooses the collectives. Simple, and the *paper-faithful analogue of
  horizontal partitioning*: token activations are gathered to wherever
  the experts live.
* ``shard_map`` — explicit capacity-bucketed all_to_all over the `model`
  mesh axis (expert parallelism). Tokens move to the shard that owns
  their expert, exactly the paper's "move the task to the data" vertical
  rule (§4.1: feature subsets pinned, tasks dispatched to them).

Both modes share the router and the capacity-drop policy so they are
numerically interchangeable (validated in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import Params, _dense_init, init_mlp, mlp_apply


def init_moe(key, cfg: ArchConfig) -> Params:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    glu = cfg.act in ("swiglu", "geglu")
    p = {
        "router": _dense_init(ks[0], (D, E)),
        "experts": {
            "w1": _dense_init(ks[1], (E, D, F)),
            "w2": _dense_init(ks[2], (E, F, D)),
        },
    }
    if glu:
        p["experts"]["w3"] = _dense_init(ks[3], (E, D, F))
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(
            jax.random.fold_in(key, 99), D, F * cfg.n_shared_experts, cfg.act
        )
    return p


def _expert_ffn(pe: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    """x [E, T, D] batched over local experts."""
    h = jnp.einsum("etd,edf->etf", x, pe["w1"].astype(x.dtype))
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("etd,edf->etf", x, pe["w3"].astype(x.dtype))
    elif act == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("etd,edf->etf", x, pe["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("etf,efd->etd", h, pe["w2"].astype(x.dtype))


def _route(p, x2d, cfg: ArchConfig):
    """Top-K routing with normalized softmax gates.

    Returns (idx [T, K], gate [T, K], aux_loss scalar).
    """
    logits = (x2d @ p["router"].astype(x2d.dtype)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Load-balance auxiliary loss (Switch-style).
    T, E = probs.shape
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * cfg.experts_per_token)
    aux = E * jnp.sum(me * ce)
    return idx, gate.astype(x2d.dtype), aux


def _capacity(T: int, cfg: ArchConfig) -> int:
    cap = int(T * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(cap, 4)


def _dispatch_indices(idx, cfg, T, cap):
    """Position of each (token, k) assignment within its expert's bucket.

    Returns (pos [T, K], keep [T, K]) — deterministic capacity-drop by
    token order (GShard policy), computed with one stable sort.
    """
    K = cfg.experts_per_token
    flat_e = idx.reshape(-1)                                   # [T*K]
    order = jnp.argsort(flat_e, stable=True)                   # group by expert
    ranks = jnp.arange(T * K, dtype=jnp.int32)
    # position within group = running index - index of group start
    sorted_e = flat_e[order]
    seg_start = jnp.full((cfg.n_experts,), T * K, jnp.int32).at[sorted_e].min(ranks)
    pos_sorted = ranks - seg_start[sorted_e]
    pos = jnp.zeros((T * K,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    return pos.reshape(T, K), keep.reshape(T, K)


def moe_apply_gspmd(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    """Dense dispatch/combine einsums; sharding left to GSPMD."""
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    T = x2d.shape[0]
    idx, gate, aux = _route(p, x2d, cfg)
    cap = _capacity(T, cfg)
    pos, keep = _dispatch_indices(idx, cfg, T, cap)

    # Scatter tokens into [E, cap, D] buckets.
    w = jnp.where(keep, gate, 0.0)                                   # [T, K]
    buckets = jnp.zeros((cfg.n_experts, cap, D), x.dtype)
    tok_rep = jnp.broadcast_to(
        x2d[:, None, :], (T, cfg.experts_per_token, D)
    ).reshape(-1, D)
    e_flat = idx.reshape(-1)
    p_flat = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)       # drops -> OOB
    buckets = buckets.at[e_flat, p_flat].add(tok_rep, mode="drop")

    out_buckets = _expert_ffn(p["experts"], buckets, cfg.act)        # [E, cap, D]

    # Gather back + gate.
    gathered = out_buckets.at[e_flat, p_flat].get(mode="fill", fill_value=0.0)
    y = (gathered.reshape(T, cfg.experts_per_token, D) * w[..., None]).sum(1)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x2d, cfg.act)
    return y.reshape(B, S, D), aux


def moe_apply_shard_map(
    p: Params, x: jnp.ndarray, cfg: ArchConfig, *, expert_axis: str = "model"
):
    """Explicit EP: capacity buckets + all_to_all over `expert_axis`.

    Runs inside an outer shard_map (see model.py) where `x` is the local
    token shard [B_loc, S, D] and the expert arrays are sharded on their
    leading axis. Here we receive the *local* expert slab and local
    tokens, and exchange bucket slabs with all_to_all.
    """
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    T = x2d.shape[0]
    idx, gate, aux = _route(p, x2d, cfg)
    cap = _capacity(T, cfg)
    pos, keep = _dispatch_indices(idx, cfg, T, cap)

    w = jnp.where(keep, gate, 0.0)
    buckets = jnp.zeros((cfg.n_experts, cap, D), x.dtype)
    tok_rep = jnp.broadcast_to(
        x2d[:, None, :], (T, cfg.experts_per_token, D)
    ).reshape(-1, D)
    e_flat = idx.reshape(-1)
    p_flat = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)
    buckets = buckets.at[e_flat, p_flat].add(tok_rep, mode="drop")   # [E, cap, D]

    P = jax.lax.axis_size(expert_axis)
    e_loc = cfg.n_experts // P
    # [E, cap, D] -> [P, e_loc, cap, D] -> exchange -> [P(src), e_loc, cap, D]
    send = buckets.reshape(P, e_loc, cap, D)
    recv = jax.lax.all_to_all(send, expert_axis, split_axis=0, concat_axis=0, tiled=False)
    # Local experts see P source-shards' buckets: [e_loc, P*cap, D].
    recv = jnp.transpose(recv, (1, 0, 2, 3)).reshape(e_loc, P * cap, D)

    # p["experts"] leaves arrive as the *local* expert slab [e_loc, ...]
    # (the enclosing shard_map shards the leading expert axis over
    # `expert_axis`).
    out_loc = _expert_ffn(p["experts"], recv, cfg.act)

    # Reverse exchange.
    back = jnp.transpose(out_loc.reshape(e_loc, P, cap, D), (1, 0, 2, 3))
    out_buckets = jax.lax.all_to_all(
        back, expert_axis, split_axis=0, concat_axis=0, tiled=False
    ).reshape(cfg.n_experts, cap, D)

    gathered = out_buckets.at[e_flat, p_flat].get(mode="fill", fill_value=0.0)
    y = (gathered.reshape(T, cfg.experts_per_token, D) * w[..., None]).sum(1)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x2d, cfg.act)
    return y.reshape(B, S, D), aux
