"""Model primitives: norms, rotary embeddings, MLPs, GQA attention.

Functional style: ``init_*`` builds param dicts, ``*_apply`` consumes
them. Per-layer params are later stacked on a leading layer axis and
driven by ``lax.scan`` (keeps HLO size flat in depth — critical for the
512-device dry-run compiles).

Attention avoids materializing repeated KV heads (GQA runs as grouped
einsum) and does softmax in fp32. The Pallas flash kernel
(kernels/flash_attention) is the TPU drop-in for the same contraction;
the einsum path is used under jit so SPMD partitioning and
cost_analysis stay exact.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig

Params = Dict[str, Any]


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_apply(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, hd] (hd even), positions broadcastable to [..., S]."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)   # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs          # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                                   # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, act: str) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w2": _dense_init(ks[1], (f, d))}
    if act in ("swiglu", "geglu"):
        p["w1"] = _dense_init(ks[0], (d, f))
        p["w3"] = _dense_init(ks[2], (d, f))
    else:
        p["w1"] = _dense_init(ks[0], (d, f))
    return p


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = x @ p["w1"].astype(x.dtype)
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"].astype(x.dtype))
    elif act == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional bias / qk-norm / window / cross)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(ks[0], (D, H, hd)),
        "wk": _dense_init(ks[1], (D, KV, hd)),
        "wv": _dense_init(ks[2], (D, KV, hd)),
        "wo": _dense_init(ks[3], (H, hd, D), scale=(H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    if cfg.qk_norm:
        p["qnorm"] = init_rmsnorm(hd)
        p["knorm"] = init_rmsnorm(hd)
    return p


def _qkv(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if "qnorm" in p:
        q = rmsnorm(p["qnorm"], q)
        k = rmsnorm(p["knorm"], k)
    return q, k, v


def _kv_for_cross(p: Params, src: jnp.ndarray, cfg: ArchConfig):
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(src.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(src.dtype)
        v = v + p["bv"].astype(src.dtype)
    if "knorm" in p:
        k = rmsnorm(p["knorm"], k)
    return k, v


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Parametric attention mask — built per query block, never at [Sq, Sk]."""
    causal: bool = True
    window: int = 0
    prefix: int = 0      # first `prefix` key positions always visible (meta)
    offset: int = 0      # qpos = q_index + offset (ends-aligned: Sk - Sq)

    def block(self, q0, qc: int, sk: int) -> jnp.ndarray:
        qpos = (jnp.arange(qc) + q0 + self.offset)[:, None]
        kpos = jnp.arange(sk)[None, :]
        m = jnp.ones((qc, sk), bool)
        if self.causal:
            m &= kpos <= qpos
        if self.window > 0:
            m &= kpos > qpos - self.window
        if self.prefix > 0:
            m |= kpos < self.prefix
        return m[None]


def gqa_attend(
    q: jnp.ndarray,      # [B, Sq, H, hd]
    k: jnp.ndarray,      # [B, Sk, KV, hd]
    v: jnp.ndarray,      # [B, Sk, KV, hd]
    *,
    mask: Optional[jnp.ndarray] = None,        # explicit [B or 1, Sq, Sk]
    mask_spec: Optional[MaskSpec] = None,      # or parametric
    q_chunk: int = 0,
) -> jnp.ndarray:
    """GQA attention. KV heads are repeated to H so the head axis (which
    all archs make TP-divisible, or GSPMD pads) carries the sharding; the
    repeat is a gather of the small KV tensor — each shard materializes
    only its own heads.

    ``q_chunk``: scan over query blocks so [Sq, Sk] logits never exist at
    full size (exact — every block still sees all keys).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        G = H // KV
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    Sk = k.shape[1]

    def attend_block(qb, q0):
        logits = jnp.einsum(
            "bqhd,bshd->bhqs", qb.astype(jnp.float32), k.astype(jnp.float32)
        ) * (hd ** -0.5)                                 # [B, H, qc, Sk]
        if mask_spec is not None:
            m = mask_spec.block(q0, qb.shape[1], Sk)
            logits = jnp.where(m[:, None], logits, -1e30)
        elif mask is not None:
            logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
        return out.astype(q.dtype)

    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0 and mask is None:
        nq = Sq // q_chunk
        qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)
        out = jax.lax.map(
            lambda t: attend_block(t[0], t[1] * q_chunk),
            (qs, jnp.arange(nq)),
        )
        return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return attend_block(q, 0)


def causal_mask(sq: int, sk: int, window: int = 0) -> jnp.ndarray:
    """[1, Sq, Sk] bool; ends aligned (Sk >= Sq)."""
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None]


def decode_mask(
    pos: jnp.ndarray, s_max: int, window: int = 0, prefix: int = 0
) -> jnp.ndarray:
    """[1, 1, S_max] bool for a single new token at position `pos`.

    ``prefix`` positions (meta tokens) stay visible regardless of window.
    """
    kpos = jnp.arange(s_max)[None, None, :]
    m = kpos <= pos
    if window > 0:
        m &= kpos > pos - window
    if prefix > 0:
        m |= kpos < prefix
    return m


def attn_out(p: Params, o: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def _auto_q_chunk(sq: int) -> int:
    """Chunk queries once [Sq, Sk] logits would dominate memory."""
    return 512 if sq > 8192 else 0


def seq_shard_qkv(q, k, v, mesh, n_heads: int, tp: str = "model",
                  enabled: bool = True):
    """Context-parallel attention layout for head counts that do not
    divide TP (smollm 9H, qwen 20H, hymba 25H, whisper 20H on tp=16):
    shard the *query sequence* over `model` and replicate K/V (small for
    GQA). Without this, GSPMD replicates the whole attention across the
    model axis — a silent tp-fold compute waste. Heads that do divide TP
    keep the classic head sharding (driven by the wq/wk specs)."""
    if mesh is None or tp not in mesh.axis_names or not enabled:
        return q, k, v
    tp_size = mesh.shape[tp]
    if n_heads % tp_size == 0:
        return q, k, v
    if q.shape[1] % tp_size != 0:   # decode (Sq=1): cache length sharding handles it
        return q, k, v
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a != tp)
    if q.shape[0] % int(np.prod([mesh.shape[a] for a in dp])) != 0:
        dp = ()
    wsc = jax.lax.with_sharding_constraint
    q = wsc(q, NamedSharding(mesh, P(dp or None, tp, None, None)))
    k = wsc(k, NamedSharding(mesh, P(dp or None, None, None, None)))
    v = wsc(v, NamedSharding(mesh, P(dp or None, None, None, None)))
    return q, k, v


def attention_train(
    p: Params, x: jnp.ndarray, positions: jnp.ndarray, cfg: ArchConfig,
    *, window: int = 0, theta: Optional[float] = None,
    cross_src: Optional[jnp.ndarray] = None, mesh=None,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill compute)."""
    theta = cfg.rope_theta if theta is None else theta
    if cross_src is None:
        q, k, v = _qkv(p, x, cfg)
        q = rope_apply(q, positions, theta)
        k = rope_apply(k, positions, theta)
        q, k, v = seq_shard_qkv(q, k, v, mesh, cfg.n_heads, enabled=cfg.seq_shard_attn)
        spec = MaskSpec(causal=True, window=window, offset=0)
        o = gqa_attend(q, k, v, mask_spec=spec, q_chunk=_auto_q_chunk(x.shape[1]))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
        if "qnorm" in p:
            q = rmsnorm(p["qnorm"], q)
        k, v = _kv_for_cross(p, cross_src, cfg)
        q, k, v = seq_shard_qkv(q, k, v, mesh, cfg.n_heads, enabled=cfg.seq_shard_attn)
        o = gqa_attend(q, k, v, q_chunk=_auto_q_chunk(x.shape[1]))  # dense
    return attn_out(p, o)


def roll_to_window(k: jnp.ndarray, window: int) -> jnp.ndarray:
    """Compress a full prefill KV [B, S, ...] into a rolling buffer [B, W, ...]
    where position p lives at slot p % W (matching decode updates)."""
    S = k.shape[1]
    if S < window:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, window - S)
        return jnp.pad(k, pad)
    last = k[:, S - window :]
    return jnp.roll(last, shift=(S - window) % window, axis=1)


def attention_prefill(p, x, positions, cfg, *, window=0, theta=None, s_max=None,
                      mesh=None):
    """Like train, but also returns the KV cache.

    Full-attention layers pad the cache to ``s_max``; windowed layers
    return a rolling buffer of length ``window`` (position p at slot
    p % W) — the cache never exceeds the attention horizon.
    """
    theta = cfg.rope_theta if theta is None else theta
    q, k, v = _qkv(p, x, cfg)
    q = rope_apply(q, positions, theta)
    k = rope_apply(k, positions, theta)
    qs, ks, vs = seq_shard_qkv(q, k, v, mesh, cfg.n_heads, enabled=cfg.seq_shard_attn)
    spec = MaskSpec(causal=True, window=window, offset=0)
    o = gqa_attend(qs, ks, vs, mask_spec=spec, q_chunk=_auto_q_chunk(x.shape[1]))
    if window > 0:
        k = roll_to_window(k, window)
        v = roll_to_window(v, window)
    else:
        s_max = s_max or x.shape[1]
        pad = s_max - x.shape[1]
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return attn_out(p, o), {"k": k, "v": v}


def _pin_cache_layout(arr, mesh, length_axis: int = 1):
    """flash-decode: constrain a cache tensor to its natural
    (batch->dp, length->model) layout so GSPMD computes softmax partials
    per length shard instead of all-gathering the cache (§Perf)."""
    if mesh is None or "model" not in mesh.axis_names:
        return arr
    if arr.shape[length_axis] % mesh.shape["model"] != 0:
        return arr
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    b = dp if arr.shape[0] % dp_total == 0 and arr.shape[0] >= dp_total else None
    spec = [b, None if length_axis != 1 else "model"] + [None] * (arr.ndim - 2)
    spec[length_axis] = "model"
    return jax.lax.with_sharding_constraint(arr, NamedSharding(mesh, P(*spec)))


def attention_decode(
    p: Params, x: jnp.ndarray, pos: jnp.ndarray, cache: Params, cfg: ArchConfig,
    *, window: int = 0, theta: Optional[float] = None,
    cross: bool = False, prefix: int = 0, mesh=None,
):
    """One-token step. x [B, 1, D].

    Full-attention cache: k/v [B, S_max, KV, hd], write at `pos`.
    Windowed cache:       k/v [B, W, KV, hd] rolling, write at pos % W.
    ``prefix`` meta tokens occupy [0, prefix) of a (prefix + W) buffer.
    """
    theta = cfg.rope_theta if theta is None else theta
    if cross:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
        if "qnorm" in p:
            q = rmsnorm(p["qnorm"], q)
        o = gqa_attend(q, cache["k"], cache["v"], mask=None)
        return attn_out(p, o), cache
    q, k_new, v_new = _qkv(p, x, cfg)
    q = rope_apply(q, pos[None, None], theta)            # single position
    k_new = rope_apply(k_new, pos[None, None], theta)
    if window > 0:
        # Rolling buffer: every resident slot is inside the window by
        # construction; mask only not-yet-filled slots (and keep meta
        # prefix slots always visible).
        slot = prefix + (pos - prefix) % window if prefix else pos % window
        kpos = jnp.arange(cache["k"].shape[1])[None, None, :]
        mask = (kpos < prefix) | (kpos <= pos)
    else:
        slot = pos
        mask = decode_mask(pos, cache["k"].shape[1], 0, prefix)
    k = cache_write(cache["k"], k_new, slot, cfg.decode_cache_update)
    v = cache_write(cache["v"], v_new, slot, cfg.decode_cache_update)
    if cfg.flash_decode:
        k = _pin_cache_layout(k, mesh)
        v = _pin_cache_layout(v, mesh)
    o = grouped_attend_one(q, k, v, mask=mask)
    return attn_out(p, o), {"k": k, "v": v}


def grouped_attend_one(q, k, v, *, mask):
    """Single-token GQA WITHOUT repeating KV heads.

    The repeat-to-H path (fine for training) breaks decode at scale: the
    head broadcast of a length-sharded cache has no valid GSPMD
    transition, so SPMD falls back to full rematerialization — an
    all-gather of the whole KV cache per layer per token (§Perf,
    llama-90b decode_32k). Grouped einsums keep the contraction local to
    each length shard; softmax over the sharded axis becomes the small
    LSE all-reduce pair (flash-decoding).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)                                     # [B, KV, G, 1, S]
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def cache_write(cache: jnp.ndarray, new: jnp.ndarray, slot, mode: str):
    """Write `new` [B, 1, ...] into `cache` [B, L, ...] at position `slot`.

    "dus": dynamic_update_slice — natural, but GSPMD must fully
    rematerialize a length-sharded cache to apply it (one all-gather of
    the cache per layer per token!).
    "where": masked elementwise rewrite — local under any sharding; costs
    one cache read+write of HBM traffic instead (§Perf, llama decode).
    """
    new = new.astype(cache.dtype)
    if mode == "where":
        L = cache.shape[1]
        sel = jnp.arange(L) == slot
        sel = sel.reshape((1, L) + (1,) * (cache.ndim - 2))
        return jnp.where(sel, new, cache)
    return jax.lax.dynamic_update_slice_in_dim(cache, new, slot, 1)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int) -> Params:
    return {"table": _dense_init(key, (vocab, d), scale=0.02)}


def embed(p: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype))


def sinusoidal_positions(s: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_at(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal embedding for a single (traced) position. [d]."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10_000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
