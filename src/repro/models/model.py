"""Model assembly: stage factoring, scan-over-layers, loss, prefill/decode.

Every architecture is a sequence of *stages*; a stage is a repeating
cycle of layer kinds (e.g. gemma3: 10 groups of [5x local, global]).
Per-stage params are stacked on a leading group axis and driven by
``lax.scan`` — HLO size stays flat in depth, which keeps the 512-device
dry-run compiles tractable, and FSDP param gathering happens one group
at a time (bounded live memory).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, _layer_kinds
from .blocks import Ctx, block_apply, block_init
from .layers import (
    Params, embed, init_embedding, init_rmsnorm, rmsnorm,
    sinusoidal_positions, unembed,
)


@dataclasses.dataclass(frozen=True)
class Stage:
    cycle: Tuple[str, ...]
    n_groups: int


def _factor_stages(kinds: List[str], max_period: int = 12) -> List[Stage]:
    """Factor a layer-kind list into repeating-cycle stages.

    Only cycles that repeat (g >= 2) are admitted — a long non-repeating
    cycle would unroll in the scan body and bloat the HLO.
    """
    stages: List[Stage] = []
    i = 0
    n = len(kinds)
    while i < n:
        best = (1, 1)  # (period, groups) — pd=1, g=1 always valid
        for pd in range(1, min(max_period, (n - i) // 2) + 1):
            cyc = kinds[i : i + pd]
            g = 1
            while i + (g + 1) * pd <= n and kinds[i + g * pd : i + (g + 1) * pd] == cyc:
                g += 1
            if g >= 2 and g * pd > best[0] * best[1]:
                best = (pd, g)
        pd, g = best
        stages.append(Stage(tuple(kinds[i : i + pd]), g))
        i += pd * g
    return stages


def build_stages(cfg: ArchConfig) -> List[Stage]:
    kinds = [k for k in _layer_kinds(cfg) if k not in ("enc",)]
    return _factor_stages(kinds)


def build_enc_stages(cfg: ArchConfig) -> List[Stage]:
    return _factor_stages(["enc"] * cfg.encoder_layers) if cfg.encoder_layers else []


class Model:
    """Functional model wrapper for one architecture."""

    def __init__(self, cfg: ArchConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.stages = build_stages(cfg)
        self.enc_stages = build_enc_stages(cfg)
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)

    def _constrain(self, x):
        """Pin activations to [batch->DP, seq, d_model replicated].

        Without this GSPMD may propagate the embedding table's layout
        into the residual stream (d_model sharded, batch REPLICATED) —
        silently multiplying compute by the DP degree.
        """
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P

        dp = tuple(a for a in self.mesh.axis_names if a != "model")
        dp_total = 1
        for a in dp:
            dp_total *= self.mesh.shape[a]
        if x.ndim < 2 or x.shape[0] % dp_total != 0:
            return x
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------ init

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params: Params = {
            "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = init_embedding(ks[1], cfg.vocab_size, cfg.d_model)
        if cfg.meta_tokens:
            params["meta"] = (
                jax.random.normal(ks[2], (cfg.meta_tokens, cfg.d_model)) * 0.02
            )
        params["stages"] = self._init_stages(ks[3], self.stages)
        if self.enc_stages:
            params["enc_stages"] = self._init_stages(ks[4], self.enc_stages)
            params["enc_norm"] = init_rmsnorm(cfg.d_model)
        if cfg.param_dtype != "float32":
            pdt = jnp.dtype(cfg.param_dtype)
            params = jax.tree_util.tree_map(lambda a: a.astype(pdt), params)
        return params

    def _init_stages(self, key, stages) -> List[Params]:
        out = []
        for si, st in enumerate(stages):
            skey = jax.random.fold_in(key, si)

            def init_group(gkey, _cycle=st.cycle):
                return {
                    f"l{j}": block_init(kind, jax.random.fold_in(gkey, j), self.cfg)
                    for j, kind in enumerate(_cycle)
                }

            out.append(jax.vmap(init_group)(jax.random.split(skey, st.n_groups)))
        return out

    # ------------------------------------------------------------- internals

    def _remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        if self.cfg.remat == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            return jax.checkpoint(fn, policy=policy)
        return jax.checkpoint(fn)

    def _run_stages(self, stage_params, stages, x, ctx: Ctx, caches=None,
                    remat=False):
        """Scan each stage. Returns (x, aux, new_caches)."""
        new_caches = []
        aux = jnp.zeros((), jnp.float32)

        for si, st in enumerate(stages):
            cycle = st.cycle
            gcaches = caches[si] if caches is not None else None

            def body(carry, xs, _cycle=cycle, _has_cache=(gcaches is not None)):
                x, aux = carry
                gp, gcache = xs if _has_cache else (xs, None)
                x = self._constrain(x)
                out_cache = {}
                for j, kind in enumerate(_cycle):
                    c_in = None if gcache is None else gcache[f"l{j}"]
                    x, c_out, a = block_apply(kind, gp[f"l{j}"], x, ctx, c_in)
                    aux = aux + a
                    out_cache[f"l{j}"] = c_out
                if any(v is not None for v in out_cache.values()):
                    return (x, aux), out_cache
                return (x, aux), None

            body_fn = self._remat(body) if remat else body
            xs = (stage_params[si], gcaches) if gcaches is not None else stage_params[si]
            (x, aux), ys = jax.lax.scan(body_fn, (x, aux), xs)
            new_caches.append(ys)
        return x, aux, new_caches

    def _encode(self, params, frames):
        """Whisper encoder on stubbed frame embeddings [B, T, D]."""
        x = frames.astype(self.compute_dtype)
        x = x + sinusoidal_positions(x.shape[1], self.cfg.d_model).astype(x.dtype)
        ctx = Ctx(cfg=self.cfg, mode="train", positions=jnp.arange(x.shape[1]))
        x, _, _ = self._run_stages(params["enc_stages"], self.enc_stages, x, ctx)
        return rmsnorm(params["enc_norm"], x)

    def _embed_in(self, params, tokens, pos=None):
        x = self._constrain(embed(params["embed"], tokens, self.compute_dtype))
        if self.cfg.rope_theta <= 0:  # whisper: sinusoidal absolute positions
            from .layers import sinusoidal_at

            if pos is None:
                x = x + sinusoidal_positions(
                    tokens.shape[1], self.cfg.d_model
                ).astype(x.dtype)
            else:
                x = x + sinusoidal_at(pos, self.cfg.d_model).astype(x.dtype)
        return x

    def _logits(self, params, x):
        x = rmsnorm(params["final_norm"], x)
        table = params["embed"] if self.cfg.tie_embeddings else params["unembed"]
        return unembed(table, x)

    def _ctx(self, mode, batch=None, params=None, **kw) -> Ctx:
        cfg = self.cfg
        cross_src = None
        if batch is not None and cfg.family == "vlm":
            cross_src = batch["vision_embeds"].astype(self.compute_dtype)
        if batch is not None and cfg.family == "encdec":
            cross_src = self._encode(params, batch["frames"])
        meta = params.get("meta") if (params and cfg.meta_tokens) else None
        return Ctx(cfg=cfg, mode=mode, cross_src=cross_src, meta=meta,
                   mesh=self.mesh, **kw)

    # ------------------------------------------------------------------ train

    def loss_fn(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Next-token CE (+ MoE aux loss). batch: tokens/targets [B, S]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        S = tokens.shape[1]
        ctx = self._ctx("train", batch, params, positions=jnp.arange(S))
        x = self._embed_in(params, tokens)
        x, aux, _ = self._run_stages(params["stages"], self.stages, x, ctx,
                                     remat=True)
        logits = self._logits(params, x)

        targets = batch["targets"]
        mask = (targets >= 0).astype(jnp.float32)
        tgt = jnp.maximum(targets, 0)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        tgt_logit = jnp.take_along_axis(
            logits.astype(jnp.float32), tgt[..., None], axis=-1
        )[..., 0]
        ce = (lse - tgt_logit) * mask
        ntok = jnp.maximum(mask.sum(), 1.0)
        loss = ce.sum() / ntok
        zloss = 1e-4 * ((lse * mask) ** 2).sum() / ntok
        total = loss + zloss + 0.01 * aux
        return total, {"ce": loss, "zloss": zloss, "aux": aux}

    # ------------------------------------------------------------- prefill

    def prefill(self, params, tokens, extras: Optional[Dict] = None, *,
                s_max: int) -> Tuple[jnp.ndarray, Any]:
        """Run the prompt; returns (last-token logits [B, V], cache)."""
        extras = extras or {}
        S = tokens.shape[1]
        ctx = self._ctx("prefill", {**extras}, params,
                        positions=jnp.arange(S), s_max=s_max)
        x = self._embed_in(params, tokens)
        x, _, caches = self._run_stages(params["stages"], self.stages, x, ctx)
        logits = self._logits(params, x[:, -1:, :])[:, 0]
        return logits, caches

    # --------------------------------------------------------------- decode

    def decode_step(self, params, caches, token, pos) -> Tuple[jnp.ndarray, Any]:
        """One token for the whole batch. token [B], pos scalar int32."""
        ctx = self._ctx("decode", None, params, pos=pos)
        x = self._embed_in(params, token[:, None], pos=pos)
        x, _, new_caches = self._run_stages(
            params["stages"], self.stages, x, ctx, caches=caches
        )
        logits = self._logits(params, x)[:, 0]
        return logits, new_caches

    # ----------------------------------------------------------- cache spec

    def cache_struct(self, batch_size: int, s_max: int):
        """abstract cache pytree (zeros) — used by the decode dry-run."""
        cfg = self.cfg
        dt = self.compute_dtype
        KV, hd = cfg.n_kv_heads, cfg.hd

        def attn_cache(g, length):
            return {
                "k": jnp.zeros((g, batch_size, length, KV, hd), dt),
                "v": jnp.zeros((g, batch_size, length, KV, hd), dt),
            }

        def layer_cache(kind, g):
            if kind == "local":       # rolling window buffer
                return attn_cache(g, min(cfg.local_window, s_max) or s_max)
            if kind in ("dense", "global"):
                return attn_cache(g, s_max)
            if kind == "moe":
                if cfg.use_mla:
                    return {
                        "ckv": jnp.zeros((g, batch_size, s_max, cfg.kv_lora_rank), dt),
                        "krope": jnp.zeros((g, batch_size, s_max, cfg.qk_rope_dim), dt),
                    }
                return attn_cache(g, s_max)
            if kind == "ssm":
                from .mamba import _dims

                d_inner, H, P, N = _dims(cfg, cfg.d_model)
                return {
                    "conv": jnp.zeros(
                        (g, batch_size, cfg.conv_width - 1, d_inner + 2 * N), dt
                    ),
                    "h": jnp.zeros((g, batch_size, H, N, P), jnp.float32),
                }
            if kind == "hybrid":
                wl = (
                    cfg.meta_tokens + min(cfg.local_window, s_max)
                    if cfg.local_window
                    else s_max + cfg.meta_tokens
                )
                return {
                    "attn": attn_cache(g, wl),
                    "ssm": layer_cache("ssm", g),
                }
            if kind == "cross":
                return attn_cache(g, cfg.vision_tokens)
            if kind == "dec":
                return {
                    "self": attn_cache(g, s_max),
                    "cross": attn_cache(g, cfg.encoder_frames),
                }
            raise ValueError(kind)

        caches = []
        for st in self.stages:
            caches.append(
                {f"l{j}": layer_cache(kind, st.n_groups)
                 for j, kind in enumerate(st.cycle)}
            )
        return caches


def build_model(cfg: ArchConfig, mesh=None) -> Model:
    return Model(cfg, mesh)
