"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are low-rank compressed; the decode cache stores only the
compressed KV latent (kv_lora_rank) + the shared RoPE key (qk_rope_dim):
576 floats/token/layer for the 671B config — the paper-relevant
sub-quadratic-memory property that lets this arch run long_500k.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (
    MaskSpec, Params, _auto_q_chunk, _dense_init, init_rmsnorm, rmsnorm,
    rope_apply,
)


def init_mla(key, cfg: ArchConfig) -> Params:
    D, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    rd, nd, vd = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wdq": _dense_init(ks[0], (D, qr)),
        "qnorm": init_rmsnorm(qr),
        "wuq": _dense_init(ks[1], (qr, H, nd + rd)),
        "wdkv": _dense_init(ks[2], (D, kvr)),
        "kvnorm": init_rmsnorm(kvr),
        "wkrope": _dense_init(ks[3], (D, rd)),
        "wuk": _dense_init(ks[4], (kvr, H, nd)),
        "wuv": _dense_init(ks[5], (kvr, H, vd)),
        "wo": _dense_init(ks[6], (H, vd, D), scale=(H * vd) ** -0.5),
    }


def _q_proj(p, x, positions, cfg):
    cq = rmsnorm(p["qnorm"], x @ p["wdq"].astype(x.dtype))
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _kv_latent(p, x, positions, cfg):
    ckv = rmsnorm(p["kvnorm"], x @ p["wdkv"].astype(x.dtype))          # [B,S,kvr]
    k_rope = rope_apply(
        (x @ p["wkrope"].astype(x.dtype))[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0]                                                          # [B,S,rd] shared
    return ckv, k_rope


def _attend(p, q_nope, q_rope, ckv, k_rope, cfg, mask=None, mask_spec=None):
    """Score via decompressed keys; fp32 softmax; q-chunked at long Sq."""
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"].astype(ckv.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"].astype(ckv.dtype))
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    B, Sq, H, _ = q_nope.shape
    Sk = ckv.shape[1]

    def attend_block(qn, qr, q0):
        logits = (
            jnp.einsum("bqhk,bshk->bhqs", qn.astype(jnp.float32), k_nope.astype(jnp.float32))
            + jnp.einsum("bqhk,bsk->bhqs", qr.astype(jnp.float32), k_rope.astype(jnp.float32))
        ) * scale
        if mask_spec is not None:
            m = mask_spec.block(q0, qn.shape[1], Sk)
            logits = jnp.where(m[:, None], logits, -1e30)
        elif mask is not None:
            logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqs,bshk->bqhk", probs, v.astype(jnp.float32)).astype(
            q_nope.dtype
        )

    qc = _auto_q_chunk(Sq)
    if qc and Sq % qc == 0 and mask is None:
        nq = Sq // qc
        qns = jnp.moveaxis(q_nope.reshape(B, nq, qc, H, -1), 1, 0)
        qrs = jnp.moveaxis(q_rope.reshape(B, nq, qc, H, -1), 1, 0)
        o = jax.lax.map(
            lambda t: attend_block(t[0], t[1], t[2] * qc),
            (qns, qrs, jnp.arange(nq)),
        )
        o = jnp.moveaxis(o, 0, 1).reshape(B, Sq, H, -1)
    else:
        o = attend_block(q_nope, q_rope, 0)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def mla_train(p: Params, x, positions, cfg: ArchConfig) -> jnp.ndarray:
    q_nope, q_rope = _q_proj(p, x, positions, cfg)
    ckv, k_rope = _kv_latent(p, x, positions, cfg)
    return _attend(p, q_nope, q_rope, ckv, k_rope, cfg,
                   mask_spec=MaskSpec(causal=True))


def mla_prefill(p, x, positions, cfg, *, s_max=None):
    q_nope, q_rope = _q_proj(p, x, positions, cfg)
    ckv, k_rope = _kv_latent(p, x, positions, cfg)
    out = _attend(p, q_nope, q_rope, ckv, k_rope, cfg,
                  mask_spec=MaskSpec(causal=True))
    s_max = s_max or x.shape[1]
    pad = s_max - x.shape[1]
    if pad:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return out, {"ckv": ckv, "krope": k_rope}


def mla_decode(p, x, pos, cache, cfg):
    """x [B, 1, D]; cache ckv [B, S_max, kvr], krope [B, S_max, rd]."""
    from .layers import cache_write

    q_nope, q_rope = _q_proj(p, x, pos[None, None], cfg)
    ckv_new, krope_new = _kv_latent(p, x, pos[None, None], cfg)
    ckv = cache_write(cache["ckv"], ckv_new, pos, cfg.decode_cache_update)
    krope = cache_write(cache["krope"], krope_new, pos, cfg.decode_cache_update)
    s_max = ckv.shape[1]
    mask = (jnp.arange(s_max)[None, None, :] <= pos)
    out = _attend(p, q_nope, q_rope, ckv, krope, cfg, mask)
    return out, {"ckv": ckv, "krope": krope}
