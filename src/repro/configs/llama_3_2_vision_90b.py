"""llama-3.2-vision-90b [vlm] — cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
Modality frontend is a STUB: input_specs() provides precomputed patch
embeddings (vision_tokens x d_model); every 5th layer cross-attends.
Full attention -> long_500k is skipped (see DESIGN.md §Arch-applicability).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    vision_tokens=1024,
    rope_theta=500_000.0,
    moment_dtype="bfloat16",
    sub_quadratic=False,
))
