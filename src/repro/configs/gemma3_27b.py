"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]
Mostly-local attention (window 1024) with 1-in-6 global layers; runs
long_500k with the global-layer KV cache length-sharded over `data`.
Pattern padded to 62 = 10*6 + 2 (trailing local layers).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    local_window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    act="geglu",
    tie_embeddings=True,
    sub_quadratic=True,
))
