from .base import ArchConfig, SHAPES, all_configs, get_config, register  # noqa: F401
