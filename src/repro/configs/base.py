"""Unified architecture config + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // n_heads

    # --- attention variants -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # gemma3: different theta on global layers
    local_window: int = 0            # sliding-window size for local layers
    pattern: Tuple[str, ...] = ()    # repeating layer cycle, e.g. 5x local + global
    tie_embeddings: bool = False
    act: str = "swiglu"              # swiglu | geglu | gelu

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0          # leading dense layers before MoE stack
    dense_d_ff: int = 0              # d_ff of those dense layers
    capacity_factor: float = 1.25
    ep_mode: str = "shard_map"       # shard_map (explicit a2a) | gspmd

    # --- MLA (deepseek-v3) ---------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / hybrid ----------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    meta_tokens: int = 0             # hymba: learnable prefix tokens

    # --- encoder-decoder / vlm -------------------------------------------------
    encoder_layers: int = 0
    encoder_frames: int = 1500       # whisper stub frontend output length
    cross_attn_every: int = 0        # llama-vision: every Nth layer cross-attends
    vision_tokens: int = 0           # stubbed patch-embedding count

    # --- training knobs ----------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    factored_second_moment: bool = False   # Adafactor-style v (XXL configs)
    remat: str = "dots"              # none | dots | full
    seq_shard_attn: bool = True      # context-parallel attn when H % tp != 0
    # "dus": dynamic_update_slice (natural, but GSPMD fully rematerializes
    # a length-sharded cache to apply it); "where": masked elementwise
    # rewrite — fully local under length sharding (§Perf).
    decode_cache_update: str = "dus"
    # flash-decode: pin K/V to the length-sharded cache layout so decode
    # attention computes per-shard softmax partials (GSPMD inserts the
    # small LSE all-reduces) instead of all-gathering the cache (§Perf).
    flash_decode: bool = False
    sub_quadratic: bool = False      # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        D, V = self.d_model, self.vocab_size
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in _layer_kinds(self):
            total += _layer_params(self, kind)
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: only routed-in experts)."""
        D, V = self.d_model, self.vocab_size
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in _layer_kinds(self):
            total += _layer_params(self, kind, active_only=True)
        return total


def _attn_params(cfg: ArchConfig) -> int:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if cfg.use_mla:
        qk = cfg.qk_rope_dim + cfg.qk_nope_dim
        p = D * cfg.q_lora_rank + cfg.q_lora_rank * H * qk           # q path
        p += D * (cfg.kv_lora_rank + cfg.qk_rope_dim)                # kv down
        p += cfg.kv_lora_rank * H * (cfg.qk_nope_dim + cfg.v_head_dim)
        p += H * cfg.v_head_dim * D                                  # out
        return p
    return D * H * hd + 2 * D * KV * hd + H * hd * D


def _mlp_params(D: int, F: int, act: str) -> int:
    return D * F * (3 if act in ("swiglu", "geglu") else 2)


def _ssm_params(cfg: ArchConfig, d_in: int) -> int:
    d_inner = cfg.ssm_expand * d_in
    H = max(d_inner // cfg.ssm_head_dim, 1)
    N = cfg.ssm_state
    p = d_in * (2 * d_inner + 2 * N + H)          # in_proj (z, x, B, C, dt)
    p += cfg.conv_width * (d_inner + 2 * N)       # conv
    p += d_inner * d_in                           # out_proj
    p += 2 * H                                    # A_log, D skip
    return p


def _layer_kinds(cfg: ArchConfig):
    """One kind string per layer, expanded from the arch family/pattern."""
    kinds = []
    if cfg.family == "encdec":
        kinds += ["enc"] * cfg.encoder_layers
        kinds += ["dec"] * cfg.n_layers
        return kinds
    for i in range(cfg.n_layers):
        if cfg.family == "vlm" and cfg.cross_attn_every and (
            (i + 1) % cfg.cross_attn_every == 0
        ):
            kinds.append("cross")
        elif cfg.family == "ssm":
            kinds.append("ssm")
        elif cfg.family == "hybrid":
            kinds.append("hybrid")
        elif cfg.n_experts and i >= cfg.n_dense_layers:
            kinds.append("moe")
        elif cfg.pattern:
            kinds.append(cfg.pattern[i % len(cfg.pattern)])
        else:
            kinds.append("dense")
    return kinds


def _layer_params(cfg: ArchConfig, kind: str, active_only: bool = False) -> int:
    D = cfg.d_model
    attn = _attn_params(cfg)
    if kind in ("dense", "local", "global"):
        ff = cfg.dense_d_ff if (cfg.n_experts and cfg.dense_d_ff) else cfg.d_ff
        return attn + _mlp_params(D, ff, cfg.act)
    if kind == "moe":
        n_routed = cfg.experts_per_token if active_only else cfg.n_experts
        p = attn + n_routed * _mlp_params(D, cfg.moe_d_ff, cfg.act)
        p += cfg.n_shared_experts * _mlp_params(D, cfg.moe_d_ff, cfg.act)
        p += D * cfg.n_experts                    # router
        return p
    if kind == "ssm":
        return _ssm_params(cfg, D) + _mlp_params(D, cfg.d_ff, cfg.act) if cfg.d_ff else _ssm_params(cfg, D)
    if kind == "hybrid":
        return attn + _ssm_params(cfg, D) + _mlp_params(D, cfg.d_ff, cfg.act)
    if kind == "cross":
        return attn + _mlp_params(D, cfg.d_ff, cfg.act)
    if kind in ("enc", "dec"):
        p = attn + _mlp_params(D, cfg.d_ff, cfg.act)
        if kind == "dec":
            p += attn                             # cross-attention
        return p
    raise ValueError(kind)


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all():
    from . import (  # noqa: F401
        llama_3_2_vision_90b, mamba2_780m, hymba_1_5b, qwen1_5_4b,
        smollm_135m, gemma3_27b, gemma3_12b, deepseek_moe_16b,
        deepseek_v3_671b, whisper_large_v3,
    )


# ---------------------------------------------------------------------------
# Input shapes (assigned; one set shared by all LM archs)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}
