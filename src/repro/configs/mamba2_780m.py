"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]
Sub-quadratic: runs long_500k.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,               # mamba2 blocks have no separate MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
    sub_quadratic=True,
))
