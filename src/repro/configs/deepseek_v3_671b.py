"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280. [arXiv:2412.19437; hf]
MLA: q_lora 1536, kv_lora 512, rope 64, nope 128, v 128. First 3 layers
dense (d_ff=18432). MTP head omitted (DESIGN.md §Arch-applicability).
MLA cache = 576 B/token/layer -> sub-quadratic memory; runs long_500k.
bf16 optimizer moments (fp32 would overflow the 16 GB/chip budget).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    n_dense_layers=3,
    dense_d_ff=18432,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    param_dtype="bfloat16",        # fp32 params = 2.7 TB: 10.5 GB/chip on 256
    moment_dtype="bfloat16",
    factored_second_moment=True,   # full AdamW v = 1.34 TB: cannot fit one pod
    sub_quadratic=True,
))
