"""whisper-large-v3 [audio] — encoder-decoder backbone; conv frontend STUB.

32L(enc)+32L(dec) d_model=1280 20H d_ff=5120 vocab=51866. [arXiv:2212.04356]
input_specs() provides precomputed frame embeddings (post-conv), per the
assignment. Decoder runs decode shapes; full attention -> long_500k skipped.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_frames=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    act="gelu",
    rope_theta=0.0,         # learned positions, no RoPE
    sub_quadratic=False,
))
