"""hymba-1.5b [hybrid] — parallel attention + mamba heads in each block.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
[arXiv:2411.13676; hf]
Sliding-window attention on local layers + meta tokens; sub-quadratic.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    local_window=1024,
    meta_tokens=64,
    sub_quadratic=True,
))
