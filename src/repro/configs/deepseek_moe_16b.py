"""deepseek-moe-16b [moe] — fine-grained experts, 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=102400.
[arXiv:2401.06066; hf]  Layer 0 is a dense FFN (d_ff=10944).
Full attention -> long_500k skipped.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    n_dense_layers=1,
    dense_d_ff=10944,
    sub_quadratic=False,
))
