"""qwen1.5-4b [dense] — QKV bias. 40L d_model=2560 20H d_ff=6912 vocab=151936.

[hf:Qwen/Qwen1.5-0.5B; hf]  Full attention -> long_500k skipped.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    sub_quadratic=False,
))
