"""smollm-135m [dense] — llama-arch small. 30L d_model=576 9H (kv=3) d_ff=1536.

[hf:HuggingFaceTB/SmolLM-135M; hf]  Full attention -> long_500k skipped.
Also the ~100M-class model used by examples/lm_pretrain.py.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    sub_quadratic=False,
))
