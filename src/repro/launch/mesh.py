"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512 virtual hosts).

Axes:
  pod    pure data parallelism across pods (DCN); gradients cross pods
         once per step. Elastic: any pod count works, shardings only
         name axes.
  data   FSDP + batch within a pod (ICI).
  model  TP / EP within a pod (ICI).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """`axis_types` only exists on jax >= 0.5; older versions reject it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small-scale runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_mesh_kwargs(len(axes)))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (everything but `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")
