import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, all_configs, get_config
from ..models import build_model
from ..roofline.analysis import HW, analyze_compiled, roofline_terms
from ..training.optimizer import AdamWConfig
from ..training.sharding import cache_specs, param_specs
from ..training.train_step import TrainState, init_state, make_train_step
from .mesh import dp_axes, make_production_mesh

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: XLA's SPMD partitioner must accept every sharding, the
collective schedule must exist, and memory_analysis must fit 16 GB/chip.
Artifacts (cost, memory, per-collective bytes, roofline terms) are
written as JSON for EXPERIMENTS.md §Dry-run and §Roofline.
"""


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, shardings_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree,
    )


def _extras_specs(cfg, batch: int, mesh, dp, *, micro_axis: bool):
    """Modality-frontend stubs (per assignment: precomputed embeddings)."""
    lead = (None, dp) if micro_axis else (dp,)
    out = {}
    if cfg.family == "vlm":
        out["vision_embeds"] = (
            (batch, cfg.vision_tokens, cfg.d_model), jnp.float32,
            P(*lead, None, None),
        )
    if cfg.family == "encdec":
        out["frames"] = (
            (batch, cfg.encoder_frames, cfg.d_model), jnp.float32,
            P(*lead, None, None),
        )
    return out


def model_flops_global(cfg, shape: Dict) -> float:
    n_active = cfg.active_param_count()
    if shape["kind"] == "train":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 6.0 * n_active * tokens
    if shape["kind"] == "prefill":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape["global_batch"]       # decode: one token


# ---------------------------------------------------------------------------
# Cell builders: return (jitted_fn, arg_specs_tuple)
# ---------------------------------------------------------------------------


def build_train_cell(cfg, shape, mesh, opts=()):
    """opts (--opt, comma-sep): §Perf hillclimb knobs.

    no-fsdp      params replicated over DP axes (TP only)
    micro4       4 sequences / device / microbatch (4x fewer FSDP gathers)
    bf16-params  parameters stored bf16
    remat-none   disable activation rematerialization
    """
    dp = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    gb, S = shape["global_batch"], shape["seq_len"]
    seqs_per_dev = 4 if "micro4" in opts else 1
    micro = min(dp_total * seqs_per_dev, gb)
    n_micro = max(gb // micro, 1)
    if "bf16-params" in opts:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if "remat-none" in opts:
        cfg = dataclasses.replace(cfg, remat="none")
    if "uneven-heads" in opts:
        cfg = dataclasses.replace(cfg, seq_shard_attn=False)

    model = build_model(cfg, mesh)
    opt = AdamWConfig(
        moment_dtype=cfg.moment_dtype, factored=cfg.factored_second_moment
    )
    train_step = make_train_step(model, opt)

    state_shape = jax.eval_shape(
        lambda k: init_state(model, k, opt), jax.random.PRNGKey(0)
    )
    fsdp_kw = {"fsdp": ()} if "no-fsdp" in opts else {}
    if "uneven-heads" in opts:
        fsdp_kw["uneven_heads"] = True
    pspecs = param_specs(state_shape.params, mesh, **fsdp_kw)
    from ..training.sharding import opt_state_specs

    ospecs = opt_state_specs(
        jax.tree_util.tree_map(lambda x: x, state_shape.opt), pspecs
    )
    sh = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    state_shardings = TrainState(
        params=sh(pspecs), opt=sh(ospecs), step=NamedSharding(mesh, P())
    )
    state_sds = _tree_sds(state_shape, state_shardings)

    batch_sds = {
        "tokens": _sds((n_micro, micro, S), jnp.int32, mesh, P(None, dp, None)),
        "targets": _sds((n_micro, micro, S), jnp.int32, mesh, P(None, dp, None)),
    }
    for k, (bshape, dt, spec) in _extras_specs(
        cfg, micro, mesh, dp, micro_axis=True
    ).items():
        batch_sds[k] = _sds((n_micro, *bshape), dt, mesh, spec)

    jitted = jax.jit(
        train_step,
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )
    return jitted, (state_sds, batch_sds)


def _param_sds(cfg, mesh, opts=()):
    if "bf16-params" in opts:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if "uneven-heads" in opts:
        cfg = dataclasses.replace(cfg, seq_shard_attn=False)
    if "where-update" in opts:
        cfg = dataclasses.replace(cfg, decode_cache_update="where")
    if "flash-decode" in opts:
        cfg = dataclasses.replace(cfg, flash_decode=True)
    model = build_model(cfg, mesh)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    fsdp_kw = {"fsdp": ()} if "no-fsdp" in opts else {}
    if "fsdp-tables-only" in opts:
        fsdp_kw["fsdp_tables_only"] = True
    if "uneven-heads" in opts:
        fsdp_kw["uneven_heads"] = True
    pspecs = param_specs(pshape, mesh, **fsdp_kw)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return model, _tree_sds(pshape, shardings)


def build_prefill_cell(cfg, shape, mesh, opts=()):
    dp = dp_axes(mesh)
    gb, S = shape["global_batch"], shape["seq_len"]
    model, params_sds = _param_sds(cfg, mesh, opts)

    tokens_sds = _sds((gb, S), jnp.int32, mesh, P(dp, None))
    extras_sds = {
        k: _sds(bshape, dt, mesh, spec)
        for k, (bshape, dt, spec) in _extras_specs(
            cfg, gb, mesh, dp, micro_axis=False
        ).items()
    }

    cache_shape = jax.eval_shape(lambda: model.cache_struct(gb, S))
    cspecs = cache_specs(cache_shape, mesh, batch_sharded=True, dp_axes=dp)
    cache_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cspecs,
        is_leaf=lambda x: isinstance(x, P),
    )

    def prefill(params, tokens, extras):
        return model.prefill(params, tokens, extras, s_max=S)

    jitted = jax.jit(prefill, out_shardings=(None, cache_sh))
    return jitted, (params_sds, tokens_sds, extras_sds)


def build_decode_cell(cfg, shape, mesh, opts=()):
    dp = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    gb, S = shape["global_batch"], shape["seq_len"]
    model, params_sds = _param_sds(cfg, mesh, opts)

    batch_sharded = gb % dp_total == 0 and gb >= dp_total
    cache_shape = jax.eval_shape(lambda: model.cache_struct(gb, S))
    cspecs = cache_specs(cache_shape, mesh, batch_sharded=batch_sharded, dp_axes=dp)
    cache_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
    cache_sds = _tree_sds(cache_shape, cache_sh)
    token_sds = _sds((gb,), jnp.int32, mesh, P(dp) if batch_sharded else P())
    pos_sds = _sds((), jnp.int32, mesh, P())

    jitted = jax.jit(
        model.decode_step,
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    return jitted, (params_sds, cache_sds, token_sds, pos_sds)


def build_prf_cell(mesh, opts=(), *, n_samples=2 ** 22, n_features=4096,
                   n_classes=16):
    """The paper's own workload at production scale (extra dry-run row).

    opts: prf-packed (class-packed segment ids), prf-rs (reduce-scatter
    T_GR combine) — the §Perf hillclimb knobs.
    """
    from ..core.distributed import make_prf_train_fn
    from ..core.types import ForestConfig

    dp = dp_axes(mesh)
    cfg = ForestConfig(
        n_trees=64, max_depth=12, n_bins=64, n_classes=n_classes,
        max_frontier=16, tree_chunk=8, feature_mode="importance",
        packed_hist="prf-packed" in opts,
        hist_reduce="psum_scatter" if "prf-rs" in opts else "psum",
    )
    train_fn, _ = make_prf_train_fn(
        cfg, mesh, sample_axes=dp, feature_axis="model"
    )
    xb = _sds((n_samples, n_features), jnp.uint8, mesh, P(dp, "model"))
    y = _sds((n_samples,), jnp.int32, mesh, P(dp))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32,
                               sharding=NamedSharding(mesh, P()))
    return train_fn, (xb, y, key), cfg


PRF_MODEL_FLOPS = None  # PRF has no 6ND analogue; report HLO flops only.


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, opts=()) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.devices.shape)))
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "devices": n_dev,
        "opts": list(opts),
    }

    t0 = time.time()
    try:
        if arch == "prf":
            fn, args, _prf_cfg = build_prf_cell(mesh, opts)
            mf = 0.0
        else:
            cfg = get_config(arch)
            shape = SHAPES[shape_name]
            if shape_name == "long_500k" and not cfg.sub_quadratic:
                result["status"] = "SKIP(full-attn)"
                return result
            builder = {
                "train": build_train_cell,
                "prefill": build_prefill_cell,
                "decode": build_decode_cell,
            }[shape["kind"]]
            fn, args = builder(cfg, shape, mesh, opts)
            mf = model_flops_global(cfg, shape) / n_dev

        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

        analysis = analyze_compiled(compiled)
        terms = roofline_terms(analysis, model_flops_per_device=mf)
        mem = analysis["memory"]
        # memory_analysis() reports per-device numbers for SPMD modules;
        # peak = live args + temps at the high-water mark.
        per_dev_bytes = mem.get("peak_bytes", 0) or (
            mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        )
        result.update(
            status="OK",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops_per_device=analysis["flops"],
            bytes_per_device=analysis["bytes_accessed"],
            collective_bytes=analysis["collective_bytes"],
            collectives={
                k: {kk: int(vv) for kk, vv in v.items()}
                for k, v in analysis["collectives"].items()
            },
            memory=mem,
            hbm_per_device_gb=round(per_dev_bytes / 2 ** 30, 3),
            fits_hbm=bool(per_dev_bytes < HW["hbm_bytes"]),
            **{k: v for k, v in terms.items()},
        )
    except Exception as e:
        result["status"] = f"FAIL: {type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = ("~" + "~".join(sorted(opts))) if opts else ""
        fname = f"{arch}__{shape_name}__{mesh_name}{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=2, default=str)
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch name, 'prf', or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--opt", default="",
                    help="comma-sep §Perf knobs: no-fsdp,micro4,bf16-params,"
                         "remat-none,prf-packed,prf-rs")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    archs = (
        list(all_configs().keys()) + ["prf"] if args.arch == "all" else [args.arch]
    )
    shapes = list(SHAPES.keys()) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    any_fail = False
    for arch in archs:
        arch_shapes = ["train_4k"] if arch == "prf" else shapes
        for shape in arch_shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, args.out, opts)
                line = (
                    f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                    f"{r['status']:18s}"
                )
                if r["status"] == "OK":
                    line += (
                        f" compile={r['compile_s']:7.1f}s"
                        f" hbm/dev={r['hbm_per_device_gb']:7.3f}GB"
                        f" dom={r['dominant']:12s}"
                        f" frac={r['roofline_fraction']:.3f}"
                    )
                else:
                    any_fail = any_fail or r["status"].startswith("FAIL")
                print(line, flush=True)
    raise SystemExit(1 if any_fail else 0)


if __name__ == "__main__":
    main()
