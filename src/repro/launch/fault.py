"""Fault tolerance & straggler mitigation harness.

What has a real single-process analogue is implemented and tested
(checkpoint/restart with elastic resharding, deadline-based straggler
detection, failure-injected training loops); what is inherently
multi-host (health RPCs, pod re-slicing) is encoded as policy objects
with the cluster calls stubbed — the control flow is real, the transport
is not. DESIGN.md §4 describes the 1000+-node deployment story.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..checkpoint.checkpoint import CheckpointManager, latest_step


class SimulatedFailure(RuntimeError):
    pass


class FaultInjector:
    """Deterministic, seeded fault injection for chaos tests.

    A callable hook: each call draws from its own ``np.random.default_rng``
    stream and raises :class:`SimulatedFailure` with probability
    ``rate``. ``max_consecutive`` bounds failure streaks, so a consumer
    with ``max_retries >= max_consecutive`` retries is *guaranteed* to
    make progress — injected chaos can slow a run down but never starve
    it, which is what lets property tests assert the trained model is
    unchanged under any fault sequence. The draw stream advances
    deterministically per call, so the same (seed, call sequence)
    reproduces the same fault sequence exactly.
    """

    def __init__(self, rate: float, *, seed: int = 0, max_consecutive: int = 2):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        if max_consecutive < 1:
            raise ValueError("max_consecutive must be >= 1")
        self.rate = rate
        self.max_consecutive = max_consecutive
        self._rng = np.random.default_rng(seed)
        self._streak = 0
        self.calls = 0
        self.injected = 0

    def __call__(self, site: str = "") -> None:
        self.calls += 1
        fail = (
            self._streak < self.max_consecutive
            and self._rng.random() < self.rate
        )
        if fail:
            self._streak += 1
            self.injected += 1
            raise SimulatedFailure(
                f"injected fault #{self.injected} at {site or 'unnamed site'}"
            )
        self._streak = 0


@dataclasses.dataclass
class StragglerMonitor:
    """Deadline-based slow-step detection (median * k rule).

    On a real pod this watches per-host step heartbeats and triggers
    re-dispatch of the slow host's shard (or pod eviction at the DCN
    level); here it flags steps so tests can assert the policy fires.
    """

    factor: float = 3.0
    warmup: int = 5
    durations: List[float] = dataclasses.field(default_factory=list)
    flagged: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, duration: float) -> bool:
        self.durations.append(duration)
        if len(self.durations) <= self.warmup:
            return False
        med = float(np.median(self.durations[:-1]))
        if duration > self.factor * med:
            self.flagged.append(step)
            return True
        return False


@dataclasses.dataclass
class ElasticRunner:
    """Checkpoint/restart training-loop supervisor.

    Runs `loop_fn(state, start_step, n_steps, on_step)`; on failure,
    restores the latest checkpoint and continues — exactly-once optimizer
    semantics come from the step counter in the checkpointed state.
    """

    manager: CheckpointManager
    max_restarts: int = 3

    def run(
        self,
        init_state_fn: Callable[[], object],
        loop_fn: Callable,
        n_steps: int,
        state_shardings=None,
    ):
        restarts = 0
        monitor = StragglerMonitor()
        state = None
        start = 0
        if latest_step(self.manager.directory) is not None:
            state, start = self.manager.restore_latest(
                init_state_fn(), shardings=state_shardings
            )
        else:
            state = init_state_fn()

        while start < n_steps:
            try:
                def on_step(step, st, metrics, t0=[time.time()]):
                    now = time.time()
                    monitor.record(step, now - t0[0])
                    t0[0] = now
                    self.manager.maybe_save(st, step)

                state = loop_fn(state, start, n_steps, on_step)
                start = n_steps
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                ls = latest_step(self.manager.directory)
                if ls is not None:
                    state, start = self.manager.restore_latest(
                        init_state_fn(), shardings=state_shardings
                    )
                else:
                    state, start = init_state_fn(), 0
        return state, monitor, restarts
