"""Fault tolerance & straggler mitigation harness.

What has a real single-process analogue is implemented and tested
(checkpoint/restart with elastic resharding, deadline-based straggler
detection, failure-injected training loops); what is inherently
multi-host (health RPCs, pod re-slicing) is encoded as policy objects
with the cluster calls stubbed — the control flow is real, the transport
is not. DESIGN.md §4 describes the 1000+-node deployment story.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..checkpoint.checkpoint import CheckpointManager, latest_step


class SimulatedFailure(RuntimeError):
    pass


class CheckpointCorruptor:
    """Deterministic byte-flipper for checkpoint-corruption drills.

    Flips ``n_bytes`` bytes (XOR 0xFF — every flip is guaranteed to
    change the byte, so the leaf's CRC32 always catches it) at seeded
    offsets inside one leaf file of a checkpoint step. File choice and
    offsets come from ``np.random.default_rng(seed)`` over the *sorted*
    file list, so the same (seed, directory contents) corrupts the same
    bytes every run — chaos drills stay reproducible.
    """

    def __init__(self, *, seed: int = 0, n_bytes: int = 16):
        if n_bytes < 1:
            raise ValueError("n_bytes must be >= 1")
        self._rng = np.random.default_rng(seed)
        self.n_bytes = n_bytes

    def corrupt(self, directory: str, step: Optional[int] = None) -> int:
        """Corrupt one leaf file of `step` (default: the newest step).
        Returns the step that was corrupted."""
        from ..checkpoint.checkpoint import list_steps

        if step is None:
            steps = list_steps(directory)
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {directory}")
            step = steps[-1]
        path = os.path.join(directory, f"step_{step:08d}")
        files = sorted(
            f for f in os.listdir(path) if f.endswith(".npy")
        )
        if not files:
            raise FileNotFoundError(f"no leaf files in {path}")
        target = os.path.join(path, files[int(self._rng.integers(len(files)))])
        data = bytearray(open(target, "rb").read())
        offsets = self._rng.integers(
            0, len(data), size=min(self.n_bytes, len(data))
        )
        for off in offsets:
            data[int(off)] ^= 0xFF
        with open(target, "wb") as f:
            f.write(bytes(data))
        return step


class FaultInjector:
    """Deterministic, seeded fault injection for chaos tests.

    A callable hook: each call draws from its own ``np.random.default_rng``
    stream and raises :class:`SimulatedFailure` with probability
    ``rate``. ``max_consecutive`` bounds failure streaks, so a consumer
    with ``max_retries >= max_consecutive`` retries is *guaranteed* to
    make progress — injected chaos can slow a run down but never starve
    it, which is what lets property tests assert the trained model is
    unchanged under any fault sequence. The draw stream advances
    deterministically per call, so the same (seed, call sequence)
    reproduces the same fault sequence exactly.
    """

    def __init__(self, rate: float, *, seed: int = 0, max_consecutive: int = 2):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        if max_consecutive < 1:
            raise ValueError("max_consecutive must be >= 1")
        self.rate = rate
        self.max_consecutive = max_consecutive
        self._rng = np.random.default_rng(seed)
        self._streak = 0
        self.calls = 0
        self.injected = 0

    def __call__(self, site: str = "") -> None:
        self.calls += 1
        fail = (
            self._streak < self.max_consecutive
            and self._rng.random() < self.rate
        )
        if fail:
            self._streak += 1
            self.injected += 1
            raise SimulatedFailure(
                f"injected fault #{self.injected} at {site or 'unnamed site'}"
            )
        self._streak = 0


@dataclasses.dataclass
class StragglerMonitor:
    """Deadline-based slow-step detection (median * k rule).

    On a real pod this watches per-host step heartbeats and triggers
    re-dispatch of the slow host's shard (or pod eviction at the DCN
    level); here it flags steps so tests can assert the policy fires.
    """

    factor: float = 3.0
    warmup: int = 5
    durations: List[float] = dataclasses.field(default_factory=list)
    flagged: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, duration: float) -> bool:
        self.durations.append(duration)
        if len(self.durations) <= self.warmup:
            return False
        med = float(np.median(self.durations[:-1]))
        if duration > self.factor * med:
            self.flagged.append(step)
            return True
        return False


@dataclasses.dataclass
class ElasticRunner:
    """Checkpoint/restart training-loop supervisor.

    Runs `loop_fn(state, start_step, n_steps, on_step)`; on failure,
    restores the latest checkpoint and continues — exactly-once optimizer
    semantics come from the step counter in the checkpointed state.
    """

    manager: CheckpointManager
    max_restarts: int = 3

    def run(
        self,
        init_state_fn: Callable[[], object],
        loop_fn: Callable,
        n_steps: int,
        state_shardings=None,
    ):
        restarts = 0
        monitor = StragglerMonitor()
        state = None
        start = 0
        if latest_step(self.manager.directory) is not None:
            state, start = self.manager.restore_latest(
                init_state_fn(), shardings=state_shardings
            )
        else:
            state = init_state_fn()

        while start < n_steps:
            try:
                def on_step(step, st, metrics, t0=[time.time()]):
                    now = time.time()
                    monitor.record(step, now - t0[0])
                    t0[0] = now
                    self.manager.maybe_save(st, step)

                state = loop_fn(state, start, n_steps, on_step)
                start = n_steps
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                ls = latest_step(self.manager.directory)
                if ls is not None:
                    state, start = self.manager.restore_latest(
                        init_state_fn(), shardings=state_shardings
                    )
                else:
                    state, start = init_state_fn(), 0
        return state, monitor, restarts
