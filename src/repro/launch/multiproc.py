"""Multi-process training plane bootstrap (paper §4 at cluster scale).

One process per host, ``jax.distributed`` coordination, a **global** mesh
over every process's devices — and the invariant that makes the
out-of-core trainer scale: *each process constructs only its addressable
slice of every array*. Host→device feed bandwidth and host RAM then
multiply by process count instead of funneling through one machine.

Three layers live here:

* :func:`initialize` — coordinator bootstrap around
  ``jax.distributed.initialize`` (CPU collectives forced to gloo, per-host
  virtual device count via ``XLA_FLAGS``). Call it before any other jax
  use in the process.
* :class:`MultiHostMesh` — extends ``launch.mesh`` meshes to the global
  device set with addressable-shard introspection: which contiguous range
  of the sample-axis shards this process owns, the local row range of any
  padded global array, and ``put``/``zeros`` constructors built on
  ``jax.make_array_from_callback`` so only local bytes ever leave this
  host. ``psum_hosts`` union-reduces small integer vectors exactly
  (16-bit limbed int32 psum — no x64 dependence), and doubles as the
  cross-process barrier.
* Multi-process checkpointing — process-0 manifests with per-host shard
  leaves (``save_checkpoint_multiproc`` / ``restore_checkpoint_multiproc``
  / :class:`MultiprocCheckpointManager`): replicated leaves are written
  once by process 0, sample-sharded leaves once per process, all under
  the single-process format's atomic tmp-dir + rename protocol with
  per-leaf CRC32s. Restoring across a *changed* process count raises
  :class:`repro.checkpoint.checkpoint.CheckpointTopologyError` — never a
  silently wrong forest. (Single-machine shared-filesystem layout; on a
  real cluster the per-host leaves would go to per-host object-store
  prefixes — the manifest protocol is the same.)
"""
from __future__ import annotations

import os
import shutil
import warnings
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import make_mesh


def initialize(
    coordinator_address: str,
    num_processes: int,
    process_id: int,
    *,
    local_device_count: Optional[int] = None,
) -> Tuple[int, int]:
    """Bootstrap this process into a ``jax.distributed`` runtime.

    Must run before any jax backend use in the process.
    ``local_device_count`` forces that many virtual host-platform devices
    per process (the CPU drill topology: N processes x M devices); on
    real accelerators leave it ``None`` and let the backend discover the
    local devices. CPU collectives are switched to gloo, the only
    cross-process CPU implementation. Returns
    ``(process_index, process_count)``.
    """
    if local_device_count is not None:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{local_device_count}"
            ).strip()
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass  # non-CPU backend, or a jax without the knob
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    return jax.process_index(), jax.process_count()


def is_multiprocess() -> bool:
    """True when this jax runtime spans more than one process."""
    return jax.process_count() > 1


def _resolve(sl: slice, dim: int) -> Tuple[int, int]:
    """A shard-index slice as concrete ``(start, stop)``."""
    return (
        0 if sl.start is None else int(sl.start),
        dim if sl.stop is None else int(sl.stop),
    )


def _local_box(sharding, shape) -> List[Tuple[int, int]]:
    """Bounding box (per-dim ``(lo, hi)``) of this process's addressable
    shards of a global array with ``sharding``/``shape``."""
    imap = sharding.addressable_devices_indices_map(tuple(shape))
    lo = [int(d) for d in shape]
    hi = [0] * len(shape)
    for idx in imap.values():
        for d, sl in enumerate(idx):
            st, sp = _resolve(sl, shape[d])
            lo[d] = min(lo[d], st)
            hi[d] = max(hi[d], sp)
    return list(zip(lo, hi))


class MultiHostMesh:
    """A global device mesh plus this process's place in it.

    Extends ``launch.mesh`` to multi-process runtimes: the mesh spans
    every process's devices (process-major, so the default
    ``(n_devices, 1)`` data x model layout gives each process a
    *contiguous* range of sample-axis shards), and the class knows which
    shard range — and therefore which global row range — belongs to this
    process. All host→device constructors go through
    ``jax.make_array_from_callback``, which asks only for the addressable
    shards: remote rows are never touched on this host (the whole point —
    an ``np.memmap`` source only pages in local rows).

    ``feed_bytes`` counts every byte this process handed to its local
    devices through the runtime (the example's per-host feed report).
    """

    def __init__(
        self,
        mesh: Optional[Mesh] = None,
        *,
        sample_axes: Sequence[str] = ("data",),
        feature_axis: str = "model",
    ):
        if mesh is None:
            mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
        self.mesh = mesh
        self.sample_axes = tuple(sample_axes)
        self.feature_axis = feature_axis
        self.process_index = int(jax.process_index())
        self.process_count = int(jax.process_count())
        self.feed_bytes = 0
        self._jit_cache: dict = {}

        names = list(mesh.axis_names)
        spos = [names.index(a) for a in self.sample_axes]
        opos = [i for i in range(len(names)) if i not in spos]
        devs = np.asarray(mesh.devices)
        D = int(np.prod([devs.shape[i] for i in spos]))
        rows = np.transpose(devs, spos + opos).reshape(D, -1)
        owned = []
        for d in range(D):
            procs = {int(dev.process_index) for dev in rows[d]}
            if self.process_index in procs:
                if procs != {self.process_index}:
                    raise ValueError(
                        f"sample-axis shard {d} spans processes "
                        f"{sorted(procs)} — the multi-process plane needs "
                        "each sample shard pinned to one process (use the "
                        "default process-major (n_devices, 1) mesh)"
                    )
                owned.append(d)
        if not owned:
            raise ValueError(
                f"process {self.process_index} owns no sample-axis shard of "
                f"mesh {dict(zip(names, devs.shape))}"
            )
        if owned != list(range(owned[0], owned[-1] + 1)):
            raise ValueError(
                f"process {self.process_index}'s sample-axis shards {owned} "
                "are not contiguous — local memmap row ranges require a "
                "process-major device order"
            )
        self.n_data_shards = D
        self.shard_lo, self.shard_hi = owned[0], owned[-1] + 1

    # -- row bookkeeping -------------------------------------------------

    def pad(self, n_rows: int) -> int:
        """Rows of padding that make ``n_rows`` divide the data shards."""
        return (-n_rows) % self.n_data_shards

    def local_row_range(self, n_rows_padded: int) -> Tuple[int, int]:
        """This process's ``[lo, hi)`` rows of a padded global row dim."""
        if n_rows_padded % self.n_data_shards:
            raise ValueError(
                f"{n_rows_padded} rows do not divide {self.n_data_shards} "
                "sample shards — pad first (see .pad())"
            )
        rps = n_rows_padded // self.n_data_shards
        return self.shard_lo * rps, self.shard_hi * rps

    # -- local-slice array constructors ---------------------------------

    def put(self, host: np.ndarray, global_shape, spec, *, box=None):
        """Build a global device array from this process's host bytes.

        ``host`` holds the **local box** of the global array — ``box``
        gives its per-dim ``(lo, hi)`` position in global coordinates
        (``None`` means ``host`` is the full array, e.g. a replicated
        leaf). The callback only ever receives addressable-shard indices,
        so nothing outside the box is read.
        """
        host = np.asarray(host)
        global_shape = tuple(int(s) for s in global_shape)
        sh = NamedSharding(self.mesh, spec)

        def cb(index):
            idx, shard_shape = [], []
            for d, sl in enumerate(index):
                st, sp = _resolve(sl, global_shape[d])
                off = 0 if box is None else box[d][0]
                idx.append(slice(st - off, sp - off))
                shard_shape.append(sp - st)
            # reshape pins the exact shard rank: ascontiguousarray
            # promotes 0-d (scalar leaves) to (1,), which the runtime
            # would reject as a shard-shape mismatch.
            out = np.ascontiguousarray(host[tuple(idx)]).reshape(shard_shape)
            self.feed_bytes += out.nbytes
            return out

        return jax.make_array_from_callback(global_shape, sh, cb)

    def put_full(self, host, spec):
        """Replicate/shard a host array every process holds in full."""
        host = np.asarray(host)
        return self.put(host, host.shape, spec)

    def zeros(self, global_shape, spec, dtype=jnp.float32):
        """A zero-filled global array, materialized shard-by-shard."""
        global_shape = tuple(int(s) for s in global_shape)
        sh = NamedSharding(self.mesh, spec)

        def cb(index):
            shape = []
            for d, sl in enumerate(index):
                st, sp = _resolve(sl, global_shape[d])
                shape.append(sp - st)
            return np.zeros(tuple(shape), dtype)

        return jax.make_array_from_callback(global_shape, sh, cb)

    def block_placement(self, padded_rows: Sequence[int], n_features: int,
                        x_spec) -> Callable:
        """A ``BlockFeeder`` placement callback: block ``i``'s host-local
        rows become the global ``[m_i, F]`` device block. The feeder
        passes ``(host_local_block, block_index)``."""
        padded_rows = [int(m) for m in padded_rows]

        def place(host_local, index):
            m = padded_rows[index]
            lo, hi = self.local_row_range(m)
            if host_local.shape[0] != hi - lo:
                raise ValueError(
                    f"block[{index}]: host-local rows {host_local.shape[0]} "
                    f"!= local range {hi - lo} of {m} padded rows"
                )
            return self.put(
                host_local, (m, n_features), x_spec,
                box=[(lo, hi), (0, n_features)],
            )

        return place

    # -- exact cross-process reductions ---------------------------------

    def psum_hosts(self, vec) -> np.ndarray:
        """Exact global sum of one small int vector per process.

        Values are split into 16-bit limbs and summed with an int32
        ``psum`` (exact without x64 for per-process values < 2**48),
        each process contributing exactly once. Every process must call
        this collectively; it doubles as the cross-process barrier."""
        v = np.asarray(vec, np.int64).ravel()
        limbs = np.stack(
            [v & 0xFFFF, (v >> 16) & 0xFFFF, (v >> 32) & 0xFFFF], axis=1
        ).astype(np.int32)                                       # [n, 3]
        D = self.n_data_shards
        n = limbs.shape[0]
        sh = NamedSharding(self.mesh, P(self.sample_axes))
        mine = self.shard_lo

        def cb(index):
            d, _ = _resolve(index[0], D)
            if d == mine:
                return limbs[None]
            return np.zeros((1, n, 3), np.int32)

        g = jax.make_array_from_callback((D, n, 3), sh, cb)
        key = ("psum_hosts", n)
        fn = self._jit_cache.get(key)
        if fn is None:
            from ..core.distributed import _shard_map

            def kernel(x_loc):
                return jax.lax.psum(x_loc[0], self.sample_axes)

            fn = jax.jit(_shard_map(
                kernel, mesh=self.mesh,
                in_specs=(P(self.sample_axes),), out_specs=P(),
            ))
            self._jit_cache[key] = fn
        out = np.asarray(jax.device_get(fn(g))).astype(np.int64)  # [n, 3]
        return out[:, 0] + (out[:, 1] << 16) + (out[:, 2] << 32)

    def barrier(self) -> None:
        """Block until every process reaches this point."""
        self.psum_hosts(np.zeros(1, np.int64))


# ---------------------------------------------------------------------------
# Multi-process checkpointing (process-0 manifest, per-host shard leaves)
# ---------------------------------------------------------------------------


def _host_view(leaf):
    """``(is_full, host_array, box)`` of one pytree leaf on this process.

    Fully-replicated (and plain host) leaves come back whole; sharded
    leaves come back as the local bounding box assembled from the
    addressable shards, with coverage verified (a gap would checkpoint
    uninitialized memory)."""
    if not isinstance(leaf, jax.Array) or leaf.is_fully_replicated:
        if isinstance(leaf, jax.Array):
            return True, np.asarray(jax.device_get(leaf)), None
        return True, np.asarray(leaf), None
    shards = leaf.addressable_shards
    if not shards:
        raise ValueError(
            "checkpoint leaf has no addressable shards on process "
            f"{jax.process_index()} — every leaf of a multi-process "
            "checkpoint must be replicated or sample-sharded"
        )
    shape = leaf.shape
    lo = [int(s) for s in shape]
    hi = [0] * leaf.ndim
    resolved = []
    for s in shards:
        idx = [_resolve(sl, shape[d]) for d, sl in enumerate(s.index)]
        for d, (st, sp) in enumerate(idx):
            lo[d] = min(lo[d], st)
            hi[d] = max(hi[d], sp)
        resolved.append(idx)
    box_shape = tuple(h - l for l, h in zip(lo, hi))
    buf = np.empty(box_shape, leaf.dtype)
    covered = np.zeros(box_shape, np.bool_)
    for s, idx in zip(shards, resolved):
        sl = tuple(slice(st - l, sp - l) for (st, sp), l in zip(idx, lo))
        buf[sl] = np.asarray(s.data)
        covered[sl] = True
    if not covered.all():
        raise ValueError(
            "addressable shards leave gaps in the local box "
            f"{list(zip(lo, hi))} of a {shape} leaf — refusing to "
            "checkpoint uninitialized memory"
        )
    return False, buf, list(zip(lo, hi))


def _sub_manifest_name(pid: int) -> str:
    return f"shards.p{pid:02d}.msgpack"


def save_checkpoint_multiproc(
    tree, directory: str, step: int, runtime: MultiHostMesh,
) -> str:
    """Collective atomic save: every process writes its shard leaves,
    process 0 writes the replicated leaves + the manifest and performs
    the atomic rename. Barriers order create → write → rename, so a
    reader never sees a torn step and a crash leaves only an orphaned
    ``.tmp_save_*`` dir (cleaned up like the single-process format's).
    """
    from ..checkpoint.checkpoint import _TMP_PREFIX, _crc32

    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f"{_TMP_PREFIX}step_{step:08d}")
    if runtime.process_index == 0:
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
    runtime.barrier()                       # tmp dir exists everywhere

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    pid = runtime.process_index
    manifest = {
        "step": step,
        "topology": {"process_count": runtime.process_count},
        "leaves": [],
    }
    sub = {"process": pid, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        full, arr, box = _host_view(leaf)
        if full:
            fname = f"leaf_{i:05d}.npy"
            if pid == 0:
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append({
                    "key": key, "file": fname, "dtype": str(arr.dtype),
                    "shape": list(arr.shape), "crc32": _crc32(arr),
                })
        else:
            fname = f"leaf_{i:05d}.p{pid:02d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            sub["leaves"].append({
                "key": key, "file": fname, "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "box": [[int(l), int(h)] for l, h in box],
                "crc32": _crc32(arr),
            })
            if pid == 0:
                manifest["leaves"].append({
                    "key": key, "sharded": True, "dtype": str(arr.dtype),
                    "shape": [int(s) for s in leaf.shape],
                })
    with open(os.path.join(tmp, _sub_manifest_name(pid)), "wb") as f:
        f.write(msgpack.packb(sub))
    if pid == 0:
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
    runtime.barrier()                       # every process done writing
    if pid == 0:
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    runtime.barrier()                       # final dir visible everywhere
    return final


def _load_sub_manifest(path: str, pid: int) -> dict:
    from ..checkpoint.checkpoint import CheckpointCorruptionError

    try:
        with open(os.path.join(path, _sub_manifest_name(pid)), "rb") as f:
            sub = msgpack.unpackb(f.read())
        if not isinstance(sub, dict) or "leaves" not in sub:
            raise ValueError("shard manifest has no leaves")
        return sub
    except CheckpointCorruptionError:
        raise
    except Exception as e:
        raise CheckpointCorruptionError(
            f"torn or unreadable shard manifest for process {pid} in "
            f"{path}: {e}"
        ) from e


def _verify_local(path: str, runtime: MultiHostMesh) -> None:
    """CRC/shape/dtype-verify the leaves this process would restore."""
    from ..checkpoint.checkpoint import (
        _check_topology, _load_leaf, _load_manifest,
    )

    manifest = _load_manifest(path)
    _check_topology(manifest, path)
    sub = _load_sub_manifest(path, runtime.process_index)
    by_key = {e["key"]: e for e in sub["leaves"]}
    for entry in manifest["leaves"]:
        if entry.get("sharded"):
            local = by_key.get(entry["key"])
            if local is None:
                from ..checkpoint.checkpoint import CheckpointCorruptionError

                raise CheckpointCorruptionError(
                    f"sharded leaf {entry['key']!r} missing from process "
                    f"{runtime.process_index}'s shard manifest in {path}"
                )
            _load_leaf(path, local)
        else:
            _load_leaf(path, entry)


def restore_checkpoint_multiproc(
    tree_like, directory: str, step: Optional[int] = None,
    shardings=None, *, runtime: MultiHostMesh, verify: bool = True,
):
    """Multi-process restore: replicated leaves load from process 0's
    files (every process reads the shared step dir), sharded leaves from
    this process's own shard files — re-assembled into global arrays via
    the runtime's local-slice ``put``. The saved local box must match
    the current sharding's box exactly (same process count and mesh), or
    :class:`CheckpointTopologyError` is raised."""
    from ..checkpoint.checkpoint import (
        CheckpointCorruptionError, CheckpointTopologyError, _check_topology,
        _load_leaf, _load_manifest, latest_step,
    )

    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = _load_manifest(path)
    _check_topology(manifest, path)
    sub = _load_sub_manifest(path, runtime.process_index)
    sub_by_key = {e["key"]: e for e in sub["leaves"]}
    by_key = {e["key"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = None
    if shardings is not None:
        sflat, _ = jax.tree_util.tree_flatten_with_path(shardings)
        shard_flat = [s for _, s in sflat]

    leaves = []
    for i, (pth, like) in enumerate(flat):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in pth
        )
        entry = by_key.get(key)
        if entry is None:
            raise CheckpointCorruptionError(
                f"leaf {key!r} missing from manifest in {path}"
            )
        sh = shard_flat[i] if shard_flat is not None else None
        spec = sh.spec if sh is not None else P()
        if not entry.get("sharded"):
            if verify:
                arr = _load_leaf(path, entry)
            else:
                arr = np.load(os.path.join(path, entry["file"]))
            leaves.append(runtime.put_full(arr, spec))
            continue
        local = sub_by_key.get(key)
        if local is None:
            raise CheckpointCorruptionError(
                f"sharded leaf {key!r} missing from process "
                f"{runtime.process_index}'s shard manifest in {path}"
            )
        arr = _load_leaf(path, local) if verify else np.load(
            os.path.join(path, local["file"])
        )
        gshape = [int(s) for s in entry["shape"]]
        if sh is None:
            raise ValueError(
                f"sharded leaf {key!r} needs an explicit sharding to "
                "restore onto (pass `shardings`)"
            )
        want = _local_box(sh, gshape)
        got = [tuple(b) for b in local["box"]]
        if [tuple(b) for b in want] != got:
            raise CheckpointTopologyError(
                f"sharded leaf {key!r} in {path} was saved with local box "
                f"{got} but this runtime's sharding expects {want} — the "
                "mesh layout changed; resume on the saving topology"
            )
        leaves.append(runtime.put(arr, gshape, spec, box=want))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_latest_valid_multiproc(
    tree_like, directory: str, shardings, runtime: MultiHostMesh,
):
    """Collective ``restore_latest_valid``: every process verifies its
    own leaves of each step (newest first) and the verdicts are
    union-reduced, so all processes agree on the step they restore —
    one host's corrupt shard walks *everyone* back together. Topology
    mismatches propagate (they apply to every step; walking back would
    silently retrain a stale carry). Returns ``(tree, step)`` or
    ``None`` when nothing verifies anywhere."""
    from ..checkpoint.checkpoint import (
        CheckpointCorruptionError, list_steps,
    )

    for step in reversed(list_steps(directory)):
        path = os.path.join(directory, f"step_{step:08d}")
        try:
            _verify_local(path, runtime)
            ok = 1
        except (CheckpointCorruptionError, OSError, ValueError, KeyError):
            ok = 0
        agree = int(runtime.psum_hosts(np.asarray([ok]))[0])
        if agree == runtime.process_count:
            return restore_checkpoint_multiproc(
                tree_like, directory, step, shardings,
                runtime=runtime, verify=False,
            )
        warnings.warn(
            f"skipping checkpoint step {step} in {directory}: only "
            f"{agree}/{runtime.process_count} processes verified it",
            RuntimeWarning,
            stacklevel=2,
        )
    return None


class MultiprocCheckpointManager:
    """Rotating multi-process checkpoints — the drop-in counterpart of
    ``checkpoint.CheckpointManager`` for the multi-process growth plane.
    Process 0 owns orphan cleanup, garbage collection, and the manifest;
    saves and restores are collective (every process participates)."""

    def __init__(
        self, directory: str, keep: int = 3, save_interval: int = 100,
        *, runtime: MultiHostMesh,
    ):
        from ..checkpoint.checkpoint import _TMP_PREFIX

        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self.runtime = runtime
        if runtime.process_index == 0 and os.path.isdir(directory):
            for d in os.listdir(directory):
                if d.startswith(_TMP_PREFIX):
                    shutil.rmtree(
                        os.path.join(directory, d), ignore_errors=True
                    )
        runtime.barrier()

    def maybe_save(self, tree, step: int) -> Optional[str]:
        if step % self.save_interval != 0:
            return None
        path = save_checkpoint_multiproc(
            tree, self.directory, step, self.runtime
        )
        if self.runtime.process_index == 0:
            self._gc()
        self.runtime.barrier()
        return path

    def _gc(self):
        from ..checkpoint.checkpoint import list_steps

        for s in list_steps(self.directory)[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    def restore_latest_valid(self, tree_like, shardings=None):
        out = restore_latest_valid_multiproc(
            tree_like, self.directory, shardings, self.runtime
        )
        if out is None:
            raise FileNotFoundError(
                f"no valid checkpoint in {self.directory}"
            )
        return out
