"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds *per device*:

  compute    = dot_FLOPs / peak_FLOPs
  memory     = bytes_accessed / HBM_bw
  collective = collective_wire_bytes / ICI_link_bw

``compiled.cost_analysis()`` counts every while-loop body ONCE — useless
for scan-over-layers models (it undercounts a 58-layer scan 58x). We
therefore parse the post-SPMD HLO text ourselves:

  * computations are walked from ENTRY through calls / fusions / while
    bodies; each ``while`` carries ``known_trip_count`` in its
    backend_config, which multiplies everything inside (nested loops
    compose multiplicatively);
  * FLOPs: every ``dot`` contributes 2 * prod(result dims) * prod(
    contracting dims) * multiplier (matmul-dominated workloads; the
    elementwise remainder is ignored and stated);
  * bytes: per instruction, result + operand bytes (post-fusion HLO only
    materializes real buffers at computation scope, so this approximates
    HBM traffic) * multiplier;
  * collectives: operand bytes (result bytes for all-gather) of every
    all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute, * multiplier; async ``-start`` counted once.

All shapes in the partitioned module are per-device, so every number
here is per-device. Validated against closed-form 6ND models in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Any, Dict, List, Tuple

# --- TPU v5e hardware constants (per chip) ---------------------------------
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 2 ** 30,   # capacity
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]"
)
# result type is either a tuple "(...)" (may contain /*index=N*/ comments)
# or a single "dtype[dims]{layout}"; the op name follows it.
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z0-9\-]+)\("
)
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # control ops: their buffers are accounted inside the callee
    "while", "conditional", "call",
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Computation:
    name: str
    # (op, result_bytes, operand_names, line)
    instructions: List[Tuple[str, int, List[str], str]]
    # (kind, target, trip) edges: kind in {while, call}
    edges: List[Tuple[str, str, int]]
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)


_NAME_RE = re.compile(r"%([\w.\-]+)")
_ATTR_NAMES = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|true_computation=|false_computation=)"
)


def _result_info(line: str, op_start: int):
    """(result_bytes, result_dims_of_first_shape); head = text before op."""
    lhs_end = line.find(" = ")
    head = line[lhs_end + 3 : op_start]
    shapes = _SHAPE_RE.findall(head)
    rbytes = sum(_shape_bytes(d, s) for d, s in shapes)
    dims = _dims(shapes[0][1]) if shapes else []
    return rbytes, dims


def _operand_names(line: str, op_end: int) -> List[str]:
    """Instruction names referenced as operands (inside the call parens)."""
    p0 = line.find("(", op_end)
    p1 = line.find(")", p0)
    if p0 < 0 or p1 < 0:
        return []
    return _NAME_RE.findall(line[p0 : p1 + 1])


def parse_module(text: str) -> Tuple[Dict[str, Computation], str, Dict[str, Tuple[int, List[int]]]]:
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, Tuple[int, List[int]]] = {}   # %name -> (bytes, dims)
    entry = None
    current = None
    for raw in text.splitlines():
        if not raw.strip():
            continue
        stripped = raw.strip()
        if not raw.startswith(" "):
            m = _HEADER_RE.match(stripped)
            if m and "{" in raw:
                name = m.group(2)
                current = Computation(name, [], [])
                comps[name] = current
                if m.group(1):
                    entry = name
                continue
            if stripped == "}":
                current = None
                continue
        if current is None or " = " not in stripped:
            continue
        mo = _OP_RE.search(stripped)
        if not mo:
            continue
        op = mo.group(1)
        mname = _NAME_RE.match(stripped)
        iname = mname.group(1) if mname else None
        rbytes, rdims = _result_info(stripped, mo.start(1))
        if iname:
            shapes[iname] = (rbytes, rdims)
        # also record parameters (header args) lazily — params are
        # instructions too ("%param = f32[..] parameter(0)") so covered.

        if op == "while":
            trip = 1
            mt = _TRIP_RE.search(stripped)
            if mt:
                trip = int(mt.group(1))
            mb = _BODY_RE.search(stripped)
            mc = _COND_RE.search(stripped)
            if mb:
                current.edges.append(("while", mb.group(1), trip))
            if mc:
                current.edges.append(("while", mc.group(1), trip))
        elif op == "conditional":
            for mbr in re.finditer(
                r"(?:true_computation|false_computation)=%?([\w.\-]+)", stripped
            ):
                current.edges.append(("call", mbr.group(1), 1))
            mbrs = re.search(r"branch_computations=\{([^}]*)\}", stripped)
            if mbrs:
                for t in _NAME_RE.findall(mbrs.group(1)):
                    current.edges.append(("call", t, 1))
        else:
            # fusion/to_apply bodies execute in registers: count their
            # FLOPs, never their bytes ("fusion" edge kind).
            kind = "call" if op == "call" else "fusion"
            for mcall in re.finditer(
                r"(?:calls=|to_apply=)%?([\w.\-]+)", stripped
            ):
                current.edges.append((kind, mcall.group(1), 1))

        current.instructions.append(
            (op, rbytes, _operand_names(stripped, mo.end(1)), stripped)
        )
    return comps, entry, shapes


def _finalize(comps: Dict[str, Computation], shapes) -> None:
    """Second pass: resolve operand bytes by name; compute per-comp stats."""
    for c in comps.values():
        for op, rbytes, operands, line in c.instructions:
            obytes = sum(shapes.get(n, (0, []))[0] for n in operands)
            if op == "dot":
                lhs_dims = shapes.get(operands[0], (0, []))[1] if operands else []
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                contract = 1
                if m:
                    for idx in _dims(m.group(1)):
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
                mres = _SHAPE_RE.search(line.split(" = ", 1)[1])
                if mres:
                    e = 1
                    for d in _dims(mres.group(2)):
                        e *= d
                    c.dot_flops += 2.0 * e * contract
            if op not in _SKIP_BYTES_OPS:
                c.bytes_accessed += rbytes + obytes
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not (
                op.endswith("-done") or op.endswith("-update")
            ):
                e = c.coll.setdefault(
                    base, {"count": 0, "operand_bytes": 0, "result_bytes": 0}
                )
                e["count"] += 1
                e["operand_bytes"] += obytes
                e["result_bytes"] += rbytes


def _multipliers(
    comps: Dict[str, Computation], entry: str
) -> Tuple[Dict[str, float], Dict[str, float]]:
    """(exec_mult, mem_mult) per computation.

    Deltas propagate along the call DAG; crossing a fusion edge zeroes
    the *memory* multiplier (fusion bodies live in registers) while the
    execution multiplier (FLOPs, collectives) carries through.
    """
    exec_m: Dict[str, float] = defaultdict(float)
    mem_m: Dict[str, float] = defaultdict(float)
    pending: List[Tuple[str, float, float]] = [(entry, 1.0, 1.0)]
    while pending:
        name, de, dm = pending.pop()
        c = comps.get(name)
        if c is None:
            continue
        exec_m[name] += de
        mem_m[name] += dm
        for kind, target, trip in c.edges:
            if kind == "while":
                pending.append((target, de * trip, dm * trip))
            elif kind == "fusion":
                pending.append((target, de, 0.0))
            else:
                pending.append((target, de, dm))
    return exec_m, mem_m


def wire_bytes(colls: Dict[str, Dict[str, float]]) -> float:
    """Ring-model per-device wire bytes.

    all-reduce moves ~2x its operand (reduce-scatter + all-gather phases);
    all-gather ~= its result; reduce-scatter / all-to-all / permute ~= 1x
    operand. (The (n-1)/n factor is dropped uniformly.)
    """
    wire = 0.0
    for kind, e in colls.items():
        if kind == "all-gather":
            wire += e["result_bytes"]
        elif kind == "all-reduce":
            wire += 2.0 * e["operand_bytes"]
        else:
            wire += e["operand_bytes"]
    return wire


def analyze_hlo_text(text: str) -> Dict[str, Any]:
    comps, entry, shapes = parse_module(text)
    if entry is None:
        return {"flops": 0.0, "bytes_accessed": 0.0, "collective_bytes": 0.0,
                "collectives": {}}
    _finalize(comps, shapes)
    exec_m, mem_m = _multipliers(comps, entry)
    flops = sum(c.dot_flops * exec_m[c.name] for c in comps.values())
    bytes_acc = sum(c.bytes_accessed * mem_m[c.name] for c in comps.values())
    colls: Dict[str, Dict[str, float]] = {}
    for c in comps.values():
        m = exec_m[c.name]
        if m == 0:
            continue
        for kind, e in c.coll.items():
            t = colls.setdefault(
                kind, {"count": 0, "operand_bytes": 0, "result_bytes": 0}
            )
            t["count"] += e["count"] * m
            t["operand_bytes"] += e["operand_bytes"] * m
            t["result_bytes"] += e["result_bytes"] * m
    wire = wire_bytes(colls)
    return {
        "flops": flops,
        "bytes_accessed": bytes_acc,
        "collective_bytes": wire,
        "collectives": colls,
    }


def analyze_compiled(compiled) -> Dict[str, Any]:
    """Loop-aware cost/memory/collective stats (per device)."""
    out = analyze_hlo_text(compiled.as_text())
    # Raw cost_analysis kept for reference (body-counted-once semantics).
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        out["xla_flops_once"] = float(cost.get("flops", 0.0))
        out["xla_bytes_once"] = float(cost.get("bytes accessed", 0.0))
    except Exception:
        pass
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        }
    except Exception:
        out["memory"] = {}
    return out


def roofline_terms(analysis: Dict[str, Any], *, model_flops_per_device: float,
                   hw: Dict[str, float] = HW) -> Dict[str, Any]:
    compute_s = analysis["flops"] / hw["peak_flops_bf16"]
    memory_s = analysis["bytes_accessed"] / hw["hbm_bw"]
    coll_s = analysis["collective_bytes"] / hw["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    model_s = model_flops_per_device / hw["peak_flops_bf16"]
    return {
        **terms,
        "dominant": dominant,
        "model_flops_per_device": model_flops_per_device,
        "model_compute_s": model_s,
        "useful_flops_ratio": (
            model_flops_per_device / analysis["flops"] if analysis["flops"] else 0.0
        ),
        "roofline_fraction": model_s / max(terms.values()) if max(terms.values()) else 0.0,
    }
