"""PRF serving layer — bucketed, batched, tree-sharded forest inference.

The ROADMAP north star is serving "heavy traffic from millions of
users"; arXiv:1804.06755 (PAPERS.md) shows deployed-RF cost is
dominated by inference, not training. This module turns a trained
:class:`repro.core.api.PRFModel` into a serving endpoint built on the
fused traversal+voting path (``ForestConfig.predict_backend``):

* **Power-of-two batch bucketing** — request batches are padded up to
  the next power-of-two bucket (clamped to ``[min_bucket, max_batch]``)
  with an explicit validity mask, so the jit cache holds at most
  ``log2(max_batch / min_bucket) + 1`` compiled shapes no matter what
  batch sizes arrive. Padded rows are masked out of the scores and
  sliced off; they can never leak into a real row (per-sample
  traversal is row-independent, and tests/test_serving.py pins it).

* **Async micro-batch queue** — ``submit()`` enqueues a request and
  returns a :class:`PRFFuture`; ``drain()`` aggregates everything
  pending into one bucketed forward pass and resolves the futures in
  submission order. ``submit`` auto-drains when the queue reaches
  ``max_batch`` rows, so latency under load is one forward pass.

* **Tree-sharded multi-device voting** — ``make_sharded_vote_fn``
  shards the forest's node-pool arrays (and vote payloads) over a mesh
  axis, each shard accumulates the weighted votes of its own trees
  into an ``[N, C]`` partial score, and a single ``psum`` combines
  them (Eq. 9/10 is a sum over trees) — mirroring
  ``core/distributed``'s T_GR histogram combine, with O(N*C) words on
  the wire instead of O(k*N*C).
"""
from __future__ import annotations

import threading
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.api import PRFModel
from ..core.binning import apply_bins
from ..core.distributed import _shard_map
from ..core.types import Forest
from ..core.voting import (
    _vote_weights, build_payload, predict_regression, predict_scores,
    resolve_predict_backend,
)


def bucket_size(n: int, *, min_bucket: int = 8, max_batch: int = 1024) -> int:
    """Next power-of-two >= n, clamped to [min_bucket, max_batch]."""
    if n <= 0:
        raise ValueError(f"batch size must be positive, got {n}")
    b = 1 << max(0, n - 1).bit_length()
    return max(min_bucket, min(b, max_batch))


class PRFFuture:
    """Result handle for a queued request (resolved by ``drain``)."""

    __slots__ = ("_value", "_done")

    def __init__(self):
        self._value = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def result(self) -> np.ndarray:
        if not self._done:
            raise RuntimeError("request not served yet — call drain()")
        return self._value

    def _resolve(self, value: np.ndarray) -> None:
        self._value = value
        self._done = True


class PRFService:
    """Serving wrapper around a trained PRF model.

    >>> svc = PRFService(model)
    >>> labels = svc.predict(x)                  # any batch size
    >>> fut = svc.submit(x1); svc.submit(x2)     # micro-batch queue
    >>> svc.drain(); fut.result()
    """

    def __init__(
        self,
        model: PRFModel,
        *,
        max_batch: int = 1024,
        min_bucket: int = 8,
        backend: Optional[str] = None,
    ):
        if max_batch & (max_batch - 1) or min_bucket & (min_bucket - 1):
            raise ValueError("max_batch and min_bucket must be powers of two")
        if min_bucket > max_batch:
            raise ValueError(
                f"min_bucket={min_bucket} must not exceed max_batch={max_batch}"
            )
        if backend is not None:
            model = model.with_predict_backend(backend)
        self.model = model
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self._edges = jnp.asarray(model.bin_edges)
        self._n_features = int(np.asarray(model.bin_edges).shape[0])
        # One entry per request — a single list (under one lock) so the
        # request order and its rows can never diverge across threads.
        self._queue: List[Tuple[np.ndarray, bool, PRFFuture]] = []
        self._queued_rows = 0
        self._lock = threading.Lock()
        self._buckets_seen: set = set()
        self._requests_served = 0

        forest = model.forest
        cfg = forest.config
        use_pallas = resolve_predict_backend(cfg.predict_backend) == "pallas"
        # Payloads depend only on the trained forest — precompute ONCE
        # at service construction so the per-request fused path does no
        # O(k*P*C) payload work (mirrors make_sharded_vote_fn). Forest
        # and payload are jit ARGUMENTS, not closure captures: every
        # bucket shape compiles its own executable, and constants would
        # embed a private copy of the model per bucket.
        self._forest = forest
        self._payload = build_payload(forest) if use_pallas else None

        def bucket_predict(forest, payload, xb, valid):
            # The mask zeroes padded rows' scores before the argmax /
            # normalization — padded rows can never leak a non-neutral
            # value even if a caller forgets to slice.
            from ..core.forest import fused_vote_scores

            if cfg.regression:
                if use_pallas:
                    norm = jnp.maximum(_vote_weights(forest).sum(), 1e-38)
                    vals = fused_vote_scores(forest, xb, payload)[:, 0] / norm
                else:
                    vals = predict_regression(forest, xb)
                return jnp.where(valid, vals, 0.0)
            scores = (
                fused_vote_scores(forest, xb, payload)
                if use_pallas
                else predict_scores(forest, xb)
            )
            scores = jnp.where(valid[:, None], scores, 0.0)
            return jnp.argmax(scores, axis=-1)

        self._bucket_predict = jax.jit(bucket_predict)

    def _validate(self, x: np.ndarray) -> np.ndarray:
        """Shape-check a request up front: a malformed request must fail
        at its own submit/predict call, never poison an aggregated
        micro-batch that other requests ride in."""
        x = np.asarray(x)
        if x.ndim == 1:
            x = x[None]
        if x.ndim != 2 or x.shape[1] != self._n_features:
            raise ValueError(
                f"expected [n, {self._n_features}] features, got {x.shape}"
            )
        if len(x) == 0:
            raise ValueError("empty request")
        return x

    # -- direct (synchronous) path ---------------------------------------

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Predict labels/values for any batch size (bucketed + padded)."""
        squeeze = np.ndim(x) == 1
        x = self._validate(x)
        # Bin once on device and keep it there: padding with jnp.pad
        # avoids the device->host->device round-trip a numpy pad costs
        # on every request.
        xb = apply_bins(jnp.asarray(x), self._edges)
        outs = []
        for i in range(0, len(xb), self.max_batch):
            outs.append(self._predict_bucketed(xb[i : i + self.max_batch]))
        out = np.concatenate(outs, axis=0)
        return out[0] if squeeze else out

    def _predict_bucketed(self, xb: jnp.ndarray) -> np.ndarray:
        n = len(xb)
        b = bucket_size(n, min_bucket=self.min_bucket, max_batch=self.max_batch)
        self._buckets_seen.add(b)
        padded = jnp.pad(xb, ((0, b - n), (0, 0)))
        valid = jnp.arange(b) < n
        out = self._bucket_predict(self._forest, self._payload, padded, valid)
        return np.asarray(out)[:n]

    # -- async micro-batch queue -----------------------------------------

    def submit(self, x: np.ndarray) -> PRFFuture:
        """Enqueue a request; returns a future resolved by ``drain``.

        Auto-drains when the aggregated queue reaches ``max_batch``
        rows, so a saturated queue costs one forward pass per batch.
        """
        single = np.ndim(x) == 1
        x = self._validate(x)
        fut = PRFFuture()
        with self._lock:
            self._queue.append((x, single, fut))
            self._queued_rows += len(x)
            full = self._queued_rows >= self.max_batch
        if full:
            self.drain()
        return fut

    @property
    def pending(self) -> int:
        """Number of queued (unserved) requests."""
        return len(self._queue)

    def drain(self) -> int:
        """Serve every queued request in one aggregated micro-batch.

        Resolves futures in submission order; returns the number of
        requests served.
        """
        # Snapshot-and-clear under the lock, run the forward pass outside
        # it — concurrent submits keep aggregating into the NEXT batch
        # while this one is in flight. On failure the snapshot is
        # re-prepended, so requests are never silently lost.
        with self._lock:
            if not self._queue:
                return 0
            queue = self._queue
            self._queue, self._queued_rows = [], 0
        try:
            out = self.predict(np.concatenate([x for x, _, _ in queue]))
        except Exception:
            with self._lock:
                self._queue = queue + self._queue
                self._queued_rows += sum(len(x) for x, _, _ in queue)
            raise
        served = 0
        offset = 0
        for (x, single, fut) in queue:
            chunk = out[offset : offset + len(x)]
            fut._resolve(chunk[0] if single else chunk)
            offset += len(x)
            served += 1
        self._requests_served += served
        return served

    def stats(self) -> dict:
        """Serving counters — bounded-recompilation evidence included."""
        return {
            "buckets_compiled": sorted(self._buckets_seen),
            "max_buckets": self.max_batch.bit_length()
            - self.min_bucket.bit_length()
            + 1,
            "requests_served": self._requests_served,
            "pending": self.pending,
        }


# ---------------------------------------------------------------------------
# Tree-sharded multi-device voting
# ---------------------------------------------------------------------------


def make_sharded_vote_fn(forest: Forest, mesh, *, tree_axis: str = "data"):
    """Build a jit'd multi-device predictor with trees sharded over
    ``tree_axis``.

    Each shard walks only its own ``k / axis_size`` trees (fused kernel
    on TPU, XLA oracle elsewhere — ``config.predict_backend``) and
    accumulates their weighted votes into an ``[N, C]`` partial score;
    one ``psum`` combines the partials (the Eq. 9/10 sum over trees is
    associative), then argmax / Eq. 9 normalization runs replicated.
    Mirrors ``core/distributed``'s training-side histogram combine:
    O(N*C) words cross the wire, never the ``[k, N, C]`` tensor.

    Returns ``fn(x_binned) -> [N]`` labels (classification) or values
    (regression). ``n_trees`` must divide evenly over ``tree_axis``.
    """
    cfg = forest.config
    w = _vote_weights(forest)
    payload = build_payload(forest)
    depth = cfg.max_depth
    use_pallas = resolve_predict_backend(cfg.predict_backend) == "pallas"
    norm = jnp.maximum(w.sum(), 1e-38)

    def shard(feat, thr, left, pay, xb):
        from ..kernels.tree_traverse.ops import fused_vote

        partial = fused_vote(
            xb, feat, thr, left, pay, depth=depth, use_pallas=use_pallas
        )
        scores = jax.lax.psum(partial, tree_axis)            # the ONE combine
        if cfg.regression:
            return scores[:, 0] / norm
        return jnp.argmax(scores, axis=-1)

    fn = jax.jit(
        _shard_map(
            shard,
            mesh=mesh,
            in_specs=(P(tree_axis), P(tree_axis), P(tree_axis), P(tree_axis), P()),
            out_specs=P(),
        )
    )

    def run(x_binned):
        return fn(
            forest.feature, forest.threshold, forest.left_child, payload,
            x_binned,
        )

    return run
