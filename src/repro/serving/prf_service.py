"""PRF serving layer — bucketed, batched, tree-sharded forest inference.

The ROADMAP north star is serving "heavy traffic from millions of
users"; arXiv:1804.06755 (PAPERS.md) shows deployed-RF cost is
dominated by inference, not training. This module turns a trained
:class:`repro.core.api.PRFModel` into a serving endpoint built on the
fused traversal+voting path (``ForestConfig.predict_backend``):

* **Power-of-two batch bucketing** — request batches are padded up to
  the next power-of-two bucket (clamped to ``[min_bucket, max_batch]``)
  with an explicit validity mask, so the jit cache holds at most
  ``log2(max_batch / min_bucket) + 1`` compiled shapes no matter what
  batch sizes arrive. Padded rows are masked out of the scores and
  sliced off; they can never leak into a real row (per-sample
  traversal is row-independent, and tests/test_serving.py pins it).

* **Async micro-batch queue** — ``submit()`` enqueues a request and
  returns a :class:`PRFFuture`; ``drain()`` aggregates everything
  pending into one bucketed forward pass and resolves the futures in
  submission order. ``submit`` auto-drains when the queue reaches
  ``max_batch`` rows, so latency under load is one forward pass.

* **Tree-sharded multi-device voting** — ``make_sharded_vote_fn``
  shards the forest's node-pool arrays (and vote payloads) over a mesh
  axis, each shard accumulates the weighted votes of its own trees
  into an ``[N, C]`` partial score, and a single ``psum`` combines
  them (Eq. 9/10 is a sum over trees) — mirroring
  ``core/distributed``'s T_GR histogram combine, with O(N*C) words on
  the wire instead of O(k*N*C).

* **Resilience** — overload is shed at admission with typed errors
  (``max_queue_rows`` -> :class:`ServiceOverloaded`, a cheap queue-depth
  check, never a forward pass); a per-service
  :class:`CircuitBreaker` opens after consecutive model failures and
  half-open-probes its way back (:class:`CircuitOpenError` while open —
  queued requests are kept, new ones shed); ``shutdown()`` settles
  every pending future deterministically (served on drain, rejected
  with :class:`ServiceClosedError` on cancel); and
  :class:`ModelRegistry` gives each published model version its own
  bulkheaded service, hot-swapping versions with an atomic pointer
  flip that drops zero in-flight futures (the old service drains with
  the old model). tests/test_serving.py pins all of it.

* **Cache-aside result cache** — an optional per-service LRU
  (``cache_size`` entries) keyed by a SHA-1 digest of the submitted
  row batch (bytes + shape + dtype). A hit returns the stored
  prediction bitwise-identically with zero device work — it is checked
  before the circuit breaker, so hot rows keep serving even while the
  model is failing. Hit/miss/eviction counters surface in ``health()``
  and ``stats()``; :class:`ModelRegistry.publish` explicitly
  invalidates the outgoing service's cache at hot-swap so a retired
  fallback never compounds a stale model with stale cached rows.

* **Degraded mode** — per-request deadlines bound queue staleness
  (:class:`DeadlineExceeded`, settled through the future at drain); a
  per-client token-bucket :class:`RateLimiter` sheds abusive clients in
  front of admission control (:class:`RateLimited`); while the live
  breaker is open, ``ModelRegistry.predict`` answers from the newest
  *healthy* retired version (stale-but-correct beats erroring); and
  ``health()`` exposes breaker / queue / shed / deadline / rate-limit /
  quarantine counters as a flat snapshot a load balancer can scrape.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.api import PRFModel
from ..core.binning import apply_bins
from ..core.distributed import _shard_map
from ..core.types import Forest
from ..core.voting import (
    _vote_weights, build_payload, predict_regression, predict_scores,
    resolve_predict_backend,
)


def bucket_size(n: int, *, min_bucket: int = 8, max_batch: int = 1024) -> int:
    """Next power-of-two >= n, clamped to [min_bucket, max_batch]."""
    if n <= 0:
        raise ValueError(f"batch size must be positive, got {n}")
    b = 1 << max(0, n - 1).bit_length()
    return max(min_bucket, min(b, max_batch))


class ServiceError(RuntimeError):
    """Base class of the serving layer's typed rejections — a caller
    catching it handles every fast-shed path (overload, open circuit,
    shutdown) without also swallowing model/compiler failures."""


class ServiceOverloaded(ServiceError):
    """Admission control: the queue is at ``max_queue_rows``."""


class CircuitOpenError(ServiceError):
    """The service's circuit breaker is open (model keeps failing)."""


class ServiceClosedError(ServiceError):
    """The service was shut down (or the registry has no model)."""


class DeadlineExceeded(ServiceError):
    """The request's deadline expired before it was served. Settled
    through the normal future path at drain time — a late future is
    rejected, never silently dropped."""


class RateLimited(ServiceError):
    """The client's token bucket is empty (per-client rate limiting in
    front of admission control)."""


class RateLimiter:
    """Per-client token-bucket rate limiter (cloud-patterns style).

    Each client id owns a bucket holding up to ``burst`` tokens that
    refills at ``rate`` tokens/second; a request for ``n`` rows is
    admitted iff ``n`` tokens are available (and consumes them). Tokens
    are charged per ROW, the same currency as ``max_queue_rows``, so
    ``burst`` must cover a client's largest single request. Lazy refill
    (computed from elapsed time at each call) keeps it O(1) per request
    with no background thread; ``clock`` is injectable so tests drive
    refills without sleeping.
    """

    def __init__(
        self, rate: float, burst: float,
        *,
        clock: Callable[[], float] = time.monotonic,
    ):
        if rate <= 0:
            raise ValueError(f"rate must be > 0 tokens/s, got {rate}")
        if burst < 1:
            raise ValueError(f"burst must be >= 1 token, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._lock = threading.Lock()
        self._buckets: Dict[str, Tuple[float, float]] = {}  # id -> (tokens, t)
        self.granted = 0
        self.rejected = 0

    def allow(self, client: str = "", n: float = 1.0) -> bool:
        """Take ``n`` tokens from ``client``'s bucket; False = shed."""
        now = self._clock()
        with self._lock:
            tokens, last = self._buckets.get(client, (self.burst, now))
            tokens = min(self.burst, tokens + (now - last) * self.rate)
            if tokens >= n:
                self._buckets[client] = (tokens - n, now)
                self.granted += 1
                return True
            self._buckets[client] = (tokens, now)
            self.rejected += 1
            return False

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "rate": self.rate, "burst": self.burst,
                "clients": len(self._buckets),
                "granted": self.granted, "rejected": self.rejected,
            }


class CircuitBreaker:
    """Per-service circuit breaker with half-open probing.

    ``failure_threshold`` consecutive model failures open the circuit;
    while open, ``allow()`` is False (callers shed with
    :class:`CircuitOpenError` instead of burning a forward pass on a
    broken model). After ``reset_timeout`` seconds ONE probe call is
    let through (half-open): success closes the circuit, failure
    re-opens it for another full timeout. ``clock`` is injectable so
    tests drive the state machine without sleeping.
    """

    def __init__(
        self,
        failure_threshold: int = 5,
        reset_timeout: float = 30.0,
        *,
        clock: Callable[[], float] = time.monotonic,
    ):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if reset_timeout < 0:
            raise ValueError("reset_timeout must be >= 0")
        self.failure_threshold = failure_threshold
        self.reset_timeout = reset_timeout
        self._clock = clock
        self._lock = threading.Lock()
        self._failures = 0
        self._state = "closed"
        self._opened_at = 0.0

    @property
    def state(self) -> str:
        """"closed" | "open" | "half_open" (open, probe window reached).
        A peek — never consumes the half-open probe."""
        with self._lock:
            if (
                self._state == "open"
                and self._clock() - self._opened_at >= self.reset_timeout
            ):
                return "half_open"
            return self._state

    def allow(self) -> bool:
        """May a call proceed? Consumes the single half-open probe."""
        with self._lock:
            if self._state == "closed":
                return True
            if (
                self._state == "open"
                and self._clock() - self._opened_at >= self.reset_timeout
            ):
                self._state = "half_open"        # this call IS the probe
                return True
            return False          # open, or a half-open probe in flight

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._state = "closed"

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            if self._state == "half_open" or self._failures >= self.failure_threshold:
                self._state = "open"
                self._opened_at = self._clock()


class PRFFuture:
    """Result handle for a queued request (settled by ``drain`` /
    ``shutdown``): resolved with a value, or rejected with an exception
    that ``result()`` re-raises."""

    __slots__ = ("_value", "_exc", "_done")

    def __init__(self):
        self._value = None
        self._exc = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def result(self) -> np.ndarray:
        if not self._done:
            raise RuntimeError("request not served yet — call drain()")
        if self._exc is not None:
            raise self._exc
        return self._value

    def exception(self) -> Optional[BaseException]:
        """The rejection, or None if resolved with a value."""
        if not self._done:
            raise RuntimeError("request not served yet — call drain()")
        return self._exc

    def _resolve(self, value: np.ndarray) -> None:
        self._value = value
        self._done = True

    def _reject(self, exc: BaseException) -> None:
        self._exc = exc
        self._done = True


class PRFService:
    """Serving wrapper around a trained PRF model.

    >>> svc = PRFService(model)
    >>> labels = svc.predict(x)                  # any batch size
    >>> fut = svc.submit(x1); svc.submit(x2)     # micro-batch queue
    >>> svc.drain(); fut.result()
    """

    def __init__(
        self,
        model: PRFModel,
        *,
        max_batch: int = 1024,
        min_bucket: int = 8,
        backend: Optional[str] = None,
        max_queue_rows: Optional[int] = None,
        breaker: Optional[CircuitBreaker] = None,
        rate_limiter: Optional[RateLimiter] = None,
        default_deadline: Optional[float] = None,
        cache_size: int = 0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_batch & (max_batch - 1) or min_bucket & (min_bucket - 1):
            raise ValueError("max_batch and min_bucket must be powers of two")
        if min_bucket > max_batch:
            raise ValueError(
                f"min_bucket={min_bucket} must not exceed max_batch={max_batch}"
            )
        if max_queue_rows is not None and max_queue_rows < 1:
            raise ValueError("max_queue_rows must be >= 1")
        if backend is not None:
            model = model.with_predict_backend(backend)
        self.model = model
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        # Admission control: queue depth past which submit() sheds with
        # ServiceOverloaded — a counter compare under the lock, so a
        # saturated service rejects in O(1) instead of queueing without
        # bound. None = unbounded (the pre-hardening behavior).
        self.max_queue_rows = max_queue_rows
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        # Degraded-mode knobs: a per-client token bucket sheds abusive
        # traffic BEFORE the queue-depth check (RateLimited), and
        # deadlines bound how stale a queued request may get before it
        # is rejected instead of served (DeadlineExceeded at drain).
        self.rate_limiter = rate_limiter
        if default_deadline is not None and default_deadline <= 0:
            raise ValueError("default_deadline must be > 0 seconds")
        self.default_deadline = default_deadline
        self._clock = clock
        self._edges = jnp.asarray(model.bin_edges)
        self._n_features = int(np.asarray(model.bin_edges).shape[0])
        # One entry per request — a single list (under one lock) so the
        # request order and its rows can never diverge across threads.
        # Entries: (x, single, future, absolute-deadline-or-None).
        self._queue: List[
            Tuple[np.ndarray, bool, PRFFuture, Optional[float]]
        ] = []
        self._queued_rows = 0
        self._lock = threading.Lock()
        self._closed = False
        self._buckets_seen: set = set()
        # Cache-aside result cache: digest of the request batch -> its
        # prediction. cache_size=0 disables it entirely (no hashing
        # cost). Entries hold private copies so a caller mutating its
        # input or output array can never poison a later hit.
        if cache_size < 0:
            raise ValueError(f"cache_size must be >= 0, got {cache_size}")
        self.cache_size = cache_size
        self._cache: "OrderedDict[bytes, np.ndarray]" = OrderedDict()
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_evictions = 0
        self._requests_served = 0
        self._requests_shed = 0
        self._requests_cancelled = 0
        self._requests_deadline_exceeded = 0
        self._requests_rate_limited = 0

        forest = model.forest
        cfg = forest.config
        use_pallas = resolve_predict_backend(cfg.predict_backend) == "pallas"
        # Payloads depend only on the trained forest — precompute ONCE
        # at service construction so the per-request fused path does no
        # O(k*P*C) payload work (mirrors make_sharded_vote_fn). Forest
        # and payload are jit ARGUMENTS, not closure captures: every
        # bucket shape compiles its own executable, and constants would
        # embed a private copy of the model per bucket.
        self._forest = forest
        self._payload = build_payload(forest) if use_pallas else None

        def bucket_predict(forest, payload, xb, valid):
            # The mask zeroes padded rows' scores before the argmax /
            # normalization — padded rows can never leak a non-neutral
            # value even if a caller forgets to slice.
            from ..core.forest import fused_vote_scores

            if cfg.regression:
                if use_pallas:
                    norm = jnp.maximum(_vote_weights(forest).sum(), 1e-38)
                    vals = fused_vote_scores(forest, xb, payload)[:, 0] / norm
                else:
                    vals = predict_regression(forest, xb)
                return jnp.where(valid, vals, 0.0)
            scores = (
                fused_vote_scores(forest, xb, payload)
                if use_pallas
                else predict_scores(forest, xb)
            )
            scores = jnp.where(valid[:, None], scores, 0.0)
            return jnp.argmax(scores, axis=-1)

        self._bucket_predict = jax.jit(bucket_predict)

    def _validate(self, x: np.ndarray) -> np.ndarray:
        """Shape-check a request up front: a malformed request must fail
        at its own submit/predict call, never poison an aggregated
        micro-batch that other requests ride in."""
        x = np.asarray(x)
        if x.ndim == 1:
            x = x[None]
        if x.ndim != 2 or x.shape[1] != self._n_features:
            raise ValueError(
                f"expected [n, {self._n_features}] features, got {x.shape}"
            )
        if len(x) == 0:
            raise ValueError("empty request")
        return x

    # -- cache-aside result cache ----------------------------------------

    @staticmethod
    def _cache_key(x: np.ndarray) -> bytes:
        h = hashlib.sha1()
        h.update(str(x.dtype).encode())
        h.update(np.asarray(x.shape, np.int64).tobytes())
        h.update(np.ascontiguousarray(x).tobytes())
        return h.digest()

    def _cache_get(self, key: bytes) -> Optional[np.ndarray]:
        with self._lock:
            out = self._cache.get(key)
            if out is None:
                self._cache_misses += 1
                return None
            self._cache.move_to_end(key)
            self._cache_hits += 1
            return out.copy()

    def _cache_put(self, key: bytes, out: np.ndarray) -> None:
        with self._lock:
            if key not in self._cache and len(self._cache) >= self.cache_size:
                self._cache.popitem(last=False)
                self._cache_evictions += 1
            self._cache[key] = out.copy()
            self._cache.move_to_end(key)

    def invalidate_cache(self) -> int:
        """Drop every cached prediction; returns how many were dropped.
        Called by :class:`ModelRegistry.publish` on the outgoing
        service at hot-swap."""
        with self._lock:
            n = len(self._cache)
            self._cache.clear()
            return n

    # -- direct (synchronous) path ---------------------------------------

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Predict labels/values for any batch size (bucketed + padded).

        The circuit breaker brackets the forward pass: while open it
        sheds with :class:`CircuitOpenError` before any device work
        (a ``drain`` hitting it keeps its requests queued for the next
        probe); client-side :class:`ValueError`/``ServiceError`` never
        count as model failures. Stateless, so it stays usable after
        ``shutdown`` (only admission closes).

        With ``cache_size > 0`` the batch digest is looked up first: a
        hit returns the stored prediction bitwise-identically — before
        the breaker, since no model work is needed.
        """
        squeeze = np.ndim(x) == 1
        x = self._validate(x)
        key = self._cache_key(x) if self.cache_size > 0 else None
        if key is not None:
            hit = self._cache_get(key)
            if hit is not None:
                return hit[0] if squeeze else hit
        if not self.breaker.allow():
            raise CircuitOpenError(
                f"circuit open after repeated model failures; retrying in "
                f"<= {self.breaker.reset_timeout:g}s"
            )
        try:
            # Bin once on device and keep it there: padding with jnp.pad
            # avoids the device->host->device round-trip a numpy pad
            # costs on every request.
            xb = apply_bins(jnp.asarray(x), self._edges)
            outs = []
            for i in range(0, len(xb), self.max_batch):
                outs.append(self._predict_bucketed(xb[i : i + self.max_batch]))
            out = np.concatenate(outs, axis=0)
        except ServiceError:
            raise
        except Exception:
            self.breaker.record_failure()
            raise
        self.breaker.record_success()
        if key is not None:
            self._cache_put(key, out)
        return out[0] if squeeze else out

    def _predict_bucketed(self, xb: jnp.ndarray) -> np.ndarray:
        n = len(xb)
        b = bucket_size(n, min_bucket=self.min_bucket, max_batch=self.max_batch)
        self._buckets_seen.add(b)
        padded = jnp.pad(xb, ((0, b - n), (0, 0)))
        valid = jnp.arange(b) < n
        out = self._bucket_predict(self._forest, self._payload, padded, valid)
        return np.asarray(out)[:n]

    # -- async micro-batch queue -----------------------------------------

    def submit(
        self, x: np.ndarray, *,
        client: str = "",
        deadline: Optional[float] = None,
    ) -> PRFFuture:
        """Enqueue a request; returns a future resolved by ``drain``.

        Auto-drains when the aggregated queue reaches ``max_batch``
        rows, so a saturated queue costs one forward pass per batch.

        Admission is the fast-shed point: a shut-down service raises
        :class:`ServiceClosedError`, an open circuit
        :class:`CircuitOpenError`, a drained token bucket
        :class:`RateLimited` (per-``client``, charged by rows), and a
        queue at ``max_queue_rows`` :class:`ServiceOverloaded` — all
        typed, all before the request touches the queue, so accepted
        requests keep their bounded one-forward-pass latency under
        overload.

        ``deadline`` (seconds from now; default ``default_deadline``)
        bounds queue staleness: a request still queued when its deadline
        passes is settled with :class:`DeadlineExceeded` at the next
        drain — through the future, never dropped.
        """
        single = np.ndim(x) == 1
        x = self._validate(x)
        if self.breaker.state == "open":
            with self._lock:
                self._requests_shed += 1
            raise CircuitOpenError(
                "circuit open after repeated model failures; request shed"
            )
        if self.rate_limiter is not None and not self.rate_limiter.allow(
            client, n=len(x)
        ):
            with self._lock:
                self._requests_rate_limited += 1
            raise RateLimited(
                f"client {client!r} exceeded its token bucket "
                f"({self.rate_limiter.rate:g} rows/s, burst "
                f"{self.rate_limiter.burst:g}) — request of {len(x)} shed"
            )
        if deadline is None:
            deadline = self.default_deadline
        elif deadline <= 0:
            raise ValueError(f"deadline must be > 0 seconds, got {deadline}")
        expires = None if deadline is None else self._clock() + deadline
        fut = PRFFuture()
        with self._lock:
            if self._closed:
                raise ServiceClosedError("submit on a shut-down service")
            if (
                self.max_queue_rows is not None
                and self._queued_rows + len(x) > self.max_queue_rows
            ):
                self._requests_shed += 1
                raise ServiceOverloaded(
                    f"queue full: {self._queued_rows} rows pending, request "
                    f"of {len(x)} exceeds max_queue_rows={self.max_queue_rows}"
                )
            self._queue.append((x, single, fut, expires))
            self._queued_rows += len(x)
            full = self._queued_rows >= self.max_batch
        if full:
            self.drain()
        return fut

    @property
    def pending(self) -> int:
        """Number of queued (unserved) requests."""
        return len(self._queue)

    def drain(self) -> int:
        """Settle every queued request: expired deadlines are rejected
        (:class:`DeadlineExceeded`), the rest served in one aggregated
        micro-batch.

        Resolves futures in submission order; returns the number of
        requests settled (served + deadline-rejected).
        """
        # Snapshot-and-clear under the lock, run the forward pass outside
        # it — concurrent submits keep aggregating into the NEXT batch
        # while this one is in flight. On failure the snapshot is
        # re-prepended, so requests are never silently lost.
        with self._lock:
            if not self._queue:
                return 0
            queue = self._queue
            self._queue, self._queued_rows = [], 0
        now = self._clock()
        live = [e for e in queue if e[3] is None or now <= e[3]]
        expired = [e for e in queue if not (e[3] is None or now <= e[3])]
        for (_, _, fut, dl) in expired:
            fut._reject(DeadlineExceeded(
                f"request expired {now - dl:.3f}s past its deadline while "
                f"queued — shed at drain"
            ))
        if expired:
            with self._lock:
                self._requests_deadline_exceeded += len(expired)
        if not live:
            return len(expired)
        try:
            out = self.predict(np.concatenate([x for x, _, _, _ in live]))
        except Exception:
            with self._lock:
                self._queue = live + self._queue
                self._queued_rows += sum(len(x) for x, _, _, _ in live)
            raise
        served = 0
        offset = 0
        for (x, single, fut, _) in live:
            chunk = out[offset : offset + len(x)]
            fut._resolve(chunk[0] if single else chunk)
            offset += len(x)
            served += 1
        self._requests_served += served
        return served + len(expired)

    def shutdown(self, drain: bool = True) -> int:
        """Stop admission and settle every pending future.

        After this, ``submit`` raises :class:`ServiceClosedError`.
        With ``drain=True`` pending requests are served one last time
        (this is how :class:`ModelRegistry` hot-swaps without dropping
        an in-flight future); with ``drain=False`` — or if the final
        drain itself fails — the remainder is rejected with
        :class:`ServiceClosedError`, so every future is deterministically
        ``done()`` either way. Returns the number of futures settled.
        Idempotent; the direct ``predict`` path stays usable (it holds
        no queue state).
        """
        with self._lock:
            self._closed = True
        settled = 0
        if drain:
            try:
                settled = self.drain()
            except Exception:
                pass                  # failed drain re-queued — cancel below
        with self._lock:
            queue, self._queue, self._queued_rows = self._queue, [], 0
        for (_, _, fut, _) in queue:
            fut._reject(
                ServiceClosedError("service shut down before request was served")
            )
        with self._lock:
            self._requests_cancelled += len(queue)
        return settled + len(queue)

    def stats(self) -> dict:
        """Serving counters — bounded-recompilation evidence included."""
        return {
            "buckets_compiled": sorted(self._buckets_seen),
            "max_buckets": self.max_batch.bit_length()
            - self.min_bucket.bit_length()
            + 1,
            "requests_served": self._requests_served,
            "requests_shed": self._requests_shed,
            "requests_cancelled": self._requests_cancelled,
            "requests_deadline_exceeded": self._requests_deadline_exceeded,
            "requests_rate_limited": self._requests_rate_limited,
            "cache_hits": self._cache_hits,
            "cache_misses": self._cache_misses,
            "cache_evictions": self._cache_evictions,
            "breaker_state": self.breaker.state,
            "closed": self._closed,
            "pending": self.pending,
        }

    def health(self) -> dict:
        """Scrapeable health snapshot for a load balancer / monitor.

        Flat scalars: breaker state, queue depth (requests and rows),
        the shed / deadline / rate-limit / cancel counters, and the
        quarantined-block count of the model's training-time integrity
        report (0 when validation was off or found nothing). One lock
        acquisition; no device work.
        """
        q = self.model.quarantine
        with self._lock:
            snap = {
                "breaker": self.breaker.state,
                "closed": self._closed,
                "queue_requests": len(self._queue),
                "queue_rows": self._queued_rows,
                "max_queue_rows": self.max_queue_rows,
                "served": self._requests_served,
                "shed": self._requests_shed,
                "cancelled": self._requests_cancelled,
                "deadline_exceeded": self._requests_deadline_exceeded,
                "rate_limited": self._requests_rate_limited,
                "cache_size": self.cache_size,
                "cache_entries": len(self._cache),
                "cache_hits": self._cache_hits,
                "cache_misses": self._cache_misses,
                "cache_evictions": self._cache_evictions,
                "quarantined_blocks": (
                    0 if q is None else len(q.quarantined)
                ),
            }
        if self.rate_limiter is not None:
            snap["rate_limiter"] = self.rate_limiter.snapshot()
        return snap


# ---------------------------------------------------------------------------
# Versioned model registry: bulkheaded services, atomic hot-swap
# ---------------------------------------------------------------------------


class ModelRegistry:
    """Versioned registry of :class:`PRFService` instances with atomic
    hot-swap.

    Every ``publish`` wraps its model in a **fresh** service — its own
    queue, circuit breaker, and counters — so versions are bulkheaded:
    a failing or breaker-open version cannot shed, block, or fail
    requests of any other version. The live version is a single
    reference flipped under a lock; readers grab it with one attribute
    read, so a request routed to the old service the instant before a
    flip simply completes against the old model — ``publish`` then
    calls ``old.shutdown(drain=True)``, which serves (never drops) its
    in-flight futures. tests/test_serving.py pins zero dropped futures
    across a swap with a concurrent submitter.
    """

    def __init__(self, **service_opts):
        self._service_opts = service_opts
        self._lock = threading.Lock()
        self._current: Optional[Tuple[int, PRFService]] = None
        self._retired: Dict[int, PRFService] = {}
        self._next_version = 1
        self._fallback_served = 0

    def publish(self, model: PRFModel, **overrides) -> int:
        """Swap in ``model`` (constructor kwargs: registry defaults +
        ``overrides``). Returns its version number. The previous
        version is drained (every pending future resolves against the
        model it was submitted to) and closed to new submits. The old
        service's result cache is invalidated: a retired fallback
        answering during degraded mode recomputes every row rather than
        compounding a stale model with stale cached predictions."""
        svc = PRFService(model, **{**self._service_opts, **overrides})
        with self._lock:
            version = self._next_version
            self._next_version += 1
            old = self._current
            self._current = (version, svc)           # the atomic flip
            if old is not None:
                self._retired[old[0]] = old[1]
        if old is not None:
            old[1].shutdown(drain=True)
            old[1].invalidate_cache()
        return version

    @property
    def service(self) -> PRFService:
        """The live service (one reference read — safe vs. publish)."""
        cur = self._current
        if cur is None:
            raise ServiceClosedError("no model published")
        return cur[1]

    @property
    def version(self) -> int:
        cur = self._current
        if cur is None:
            raise ServiceClosedError("no model published")
        return cur[0]

    # Thin delegation so callers can hold the registry, not a service
    # reference that goes stale at the next publish.

    def _newest_healthy_retired(self) -> Optional[Tuple[int, PRFService]]:
        """Newest retired version whose own breaker is not open (retired
        services are closed for submit but their stateless ``predict``
        stays fully usable — the degraded-mode fallback)."""
        with self._lock:
            candidates = sorted(self._retired.items(), reverse=True)
        for version, svc in candidates:
            if svc.breaker.state != "open":
                return version, svc
        return None

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Predict against the live version; while its breaker is open,
        fall back to the newest *healthy* retired version — a stale but
        correct answer beats an error while the live model recovers.
        With no healthy fallback the :class:`CircuitOpenError`
        propagates. Fallback answers are counted in ``health()``
        (``fallback_served``)."""
        try:
            return self.service.predict(x)
        except CircuitOpenError:
            fallback = self._newest_healthy_retired()
            if fallback is None:
                raise
            out = fallback[1].predict(x)
            with self._lock:
                self._fallback_served += 1
            return out

    def submit(self, x: np.ndarray, **kwargs) -> PRFFuture:
        return self.service.submit(x, **kwargs)

    def drain(self) -> int:
        return self.service.drain()

    def stats(self) -> dict:
        return {"version": self.version, **self.service.stats()}

    def health(self) -> dict:
        """Registry-level health: the live service's ``health()`` plus
        version bookkeeping (live version, per-retired-version breaker
        states, stale-fallback counter)."""
        cur = self._current
        with self._lock:
            retired = {v: s.breaker.state for v, s in self._retired.items()}
            snap = {
                "fallback_served": self._fallback_served,
                "retired": retired,
            }
        if cur is None:
            snap.update({"version": None, "live": None})
        else:
            snap.update({"version": cur[0], "live": cur[1].health()})
        return snap

    def shutdown(self, drain: bool = True) -> int:
        """Shut down the live service AND release every retired version.

        Retired services were closed to new submits at publish time, but
        the registry still held them (they back the stale-prediction
        fallback), keeping their jit caches and queue state alive.
        Shutdown settles the live queue (``drain``), re-runs the
        (idempotent) shutdown of each retired service, and drops the
        references so their compiled executables can be collected.
        Returns the number of futures settled.
        """
        cur = self._current
        settled = 0 if cur is None else cur[1].shutdown(drain=drain)
        with self._lock:
            retired, self._retired = self._retired, {}
        for _, svc in sorted(retired.items()):
            settled += svc.shutdown(drain=False)
        return settled


# ---------------------------------------------------------------------------
# Tree-sharded multi-device voting
# ---------------------------------------------------------------------------


def make_sharded_vote_fn(forest: Forest, mesh, *, tree_axis: str = "data"):
    """Build a jit'd multi-device predictor with trees sharded over
    ``tree_axis``.

    Each shard walks only its own ``k / axis_size`` trees (fused kernel
    on TPU, XLA oracle elsewhere — ``config.predict_backend``) and
    accumulates their weighted votes into an ``[N, C]`` partial score;
    one ``psum`` combines the partials (the Eq. 9/10 sum over trees is
    associative), then argmax / Eq. 9 normalization runs replicated.
    Mirrors ``core/distributed``'s training-side histogram combine:
    O(N*C) words cross the wire, never the ``[k, N, C]`` tensor.

    Returns ``fn(x_binned) -> [N]`` labels (classification) or values
    (regression). ``n_trees`` must divide evenly over ``tree_axis``.
    """
    cfg = forest.config
    w = _vote_weights(forest)
    payload = build_payload(forest)
    depth = cfg.max_depth
    use_pallas = resolve_predict_backend(cfg.predict_backend) == "pallas"
    norm = jnp.maximum(w.sum(), 1e-38)

    def shard(feat, thr, left, pay, xb):
        from ..kernels.tree_traverse.ops import fused_vote

        partial = fused_vote(
            xb, feat, thr, left, pay, depth=depth, use_pallas=use_pallas
        )
        scores = jax.lax.psum(partial, tree_axis)            # the ONE combine
        if cfg.regression:
            return scores[:, 0] / norm
        return jnp.argmax(scores, axis=-1)

    fn = jax.jit(
        _shard_map(
            shard,
            mesh=mesh,
            in_specs=(P(tree_axis), P(tree_axis), P(tree_axis), P(tree_axis), P()),
            out_specs=P(),
        )
    )

    def run(x_binned):
        return fn(
            forest.feature, forest.threshold, forest.left_child, payload,
            x_binned,
        )

    return run
