from .serve_step import make_serve_fns  # noqa: F401
