"""Serving layer.

* ``serve_step``   -- LM prefill/decode step factories.
* ``prf_service``  -- forest serving: bucketed batching, async
  micro-batch aggregation, tree-sharded multi-device voting on top of
  the fused prediction path (``ForestConfig.predict_backend``), the
  hardening layer (typed shedding, circuit breaker, deterministic
  shutdown, versioned hot-swap registry), and degraded-mode operation
  (per-request deadlines, per-client token-bucket rate limiting,
  stale-fallback prediction, scrapeable ``health()`` snapshots).
"""
from .prf_service import (  # noqa: F401
    CircuitBreaker, CircuitOpenError, DeadlineExceeded, ModelRegistry,
    PRFFuture, PRFService, RateLimited, RateLimiter, ServiceClosedError,
    ServiceError, ServiceOverloaded, bucket_size, make_sharded_vote_fn,
)
from .serve_step import make_serve_fns  # noqa: F401
