"""Serving layer.

* ``serve_step``   -- LM prefill/decode step factories.
* ``prf_service``  -- forest serving: bucketed batching, async
  micro-batch aggregation, tree-sharded multi-device voting on top of
  the fused prediction path (``ForestConfig.predict_backend``), and the
  hardening layer (typed shedding, circuit breaker, deterministic
  shutdown, versioned hot-swap registry).
"""
from .prf_service import (  # noqa: F401
    CircuitBreaker, CircuitOpenError, ModelRegistry, PRFFuture, PRFService,
    ServiceClosedError, ServiceError, ServiceOverloaded, bucket_size,
    make_sharded_vote_fn,
)
from .serve_step import make_serve_fns  # noqa: F401
