"""Serving step factories: batched prefill + decode with cache shardings.

Cache sharding policy (see training/sharding.cache_specs):
  * decode_32k  — batch >= DP size: batch-sharded cache, heads over TP.
  * long_500k   — batch == 1: cache LENGTH sharded over `data` (the
    paper's vertical partitioning applied to the KV positions; softmax
    over the sharded axis becomes a max/sum all-reduce pair that GSPMD
    inserts — flash-decoding's LSE combine, derived not hand-written).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.model import Model
from ..training.sharding import cache_specs, param_shardings


def make_serve_fns(model: Model, mesh: Optional[Mesh] = None, *,
                   s_max: int, batch_sharded: bool = True,
                   dp_axes=("data",)):
    """Returns (prefill_fn, decode_fn[, shardings dict if mesh])."""

    def prefill(params, tokens, extras):
        return model.prefill(params, tokens, extras, s_max=s_max)

    def decode(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    if mesh is None:
        return jax.jit(prefill, static_argnames=()), jax.jit(decode), None

    cache_shape = jax.eval_shape(
        lambda: model.cache_struct(1, 8)
    )  # structure only; real specs computed on the fly by dryrun
    shardings = {
        "dp_spec": P(tuple(dp_axes)),
    }
    return jax.jit(prefill), jax.jit(decode), shardings


def greedy_generate(model: Model, params, tokens, extras=None, *,
                    steps: int, s_max: int):
    """Simple batched greedy decoding loop (examples/serve_lm.py)."""
    logits, cache = jax.jit(
        lambda p, t, e: model.prefill(p, t, e, s_max=s_max)
    )(params, tokens, extras or {})
    decode = jax.jit(model.decode_step)
    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    pos = tokens.shape[1]
    for i in range(steps - 1):
        logits, cache = decode(params, cache, out[-1], jnp.int32(pos + i))
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    return jnp.stack(out, axis=1)
