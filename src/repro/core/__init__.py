"""PRF — the paper's contribution: Parallel Random Forest in JAX.

Public surface:
  ForestConfig, Forest, GrowthState  core/types.py
  train_prf, PRFModel                core/api.py
  grow_forest_streamed               core/api.py (out-of-core sample blocks)
  train_prf_distributed              core/distributed.py (mesh-sharded)
"""
from .types import Forest, ForestConfig, GrowthState  # noqa: F401
from .api import PRFModel, grow_forest_streamed, train_prf  # noqa: F401
