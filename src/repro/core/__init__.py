"""PRF — the paper's contribution: Parallel Random Forest in JAX.

Public surface:
  ForestConfig, Forest            core/types.py
  train_prf, PRFModel             core/api.py
  train_prf_distributed           core/distributed.py (mesh-sharded)
"""
from .types import Forest, ForestConfig  # noqa: F401
from .api import PRFModel, train_prf  # noqa: F401
