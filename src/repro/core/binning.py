"""Quantile binning — the TPU-native form of the paper's split-point search.

The Spark implementation evaluates candidate splits on raw feature values
(C4.5); Spark-MLRF approximates them by *sampling each partition* (the
paper criticizes exactly this for losing accuracy). We instead compute
**global quantile bin edges once** and train on ``uint8`` bin ids:

* split finding becomes dense histogram math (MXU/VPU friendly);
* every feature costs the same number of bytes -> the paper's
  "static data allocation" balancing problem (§4.1.3, Fig. 5) disappears;
* accuracy loss is bounded by bin resolution (validated in tests), unlike
  per-partition sampling whose error grows with data size (paper §5.2.2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def fit_bins(x: np.ndarray, n_bins: int = 64) -> np.ndarray:
    """Compute per-feature quantile bin edges.

    Args:
      x: [N, F] float array (host / numpy — binning is a one-shot
         preprocessing pass, exactly like the paper's vertical-partition
         ETL step).
      n_bins: number of bins B; edges has B-1 interior boundaries.

    Returns:
      edges: [F, B-1] float64, ascending per feature.
    """
    x = np.asarray(x)
    qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = np.quantile(x, qs, axis=0).T  # [F, B-1]
    # Guarantee monotonicity even for degenerate (constant) features.
    edges = np.maximum.accumulate(edges, axis=1)
    return edges


@partial(jax.jit, static_argnames=())
def apply_bins(x: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Digitize features into uint8 bin ids.

    Args:
      x: [N, F] floats.  edges: [F, B-1].
    Returns:
      [N, F] uint8 bin ids in [0, B-1].
    """
    # vmap searchsorted over the feature axis.
    def _one(col, e):
        return jnp.searchsorted(e, col, side="right")

    bins = jax.vmap(_one, in_axes=(1, 0), out_axes=1)(x, edges)
    return bins.astype(jnp.uint8)


def bin_dataset(x: np.ndarray, n_bins: int = 64):
    """Convenience: fit + apply. Returns (binned [N,F] uint8, edges)."""
    edges = fit_bins(x, n_bins)
    return np.asarray(apply_bins(jnp.asarray(x), jnp.asarray(edges))), edges
