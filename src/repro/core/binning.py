"""Quantile binning — the TPU-native form of the paper's split-point search.

The Spark implementation evaluates candidate splits on raw feature values
(C4.5); Spark-MLRF approximates them by *sampling each partition* (the
paper criticizes exactly this for losing accuracy). We instead compute
**global quantile bin edges once** and train on ``uint8`` bin ids:

* split finding becomes dense histogram math (MXU/VPU friendly);
* every feature costs the same number of bytes -> the paper's
  "static data allocation" balancing problem (§4.1.3, Fig. 5) disappears;
* accuracy loss is bounded by bin resolution (validated in tests), unlike
  per-partition sampling whose error grows with data size (paper §5.2.2).

Two fitting paths share one edge contract (``[F, B-1]`` float64, ascending):

* ``fit_bins`` — the resident reference: one ``np.quantile`` over the full
  ``[N, F]`` array (copies + sorts it in host RAM).
* ``fit_bins_blocked`` / ``StreamingQuantileSketch`` — the out-of-core path:
  per-block sorted per-feature summaries merged deterministically, memory
  bounded by O(block) + O(F * max_size) regardless of N. Below the
  compression threshold the merge is *exact* and reproduces ``np.quantile``
  **bitwise** (same two-sided linear interpolation, evaluated in the source
  dtype — see ``StreamingQuantileSketch`` for the documented rule). This is
  the per-attribute distributed-quantile approach of "Exact Distributed
  Training: Random Forest with Billions of Examples" (arXiv 1804.06755);
  ``core/distributed.fit_bins_sharded`` runs one sketch per mesh data shard
  and merges them host-side.

Bin ids are ``uint8``, so ``n_bins`` is hard-capped at 256 — validated here
and in ``ForestConfig`` with a typed ``BinCountError`` instead of silently
wrapping ids.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Bin ids travel as uint8 end to end (histogram scatter, tree thresholds,
# serving payloads) — more than 256 bins would silently wrap.
MAX_BINS = 256

# Per-feature summary size the sketch compresses down to. A summary is kept
# exact (uncompressed) until it would exceed 2 * max_size distinct points,
# so any source with <= 2 * DEFAULT_SKETCH_SIZE rows per feature reproduces
# np.quantile bitwise.
DEFAULT_SKETCH_SIZE = 4096


class BinCountError(ValueError):
    """Raised when n_bins (or an edges array) exceeds the uint8 bin-id range."""


def validate_n_bins(n_bins) -> int:
    """Validate ``2 <= n_bins <= MAX_BINS``; returns the int value.

    uint8 bin ids wrap silently past 256 (e.g. 300 bins -> id 44), which
    corrupts histograms without any error — so every fit path and
    ``ForestConfig`` reject out-of-range counts up front.
    """
    if isinstance(n_bins, bool) or not isinstance(n_bins, (int, np.integer)):
        raise BinCountError(
            f"n_bins must be an int, got {type(n_bins).__name__}: {n_bins!r}"
        )
    n = int(n_bins)
    if not 2 <= n <= MAX_BINS:
        raise BinCountError(
            f"n_bins must be in [2, {MAX_BINS}] (bin ids are uint8; larger "
            f"counts would silently wrap), got {n}"
        )
    return n


def _weighted_quantiles(v: np.ndarray, c: np.ndarray, qs: np.ndarray) -> np.ndarray:
    """Quantiles of a weighted sorted summary, replicating np.quantile.

    ``v`` is sorted (any float dtype), ``c`` the float64 cumulative weights
    (``c[-1]`` = total mass W). The rule, bit-for-bit numpy's
    ``method='linear'`` when all weights are 1:

    * virtual position ``pos = q * (W - 1)``; ``lo = floor(pos)``,
      ``gamma = pos - lo`` (float64);
    * bracketing elements ``a = v[searchsorted(c, lo, 'right')]`` and
      ``b = v[searchsorted(c, lo + 1, 'right')]`` (clamped to the last
      element) — ties broken toward the *higher* cumulative rank;
    * two-sided lerp ``b - (b-a)*(1-gamma)`` if ``gamma >= 0.5`` else
      ``a + (b-a)*gamma``, with the difference ``b - a`` computed in the
      *source dtype* (float32 in -> float32 diff) exactly as numpy does.
    """
    total = c[-1]
    pos = qs * (total - 1.0)
    lo = np.floor(pos)
    gamma = pos - lo
    last = v.size - 1
    ia = np.minimum(np.searchsorted(c, lo, side="right"), last)
    ib = np.minimum(np.searchsorted(c, lo + 1.0, side="right"), last)
    a = v[ia]
    b = v[ib]
    diff = b - a  # source dtype on purpose — bitwise parity with np.quantile
    return np.where(gamma >= 0.5, b - diff * (1.0 - gamma), a + diff * gamma)


class StreamingQuantileSketch:
    """Mergeable per-feature quantile summary with deterministic compression.

    Feed ``[n_block, F]`` blocks via :meth:`update`; combine shard sketches
    with :meth:`merge`; read per-feature quantiles/edges at the end. Memory
    is bounded by O(F * max_size) points independent of total rows.

    Deterministic rules (no RNG, no order sensitivity beyond float
    associativity in weight sums — weights are integer-valued counts until
    a compression, so uncompressed merges are exactly associative):

    * Values are kept in the source float dtype (integers promote to
      float64, matching ``np.quantile``); exact duplicates are coalesced by
      summing weights, which preserves the CDF exactly.
    * A summary is exact until it would exceed ``2 * max_size`` points;
      it is then recompressed to ``max_size`` representatives: bucket j of
      equal mass ``W / max_size`` is represented by the element at
      cumulative mass ``W * (j + 0.5) / max_size`` (ties toward the higher
      rank), carrying the bucket's full mass. Rank error after k
      compressions is at most ``k / (2 * max_size)`` of total mass.
    * Quantiles interpolate exactly like ``np.quantile(method='linear')``
      — see :func:`_weighted_quantiles` — so while every feature summary
      is uncompressed the result is **bitwise identical** to the resident
      ``fit_bins``.
    * NaN cells are dropped (deterministically — the validator's screening
      masks arrive via ``update(exclude=...)`` for cells that were imputed
      upstream); ±inf are kept, as ``np.quantile`` would.
    * A feature with no surviving samples yields edges of 0.0.
    """

    def __init__(self, n_features: int, *, max_size: int = DEFAULT_SKETCH_SIZE):
        if n_features < 1:
            raise ValueError(f"n_features must be >= 1, got {n_features}")
        if max_size < 2:
            raise ValueError(f"max_size must be >= 2, got {max_size}")
        self.n_features = int(n_features)
        self.max_size = int(max_size)
        self._v = [np.empty(0, np.float64) for _ in range(self.n_features)]
        self._w = [np.empty(0, np.float64) for _ in range(self.n_features)]
        self._compressed = np.zeros(self.n_features, np.bool_)
        self.count = np.zeros(self.n_features, np.int64)
        self._vdtype: np.dtype | None = None

    # -- properties ------------------------------------------------------

    @property
    def exact(self) -> bool:
        """True while every feature summary is an exact (uncompressed) CDF."""
        return not bool(self._compressed.any())

    @property
    def value_dtype(self) -> np.dtype:
        return np.dtype(self._vdtype if self._vdtype is not None else np.float64)

    def summary_sizes(self) -> np.ndarray:
        """Stored points per feature (memory = sum * 16 bytes, roughly)."""
        return np.array([v.size for v in self._v], np.int64)

    # -- ingest ----------------------------------------------------------

    def _promote(self, dtype: np.dtype) -> None:
        dt = np.dtype(dtype)
        if not np.issubdtype(dt, np.floating):
            dt = np.dtype(np.float64)  # np.quantile promotes ints to float64
        if self._vdtype is None:
            self._vdtype = dt
        elif dt != self._vdtype:
            target = np.result_type(self._vdtype, dt)
            if target != self._vdtype:
                self._v = [v.astype(target) for v in self._v]
                self._vdtype = target

    def update(self, block, exclude=None) -> "StreamingQuantileSketch":
        """Absorb one ``[n_block, F]`` block.

        ``exclude`` (optional ``[n_block, F]`` bool) marks cells to leave
        out — the streamed trainer passes the validator's imputed-cell
        masks here so sanitized blocks contribute only their finite,
        original values.
        """
        b = np.asarray(block)
        if b.ndim != 2 or b.shape[1] != self.n_features:
            raise ValueError(
                f"expected [n, {self.n_features}] block, got shape {b.shape}"
            )
        if b.shape[0] == 0:
            return self
        self._promote(b.dtype)
        ex = None
        if exclude is not None:
            ex = np.asarray(exclude, np.bool_)
            if ex.shape != b.shape:
                raise ValueError(
                    f"exclude mask shape {ex.shape} != block shape {b.shape}"
                )
        for f in range(self.n_features):
            col = b[:, f]
            if ex is not None:
                col = col[~ex[:, f]]
            col = col[~np.isnan(col)]
            if col.size == 0:
                continue
            self.count[f] += col.size
            v = np.sort(col.astype(self._vdtype, copy=False))
            self._insert(f, v, np.ones(v.size, np.float64))
        return self

    def merge(self, other: "StreamingQuantileSketch") -> "StreamingQuantileSketch":
        """Fold another sketch in (exact while both are uncompressed)."""
        if other.n_features != self.n_features:
            raise ValueError(
                f"cannot merge sketches over {other.n_features} vs "
                f"{self.n_features} features"
            )
        # Only a sketch that actually holds points can force a dtype
        # promotion — merging an empty (e.g. blockless-shard) sketch must
        # be a strict no-op, or it would widen f32 summaries to f64 and
        # break bitwise parity with np.quantile on f32 sources.
        if other._vdtype is not None and any(v.size for v in other._v):
            self._promote(other._vdtype)
        for f in range(self.n_features):
            ov = other._v[f]
            if ov.size:
                self._insert(f, ov.astype(self.value_dtype, copy=False), other._w[f])
        self.count += other.count
        self._compressed |= other._compressed
        return self

    def _insert(self, f: int, v: np.ndarray, w: np.ndarray) -> None:
        if self._v[f].size:
            v = np.concatenate([self._v[f], v])
            w = np.concatenate([self._w[f], w])
            order = np.argsort(v, kind="stable")
            v = v[order]
            w = w[order]
        if v.size > 1:
            keep = np.empty(v.size, np.bool_)
            keep[0] = True
            np.not_equal(v[1:], v[:-1], out=keep[1:])
            if not keep.all():
                idx = np.cumsum(keep) - 1
                w = np.bincount(idx, weights=w)
                v = v[keep]
        if v.size > 2 * self.max_size:
            v, w = self._compress(v, w)
            self._compressed[f] = True
        self._v[f] = v
        self._w[f] = w

    def _compress(self, v: np.ndarray, w: np.ndarray):
        """Deterministic recompression to ``max_size`` representatives."""
        c = np.cumsum(w)
        total = c[-1]
        m = self.max_size
        t = total * (np.arange(m, dtype=np.float64) + 0.5) / m
        idx = np.minimum(np.searchsorted(c, t, side="right"), v.size - 1)
        nv = v[idx]
        nw = np.full(m, total / m, np.float64)
        keep = np.empty(m, np.bool_)
        keep[0] = True
        np.not_equal(nv[1:], nv[:-1], out=keep[1:])
        if not keep.all():
            gi = np.cumsum(keep) - 1
            nw = np.bincount(gi, weights=nw)
            nv = nv[keep]
        return nv, nw

    # -- readout ---------------------------------------------------------

    def quantiles(self, qs) -> np.ndarray:
        """Per-feature quantiles, [F, len(qs)] float64."""
        qs = np.asarray(qs, np.float64)
        out = np.zeros((self.n_features, qs.size), np.float64)
        for f in range(self.n_features):
            v = self._v[f]
            if v.size == 0:
                continue  # empty feature -> 0.0 edges (documented)
            c = np.cumsum(self._w[f])
            out[f] = _weighted_quantiles(v, c, qs)
        return out

    def edges(self, n_bins: int) -> np.ndarray:
        """Bin edges [F, n_bins-1] float64 — same contract as ``fit_bins``."""
        n_bins = validate_n_bins(n_bins)
        qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
        e = self.quantiles(qs)
        return np.maximum.accumulate(e, axis=1)

    # -- serialization (mesh exchange) -----------------------------------

    def state(self, pad_to: int | None = None) -> dict:
        """Dense-array snapshot for cross-shard exchange.

        Values are carried as float64 (exact for any narrower float) with
        the source dtype recorded, so ``from_state`` round-trips bitwise.
        ``pad_to`` fixes the row width (required for collective transport,
        where every shard must ship the same shape; stored summaries never
        exceed ``2 * max_size`` points).
        """
        m = max(int(v.size) for v in self._v)
        width = m if pad_to is None else int(pad_to)
        if width < m:
            raise ValueError(f"pad_to={pad_to} < largest summary {m}")
        width = max(width, 1)
        vals = np.zeros((self.n_features, width), np.float64)
        wts = np.zeros((self.n_features, width), np.float64)
        for f in range(self.n_features):
            vals[f, : self._v[f].size] = self._v[f]
            wts[f, : self._w[f].size] = self._w[f]
        return {
            "values": vals,
            "weights": wts,
            "count": self.count.copy(),
            "compressed": self._compressed.copy(),
            "value_dtype": self.value_dtype.str,
            "max_size": self.max_size,
        }

    @classmethod
    def from_state(cls, state: dict) -> "StreamingQuantileSketch":
        vals = np.asarray(state["values"], np.float64)
        wts = np.asarray(state["weights"], np.float64)
        sk = cls(vals.shape[0], max_size=int(state["max_size"]))
        vdt = np.dtype(state["value_dtype"])
        sk._vdtype = vdt
        for f in range(sk.n_features):
            live = wts[f] > 0  # padding rows carry weight 0
            sk._v[f] = vals[f, live].astype(vdt, copy=False)
            sk._w[f] = wts[f, live].copy()
        sk.count = np.asarray(state["count"], np.int64).copy()
        sk._compressed = np.asarray(state["compressed"], np.bool_).copy()
        return sk


def fit_bins(x: np.ndarray, n_bins: int = 64) -> np.ndarray:
    """Compute per-feature quantile bin edges (resident reference path).

    Args:
      x: [N, F] float array (host / numpy). NOTE: this is the full-pass
         path — ``np.quantile`` copies and sorts all of ``x`` in host RAM.
         For out-of-core sources use :func:`fit_bins_blocked`.
      n_bins: number of bins B in [2, 256]; edges has B-1 interior
         boundaries.

    Returns:
      edges: [F, B-1] float64, ascending per feature.
    """
    n_bins = validate_n_bins(n_bins)
    x = np.asarray(x)
    qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = np.quantile(x, qs, axis=0).T  # [F, B-1]
    # Guarantee monotonicity even for degenerate (constant) features.
    edges = np.maximum.accumulate(edges, axis=1)
    return edges


def fit_bins_blocked(
    blocks,
    n_bins: int = 64,
    *,
    exclude_masks=None,
    max_size: int = DEFAULT_SKETCH_SIZE,
) -> np.ndarray:
    """Out-of-core bin-edge fitting over an iterable of ``[n_i, F]`` blocks.

    One pass, O(block) + O(F * max_size) memory: each block is absorbed
    into a :class:`StreamingQuantileSketch` and released. While the total
    distinct values per feature stay <= ``2 * max_size`` the result is
    bitwise identical to ``fit_bins`` over the concatenated blocks;
    beyond that the sketch compresses deterministically with bounded rank
    error (same blocks -> same edges, always).

    Args:
      blocks: iterable of [n_i, F] arrays (e.g. ``sample_blocks`` views of
        an ``np.memmap``); ragged last block fine.
      n_bins: number of bins in [2, 256].
      exclude_masks: optional per-block bool cell masks (True = leave the
        cell out). Either a sequence aligned with ``blocks`` (None entries
        allowed) or a dict keyed by block position — the streamed trainer
        passes the validator's imputed-cell masks this way.
      max_size: per-feature summary budget (see the sketch docstring).

    Returns:
      edges: [F, n_bins-1] float64, ascending per feature.
    """
    n_bins = validate_n_bins(n_bins)
    sketch = None
    for i, b in enumerate(blocks):
        b = np.asarray(b)
        if sketch is None:
            sketch = StreamingQuantileSketch(b.shape[1], max_size=max_size)
        if exclude_masks is None:
            mask = None
        elif isinstance(exclude_masks, dict):
            mask = exclude_masks.get(i)
        else:
            mask = exclude_masks[i]
        sketch.update(b, exclude=mask)
    if sketch is None:
        raise ValueError("fit_bins_blocked: no blocks provided")
    return sketch.edges(n_bins)


@partial(jax.jit, static_argnames=())
def apply_bins(x: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Digitize features into uint8 bin ids.

    Boundary contract (explicit and deterministic): both ``x`` and
    ``edges`` are evaluated in **float32** — edges are fit in float64, and
    relying on jax's implicit x64-mode-dependent downcast made boundary
    samples land differently than a host float64 ``np.digitize``. A sample
    bit-equal (in float32) to edge ``e_j`` lands in bin ``j + 1``
    (``side="right"``); :func:`host_digitize` is the host-side reference
    of exactly this rule.

    Args:
      x: [N, F] floats.  edges: [F, B-1] with B <= 256.
    Returns:
      [N, F] uint8 bin ids in [0, B-1].
    """
    if edges.shape[-1] > MAX_BINS - 1:  # static shape -> trace-time error
        raise BinCountError(
            f"edges has {edges.shape[-1]} boundaries -> {edges.shape[-1] + 1} "
            f"bins, beyond the uint8 limit of {MAX_BINS}"
        )
    x = jnp.asarray(x, jnp.float32)
    edges = jnp.asarray(edges, jnp.float32)

    # vmap searchsorted over the feature axis.
    def _one(col, e):
        return jnp.searchsorted(e, col, side="right")

    bins = jax.vmap(_one, in_axes=(1, 0), out_axes=1)(x, edges)
    return bins.astype(jnp.uint8)


def host_digitize(x: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Host-side reference for ``apply_bins``' float32 boundary contract."""
    xf = np.asarray(x, np.float32)
    ef = np.asarray(edges, np.float32)
    out = np.empty(xf.shape, np.uint8)
    for f in range(ef.shape[0]):
        out[:, f] = np.searchsorted(ef[f], xf[:, f], side="right")
    return out


def bin_dataset(x: np.ndarray, n_bins: int = 64):
    """Convenience: fit + apply. Returns (binned [N,F] uint8, edges)."""
    edges = fit_bins(x, n_bins)
    return np.asarray(apply_bins(jnp.asarray(x), jnp.asarray(edges))), edges
