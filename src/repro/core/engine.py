"""Unified task-DAG growth engine (paper §4.2) — ONE level-step for every
execution plane.

The paper's schedulers dispatch only the T_GR/T_NS tasks that actually
exist; here that DAG is a single level-step implementation, threaded as
a real ``GrowthState`` carry and parameterized by a **collective plane**:

* ``combine_hist``    — T_GR combine of per-shard histograms (``None``
                        on the single-host plane, which unlocks the
                        fused no-HBM-histogram path; ``psum`` /
                        ``psum_scatter`` on the mesh plane);
* ``merge_winners``   — T_NS cross-shard argmax merge of the per-shard
                        split leaders (identity locally);
* ``broadcast_route`` — the per-sample go-left/right bit (a local
                        gather+compare, plus a masked ``psum`` over the
                        feature axis when features are sharded).

``forest.grow_forest`` (LocalPlane), ``distributed._grow_sharded``
(MeshPlane, built in core/distributed.py next to its collectives) and
the host-streaming ``api.grow_forest_streamed`` driver are thin entry
points over the same ``plan_level`` / ``write_level`` / ``route_level``
pieces, so a split decision is computed by exactly one piece of code no
matter where the data lives.

Scheduling upgrades over the fixed-depth scan of the original trainers:

* **early-exit** (``ForestConfig.early_exit``) — ``grow`` runs a
  ``lax.while_loop`` that stops as soon as every tree's frontier is
  empty, and trees whose frontiers died earlier contribute zero-weight
  (masked) work inside each ``tree_chunk`` task group;
* **sample-block streaming** (``ForestConfig.sample_block``) — level
  histograms accumulate over ``[Nb, F]`` row blocks (the resumable
  T_GR carry, ``histograms.blocked_level_histograms``), mirroring
  ``fused_vote_scores``' chunk carry on the predict side.

Every path stays bit-identical where semantics are unchanged: the pad
slot is sanitized after growth (``finalize_forest``), so
{local, mesh} x {early-exit, fixed-depth} x {streamed, resident}
produce identical ``Forest`` arrays (tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .gain import (
    SplitScores,
    level_scores,
    node_counts,
    resolve_split_backend,
    sibling_plan,
)
from .histograms import (
    blocked_level_histograms,
    hist_feature_slab,
    level_histograms,
    sibling_expand,
    sibling_perm,
    sibling_segments,
)
from .types import Forest, ForestConfig, GrowthState


def init_forest(config: ForestConfig) -> Forest:
    k, P = config.n_trees, config.max_nodes + 1  # +1 pad slot
    C = 3 if config.regression else config.n_classes
    return Forest(
        feature=jnp.full((k, P), -1, jnp.int32),
        threshold=jnp.zeros((k, P), jnp.int32),
        left_child=jnp.full((k, P), -1, jnp.int32),
        class_counts=jnp.zeros((k, P, C), jnp.float32),
        value=jnp.zeros((k, P), jnp.float32),
        tree_weight=jnp.ones((k,), jnp.float32),
        config=config,
    )


def _safe_mean(counts: jnp.ndarray) -> jnp.ndarray:
    """Weighted mean ``sum / count`` of [..., C>=2] regression channels,
    0 when the count is 0.

    ``sum / maximum(count, 1e-38)`` is NOT safe here: 1e-38 is a
    subnormal float32, which XLA flushes to zero on CPU/TPU, so
    zero-count slots (every non-split frontier slot writes the pad
    node) silently became 0/0 = NaN. Harmless to the gather-based
    predict path (the pad slot is unreachable), but the fused traversal
    kernel reads every pool row through a one-hot matmul and 0 * NaN
    poisons the scores.
    """
    return jnp.where(
        counts[..., 0] > 0,
        counts[..., 1] / jnp.maximum(counts[..., 0], 1e-38),
        0.0,
    )


def _gather_feature_bins(xb: jnp.ndarray, f: jnp.ndarray) -> jnp.ndarray:
    """bins[t, i] = xb[i, f[t, i]] as ONE flattened gather.

    Replaces the per-tree ``vmap(take_along_axis)`` that re-materialized
    a [k, N] int32 gather per call site per level: broadcasting the row
    index over the tree axis lowers to a single gather of [k, N] pairs.
    """
    return xb.astype(jnp.int32)[jnp.arange(xb.shape[0])[None, :], f]


def _rank_splits(gain: jnp.ndarray, valid: jnp.ndarray, n_max: int) -> jnp.ndarray:
    """Beam selection: rank valid slots by gain, admit top n_max.

    Returns split_rank [k, S] int32 in [0, n_max) for admitted slots, -1 else.
    """
    score = jnp.where(valid, gain, -jnp.inf)
    order = jnp.argsort(-score, axis=-1)
    pos = jnp.argsort(order, axis=-1).astype(jnp.int32)        # rank of each slot
    admitted = valid & (pos < n_max)
    return jnp.where(admitted, pos, -1)


# ---------------------------------------------------------------------------
# Collective planes
# ---------------------------------------------------------------------------


class CollectivePlane:
    """The engine's collective protocol — identity ops on a single host.

    A plane answers the three questions the level-step cannot answer
    locally: how per-shard histograms combine (``combine_hist``), how
    per-shard split leaders merge (``merge_winners``), and how the
    winning feature's go-right bit reaches every sample shard
    (``broadcast_route``). ``reduce_root`` combines the root class
    counts once, before the level loop. ``level_mask`` is the feature
    mask as this plane's histogram consumer expects it (the
    reduce-scatter mesh plane scores a narrower post-scatter slice).

    The mesh implementation (``distributed.MeshPlane``) lives next to
    its collectives in core/distributed.py.
    """

    combine_hist = None          # Optional[Callable]; None => no combine,
    level_mask = None            # which unlocks the fused single-host path

    def reduce_root(self, root_counts: jnp.ndarray) -> jnp.ndarray:
        return root_counts

    def merge_winners(self, scores: SplitScores, n_node: jnp.ndarray):
        return scores, n_node

    def broadcast_route(self, x_binned, f_i, thr_i) -> jnp.ndarray:
        bins_i = _gather_feature_bins(x_binned, f_i)
        return (bins_i > thr_i).astype(jnp.int32)

    def hist_width(self, n_features: int) -> int:
        """Feature width of a post-``combine_hist`` histogram on this
        plane — what the ``hist_reuse`` cache must allocate. The local
        shard's full width here; the reduce-scatter mesh plane keeps
        only its post-scatter feature slice."""
        return n_features


class LocalPlane(CollectivePlane):
    """Single-host plane: the whole ``[N, F]`` block lives on one device."""

    def __init__(self, feature_mask: Optional[jnp.ndarray] = None):
        self.level_mask = feature_mask


# ---------------------------------------------------------------------------
# T_GR + T_NS stage 1: histogram -> score, chunked over the tree axis
# ---------------------------------------------------------------------------


def _level_hists(
    x_binned, base_channels, w_c, slot_c, config: ForestConfig,
    n_slots: Optional[int] = None,
):
    """One chunk's level histogram, blocked over samples when
    ``config.sample_block`` asks for it. ``n_slots`` overrides the
    frontier width (the sibling-subtraction reuse path histograms into
    ``max_splits_per_level`` *rank* segments instead of slots)."""
    packed = config.packed_hist and not config.regression
    S = config.frontier if n_slots is None else n_slots
    if config.sample_block > 0:
        return blocked_level_histograms(
            x_binned, base_channels, w_c, slot_c,
            n_slots=S, n_bins=config.n_bins,
            sample_block=config.sample_block, packed=packed,
            backend=config.hist_backend,
        )
    return level_histograms(
        x_binned, base_channels, w_c, slot_c,
        n_slots=S, n_bins=config.n_bins, packed=packed,
        backend=config.hist_backend,
    )


def fused_level_scores(
    x_binned: jnp.ndarray,       # [N, F] uint8
    base_channels: jnp.ndarray,  # [N, C]
    weights: jnp.ndarray,        # [tc, N]
    sample_slot: jnp.ndarray,    # [tc, N]
    feature_mask: Optional[jnp.ndarray],  # [tc, F] bool or None
    config: ForestConfig,
):
    """Fully-fused T_GR -> T_NS: histogram kernel -> split-scan kernel
    per feature slab; the ``[tc, S, F, B, C]`` histogram never exists in
    HBM. Peak histogram footprint is one ``[tc, S, W, B, C]`` slab,
    where ``W = hist_feature_slab(...)`` is the hist kernel's own
    feature block — so per-slab pallas histograms are bit-identical to
    slices of the unfused call, and so are the resulting forests.

    The T_NS argmax rides along as the split-scan kernel's running-best
    carry, threaded through the slab loop; only O(tc*S) descriptors
    survive. With ``config.sample_block > 0`` each slab additionally
    accumulates its histogram over sample blocks, composing the two
    resumable carries. Returns (SplitScores, n_node [tc, S]).
    """
    from ..kernels.gain_ratio.kernel import _round_up
    from ..kernels.split_scan.kernel import init_carry, split_scan_block

    tc = weights.shape[0]
    N, F = x_binned.shape
    S, B = config.frontier, config.n_bins
    C = base_channels.shape[-1]
    packed = config.packed_hist and not config.regression
    W = hist_feature_slab(N, F, S, B, C, packed=packed)
    Fp = _round_up(F, W)
    xb = jnp.pad(x_binned, ((0, 0), (0, Fp - F)))
    mask = (
        feature_mask if feature_mask is not None else jnp.ones((tc, F), jnp.bool_)
    )
    mask = jnp.pad(mask, ((0, 0), (0, Fp - F)))   # padded features masked out
    interpret = jax.default_backend() != "tpu"

    def slab(j, carry):
        f0 = j * W
        xb_s = jax.lax.dynamic_slice_in_dim(xb, f0, W, axis=1)
        mask_s = jax.lax.dynamic_slice_in_dim(mask, f0, W, axis=1)
        hist = _level_hists(xb_s, base_channels, weights, sample_slot, config)
        return split_scan_block(
            hist, mask_s, carry, f0,
            regression=config.regression, interpret=interpret,
        )

    carry = jax.lax.fori_loop(0, Fp // W, slab, init_carry(tc, S, C))
    scores = SplitScores(*carry)
    return scores, node_counts(scores, regression=config.regression)


def chunked_level_scores(
    x_binned: jnp.ndarray,       # [N, F] uint8 (local shard in distributed mode)
    base_channels: jnp.ndarray,  # [N, C]
    weights: jnp.ndarray,        # [k, N]
    sample_slot: jnp.ndarray,    # [k, N]
    feature_mask: Optional[jnp.ndarray],  # [k, F] bool or None
    config: ForestConfig,
    *,
    hist_reduce=None,            # optional fn(hist) -> hist (e.g. psum over 'data')
):
    """T_GR + T_NS-stage-1 for all k trees, chunked over the tree axis.

    The histogram tensor only ever exists for ``tree_chunk`` trees at a
    time; only the O(k*S) split descriptors survive the chunk loop.
    With ``split_backend="pallas"`` on the single-host path
    (``hist_reduce is None``) the chunk runs ``fused_level_scores`` and
    the histogram never exists at all beyond one feature slab; the
    distributed path still combines full feature-shard histograms
    (psum / psum_scatter) and applies the fused scorer post-combine.

    ``n_trees`` need not divide ``tree_chunk``: the final chunk is
    padded with zero-weight, all-parked, no-feature dummy trees (the
    same remainder handling ``fused_vote_scores`` applies on the
    predict side) and the pad rows are sliced off the result, so
    training and prediction accept the same chunk sizes.

    Returns (SplitScores [k, S, ...], n_node [k, S]).
    """
    k = config.n_trees
    S = config.frontier
    tc = config.tree_chunk if config.tree_chunk > 0 else k
    tc = min(tc, k)

    split_be = resolve_split_backend(config.split_backend)

    def score_chunk(w_c, slot_c, mask_c):
        if hist_reduce is None and split_be == "pallas":
            return fused_level_scores(
                x_binned, base_channels, w_c, slot_c, mask_c, config
            )
        hist = _level_hists(x_binned, base_channels, w_c, slot_c, config)
        if hist_reduce is not None:
            hist = hist_reduce(hist)     # psum over the sample axis (T_GR combine)
        return level_scores(
            hist, mask_c, regression=config.regression, backend=split_be
        )

    if tc >= k:
        return score_chunk(weights, sample_slot, feature_mask)

    # NOTE: the mask's feature dim may be narrower than x_binned's when
    # the histogram reduce scatters features (psum_scatter path).
    mask = (
        feature_mask
        if feature_mask is not None
        else jnp.ones((k, x_binned.shape[1]), jnp.bool_)
    )
    kp = -(-k // tc) * tc
    if kp != k:                  # pad the remainder chunk with dummy trees
        weights = jnp.pad(weights, ((0, kp - k), (0, 0)))
        sample_slot = jnp.pad(
            sample_slot, ((0, kp - k), (0, 0)), constant_values=-1
        )
        mask = jnp.pad(mask, ((0, kp - k), (0, 0)))
    nc = kp // tc
    scores, n_node = jax.lax.map(
        lambda args: score_chunk(*args),
        (
            weights.reshape(nc, tc, -1),
            sample_slot.reshape(nc, tc, -1),
            mask.reshape(nc, tc, mask.shape[-1]),
        ),
    )
    scores = jax.tree_util.tree_map(
        lambda a: a.reshape(kp, *a.shape[2:])[:k], scores
    )
    return scores, n_node.reshape(kp, S)[:k]


# ---------------------------------------------------------------------------
# Sibling-subtraction histogram reuse (ForestConfig.hist_reuse)
# ---------------------------------------------------------------------------


def resolve_hist_reuse(config: ForestConfig, n_features: int) -> bool:
    """Whether growth should carry the between-level histogram cache.

    ``resolved_hist_reuse()`` answers the policy question (auto ->
    classification only); this adds the capacity gate: the cache is one
    ``[k, S, F, B, C]`` f32 tensor pinned across the whole growth, so if
    ``4*k*S*F*B*C`` exceeds ``hist_reuse_budget_mb`` the engine falls
    back to ``off`` rather than OOM a device. ``n_features`` is the
    width this plane would cache (the local shard width on a mesh — the
    budget is per-device, and identical on every shard).
    """
    if config.resolved_hist_reuse() == "off":
        return False
    C = 3 if config.regression else config.n_classes
    cache_bytes = 4 * config.n_trees * config.frontier * n_features * config.n_bins * C
    return cache_bytes <= config.hist_reuse_budget_mb * (1 << 20)


def init_hist_cache(config: ForestConfig, hist_width: int) -> dict:
    """Level-0 reuse cache. ``small_right = 0`` makes slot 0 the "small"
    child of rank 0, so the root histogram falls out of the same packed
    path with no special case: every sample (slot 0) lands in rank
    segment 0, and the all-(-1) ``parent`` table zeroes every
    subtraction row against the zero ``hist``."""
    k, S, R = config.n_trees, config.frontier, config.max_splits_per_level
    C = 3 if config.regression else config.n_classes
    return {
        "hist": jnp.zeros((k, S, hist_width, config.n_bins, C), jnp.float32),
        "perm": jnp.tile(jnp.arange(S, dtype=jnp.int32)[None, :], (k, 1)),
        "parent": jnp.full((k, R), -1, jnp.int32),
        "small_right": jnp.zeros((k, R), jnp.int32),
    }


def fused_reuse_level_scores(
    x_binned, base_channels, weights, seg, feature_mask, cache,
    config: ForestConfig,
):
    """Reuse-mode analogue of ``fused_level_scores``: per feature slab,
    build the *packed* small-child histogram (R rank rows — half the
    one-hot matmul width of the off path), expand it against the cached
    slab (``parent - small``), feed the expanded slab to the split-scan
    carry, and write it into the next cache tensor. The full-width
    cache lives in HBM (that is exactly what ``hist_reuse_budget_mb``
    budgets); the *working set* stays one ``[k, S, W, B, C]`` slab, so
    the PR-2 no-full-HBM-histogram invariant degrades gracefully to
    "no second full tensor".

    Returns (row-order SplitScores, row-order n_node, hist2
    [k, S, F, B, C] in paired-row order).
    """
    from ..kernels.gain_ratio.kernel import _round_up
    from ..kernels.split_scan.kernel import init_carry, split_scan_block

    k = weights.shape[0]
    N, F = x_binned.shape
    S, B, R = config.frontier, config.n_bins, config.max_splits_per_level
    C = base_channels.shape[-1]
    packed = config.packed_hist and not config.regression
    # Off-path slab width (sized for S rows) keeps split_scan_block's
    # geometry — and therefore its running-best carry arithmetic —
    # identical to the reuse=off trace.
    W = hist_feature_slab(N, F, S, B, C, packed=packed)
    Fp = _round_up(F, W)
    xb = jnp.pad(x_binned, ((0, 0), (0, Fp - F)))
    mask = (
        feature_mask if feature_mask is not None else jnp.ones((k, F), jnp.bool_)
    )
    mask = jnp.pad(mask, ((0, 0), (0, Fp - F)))
    cache_h = jnp.pad(cache["hist"], ((0, 0), (0, 0), (0, Fp - F)) + ((0, 0),) * 2)
    interpret = jax.default_backend() != "tpu"

    def slab(j, acc):
        carry, h2 = acc
        f0 = j * W
        xb_s = jax.lax.dynamic_slice_in_dim(xb, f0, W, axis=1)
        mask_s = jax.lax.dynamic_slice_in_dim(mask, f0, W, axis=1)
        ch_s = jax.lax.dynamic_slice_in_dim(cache_h, f0, W, axis=2)
        packed_s = _level_hists(xb_s, base_channels, weights, seg, config, n_slots=R)
        hist_s = sibling_expand(packed_s, ch_s, cache["perm"], cache["parent"], S)
        carry = split_scan_block(
            hist_s, mask_s, carry, f0,
            regression=config.regression, interpret=interpret,
        )
        h2 = jax.lax.dynamic_update_slice_in_dim(h2, hist_s, f0, axis=2)
        return carry, h2

    carry, h2 = jax.lax.fori_loop(
        0, Fp // W, slab,
        (init_carry(k, S, C), jnp.zeros((k, S, Fp, B, C), jnp.float32)),
    )
    scores = SplitScores(*carry)
    return scores, node_counts(scores, regression=config.regression), h2[:, :, :F]


def _permute_rows(perm: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Gather the [k, S, ...] per-row descriptors ``a`` into slot order
    (``perm`` is ``sibling_perm``'s slot -> paired-row map)."""
    idx = perm.reshape(perm.shape + (1,) * (a.ndim - 2))
    return jnp.take_along_axis(a, idx, axis=1)


def reuse_expand_scores(
    packed_h, cache, feature_mask, config: ForestConfig,
):
    """Post-combine half of the reuse task group, shared with the
    streaming drivers (whose packed histogram accumulates over blocks
    before this runs once per level): expand the packed tensor against
    the cache (``parent - small``), score the paired rows, and permute
    the O(k*S) descriptors to slot order.

    Returns (slot-order SplitScores, n_node, hist2 paired-row tensor,
    perm) — the latter two are the next cache's ``hist`` / ``perm``.
    """
    S = config.frontier
    hist2 = sibling_expand(
        packed_h, cache["hist"], cache["perm"], cache["parent"], S
    )
    perm = sibling_perm(cache["small_right"], S)
    scores_r, n_r = level_scores(
        hist2, feature_mask, regression=config.regression,
        backend=resolve_split_backend(config.split_backend),
    )
    scores = jax.tree_util.tree_map(partial(_permute_rows, perm), scores_r)
    return scores, _permute_rows(perm, n_r), hist2, perm


def reuse_level_task_group(
    x_binned, base_channels, weights, sample_slot, slot_node, cache,
    config: ForestConfig, plane: CollectivePlane,
):
    """Reuse-mode T_GR + T_NS task group.

    Histogram ONLY the samples routed to small children (R rank
    segments instead of S slot segments — ``sibling_segments`` parks
    everything else into the dump row, the same masking machinery
    early-exit uses for dead trees), combine the *packed* tensor on the
    plane (half the psum / psum_scatter bytes of the off path),
    reconstruct large children as ``parent - small`` post-combine so
    every shard agrees, and score the paired-row tensor. Only the
    O(k*S) split descriptors are permuted back to slot order —
    reordering the [k, S, F, B, C] tensor itself would be a full extra
    memory pass, which is why the cache stores paired rows plus their
    ``perm``.

    Returns (slot-order merged SplitScores, n_node, next cache dict
    missing its ``parent`` / ``small_right`` entries — ``level_step``
    fills those from ``sibling_plan`` once the level is planned).
    """
    S, R = config.frontier, config.max_splits_per_level
    tree_live = jnp.any(slot_node >= 0, axis=1)
    w_level = weights * tree_live[:, None].astype(weights.dtype)
    seg = sibling_segments(sample_slot, cache["small_right"])
    split_be = resolve_split_backend(config.split_backend)

    if plane.combine_hist is None and split_be == "pallas":
        perm = sibling_perm(cache["small_right"], S)
        scores_r, n_r, hist2 = fused_reuse_level_scores(
            x_binned, base_channels, w_level, seg, plane.level_mask,
            cache, config,
        )
        scores = jax.tree_util.tree_map(partial(_permute_rows, perm), scores_r)
        n_node = _permute_rows(perm, n_r)
    else:
        packed_h = _level_hists(
            x_binned, base_channels, w_level, seg, config, n_slots=R
        )
        if plane.combine_hist is not None:
            packed_h = plane.combine_hist(packed_h)   # half the wire bytes
        scores, n_node, hist2, perm = reuse_expand_scores(
            packed_h, cache, plane.level_mask, config
        )

    scores, n_node = plane.merge_winners(scores, n_node)
    return scores, n_node, {"hist": hist2, "perm": perm}


# ---------------------------------------------------------------------------
# The level-step pieces — shared by every plane and the streaming driver
# ---------------------------------------------------------------------------


def init_growth_state(
    base_channels: jnp.ndarray,   # [N, C] (local shard in distributed mode)
    weights: jnp.ndarray,         # [k, N]
    config: ForestConfig,
    plane: CollectivePlane,
    *,
    rng: Optional[jnp.ndarray] = None,
    root_counts: Optional[jnp.ndarray] = None,   # [k, C] precomputed (streaming)
    n_features: Optional[int] = None,            # local-shard F; enables hist_reuse
) -> GrowthState:
    """Forest with the root node populated + an empty level-0 frontier.

    ``n_features`` opts the state into the ``hist_reuse`` cache (when
    the config and budget allow it): callers that do not thread it get
    the reuse-off pytree structure, so existing states and checkpoints
    are untouched."""
    k, S = config.n_trees, config.frontier
    forest = init_forest(config)
    if root_counts is None:
        root_counts = plane.reduce_root(
            jnp.einsum("kn,nc->kc", weights, base_channels)
        )
    forest = dataclasses.replace(
        forest, class_counts=forest.class_counts.at[:, 0].set(root_counts)
    )
    if config.regression:
        forest = dataclasses.replace(
            forest, value=forest.value.at[:, 0].set(_safe_mean(root_counts))
        )
    hist_cache = None
    if n_features is not None and resolve_hist_reuse(config, n_features):
        hist_cache = init_hist_cache(config, plane.hist_width(n_features))
    return GrowthState(
        forest=forest,
        slot_node=jnp.full((k, S), -1, jnp.int32).at[:, 0].set(0),
        sample_slot=jnp.zeros((k, weights.shape[1]), jnp.int32),
        rng=rng if rng is not None else jax.random.PRNGKey(0),
        level=jnp.asarray(0, jnp.int32),
        hist_cache=hist_cache,
    )


def level_task_group(
    x_binned, base_channels, weights, sample_slot, slot_node,
    config: ForestConfig, plane: CollectivePlane,
):
    """One level's T_GR + T_NS task group: local scores through the
    plane's histogram combine, then the cross-shard winner merge.

    Trees whose frontiers already died (no live slot) get their DSI
    weights masked to zero, so finished trees contribute zero-weight
    work inside each ``tree_chunk`` task group — the engine analogue of
    the paper's schedulers not dispatching tasks for finished trees.
    """
    tree_live = jnp.any(slot_node >= 0, axis=1)               # [k]
    w_level = weights * tree_live[:, None].astype(weights.dtype)
    scores_loc, n_loc = chunked_level_scores(
        x_binned, base_channels, w_level, sample_slot,
        plane.level_mask, config, hist_reduce=plane.combine_hist,
    )
    return plane.merge_winners(scores_loc, n_loc)


def plan_level(
    scores: SplitScores, n_node: jnp.ndarray, slot_node: jnp.ndarray,
    config: ForestConfig, level: jnp.ndarray,
):
    """T_NS stage 2: admit splits (gain + support gates, beam rank) and
    fix this level's child-pool band. Returns (split_rank, is_split,
    child_base)."""
    n_max = config.max_splits_per_level
    active = slot_node >= 0
    valid = (
        active
        & (scores.gain_ratio > config.min_gain)
        & (n_node >= config.min_samples_split)
    )
    split_rank = _rank_splits(scores.gain_ratio, valid, n_max)    # [k, S]
    is_split = split_rank >= 0
    child_base = 1 + 2 * n_max * level
    return split_rank, is_split, child_base


def write_level(
    forest: Forest, slot_node, split_rank, is_split, child_base,
    scores: SplitScores, config: ForestConfig,
) -> Forest:
    """Write this level's split descriptors + child nodes into the pool
    (non-split slots dump into the pad node, sanitized at the end)."""
    pad = config.max_nodes          # scatter dump index
    t_idx = jnp.arange(config.n_trees)[:, None]
    left_id = child_base + 2 * split_rank
    node_or_pad = jnp.where(is_split, slot_node, pad)

    feature = forest.feature.at[t_idx, node_or_pad].set(
        jnp.where(is_split, scores.feature, -1)
    )
    threshold = forest.threshold.at[t_idx, node_or_pad].set(scores.threshold)
    left_child = forest.left_child.at[t_idx, node_or_pad].set(left_id)

    lid = jnp.where(is_split, left_id, pad)
    rid = jnp.where(is_split, left_id + 1, pad)
    class_counts = forest.class_counts.at[t_idx, lid].set(scores.left_counts)
    class_counts = class_counts.at[t_idx, rid].set(scores.right_counts)
    if config.regression:
        lval = _safe_mean(scores.left_counts)
        rval = _safe_mean(scores.right_counts)
        value = forest.value.at[t_idx, lid].set(lval).at[t_idx, rid].set(rval)
    else:
        value = forest.value

    return dataclasses.replace(
        forest,
        feature=feature,
        threshold=threshold,
        left_child=left_child,
        class_counts=class_counts,
        value=value,
    )


def route_level(
    x_binned, sample_slot, split_rank, scores: SplitScores,
    plane: CollectivePlane,
) -> jnp.ndarray:
    """Route samples to child slots (the paper's "distribute the
    data-index list of {v01, v02, ...} to the slaves")."""
    live = sample_slot >= 0
    s_safe = jnp.where(live, sample_slot, 0)
    rank_i = jnp.take_along_axis(split_rank, s_safe, 1)            # [k, N]
    f_i = jnp.take_along_axis(scores.feature, s_safe, 1)
    thr_i = jnp.take_along_axis(scores.threshold, s_safe, 1)
    go_right = plane.broadcast_route(x_binned, f_i, thr_i)
    return jnp.where(live & (rank_i >= 0), 2 * rank_i + go_right, -1)


def stream_block_step(
    hist_acc, xb_b, base_b, w_b, slot_b, slot_node,
    split_rank, scores: Optional[SplitScores],
    config: ForestConfig, plane: CollectivePlane, *, route: bool,
    small_right: Optional[jnp.ndarray] = None,
):
    """ONE device call per (block, level) of the streaming data plane.

    Fuses the route and histogram passes the PR-4 driver ran as two
    separate sweeps: route the block's samples from the *previous*
    level's frontier into this level's child slots (``route=True`` from
    level 1 on; ``split_rank``/``scores`` are that level's plan), then
    immediately fold the block into this level's histogram carry — so
    each level reads every block exactly once, and the per-sample slot
    table ``slot_b`` stays device-resident across levels (it is carried
    through this call, never round-tripped to the host).

    ``base_b`` (label channels) and ``w_b`` (DSI weights) are the
    per-block constants a ``BlockFeeder`` pins on device once for the
    whole growth. Works on any plane: ``route_level`` goes through
    ``plane.broadcast_route`` (identity gather locally, feature-axis
    psum on the mesh) and the histogram stays a local partial — the
    plane's ``combine_hist`` runs once per level in the plan step, not
    per block.

    With ``small_right`` (the sibling-subtraction reuse plane,
    ``config.hist_reuse``) the block is histogrammed into the *packed*
    ``max_splits_per_level`` rank segments — only samples routed to
    small children contribute; everything else parks in the dump row —
    so the accumulated carry (and, on the mesh, the per-level combine)
    is half the off-path tensor. ``hist_acc`` must then be the packed
    ``[k, R, F, B, C]`` carry.

    Returns ``(hist_acc + block_hist, routed slot_b)``.
    """
    if route:
        slot_b = route_level(xb_b, slot_b, split_rank, scores, plane)
    tree_live = jnp.any(slot_node >= 0, axis=1)
    w_lvl = w_b * tree_live[:, None].astype(w_b.dtype)
    if small_right is None:
        slots, n_slots = slot_b, config.frontier
    else:
        slots = sibling_segments(slot_b, small_right)
        n_slots = config.max_splits_per_level
    h = level_histograms(
        xb_b, base_b, w_lvl, slots,
        n_slots=n_slots, n_bins=config.n_bins,
        packed=config.packed_hist and not config.regression,
        backend=config.hist_backend,
    )
    return hist_acc + h, slot_b


def next_frontier(is_split, child_base, n_slots: int) -> jnp.ndarray:
    """Next level's frontier: this level's children, densely packed."""
    j = jnp.arange(n_slots)[None, :]
    n_children = 2 * is_split.sum(-1, keepdims=True)
    return jnp.where(j < n_children, child_base + j, -1).astype(jnp.int32)


def finalize_forest(forest: Forest) -> Forest:
    """Sanitize the pad slot after growth.

    Every non-split frontier slot dumps its writes into the pad node,
    so its content is "whatever the last executed level wrote" — a
    function of how MANY levels ran. Resetting it to the leaf defaults
    makes forests bit-identical across {early-exit, fixed-depth} x
    {streamed, resident} x planes, and is semantically free: no real
    node ever points at the pad slot, and the fused traversal kernel
    (which reads every pool row) sees zero payload for it.
    """
    pad = forest.config.max_nodes
    return dataclasses.replace(
        forest,
        feature=forest.feature.at[:, pad].set(-1),
        threshold=forest.threshold.at[:, pad].set(0),
        left_child=forest.left_child.at[:, pad].set(-1),
        class_counts=forest.class_counts.at[:, pad].set(0.0),
        value=forest.value.at[:, pad].set(0.0),
    )


# ---------------------------------------------------------------------------
# The engine loop
# ---------------------------------------------------------------------------


def level_step(
    x_binned: jnp.ndarray,
    base_channels: jnp.ndarray,
    weights: jnp.ndarray,
    state: GrowthState,
    config: ForestConfig,
    plane: CollectivePlane,
) -> GrowthState:
    """ONE level of growth: task group -> plan -> write -> route ->
    frontier, threaded through the ``GrowthState`` carry.

    This is the body of ``grow``'s ``lax.while_loop`` AND the body of
    the host-driven ``grow_checkpointed`` loop — the same traced
    computation either way, so a run that checkpoints between levels
    produces the bit-identical forest of an uninterrupted ``grow``.

    With ``state.hist_cache`` present (``ForestConfig.hist_reuse``) the
    task group runs the sibling-subtraction path and the carry's cache
    is refreshed with this level's paired histograms plus the next
    level's small-side plan; the branch is on pytree *structure*, so
    both modes are one traced computation each.
    """
    if state.hist_cache is None:
        scores, n_node = level_task_group(
            x_binned, base_channels, weights, state.sample_slot,
            state.slot_node, config, plane,
        )
        new_cache = None
    else:
        scores, n_node, new_cache = reuse_level_task_group(
            x_binned, base_channels, weights, state.sample_slot,
            state.slot_node, state.hist_cache, config, plane,
        )
    split_rank, is_split, child_base = plan_level(
        scores, n_node, state.slot_node, config, state.level
    )
    forest = write_level(
        state.forest, state.slot_node, split_rank, is_split, child_base,
        scores, config,
    )
    sample_slot = route_level(
        x_binned, state.sample_slot, split_rank, scores, plane
    )
    slot_node = next_frontier(is_split, child_base, config.frontier)
    if new_cache is not None:
        parent, small_right = sibling_plan(
            scores, split_rank, is_split,
            n_ranks=config.max_splits_per_level,
            regression=config.regression,
        )
        new_cache = dict(new_cache, parent=parent, small_right=small_right)
    return GrowthState(
        forest=forest,
        slot_node=slot_node,
        sample_slot=sample_slot,
        rng=state.rng,
        level=state.level + 1,
        hist_cache=new_cache,
    )


def grow_checkpointed(
    x_binned: jnp.ndarray,
    base_channels: jnp.ndarray,
    weights: jnp.ndarray,
    config: ForestConfig,
    plane: CollectivePlane,
    *,
    rng: Optional[jnp.ndarray] = None,
    manager=None,
    resume_from: Optional[str] = None,
    on_level=None,
) -> Forest:
    """``grow`` with per-level ``GrowthState`` checkpointing.

    A host-driven loop over the jitted ``level_step`` — each iteration
    runs the identical traced level-step of the ``lax.while_loop``
    engine, so the forest is bit-identical to ``grow`` on the same
    plane. Between levels the full carry (forest, frontier, per-sample
    slots, rng, level — everything a crash would lose) is handed to
    ``manager.maybe_save`` (atomic-rename checkpoints,
    ``checkpoint.CheckpointManager``); ``resume_from`` names a
    checkpoint directory whose newest *CRC-verified* step restores the
    carry (``checkpoint.restore_latest_valid`` — corrupt or torn steps
    are skipped, so a byte-flipped newest checkpoint costs one level of
    recompute, never a poisoned carry) and growth continues from the
    level after it. An empty/missing/fully-corrupt ``resume_from``
    directory falls back to a fresh start (the ``ElasticRunner``
    convention), so crash-retry supervisors need no
    has-a-checkpoint-yet branch.

    ``on_level(level, state)`` fires after each completed level (and
    after its checkpoint, so a raise here models a crash at the level
    boundary with the level's checkpoint already durable).
    """
    state = None
    if resume_from is not None:
        from ..checkpoint.checkpoint import restore_latest_valid

        like = init_growth_state(
            base_channels, weights, config, plane, rng=rng,
            n_features=x_binned.shape[1],
        )
        restored = restore_latest_valid(like, resume_from)
        if restored is not None:
            state, _ = restored
    if state is None:
        state = init_growth_state(
            base_channels, weights, config, plane, rng=rng,
            n_features=x_binned.shape[1],
        )

    step = jax.jit(
        lambda xb, base, w, st: level_step(xb, base, w, st, config, plane)
    )
    while int(state.level) < config.max_depth and bool(
        np.any(np.asarray(state.slot_node) >= 0)
    ):
        state = step(x_binned, base_channels, weights, state)
        if manager is not None:
            manager.maybe_save(state, int(state.level))
        if on_level is not None:
            on_level(int(state.level), state)
    return finalize_forest(state.forest)


def grow(
    x_binned: jnp.ndarray,        # [N, F] uint8 (local shard in distributed mode)
    base_channels: jnp.ndarray,   # [N, C]
    weights: jnp.ndarray,         # [k, N] DSI in-bag multiplicities
    config: ForestConfig,
    plane: CollectivePlane,
    *,
    rng: Optional[jnp.ndarray] = None,
) -> Forest:
    """Level-synchronous growth over ``plane`` — the unified engine.

    A ``lax.while_loop`` threads the full ``GrowthState`` carry through
    the level-step; with ``config.early_exit`` the loop also stops as
    soon as every tree's frontier is empty (the paper's schedulers
    dispatching no tasks for finished trees), which skips entire levels
    of histogram + routing work for shallow-converging forests.
    """
    depth = config.max_depth
    state = init_growth_state(
        base_channels, weights, config, plane, rng=rng,
        n_features=x_binned.shape[1],
    )

    def cond(state: GrowthState):
        more = state.level < depth
        if config.early_exit:
            more = more & jnp.any(state.slot_node >= 0)
        return more

    def body(state: GrowthState) -> GrowthState:
        return level_step(x_binned, base_channels, weights, state, config, plane)

    state = jax.lax.while_loop(cond, body, state)
    return finalize_forest(state.forest)
