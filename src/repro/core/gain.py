"""Entropy / information gain / gain ratio / variable importance (paper Eq. 2-7).

All quantities are computed from **weighted class histograms** — the
TPU-native form of the paper's gain-ratio-computing tasks T_GR (§4.2.1):

    hist[t, s, f, b, c] = sum of in-bag weights of samples of tree t,
                          sitting at frontier slot s, whose feature f
                          falls in bin b, with label c.

Cumulative sums over the bin axis evaluate *every* candidate binary split
of every feature simultaneously; Eq. 2-6 then reduce those to a gain
ratio per (tree, node, feature, threshold). The only cross-device
communication this ever needs is a psum of `hist` over the sample axis
(see core/distributed.py) — the vertical-partition property.

The split-scoring stage (T_NS stage 1) has two backends, selected by
``ForestConfig.split_backend`` and dispatched by ``level_scores``:

* ``"xla"``    — the vectorized jnp path below (portable oracle);
* ``"pallas"`` — the fused split-scan kernel (``kernels/split_scan``)
  that consumes the histogram per feature block and keeps a running-best
  carry, so only O(k*S) split descriptors ever leave the kernel;
* ``"auto"``   — ``pallas`` on TPU, else ``xla``.

Both backends score *from one shared cumsum* of the histogram
(``split_gain_ratios_from_cumsum`` / ``variance_gains_from_cumsum``):
the prefix sums that produce the gain ratios are re-used for the winner's
child counts, so the bin axis is only scanned once.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Natural-log entropies throughout; the gain *ratio* (Eq. 6) is invariant
# to the log base as long as G and I use the same one.


def _xlogx(p: jnp.ndarray) -> jnp.ndarray:
    """x * log(x), safe at 0 (0 log 0 := 0)."""
    return jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-38)), 0.0)


def entropy_from_counts(counts: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Shannon entropy of a (possibly unnormalized) count vector. Eq. (2)."""
    total = jnp.sum(counts, axis=axis, keepdims=True)
    p = counts / jnp.maximum(total, 1e-38)
    return -jnp.sum(_xlogx(p), axis=axis)


class SplitScores(NamedTuple):
    """Per-(tree, slot) best split, after the T_NS argmax."""

    gain_ratio: jnp.ndarray    # [k, S] best gain ratio
    feature: jnp.ndarray       # [k, S] int32 best feature
    threshold: jnp.ndarray     # [k, S] int32 best bin threshold (left: bin <= thr)
    left_counts: jnp.ndarray   # [k, S, C] class counts of left child
    right_counts: jnp.ndarray  # [k, S, C] class counts of right child


def split_gain_ratios_from_cumsum(cum: jnp.ndarray, total: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2)-(6) from bin prefix sums — shared by the XLA and Pallas
    split backends so their gain ratios are bit-identical.

    Args:
      cum:   [..., F, B, C] ``cumsum(hist, axis=-2)``.
      total: [..., F, C] node class counts (``cum[..., -1, :]``).
    Returns:
      gr: [..., F, B-1]; invalid (empty-side) splits get -inf.
    """
    n = total.sum(axis=-1)                          # [..., F]
    h_node = entropy_from_counts(total)             # [..., F]  Entropy(S_i), Eq. 2

    left = cum[..., :-1, :]                         # [..., F, B-1, C]
    right = total[..., None, :] - left              # [..., F, B-1, C]
    n_l = left.sum(-1)                              # [..., F, B-1]
    n_r = right.sum(-1)
    n_tot = jnp.maximum(n[..., None], 1e-38)

    # Eq. (3): conditional entropy of the target given the split.
    h_cond = (n_l / n_tot) * entropy_from_counts(left) + (
        n_r / n_tot
    ) * entropy_from_counts(right)
    gain = h_node[..., None] - h_cond               # Eq. (5)

    # Eq. (4): self-split information of the binary partition.
    p_l = n_l / n_tot
    p_r = n_r / n_tot
    split_info = -(_xlogx(p_l) + _xlogx(p_r))

    gr = gain / jnp.maximum(split_info, 1e-12)      # Eq. (6)
    valid = (n_l > 0) & (n_r > 0)
    return jnp.where(valid, gr, -jnp.inf)


def split_gain_ratios(hist: jnp.ndarray) -> jnp.ndarray:
    """Gain ratio of every candidate split. Eq. (2)-(6), vectorized.

    Args:
      hist: [..., F, B, C] weighted class histograms of one node subset.
    Returns:
      gr: [..., F, B-1] gain ratio of splitting feature f at threshold b
          (left = bins 0..b). Invalid (empty-side) splits get -inf.
    """
    cum = jnp.cumsum(hist, axis=-2)
    return split_gain_ratios_from_cumsum(cum, cum[..., -1, :])


def variance_gains_from_cumsum(cum: jnp.ndarray, total: jnp.ndarray) -> jnp.ndarray:
    """Regression analogue of ``split_gain_ratios_from_cumsum``.

    Args:
      cum:   [..., F, B, 3] prefix sums of the [count, sum, sumsq] channels.
      total: [..., F, 3].
    Returns: [..., F, B-1] variance reduction (invalid -> -inf).
    """

    def sse(h):
        return h[..., 2] - h[..., 1] * h[..., 1] / jnp.maximum(h[..., 0], 1e-38)

    left = cum[..., :-1, :]
    right = total[..., None, :] - left
    gain = sse(total)[..., None] - sse(left) - sse(right)
    valid = (left[..., 0] > 0) & (right[..., 0] > 0)
    return jnp.where(valid, gain, -jnp.inf)


def variance_gains(sum_hist, sumsq_hist, cnt_hist):
    """Regression analogue: variance reduction per candidate split.

    Args: [..., F, B] histograms of sum(y*w), sum(y^2*w), sum(w).
    Returns: [..., F, B-1] gain (invalid -> -inf).
    """
    hist = jnp.stack([cnt_hist, sum_hist, sumsq_hist], axis=-1)
    cum = jnp.cumsum(hist, axis=-2)
    return variance_gains_from_cumsum(cum, cum[..., -1, :])


def _select_winners(gr: jnp.ndarray, cum: jnp.ndarray, total: jnp.ndarray) -> SplitScores:
    """T_NS argmax + child-count gather, re-using the scoring cumsum.

    The child counts come for free from the same prefix sums the gain
    ratios were computed from (the paper's "intermediate results
    submitted to subsequent tasks") — no second pass over the bin axis.
    """
    k, S, F, B, C = cum.shape
    flat = gr.reshape(k, S, F * (B - 1))
    best = jnp.argmax(flat, axis=-1)                # [k, S]
    best_gr = jnp.take_along_axis(flat, best[..., None], axis=-1)[..., 0]
    best_f = (best // (B - 1)).astype(jnp.int32)
    best_thr = (best % (B - 1)).astype(jnp.int32)

    f_idx = best_f[..., None, None, None]           # [k, S, 1, 1, 1]
    cum_f = jnp.take_along_axis(cum, jnp.broadcast_to(f_idx, (k, S, 1, B, C)), axis=2)[:, :, 0]
    left_counts = jnp.take_along_axis(
        cum_f, jnp.broadcast_to(best_thr[..., None, None], (k, S, 1, C)), axis=2
    )[:, :, 0]
    total_f = jnp.take_along_axis(
        total, jnp.broadcast_to(best_f[..., None, None], (k, S, 1, C)), axis=2
    )[:, :, 0]
    right_counts = total_f - left_counts
    return SplitScores(best_gr, best_f, best_thr, left_counts, right_counts)


def best_splits(hist: jnp.ndarray, feature_mask: jnp.ndarray | None = None) -> SplitScores:
    """The node-splitting task T_NS (paper Definition 4): global best split.

    Args:
      hist: [k, S, F, B, C].
      feature_mask: optional [k, F] bool — features admitted by the
        dimension-reduction step (paper Alg. 3.1). Masked-out features
        never win the argmax.
    Returns: SplitScores with [k, S] leaders + child class counts.
    """
    cum = jnp.cumsum(hist, axis=-2)                 # the ONE bin scan
    total = cum[..., -1, :]
    gr = split_gain_ratios_from_cumsum(cum, total)  # [k, S, F, B-1]
    if feature_mask is not None:
        gr = jnp.where(feature_mask[:, None, :, None], gr, -jnp.inf)
    return _select_winners(gr, cum, total)


def node_counts(scores: SplitScores, *, regression: bool = False) -> jnp.ndarray:
    """Node sample count [k, S] recovered from the winner's child counts."""
    if regression:
        return scores.left_counts[..., 0] + scores.right_counts[..., 0]
    return scores.left_counts.sum(-1) + scores.right_counts.sum(-1)


def sibling_plan(
    scores: SplitScores,
    split_rank: jnp.ndarray,   # [k, S] int32 dense rank of admitted splits, -1 else
    is_split: jnp.ndarray,     # [k, S] bool
    *,
    n_ranks: int,              # R = ForestConfig.max_splits_per_level
    regression: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Plan next level's sibling-subtraction reuse (``hist_reuse``).

    For every admitted split rank r, record (a) which frontier slot is
    its parent and (b) which child is the *smaller* one — the only child
    the next level will histogram directly; the sibling is reconstructed
    as ``parent - small``. "Smaller" means fewer weighted samples, read
    off the winner's child counts the scoring cumsum already produced
    (no extra pass); ties go left. Both tables are derived from the
    post-``merge_winners`` scores, so every mesh shard plans the same
    small side.

    Returns ``(parent [k, R] int32 slot, -1 for unused ranks;
    small_right [k, R] int32, 1 = right child is the small one)``.
    """
    k, S = split_rank.shape
    R = n_ranks
    if regression:
        n_l, n_r = scores.left_counts[..., 0], scores.right_counts[..., 0]
    else:
        n_l, n_r = scores.left_counts.sum(-1), scores.right_counts.sum(-1)
    sr_slot = (n_r < n_l).astype(jnp.int32)                   # [k, S]
    # Rank -> slot scatter. Dense ranks are unique per tree; every
    # non-admitted slot dumps into the sliced-off row R.
    rank = jnp.where(is_split, split_rank, R)
    t = jnp.arange(k)[:, None]
    slots = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (k, S))
    parent = jnp.full((k, R + 1), -1, jnp.int32).at[t, rank].set(slots)[:, :R]
    small_right = (
        jnp.zeros((k, R + 1), jnp.int32).at[t, rank].set(sr_slot)[:, :R]
    )
    return parent, small_right


SPLIT_BACKENDS = ("auto", "pallas", "xla")


def resolve_split_backend(backend: str) -> str:
    """'auto' -> 'pallas' on TPU, 'xla' elsewhere."""
    if backend not in SPLIT_BACKENDS:
        raise ValueError(f"split_backend={backend!r} not in {SPLIT_BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def level_scores(
    hist: jnp.ndarray,
    feature_mask: jnp.ndarray | None,
    *,
    regression: bool = False,
    backend: str = "xla",
    interpret: bool | None = None,
) -> tuple[SplitScores, jnp.ndarray]:
    """T_NS stage-1: per-(tree, slot) winning split + node sample count.

    Args:
      hist: [k, S, F, B, C] (C = n_classes, or 3 regression channels).
      backend: split-scoring backend ("auto" | "pallas" | "xla"); the
        pallas backend consumes ``hist`` per feature block in VMEM and
        only the O(k*S) winners leave the kernel.
      interpret: pallas backend only; ``None`` = interpret off-TPU.
    Returns: (SplitScores, n_node [k, S]).
    """
    backend = resolve_split_backend(backend)
    if backend == "pallas":
        from ..kernels.split_scan.kernel import split_scan_scores

        scores = split_scan_scores(
            hist, feature_mask, regression=regression, interpret=interpret
        )
    elif regression:
        cum = jnp.cumsum(hist, axis=-2)
        total = cum[..., -1, :]
        gains = variance_gains_from_cumsum(cum, total)
        if feature_mask is not None:
            gains = jnp.where(feature_mask[:, None, :, None], gains, -jnp.inf)
        scores = _select_winners(gains, cum, total)
    else:
        scores = best_splits(hist, feature_mask)
    return scores, node_counts(scores, regression=regression)


def multiway_gain_ratio(hist: jnp.ndarray) -> jnp.ndarray:
    """Faithful Eq. (2)-(6) with V(y_ij) = the bin values (multiway form).

    This is the quantity the paper ranks features by in Alg. 3.1: each
    distinct value of y_ij is a branch. With binned features the value
    set is the bin set.

    Args:  hist: [..., F, B, C].
    Returns: gr: [..., F].
    """
    total = hist.sum(axis=-2)                        # [..., F, C]
    n = jnp.maximum(total.sum(axis=-1), 1e-38)       # [..., F]
    h_node = entropy_from_counts(total)              # Eq. 2
    n_b = hist.sum(axis=-1)                          # [..., F, B]
    p_b = n_b / n[..., None]
    h_cond = jnp.sum(p_b * entropy_from_counts(hist), axis=-1)   # Eq. 3
    gain = h_node - h_cond                           # Eq. 5
    split_info = -jnp.sum(_xlogx(p_b), axis=-1)      # Eq. 4 (self-split info)
    return gain / jnp.maximum(split_info, 1e-12)     # Eq. 6


def variable_importance(gr: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7): VI(y_ij) = GR(y_ij) / sum_a GR(y_ia), per tree.

    Args:  gr: [k, F] root-node gain ratio of each feature (clamped >= 0).
    Returns: vi: [k, F] normalized importances.
    """
    g = jnp.maximum(gr, 0.0)
    return g / jnp.maximum(g.sum(axis=-1, keepdims=True), 1e-38)
