"""Entropy / information gain / gain ratio / variable importance (paper Eq. 2-7).

All quantities are computed from **weighted class histograms** — the
TPU-native form of the paper's gain-ratio-computing tasks T_GR (§4.2.1):

    hist[t, s, f, b, c] = sum of in-bag weights of samples of tree t,
                          sitting at frontier slot s, whose feature f
                          falls in bin b, with label c.

Cumulative sums over the bin axis evaluate *every* candidate binary split
of every feature simultaneously; Eq. 2-6 then reduce those to a gain
ratio per (tree, node, feature, threshold). The only cross-device
communication this ever needs is a psum of `hist` over the sample axis
(see core/distributed.py) — the vertical-partition property.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Natural-log entropies throughout; the gain *ratio* (Eq. 6) is invariant
# to the log base as long as G and I use the same one.


def _xlogx(p: jnp.ndarray) -> jnp.ndarray:
    """x * log(x), safe at 0 (0 log 0 := 0)."""
    return jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-38)), 0.0)


def entropy_from_counts(counts: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Shannon entropy of a (possibly unnormalized) count vector. Eq. (2)."""
    total = jnp.sum(counts, axis=axis, keepdims=True)
    p = counts / jnp.maximum(total, 1e-38)
    return -jnp.sum(_xlogx(p), axis=axis)


class SplitScores(NamedTuple):
    """Per-(tree, slot) best split, after the T_NS argmax."""

    gain_ratio: jnp.ndarray    # [k, S] best gain ratio
    feature: jnp.ndarray       # [k, S] int32 best feature
    threshold: jnp.ndarray     # [k, S] int32 best bin threshold (left: bin <= thr)
    left_counts: jnp.ndarray   # [k, S, C] class counts of left child
    right_counts: jnp.ndarray  # [k, S, C] class counts of right child


def split_gain_ratios(hist: jnp.ndarray) -> jnp.ndarray:
    """Gain ratio of every candidate split. Eq. (2)-(6), vectorized.

    Args:
      hist: [..., F, B, C] weighted class histograms of one node subset.
    Returns:
      gr: [..., F, B-1] gain ratio of splitting feature f at threshold b
          (left = bins 0..b). Invalid (empty-side) splits get -inf.
    """
    total = hist.sum(axis=-2)                       # [..., F, C] node class counts
    n = total.sum(axis=-1)                          # [..., F]
    h_node = entropy_from_counts(total)             # [..., F]  Entropy(S_i), Eq. 2

    left = jnp.cumsum(hist, axis=-2)[..., :-1, :]   # [..., F, B-1, C]
    right = total[..., None, :] - left              # [..., F, B-1, C]
    n_l = left.sum(-1)                              # [..., F, B-1]
    n_r = right.sum(-1)
    n_tot = jnp.maximum(n[..., None], 1e-38)

    # Eq. (3): conditional entropy of the target given the split.
    h_cond = (n_l / n_tot) * entropy_from_counts(left) + (
        n_r / n_tot
    ) * entropy_from_counts(right)
    gain = h_node[..., None] - h_cond               # Eq. (5)

    # Eq. (4): self-split information of the binary partition.
    p_l = n_l / n_tot
    p_r = n_r / n_tot
    split_info = -(_xlogx(p_l) + _xlogx(p_r))

    gr = gain / jnp.maximum(split_info, 1e-12)      # Eq. (6)
    valid = (n_l > 0) & (n_r > 0)
    return jnp.where(valid, gr, -jnp.inf)


def variance_gains(sum_hist, sumsq_hist, cnt_hist):
    """Regression analogue: variance reduction per candidate split.

    Args: [..., F, B] histograms of sum(y*w), sum(y^2*w), sum(w).
    Returns: [..., F, B-1] gain (invalid -> -inf).
    """

    def sse(s, ss, c):
        return ss - s * s / jnp.maximum(c, 1e-38)

    tot_s = sum_hist.sum(-1)
    tot_ss = sumsq_hist.sum(-1)
    tot_c = cnt_hist.sum(-1)
    l_s = jnp.cumsum(sum_hist, -1)[..., :-1]
    l_ss = jnp.cumsum(sumsq_hist, -1)[..., :-1]
    l_c = jnp.cumsum(cnt_hist, -1)[..., :-1]
    r_s = tot_s[..., None] - l_s
    r_ss = tot_ss[..., None] - l_ss
    r_c = tot_c[..., None] - l_c
    gain = sse(tot_s, tot_ss, tot_c)[..., None] - sse(l_s, l_ss, l_c) - sse(r_s, r_ss, r_c)
    valid = (l_c > 0) & (r_c > 0)
    return jnp.where(valid, gain, -jnp.inf)


def best_splits(hist: jnp.ndarray, feature_mask: jnp.ndarray | None = None) -> SplitScores:
    """The node-splitting task T_NS (paper Definition 4): global best split.

    Args:
      hist: [k, S, F, B, C].
      feature_mask: optional [k, F] bool — features admitted by the
        dimension-reduction step (paper Alg. 3.1). Masked-out features
        never win the argmax.
    Returns: SplitScores with [k, S] leaders + child class counts.
    """
    k, S, F, B, C = hist.shape
    gr = split_gain_ratios(hist)                    # [k, S, F, B-1]
    if feature_mask is not None:
        gr = jnp.where(feature_mask[:, None, :, None], gr, -jnp.inf)

    flat = gr.reshape(k, S, F * (B - 1))
    best = jnp.argmax(flat, axis=-1)                # [k, S]
    best_gr = jnp.take_along_axis(flat, best[..., None], axis=-1)[..., 0]
    best_f = (best // (B - 1)).astype(jnp.int32)
    best_thr = (best % (B - 1)).astype(jnp.int32)

    # Child class counts of the winning split (free from the histogram —
    # the paper's "intermediate results submitted to subsequent tasks").
    cum = jnp.cumsum(hist, axis=-2)                 # [k, S, F, B, C]
    f_idx = best_f[..., None, None, None]           # [k, S, 1, 1, 1]
    cum_f = jnp.take_along_axis(cum, jnp.broadcast_to(f_idx, (k, S, 1, B, C)), axis=2)[:, :, 0]
    left_counts = jnp.take_along_axis(
        cum_f, jnp.broadcast_to(best_thr[..., None, None], (k, S, 1, C)), axis=2
    )[:, :, 0]
    total = hist.sum(axis=-2)                       # [k, S, F, C]
    total_f = jnp.take_along_axis(
        total, jnp.broadcast_to(best_f[..., None, None], (k, S, 1, C)), axis=2
    )[:, :, 0]
    right_counts = total_f - left_counts
    return SplitScores(best_gr, best_f, best_thr, left_counts, right_counts)


def level_scores(
    hist: jnp.ndarray,
    feature_mask: jnp.ndarray | None,
    *,
    regression: bool = False,
) -> tuple[SplitScores, jnp.ndarray]:
    """T_NS stage-1: per-(tree, slot) winning split + node sample count.

    Args:
      hist: [k, S, F, B, C] (C = n_classes, or 3 regression channels).
    Returns: (SplitScores, n_node [k, S]).
    """
    k, S, F, B, C = hist.shape
    if not regression:
        scores = best_splits(hist, feature_mask)
        n_node = scores.left_counts.sum(-1) + scores.right_counts.sum(-1)
        return scores, n_node

    gains = variance_gains(hist[..., 1], hist[..., 2], hist[..., 0])
    if feature_mask is not None:
        gains = jnp.where(feature_mask[:, None, :, None], gains, -jnp.inf)
    flat = gains.reshape(k, S, -1)
    bi = jnp.argmax(flat, -1)
    best_gain = jnp.take_along_axis(flat, bi[..., None], -1)[..., 0]
    best_f = (bi // (B - 1)).astype(jnp.int32)
    best_thr = (bi % (B - 1)).astype(jnp.int32)
    cum = jnp.cumsum(hist, axis=-2)
    cum_f = jnp.take_along_axis(
        cum, jnp.broadcast_to(best_f[..., None, None, None], (k, S, 1, B, C)), 2
    )[:, :, 0]
    left_counts = jnp.take_along_axis(
        cum_f, jnp.broadcast_to(best_thr[..., None, None], (k, S, 1, C)), 2
    )[:, :, 0]
    total_f = jnp.take_along_axis(
        hist.sum(-2), jnp.broadcast_to(best_f[..., None, None], (k, S, 1, C)), 2
    )[:, :, 0]
    right_counts = total_f - left_counts
    scores = SplitScores(best_gain, best_f, best_thr, left_counts, right_counts)
    return scores, total_f[..., 0]


def multiway_gain_ratio(hist: jnp.ndarray) -> jnp.ndarray:
    """Faithful Eq. (2)-(6) with V(y_ij) = the bin values (multiway form).

    This is the quantity the paper ranks features by in Alg. 3.1: each
    distinct value of y_ij is a branch. With binned features the value
    set is the bin set.

    Args:  hist: [..., F, B, C].
    Returns: gr: [..., F].
    """
    total = hist.sum(axis=-2)                        # [..., F, C]
    n = jnp.maximum(total.sum(axis=-1), 1e-38)       # [..., F]
    h_node = entropy_from_counts(total)              # Eq. 2
    n_b = hist.sum(axis=-1)                          # [..., F, B]
    p_b = n_b / n[..., None]
    h_cond = jnp.sum(p_b * entropy_from_counts(hist), axis=-1)   # Eq. 3
    gain = h_node - h_cond                           # Eq. 5
    split_info = -jnp.sum(_xlogx(p_b), axis=-1)      # Eq. 4 (self-split info)
    return gain / jnp.maximum(split_info, 1e-12)     # Eq. 6


def variable_importance(gr: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7): VI(y_ij) = GR(y_ij) / sum_a GR(y_ia), per tree.

    Args:  gr: [k, F] root-node gain ratio of each feature (clamped >= 0).
    Returns: vi: [k, F] normalized importances.
    """
    g = jnp.maximum(gr, 0.0)
    return g / jnp.maximum(g.sum(axis=-1, keepdims=True), 1e-38)
