"""Core datatypes for the Parallel Random Forest (PRF).

The forest is stored as flat, fixed-shape arrays (a *node pool*) so that
training and inference are pure XLA programs with static shapes:

* every tree owns ``max_nodes = 1 + 2 * frontier * depth`` pool slots;
* level ``L`` always allocates its children inside the pool range
  ``[1 + 2*frontier*L, 1 + 2*frontier*(L+1))`` — allocation is a pure
  index computation, no dynamic counters cross a ``lax.scan`` boundary.

This mirrors the paper's task DAG: one pool "band" per DAG stage.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp


def _pytree_dataclass(cls):
    """Register a dataclass as a JAX pytree (fields = leaves, config aux)."""
    fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static")]
    static = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]

    def flatten(obj):
        return tuple(getattr(obj, n) for n in fields), tuple(getattr(obj, n) for n in static)

    def unflatten(aux, leaves):
        return cls(**dict(zip(fields, leaves)), **dict(zip(static, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


@dataclasses.dataclass(frozen=True)
class ForestConfig:
    """Hyper-parameters of the PRF algorithm (paper §3–§4)."""

    n_trees: int = 32                 # k — ensemble size
    max_depth: int = 8                # levels of splitting
    n_bins: int = 64                  # histogram bins per feature (TPU adaptation)
    n_classes: int = 2                # C
    max_frontier: int = 0             # beam width; 0 => full 2**max_depth
    min_samples_split: int = 2
    min_gain: float = 1e-7            # minimal gain ratio to split
    # --- paper §3.2: dimension reduction ----------------------------------
    # "importance": paper's Alg. 3.1 (top-k_imp by VI + random rest)
    # "random":     Breiman RF — m features per tree, uniformly (paper §3.1)
    # "all":        no per-tree feature restriction (bagged trees)
    feature_mode: str = "importance"
    n_important: int = 0              # paper's k  (0 => ceil(sqrt(m_selected)))
    n_selected: int = 0               # paper's m  (0 => ceil(sqrt(M)))
    # --- paper §3.3: weighted voting --------------------------------------
    weighted_voting: bool = True
    soft_voting: bool = False         # Majority[w_i * h_i(x)] (hard) vs prob-weighted
    # --- task-parallel execution knobs (§4.2) ------------------------------
    tree_chunk: int = 0               # trees processed per level-step (0 => all)
    # Early-exit scheduling (paper §4.2: schedulers only dispatch the
    # T_GR/T_NS tasks that exist): the growth loop is a ``lax.while_loop``
    # that stops as soon as every tree's frontier is empty, and trees
    # whose frontiers died contribute zero-weight (masked) work inside
    # each tree_chunk task group. Off => fixed ``max_depth`` iterations.
    # Either way the resulting Forest arrays are bit-identical (the pad
    # slot is sanitized after growth), so this is purely a scheduling knob.
    early_exit: bool = True
    # Sample-block streaming: > 0 => level histograms are accumulated over
    # [sample_block, F] row blocks instead of one [N, F] pass, bounding
    # the per-call sample working set (resumable hist carry, mirroring
    # fused_vote_scores' chunk carry on the predict side). 0 => one pass.
    # Integer-valued DSI counts make the blocked accumulation bit-exact
    # for classification; regression channels agree to float rounding.
    # ``train_prf`` dispatches the WHOLE pipeline (binning, dimred,
    # growth, OOB weights, prediction) through the streaming data plane
    # when this is > 0 — the host-streaming ``grow_forest_streamed``
    # driver (core/api.py) feeds blocks of this size from a NumPy/memmap
    # source with async double-buffered host->device copies
    # (data.pipeline.BlockFeeder), so the full [N, F] matrix is never
    # device-resident.
    sample_block: int = 0
    # Bin-edge fitting strategy (core/binning.py):
    #   "exact"   — one np.quantile over the full raw source (copies +
    #               sorts [N, F] in host RAM; the original behavior).
    #   "blocked" — StreamingQuantileSketch over sample blocks: O(block)
    #               + O(F * sketch) memory, bitwise identical to "exact"
    #               below the sketch's compression threshold and
    #               deterministic always.
    #   "auto"    — "blocked" whenever sample_block > 0 (the streamed
    #               trainer must not take a full pass over a memmap),
    #               "exact" otherwise.
    bin_fit: str = "auto"
    regression: bool = False
    # --- §Perf optimizations (beyond-paper; see EXPERIMENTS.md §Perf) ------
    packed_hist: bool = False         # class index folded into segment ids
    hist_reduce: str = "psum"         # psum | psum_scatter (distributed T_GR)
    # Sibling-subtraction histogram reuse (PERF.md §Histogram reuse):
    # between levels the engine carries the previous level's per-slot
    # histograms, histograms ONLY samples routed to the *smaller* child
    # of each split, and reconstructs every large child as
    # ``parent - small_sibling`` — halving T_GR's histogram build (and,
    # on the mesh plane, the psum/psum_scatter volume: only the packed
    # small-child partials cross the wire). Exact for classification
    # (integer DSI counts: ``hist(parent) = hist(left) + hist(right)``
    # holds bitwise below 2**24), so "on" forests are bit-identical to
    # "off" on every plane; regression channels ([1, y, y^2] f32 sums)
    # only agree to float rounding, so:
    #   "auto" — reuse for classification, off for regression;
    #   "on"   — always (regression is an explicit tolerance opt-in);
    #   "off"  — never.
    # The carried cache costs k*S*F*B*C f32 of HBM (updated slab-by-slab
    # on the fused path); when that exceeds ``hist_reuse_budget_mb`` the
    # engine falls back to "off" (engine.resolve_hist_reuse).
    hist_reuse: str = "auto"
    hist_reuse_budget_mb: int = 256   # cache budget gate for hist_reuse
    # Backend "auto" resolution (all three knobs below): pallas ONLY when
    # `jax.default_backend() == "tpu"`, the XLA oracle everywhere else.
    # Off-TPU the pallas kernels exist solely in `interpret=True`
    # emulation — a Python-level interpreter, not hardware — and the
    # measured CPU numbers in BENCH_kernels.json make the policy hard:
    # predict_pallas is ~65x slower than predict_xla (162983 vs 2513
    # us/call), level_hist_pallas ~1.3x slower than segment_sum, and
    # level_scores_pallas ~1.7x slower than the xla scorer. "auto" must
    # therefore NEVER resolve to an emulated kernel: the resolvers
    # (histograms.resolve_backend, gain.resolve_split_backend,
    # voting.resolve_predict_backend) key on the platform, never on
    # availability. Force `*_backend="pallas"` off-TPU only to exercise
    # the kernel code paths (that is what the parity tests do).
    #
    # T_GR backend: "pallas" = fused MXU one-hot-matmul kernel
    # (kernels/gain_ratio), "segment_sum" = XLA scatter vmap. See PERF.md.
    hist_backend: str = "auto"
    # T_NS backend: "pallas" = fused split-scan kernel (kernels/split_scan)
    # — on the single-host path it chains hist-kernel -> score-kernel per
    # feature slab so the [tc, S, F, B, C] histogram never reaches HBM;
    # "xla" = vectorized jnp argmax over the full histogram. See PERF.md.
    split_backend: str = "auto"
    # Prediction backend: "pallas" = fused traversal+voting kernel
    # (kernels/tree_traverse) — the depth walk runs in VMEM and the
    # Eq. 9/10 weighted vote accumulates across the tree grid axis, so
    # the [k, N, C] per-tree probability tensor never exists; "xla" =
    # route_to_leaves + weighted_vote over the full tensor. Honored by
    # voting.predict / predict_regression, PRFModel.predict and
    # serving/. See PERF.md.
    predict_backend: str = "auto"

    def __post_init__(self):
        # Bin ids are uint8 end to end — reject wrap-prone counts with a
        # typed error at config time, not as corrupted histograms later.
        from .binning import validate_n_bins

        validate_n_bins(self.n_bins)
        if self.bin_fit not in ("auto", "exact", "blocked"):
            raise ValueError(
                f"bin_fit must be 'auto', 'exact' or 'blocked', got {self.bin_fit!r}"
            )
        if self.hist_reuse not in ("auto", "on", "off"):
            raise ValueError(
                f"hist_reuse must be 'auto', 'on' or 'off', got {self.hist_reuse!r}"
            )

    def resolved_bin_fit(self) -> str:
        """Resolve bin_fit='auto': blocked iff the trainer streams blocks."""
        if self.bin_fit != "auto":
            return self.bin_fit
        return "blocked" if self.sample_block > 0 else "exact"

    def resolved_hist_reuse(self) -> str:
        """Resolve hist_reuse='auto': reuse is bitwise-exact only for
        integer classification counts, so auto enables it for
        classification and keeps regression (float channel sums) off.
        The shape-dependent cache budget gate is applied downstream
        (``engine.resolve_hist_reuse``)."""
        if self.hist_reuse != "auto":
            return self.hist_reuse
        return "off" if self.regression else "on"

    @property
    def frontier(self) -> int:
        f = self.max_frontier if self.max_frontier > 0 else 2 ** self.max_depth
        return min(f, 2 ** self.max_depth)

    @property
    def max_splits_per_level(self) -> int:
        return max(self.frontier // 2, 1)

    @property
    def max_nodes(self) -> int:
        # Each level allocates one band of at most 2*max_splits children.
        return 1 + 2 * self.max_splits_per_level * self.max_depth

    def resolved(self, n_features: int) -> "ForestConfig":
        """Fill data-dependent defaults (m = ceil(sqrt(M)), k_imp = ceil(sqrt(m)))."""
        import math

        m = self.n_selected if self.n_selected > 0 else max(1, int(math.ceil(math.sqrt(n_features))))
        m = min(m, n_features)
        k_imp = self.n_important if self.n_important > 0 else max(1, int(math.ceil(math.sqrt(m))))
        k_imp = min(k_imp, m)
        return dataclasses.replace(self, n_selected=m, n_important=k_imp)


@_pytree_dataclass
@dataclasses.dataclass
class Forest:
    """A trained PRF model — flat node-pool representation.

    Shapes (k = n_trees, P = max_nodes, C = n_classes):
      feature      [k, P] int32   split feature, -1 => leaf / unused
      threshold    [k, P] int32   go left iff bin <= threshold
      left_child   [k, P] int32   pool id of left child (right = left+1), -1 => leaf
      class_counts [k, P, C] f32  weighted class histogram at node creation
      value        [k, P] f32     regression value (weighted mean of y)
      tree_weight  [k] f32        w_i — OOB accuracy (Eq. 8) or 1.0
    """

    feature: jnp.ndarray
    threshold: jnp.ndarray
    left_child: jnp.ndarray
    class_counts: jnp.ndarray
    value: jnp.ndarray
    tree_weight: jnp.ndarray
    config: ForestConfig = static_field(default=None)

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]


@_pytree_dataclass
@dataclasses.dataclass
class GrowthState:
    """The growth engine's level-loop carry (core/engine.py).

    One value of this pytree fully describes a paused level-synchronous
    training run: ``core.engine.grow`` threads it through a
    ``lax.while_loop`` (early-exit scheduling), and the host-streaming
    driver (``core.api.grow_forest_streamed``) keeps the same fields
    across its per-block device calls. Registered as a pytree so it
    round-trips ``jax.jit`` boundaries (see tests/test_engine.py).
    """

    forest: Forest
    slot_node: jnp.ndarray     # [k, S] pool node id of each active frontier slot, -1 idle
    sample_slot: jnp.ndarray   # [k, N] frontier slot of each sample, -1 parked
    rng: jnp.ndarray           # PRNGKey (reserved for stochastic split policies)
    level: jnp.ndarray         # scalar int32 — next level to grow
    # Sibling-subtraction histogram cache (``config.hist_reuse``): the
    # previous level's post-combine per-slot histograms in rank-paired
    # row order plus the slot->row permutation and the next level's
    # parent/small-side tables (see ``engine.resolve_hist_reuse`` /
    # ``histograms.sibling_expand``). ``None`` when reuse is off — a
    # None leaf is an empty pytree, so off-mode states, jaxprs and
    # checkpoints are unchanged. As a pytree leaf the cache rides every
    # carry (``lax.while_loop``, jit boundaries, ``CheckpointManager``),
    # which is what keeps ``resume_from`` bit-identical with reuse on.
    hist_cache: Optional[dict] = None
