"""OOB-weighted voting (paper §3.3, Eq. 8-10).

After training, each tree h_i is evaluated on its own Out-Of-Bag set
OOB_i; the classification accuracy CA_i (Eq. 8) becomes the tree's voting
weight w_i. Prediction then takes the weighted majority (Eq. 10) or the
weighted regression average (Eq. 9).

Prediction has two backends, selected by ``ForestConfig.predict_backend``
and dispatched by ``predict`` / ``predict_regression`` / the score-level
``predict_scores``:

* ``"xla"``    — ``route_to_leaves`` + ``weighted_vote`` over the full
  ``[k, N, C]`` per-tree probability tensor (portable oracle);
* ``"pallas"`` — the fused traversal+voting kernel
  (``kernels/tree_traverse``): the depth walk runs in VMEM and the
  weighted vote accumulates across the tree grid axis, so only the
  ``[N, C]`` scores ever exist;
* ``"auto"``   — ``pallas`` on TPU, else ``xla``.

Both backends vote with the same per-leaf payloads (``leaf_vote_payload``
/ ``leaf_value_payload``: tree weight folded into the per-node vote
vector), so predicted labels are identical across backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .forest import fused_vote_scores, predict_proba_trees, predict_value_trees
from .types import Forest

PREDICT_BACKENDS = ("auto", "pallas", "xla")


def resolve_predict_backend(backend: str) -> str:
    """'auto' -> 'pallas' on TPU, 'xla' elsewhere."""
    if backend not in PREDICT_BACKENDS:
        raise ValueError(
            f"predict_backend={backend!r} not in {PREDICT_BACKENDS}"
        )
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def oob_accuracy(
    forest: Forest, x_binned: jnp.ndarray, y: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Eq. (8): CA_i = #correct / (#correct + #error) over OOB_i.

    Args:
      weights: [k, N] in-bag multiplicities (0 => sample is OOB for tree).
    Returns: [k] float32 accuracies. A tree whose OOB set is empty (every
    sample in-bag — possible under the DSI bootstrap) has no evidence
    either way and gets the **neutral prior 0.5**, never a degenerate
    0/0.
    """
    probs = predict_proba_trees(forest, x_binned)          # [k, N, C]
    pred = jnp.argmax(probs, axis=-1)                      # [k, N]
    oob = (weights == 0.0).astype(jnp.float32)             # [k, N]
    correct = jnp.sum(oob * (pred == y[None]).astype(jnp.float32), axis=1)
    total = jnp.sum(oob, axis=1)
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def oob_r2(forest, x_binned, y, weights):
    """Regression analogue of Eq. (8): per-tree OOB R^2 clipped to [0, 1].

    Degenerate OOB sets get the same **neutral prior 0.5** as
    ``oob_accuracy`` — both when the OOB set is empty (previously the
    0/eps arithmetic silently produced a confident 1.0) and when its
    target variance is zero (R^2 undefined; the clip used to hide the
    garbage ratio). Only a tree with real OOB evidence earns a
    non-neutral weight.
    """
    vals = predict_value_trees(forest, x_binned)           # [k, N]
    oob = (weights == 0.0).astype(jnp.float32)
    total = oob.sum(1)
    n = jnp.maximum(total, 1.0)
    err = jnp.sum(oob * (vals - y[None]) ** 2, axis=1) / n
    mean = jnp.sum(oob * y[None], axis=1) / n
    var = jnp.sum(oob * (y[None] - mean[:, None]) ** 2, axis=1) / n
    r2 = jnp.clip(1.0 - err / jnp.maximum(var, 1e-38), 0.0, 1.0)
    return jnp.where((total > 0) & (var > 0), r2, 0.5)


def weighted_vote(
    probs: jnp.ndarray, tree_weight: jnp.ndarray, *, soft: bool = False
) -> jnp.ndarray:
    """Eq. (10): H_c(X) = Majority_i [ w_i x h_i(x) ].

    Args:
      probs: [k, N, C] per-tree class distributions.
      tree_weight: [k] w_i = CA_i (or ones for the unweighted baseline).
      soft: weight the full distribution instead of the argmax vote
            (a strictly-stronger variant; the paper's Eq. 10 is hard).
    Returns: scores [N, C]; argmax is the predicted class.
    """
    w = tree_weight[:, None, None]
    if soft:
        return jnp.sum(w * probs, axis=0)
    votes = jax.nn.one_hot(jnp.argmax(probs, -1), probs.shape[-1], dtype=probs.dtype)
    return jnp.sum(w * votes, axis=0)


def weighted_regression(
    values: jnp.ndarray, tree_weight: jnp.ndarray, *, faithful_eq9: bool = False
) -> jnp.ndarray:
    """Eq. (9): H_r(X) = (1/k) sum_i w_i * h_i(x).

    The literal Eq. (9) divides by k, which biases the magnitude whenever
    sum(w) != k; the default normalizes by sum(w) (the standard weighted
    mean). ``faithful_eq9=True`` reproduces the paper exactly.
    """
    w = tree_weight[:, None]
    if faithful_eq9:
        return jnp.mean(w * values, axis=0)
    return jnp.sum(w * values, axis=0) / jnp.maximum(tree_weight.sum(), 1e-38)


# ---------------------------------------------------------------------------
# Leaf payloads — the fused backend's vote vectors (weight folded in)
# ---------------------------------------------------------------------------


def leaf_vote_payload(
    forest: Forest, tree_weight: jnp.ndarray, *, soft: bool = False
) -> jnp.ndarray:
    """Per-(tree, node) classification vote vectors, weight folded in.

    ``payload[t, p] = w_t * onehot(argmax_c probs[t, p])`` (hard,
    Eq. 10) or ``w_t * probs[t, p]`` (soft), where ``probs`` are the
    node's normalized class counts — exactly what the xla path computes
    per *leaf*, precomputed for every pool node so the fused kernel is
    a pure traversal + payload gather. [k, P, C] float32.
    """
    counts = forest.class_counts
    total = counts.sum(-1, keepdims=True)
    # Zero-mass pool slots (the scatter pad, never-allocated bands) vote
    # zero. The unguarded 0 / maximum(0, 1e-38) is NaN — 1e-38 is a
    # subnormal f32 that XLA flushes to zero — and the fused kernel's
    # one-hot matmul reads EVERY pool row (0 * NaN poisons the scores);
    # the xla path only gathers reachable leaves, where total > 0 makes
    # the two normalizations identical.
    probs = jnp.where(total > 0, counts / jnp.maximum(total, 1e-38), 0.0)
    if soft:
        vote = probs
    else:
        vote = jnp.where(
            total > 0,
            jax.nn.one_hot(
                jnp.argmax(probs, -1), probs.shape[-1], dtype=jnp.float32
            ),
            0.0,
        )
    return tree_weight[:, None, None] * vote


def leaf_value_payload(forest: Forest, tree_weight: jnp.ndarray) -> jnp.ndarray:
    """Per-(tree, node) weighted regression values, [k, P, 1] float32.

    ``payload[t, p, 0] = w_t * value[t, p]`` — the Eq. (9) numerator;
    the ``/ sum_i w_i`` normalization happens on the [N] result.
    Zero-mass pool slots get a zero payload (see ``leaf_vote_payload``:
    the fused kernel requires finite payloads at every pool row).
    """
    mass = forest.class_counts[..., 0]          # regression count channel
    value = jnp.where(mass > 0, forest.value, 0.0)
    return (tree_weight[:, None] * value)[..., None]


# ---------------------------------------------------------------------------
# Backend-dispatched prediction
# ---------------------------------------------------------------------------


def _vote_weights(forest: Forest) -> jnp.ndarray:
    return (
        forest.tree_weight
        if forest.config.weighted_voting
        else jnp.ones_like(forest.tree_weight)
    )


def build_payload(forest: Forest) -> jnp.ndarray:
    """The forest's vote payload under its own config — the ONE place
    that maps (regression, soft_voting, weighted_voting) to a payload
    (used by the serving layer's direct and tree-sharded paths)."""
    w = _vote_weights(forest)
    if forest.config.regression:
        return leaf_value_payload(forest, w)
    return leaf_vote_payload(forest, w, soft=forest.config.soft_voting)


@jax.jit
def _fused_class_scores(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """jit'd pallas-backend scores: payload construction is traced into
    the same compiled program as the traversal, so a predict call does
    no eager per-request O(k*P*C) work."""
    payload = leaf_vote_payload(
        forest, _vote_weights(forest), soft=forest.config.soft_voting
    )
    return fused_vote_scores(forest, x_binned, payload)


@jax.jit
def _fused_value_scores(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    payload = leaf_value_payload(forest, _vote_weights(forest))
    return fused_vote_scores(forest, x_binned, payload)[:, 0]


def predict_scores(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Weighted-vote class scores [N, C] (argmax = predicted label).

    Dispatches on ``backend`` (default ``forest.config.predict_backend``):
    the fused pallas path never materializes the ``[k, N, C]`` per-tree
    tensor; the xla path is the portable oracle.
    """
    backend = resolve_predict_backend(
        backend if backend is not None else forest.config.predict_backend
    )
    if backend == "pallas":
        return _fused_class_scores(forest, x_binned)
    probs = predict_proba_trees(forest, x_binned)
    return weighted_vote(probs, _vote_weights(forest), soft=forest.config.soft_voting)


def predict_regression_scores(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Unnormalized Eq. (9) numerator ``sum_i w_i h_i(x)`` as [N]."""
    backend = resolve_predict_backend(
        backend if backend is not None else forest.config.predict_backend
    )
    if backend == "pallas":
        return _fused_value_scores(forest, x_binned)
    vals = predict_value_trees(forest, x_binned)
    return jnp.sum(_vote_weights(forest)[:, None] * vals, axis=0)


def predict(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Full PRF prediction (classification): weighted majority class [N]."""
    return jnp.argmax(predict_scores(forest, x_binned, backend=backend), axis=-1)


def predict_regression(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Full PRF regression prediction: weighted mean of h_i(x), [N]."""
    num = predict_regression_scores(forest, x_binned, backend=backend)
    return num / jnp.maximum(_vote_weights(forest).sum(), 1e-38)
