"""OOB-weighted voting (paper §3.3, Eq. 8-10).

After training, each tree h_i is evaluated on its own Out-Of-Bag set
OOB_i; the classification accuracy CA_i (Eq. 8) becomes the tree's voting
weight w_i. Prediction then takes the weighted majority (Eq. 10) or the
weighted regression average (Eq. 9).

Prediction has two backends, selected by ``ForestConfig.predict_backend``
and dispatched by ``predict`` / ``predict_regression`` / the score-level
``predict_scores``:

* ``"xla"``    — ``route_to_leaves`` + ``weighted_vote`` over the full
  ``[k, N, C]`` per-tree probability tensor (portable oracle);
* ``"pallas"`` — the fused traversal+voting kernel
  (``kernels/tree_traverse``): the depth walk runs in VMEM and the
  weighted vote accumulates across the tree grid axis, so only the
  ``[N, C]`` scores ever exist;
* ``"auto"``   — ``pallas`` on TPU, else ``xla``.

Both backends vote with the same per-leaf payloads (``leaf_vote_payload``
/ ``leaf_value_payload``: tree weight folded into the per-node vote
vector), so predicted labels are identical across backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .forest import fused_vote_scores, predict_proba_trees, predict_value_trees
from .types import Forest

PREDICT_BACKENDS = ("auto", "pallas", "xla")


def resolve_predict_backend(backend: str) -> str:
    """'auto' -> 'pallas' on TPU, 'xla' elsewhere."""
    if backend not in PREDICT_BACKENDS:
        raise ValueError(
            f"predict_backend={backend!r} not in {PREDICT_BACKENDS}"
        )
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def oob_accuracy(
    forest: Forest, x_binned: jnp.ndarray, y: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Eq. (8): CA_i = #correct / (#correct + #error) over OOB_i.

    Args:
      weights: [k, N] in-bag multiplicities (0 => sample is OOB for tree).
    Returns: [k] float32 accuracies. A tree whose OOB set is empty (every
    sample in-bag — possible under the DSI bootstrap) has no evidence
    either way and gets the **neutral prior 0.5**, never a degenerate
    0/0.
    """
    probs = predict_proba_trees(forest, x_binned)          # [k, N, C]
    pred = jnp.argmax(probs, axis=-1)                      # [k, N]
    oob = (weights == 0.0).astype(jnp.float32)             # [k, N]
    correct = jnp.sum(oob * (pred == y[None]).astype(jnp.float32), axis=1)
    total = jnp.sum(oob, axis=1)
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def oob_r2(forest, x_binned, y, weights):
    """Regression analogue of Eq. (8): per-tree OOB R^2 clipped to [0, 1].

    Degenerate OOB sets get the same **neutral prior 0.5** as
    ``oob_accuracy`` — both when the OOB set is empty (previously the
    0/eps arithmetic silently produced a confident 1.0) and when its
    target variance is zero (R^2 undefined; the clip used to hide the
    garbage ratio). Only a tree with real OOB evidence earns a
    non-neutral weight.

    The sample reduction runs on HOST in float64 over per-sample f32
    moment terms (``_r2_block_terms`` — the same jitted kernel the
    streamed path folds per block), then one final float32 cast. That
    makes ``oob_r2`` and ``oob_r2_streamed`` **bit-identical**: the
    per-sample terms are batch-shape independent, and the float64
    accumulations (one-shot pairwise here, Neumaier-compensated across
    blocks there) agree to well under a float32 ulp before the cast.
    """
    y32 = jnp.asarray(y, jnp.float32)
    w32 = jnp.asarray(weights, jnp.float32)
    sum_y, total = _r2_mean_stats(y32, w32)
    mean = sum_y / jnp.maximum(total, 1.0)
    err_t, var_t = _r2_block_terms(forest, x_binned, y32, w32, mean)
    return _r2_finalize(
        np.asarray(err_t, np.float64).sum(axis=1),
        np.asarray(var_t, np.float64).sum(axis=1),
        np.asarray(total, np.float64),
    )


def weighted_vote(
    probs: jnp.ndarray, tree_weight: jnp.ndarray, *, soft: bool = False
) -> jnp.ndarray:
    """Eq. (10): H_c(X) = Majority_i [ w_i x h_i(x) ].

    Args:
      probs: [k, N, C] per-tree class distributions.
      tree_weight: [k] w_i = CA_i (or ones for the unweighted baseline).
      soft: weight the full distribution instead of the argmax vote
            (a strictly-stronger variant; the paper's Eq. 10 is hard).
    Returns: scores [N, C]; argmax is the predicted class.
    """
    w = tree_weight[:, None, None]
    if soft:
        return jnp.sum(w * probs, axis=0)
    votes = jax.nn.one_hot(jnp.argmax(probs, -1), probs.shape[-1], dtype=probs.dtype)
    return jnp.sum(w * votes, axis=0)


def weighted_regression(
    values: jnp.ndarray, tree_weight: jnp.ndarray, *, faithful_eq9: bool = False
) -> jnp.ndarray:
    """Eq. (9): H_r(X) = (1/k) sum_i w_i * h_i(x).

    The literal Eq. (9) divides by k, which biases the magnitude whenever
    sum(w) != k; the default normalizes by sum(w) (the standard weighted
    mean). ``faithful_eq9=True`` reproduces the paper exactly.
    """
    w = tree_weight[:, None]
    if faithful_eq9:
        return jnp.mean(w * values, axis=0)
    return jnp.sum(w * values, axis=0) / jnp.maximum(tree_weight.sum(), 1e-38)


# ---------------------------------------------------------------------------
# Streamed OOB + prediction — the sample-block carriers of the data plane
# ---------------------------------------------------------------------------


def _block_feeder(x_binned, sample_block, prefetch, *, what,
                  n_y=None, n_w=None):
    """BlockFeeder over a validated block list (``pipeline.stream_blocks``:
    explicit sequences pass through — device arrays included — array
    sources require ``sample_block > 0``, and blocks must cover the
    caller's label/weight lengths when given)."""
    from ..data.pipeline import BlockFeeder, stream_blocks

    return BlockFeeder(
        stream_blocks(x_binned, sample_block, what=what, n_y=n_y, n_w=n_w),
        prefetch=prefetch,
    )


@jax.jit
def _oob_block_counts(forest: Forest, xb_b, y_b, w_b):
    """One block's contribution to Eq. (8): (#correct, #OOB) per tree."""
    probs = predict_proba_trees(forest, xb_b)              # [k, Nb, C]
    pred = jnp.argmax(probs, axis=-1)
    oob = (w_b == 0.0).astype(jnp.float32)
    correct = jnp.sum(oob * (pred == y_b[None]).astype(jnp.float32), axis=1)
    return correct, jnp.sum(oob, axis=1)


def oob_accuracy_streamed(
    forest: Forest, x_binned, y, weights, *,
    sample_block: int | None = None, prefetch: int = 2,
) -> jnp.ndarray:
    """Eq. (8) accumulated over sample blocks — the full binned matrix is
    never device-resident. ``#correct`` and ``#OOB`` are sums of 0/1
    floats (exact f32 integers), so the blocked accumulation is
    **bit-identical** to the resident ``oob_accuracy``."""
    y_np = np.asarray(y)
    w_np = np.asarray(weights, dtype=np.float32)
    feeder = _block_feeder(
        x_binned, sample_block, prefetch, what="oob_accuracy_streamed",
        n_y=y_np.shape[0], n_w=w_np.shape[1],
    )
    k = w_np.shape[0]
    correct = jnp.zeros((k,), jnp.float32)
    total = jnp.zeros((k,), jnp.float32)
    o = 0
    with feeder:
        for xb_b in feeder.sweep():
            n = xb_b.shape[0]
            c, t = _oob_block_counts(
                forest, xb_b, feeder.pin(y_np[o:o + n]),
                feeder.pin(w_np[:, o:o + n]),
            )
            correct, total = correct + c, total + t
            o += n
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


@jax.jit
def _r2_mean_stats(y, w):
    """The OOB mean's sufficient statistics — needs y/weights only, so
    it runs on the full [k, N] arrays exactly like the resident path
    (same one-shot jnp sums, no feature block ever touched)."""
    oob = (w == 0.0).astype(jnp.float32)
    return jnp.sum(oob * y[None], axis=1), oob.sum(1)


@jax.jit
def _r2_block_terms(forest: Forest, xb_b, y_b, w_b, mean):
    """Per-sample OOB squared-error / variance terms for one block,
    [k, Nb] each. Tree traversal and the moment arithmetic are
    per-sample elementwise, so each term is bit-identical whether the
    block is the whole dataset or one slice of it — the same
    batch-shape independence the streamed predict parity rests on. The
    sample reduction deliberately does NOT happen on device: both
    ``oob_r2`` paths reduce the terms on host in float64."""
    vals = predict_value_trees(forest, xb_b)               # [k, Nb]
    oob = (w_b == 0.0).astype(jnp.float32)
    err_t = oob * (vals - y_b[None]) ** 2
    var_t = oob * (y_b[None] - mean[:, None]) ** 2
    return err_t, var_t


def _neumaier_add(s: np.ndarray, c: np.ndarray, x: np.ndarray) -> None:
    """One Neumaier-compensated accumulation step, in place: ``s += x``
    with the rounding error banked in the running compensation ``c``
    (all float64 [k]). The true sum is ``s + c``."""
    t = s + x
    c += np.where(np.abs(s) >= np.abs(x), (s - t) + x, (x - t) + s)
    s[:] = t


def _r2_finalize(err_sum, var_sum, total) -> jnp.ndarray:
    """R^2 from the float64 moment sums (np.float64 [k] each): the
    whole formula evaluates in float64, then ONE cast to float32 — the
    only rounding either oob_r2 path performs after the per-sample
    terms. Neutral prior 0.5 for degenerate OOB sets."""
    n = np.maximum(total, 1.0)
    err = err_sum / n
    var = var_sum / n
    r2 = np.clip(1.0 - err / np.maximum(var, 1e-300), 0.0, 1.0)
    out = np.where((total > 0) & (var_sum > 0), r2, 0.5)
    return jnp.asarray(out.astype(np.float32))


def oob_r2_streamed(
    forest: Forest, x_binned, y, weights, *,
    sample_block: int | None = None, prefetch: int = 2,
) -> jnp.ndarray:
    """Blocked ``oob_r2``: ONE sweep over the feature blocks. The OOB
    mean needs only ``y``/``weights`` (computed with the resident
    path's one-shot sums — no block feed), so only the moment pass
    streams the ``[Nb, F]`` blocks. Per-block float64 partial sums are
    folded with Neumaier compensation, so the result is
    **bit-identical** to the resident ``oob_r2`` (see its docstring;
    tests/test_engine.py pins the equality)."""
    y_np = np.asarray(y, dtype=np.float32)
    w_np = np.asarray(weights, dtype=np.float32)
    feeder = _block_feeder(
        x_binned, sample_block, prefetch, what="oob_r2_streamed",
        n_y=y_np.shape[0], n_w=w_np.shape[1],
    )
    sum_y, total = _r2_mean_stats(jnp.asarray(y_np), jnp.asarray(w_np))
    mean = sum_y / jnp.maximum(total, 1.0)

    k = w_np.shape[0]
    err_sum, err_c = np.zeros(k, np.float64), np.zeros(k, np.float64)
    var_sum, var_c = np.zeros(k, np.float64), np.zeros(k, np.float64)
    o = 0
    with feeder:
        for xb_b in feeder.sweep():
            nb = xb_b.shape[0]
            err_t, var_t = _r2_block_terms(
                forest, xb_b, feeder.pin(y_np[o:o + nb]),
                feeder.pin(w_np[:, o:o + nb]), mean,
            )
            _neumaier_add(err_sum, err_c, np.asarray(err_t, np.float64).sum(1))
            _neumaier_add(var_sum, var_c, np.asarray(var_t, np.float64).sum(1))
            o += nb
    return _r2_finalize(
        err_sum + err_c, var_sum + var_c, np.asarray(total, np.float64)
    )


def predict_scores_streamed(
    forest: Forest, x_binned, *, sample_block: int | None = None,
    backend: str | None = None, prefetch: int = 2,
) -> jnp.ndarray:
    """``predict_scores`` over sample blocks. Scores are per-sample, so
    the blocked path is bit-identical to the resident call; only the
    [N, C] score matrix (never [N, F]) is materialized."""
    feeder = _block_feeder(
        x_binned, sample_block, prefetch, what="predict_scores_streamed"
    )
    with feeder:
        return jnp.concatenate([
            predict_scores(forest, xb_b, backend=backend)
            for xb_b in feeder.sweep()
        ])


def predict_streamed(
    forest: Forest, x_binned, *, sample_block: int | None = None,
    backend: str | None = None, prefetch: int = 2,
) -> jnp.ndarray:
    """Streamed classification labels [N] (bit-identical to ``predict``)."""
    return jnp.argmax(
        predict_scores_streamed(
            forest, x_binned, sample_block=sample_block, backend=backend,
            prefetch=prefetch,
        ),
        axis=-1,
    )


def predict_regression_streamed(
    forest: Forest, x_binned, *, sample_block: int | None = None,
    backend: str | None = None, prefetch: int = 2,
) -> jnp.ndarray:
    """Streamed regression predictions [N] (per-sample, so bit-identical
    to ``predict_regression``)."""
    feeder = _block_feeder(
        x_binned, sample_block, prefetch, what="predict_regression_streamed"
    )
    with feeder:
        num = jnp.concatenate([
            predict_regression_scores(forest, xb_b, backend=backend)
            for xb_b in feeder.sweep()
        ])
    return num / jnp.maximum(_vote_weights(forest).sum(), 1e-38)


# ---------------------------------------------------------------------------
# Leaf payloads — the fused backend's vote vectors (weight folded in)
# ---------------------------------------------------------------------------


def leaf_vote_payload(
    forest: Forest, tree_weight: jnp.ndarray, *, soft: bool = False
) -> jnp.ndarray:
    """Per-(tree, node) classification vote vectors, weight folded in.

    ``payload[t, p] = w_t * onehot(argmax_c probs[t, p])`` (hard,
    Eq. 10) or ``w_t * probs[t, p]`` (soft), where ``probs`` are the
    node's normalized class counts — exactly what the xla path computes
    per *leaf*, precomputed for every pool node so the fused kernel is
    a pure traversal + payload gather. [k, P, C] float32.
    """
    counts = forest.class_counts
    total = counts.sum(-1, keepdims=True)
    # Zero-mass pool slots (the scatter pad, never-allocated bands) vote
    # zero. The unguarded 0 / maximum(0, 1e-38) is NaN — 1e-38 is a
    # subnormal f32 that XLA flushes to zero — and the fused kernel's
    # one-hot matmul reads EVERY pool row (0 * NaN poisons the scores);
    # the xla path only gathers reachable leaves, where total > 0 makes
    # the two normalizations identical.
    probs = jnp.where(total > 0, counts / jnp.maximum(total, 1e-38), 0.0)
    if soft:
        vote = probs
    else:
        vote = jnp.where(
            total > 0,
            jax.nn.one_hot(
                jnp.argmax(probs, -1), probs.shape[-1], dtype=jnp.float32
            ),
            0.0,
        )
    return tree_weight[:, None, None] * vote


def leaf_value_payload(forest: Forest, tree_weight: jnp.ndarray) -> jnp.ndarray:
    """Per-(tree, node) weighted regression values, [k, P, 1] float32.

    ``payload[t, p, 0] = w_t * value[t, p]`` — the Eq. (9) numerator;
    the ``/ sum_i w_i`` normalization happens on the [N] result.
    Zero-mass pool slots get a zero payload (see ``leaf_vote_payload``:
    the fused kernel requires finite payloads at every pool row).
    """
    mass = forest.class_counts[..., 0]          # regression count channel
    value = jnp.where(mass > 0, forest.value, 0.0)
    return (tree_weight[:, None] * value)[..., None]


# ---------------------------------------------------------------------------
# Backend-dispatched prediction
# ---------------------------------------------------------------------------


def _vote_weights(forest: Forest) -> jnp.ndarray:
    return (
        forest.tree_weight
        if forest.config.weighted_voting
        else jnp.ones_like(forest.tree_weight)
    )


def build_payload(forest: Forest) -> jnp.ndarray:
    """The forest's vote payload under its own config — the ONE place
    that maps (regression, soft_voting, weighted_voting) to a payload
    (used by the serving layer's direct and tree-sharded paths)."""
    w = _vote_weights(forest)
    if forest.config.regression:
        return leaf_value_payload(forest, w)
    return leaf_vote_payload(forest, w, soft=forest.config.soft_voting)


@jax.jit
def _fused_class_scores(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """jit'd pallas-backend scores: payload construction is traced into
    the same compiled program as the traversal, so a predict call does
    no eager per-request O(k*P*C) work."""
    payload = leaf_vote_payload(
        forest, _vote_weights(forest), soft=forest.config.soft_voting
    )
    return fused_vote_scores(forest, x_binned, payload)


@jax.jit
def _fused_value_scores(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    payload = leaf_value_payload(forest, _vote_weights(forest))
    return fused_vote_scores(forest, x_binned, payload)[:, 0]


def predict_scores(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Weighted-vote class scores [N, C] (argmax = predicted label).

    Dispatches on ``backend`` (default ``forest.config.predict_backend``):
    the fused pallas path never materializes the ``[k, N, C]`` per-tree
    tensor; the xla path is the portable oracle.
    """
    backend = resolve_predict_backend(
        backend if backend is not None else forest.config.predict_backend
    )
    if backend == "pallas":
        return _fused_class_scores(forest, x_binned)
    probs = predict_proba_trees(forest, x_binned)
    return weighted_vote(probs, _vote_weights(forest), soft=forest.config.soft_voting)


def predict_regression_scores(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Unnormalized Eq. (9) numerator ``sum_i w_i h_i(x)`` as [N]."""
    backend = resolve_predict_backend(
        backend if backend is not None else forest.config.predict_backend
    )
    if backend == "pallas":
        return _fused_value_scores(forest, x_binned)
    vals = predict_value_trees(forest, x_binned)
    return jnp.sum(_vote_weights(forest)[:, None] * vals, axis=0)


def predict(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Full PRF prediction (classification): weighted majority class [N]."""
    return jnp.argmax(predict_scores(forest, x_binned, backend=backend), axis=-1)


def predict_regression(
    forest: Forest, x_binned: jnp.ndarray, *, backend: str | None = None
) -> jnp.ndarray:
    """Full PRF regression prediction: weighted mean of h_i(x), [N]."""
    num = predict_regression_scores(forest, x_binned, backend=backend)
    return num / jnp.maximum(_vote_weights(forest).sum(), 1e-38)
