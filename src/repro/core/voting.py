"""OOB-weighted voting (paper §3.3, Eq. 8-10).

After training, each tree h_i is evaluated on its own Out-Of-Bag set
OOB_i; the classification accuracy CA_i (Eq. 8) becomes the tree's voting
weight w_i. Prediction then takes the weighted majority (Eq. 10) or the
weighted regression average (Eq. 9).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .forest import predict_proba_trees, predict_value_trees
from .types import Forest


def oob_accuracy(
    forest: Forest, x_binned: jnp.ndarray, y: jnp.ndarray, weights: jnp.ndarray
) -> jnp.ndarray:
    """Eq. (8): CA_i = #correct / (#correct + #error) over OOB_i.

    Args:
      weights: [k, N] in-bag multiplicities (0 => sample is OOB for tree).
    Returns: [k] float32 accuracies (0.5 prior when OOB set is empty).
    """
    probs = predict_proba_trees(forest, x_binned)          # [k, N, C]
    pred = jnp.argmax(probs, axis=-1)                      # [k, N]
    oob = (weights == 0.0).astype(jnp.float32)             # [k, N]
    correct = jnp.sum(oob * (pred == y[None]).astype(jnp.float32), axis=1)
    total = jnp.sum(oob, axis=1)
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def oob_r2(forest, x_binned, y, weights):
    """Regression analogue of Eq. (8): per-tree OOB R^2 clipped to [0, 1]."""
    vals = predict_value_trees(forest, x_binned)           # [k, N]
    oob = (weights == 0.0).astype(jnp.float32)
    n = jnp.maximum(oob.sum(1), 1.0)
    err = jnp.sum(oob * (vals - y[None]) ** 2, axis=1) / n
    mean = jnp.sum(oob * y[None], axis=1) / n
    var = jnp.sum(oob * (y[None] - mean[:, None]) ** 2, axis=1) / n
    return jnp.clip(1.0 - err / jnp.maximum(var, 1e-38), 0.0, 1.0)


def weighted_vote(
    probs: jnp.ndarray, tree_weight: jnp.ndarray, *, soft: bool = False
) -> jnp.ndarray:
    """Eq. (10): H_c(X) = Majority_i [ w_i x h_i(x) ].

    Args:
      probs: [k, N, C] per-tree class distributions.
      tree_weight: [k] w_i = CA_i (or ones for the unweighted baseline).
      soft: weight the full distribution instead of the argmax vote
            (a strictly-stronger variant; the paper's Eq. 10 is hard).
    Returns: scores [N, C]; argmax is the predicted class.
    """
    w = tree_weight[:, None, None]
    if soft:
        return jnp.sum(w * probs, axis=0)
    votes = jax.nn.one_hot(jnp.argmax(probs, -1), probs.shape[-1], dtype=probs.dtype)
    return jnp.sum(w * votes, axis=0)


def weighted_regression(
    values: jnp.ndarray, tree_weight: jnp.ndarray, *, faithful_eq9: bool = False
) -> jnp.ndarray:
    """Eq. (9): H_r(X) = (1/k) sum_i w_i * h_i(x).

    The literal Eq. (9) divides by k, which biases the magnitude whenever
    sum(w) != k; the default normalizes by sum(w) (the standard weighted
    mean). ``faithful_eq9=True`` reproduces the paper exactly.
    """
    w = tree_weight[:, None]
    if faithful_eq9:
        return jnp.mean(w * values, axis=0)
    return jnp.sum(w * values, axis=0) / jnp.maximum(tree_weight.sum(), 1e-38)


def predict(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """Full PRF prediction (classification): weighted majority class [N]."""
    probs = predict_proba_trees(forest, x_binned)
    w = forest.tree_weight if forest.config.weighted_voting else jnp.ones_like(
        forest.tree_weight
    )
    scores = weighted_vote(probs, w, soft=forest.config.soft_voting)
    return jnp.argmax(scores, axis=-1)


def predict_regression(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    vals = predict_value_trees(forest, x_binned)
    w = forest.tree_weight if forest.config.weighted_voting else jnp.ones_like(
        forest.tree_weight
    )
    return weighted_regression(vals, w)
