"""Data-Sampling-Index (DSI) table — the paper's data-multiplexing method (§4.1.2).

The paper's key data-parallel idea: bootstrap sampling never copies data.
A k x N table of sample indexes is broadcast once; every tree's tasks read
the *same* feature subsets through it, so the training-data volume is flat
in the ensemble size k (paper Fig. 14).

On TPU we push the idea one step further: histogram-based training only
needs *how many times* each sample was drawn, so the DSI table collapses
into a ``counts[k, N]`` in-bag weight matrix. The binned dataset is the
single shared copy (N*M bytes); ensemble growth costs k*N extra bytes of
weights — strictly better than the paper's 2*N*M bound (§4.3.2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("n_trees", "n_samples"))
def make_dsi(key: jax.Array, n_trees: int, n_samples: int) -> jnp.ndarray:
    """Bootstrap index table: [k, N] int32, rows i.i.d. uniform with replacement."""
    return jax.random.randint(key, (n_trees, n_samples), 0, n_samples, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("n_samples",))
def dsi_counts(dsi: jnp.ndarray, n_samples: int) -> jnp.ndarray:
    """Collapse a DSI table into in-bag multiplicity weights.

    Returns counts [k, N] float32; counts[t, i] = #{j : dsi[t, j] == i}.
    """

    def _one(row):
        return jnp.zeros((n_samples,), jnp.float32).at[row].add(1.0)

    return jax.vmap(_one)(dsi)


def oob_mask(counts: jnp.ndarray) -> jnp.ndarray:
    """Out-Of-Bag mask [k, N] bool — samples never drawn by tree t (paper §3.1)."""
    return counts == 0.0


@partial(jax.jit, static_argnames=("n_trees", "n_samples"))
def bootstrap_counts(key: jax.Array, n_trees: int, n_samples: int) -> jnp.ndarray:
    """Fused make_dsi + dsi_counts (never materializes the index table)."""
    dsi = make_dsi(key, n_trees, n_samples)
    return dsi_counts(dsi, n_samples)
