"""The paper's comparison algorithms (§5): original RF and Spark-MLRF-like.

* ``train_rf``       — Breiman RF as the paper describes it (§3.1): per-tree
  bootstrap with *copied* sampled data (volume k*N*M), m features selected
  uniformly per tree, unweighted majority voting.
* ``train_mlrf_like`` — Spark MLlib RF's accuracy-relevant deviation: split
  candidates come from a *sampled subset* of the data (MLlib samples each
  partition to pick split thresholds). We emulate it by fitting bin edges
  on a fixed ``sample_budget`` subsample — as N grows with a fixed budget,
  quantile quality drops and accuracy decays, reproducing the paper's
  Fig. 9 observation ("the ratio of the random selection increases, and
  the accuracy of Spark-MLRF decreases inevitably").

Both reuse the PRF growth engine (the tree math is identical — the paper's
algorithms differ in sampling, feature selection, voting and data motion,
not in the split criterion).

``data_volume_bytes`` implements the §4.3.2 volume model for Fig. 14.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .api import PRFModel, train_prf
from .binning import fit_bins, apply_bins
from .dsi import bootstrap_counts
from .dimred import random_feature_mask
from .forest import grow_forest
from .types import ForestConfig


def train_rf(x: np.ndarray, y: np.ndarray, config: ForestConfig, seed: int = 0) -> PRFModel:
    """Original RF baseline: random per-tree features, plain majority vote."""
    cfg = dataclasses.replace(
        config, feature_mode="random", weighted_voting=False
    )
    return train_prf(x, y, cfg, seed=seed)


def train_mlrf_like(
    x: np.ndarray,
    y: np.ndarray,
    config: ForestConfig,
    seed: int = 0,
    sample_budget: int = 2000,
) -> PRFModel:
    """Spark-MLRF-style: split thresholds from a bounded random subsample."""
    cfg = dataclasses.replace(
        config, feature_mode="random", weighted_voting=False
    ).resolved(x.shape[1])
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.choice(n, size=min(sample_budget, n), replace=False)
    edges = fit_bins(x[idx], cfg.n_bins)             # <- sampled split candidates
    xb = apply_bins(jnp.asarray(x), jnp.asarray(edges))

    key = jax.random.PRNGKey(seed)
    k_boot, k_feat = jax.random.split(key)
    weights = bootstrap_counts(k_boot, cfg.n_trees, n)
    mask = random_feature_mask(
        k_feat, n_trees=cfg.n_trees, n_features=x.shape[1], n_selected=cfg.n_selected
    )
    forest = grow_forest(xb, jnp.asarray(y), weights, cfg, mask)
    return PRFModel(forest=forest, bin_edges=edges)


# ---------------------------------------------------------------------------
# Analytical data-volume model (paper §4.3.2 / Fig. 14)
# ---------------------------------------------------------------------------


def data_volume_bytes(
    algorithm: str, n_samples: int, n_features: int, n_trees: int,
    value_bytes: int = 8,
) -> int:
    """Training-set volume each algorithm materializes.

    paper: RF & Spark-MLRF sample *copies* -> N*M*k; PRF keeps one vertical
    copy + DSI -> ~2*N*M flat in k. Our TPU PRF goes further: one binned
    copy (N*M uint8) + k*N float32 in-bag counts.
    """
    N, M, k = n_samples, n_features, n_trees
    if algorithm in ("rf", "spark-mlrf"):
        return N * M * k * value_bytes
    if algorithm == "prf-paper":                     # vertical FS_j = <idx, y_j, y_target>
        return 2 * N * M * value_bytes
    if algorithm == "prf-tpu":                       # binned matrix + DSI counts
        return N * M * 1 + k * N * 4
    raise ValueError(algorithm)
