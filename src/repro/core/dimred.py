"""Dimension reduction in the training process (paper §3.2, Alg. 3.1).

Per tree (training subset S_i):
  1. gain ratio GR(y_ij) of every feature on the bootstrap sample (Eq. 2-6,
     multiway/faithful form over the feature's value set);
  2. variable importance VI = GR / sum(GR) (Eq. 7);
  3. keep the top ``k_imp`` features deterministically;
  4. draw ``m - k_imp`` more uniformly from the remaining ``M - k_imp``.

The result is a boolean feature mask per tree; growth never considers
masked features, reducing the effective dimensionality M -> m while
keeping the top-importance features always in play (the paper's balance
of "accuracy and diversity").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .gain import multiway_gain_ratio, variable_importance
from .histograms import class_channels, hist_feature_slab, level_histograms
from .types import ForestConfig


def root_gain_ratios(
    x_binned: jnp.ndarray, y: jnp.ndarray, weights: jnp.ndarray, config: ForestConfig
) -> jnp.ndarray:
    """GR(y_ij) of every feature on every tree's bootstrap sample. [k, F].

    Swept one ``hist_feature_slab``-wide feature block at a time: the
    multiway gain ratio is per-feature, so the root histogram reduces to
    [k, F] without the [k, 1, F, B, C] tensor ever existing beyond one
    slab (same discipline as ``forest.fused_level_scores``).
    """
    k, N = weights.shape
    F = x_binned.shape[1]
    B = config.n_bins
    base = class_channels(y, config.n_classes)
    slot0 = jnp.zeros((k, N), jnp.int32)
    W = hist_feature_slab(N, F, 1, B, config.n_classes)

    def slab_gr(xb_s):                                   # [N, W] -> [k, W]
        hist = level_histograms(
            xb_s, base, weights, slot0, n_slots=1, n_bins=B,
            backend=config.hist_backend,
        )                                                # [k, 1, W, B, C]
        return multiway_gain_ratio(hist[:, 0])

    if W >= F:
        return slab_gr(x_binned)                         # single slab
    from ..kernels.gain_ratio.kernel import _round_up

    Fp = _round_up(F, W)
    xb = jnp.pad(x_binned, ((0, 0), (0, Fp - F)))
    gr = jax.lax.map(
        lambda j: slab_gr(jax.lax.dynamic_slice_in_dim(xb, j * W, W, axis=1)),
        jnp.arange(Fp // W),
    )                                                    # [Fp/W, k, W]
    return jnp.moveaxis(gr, 0, 1).reshape(k, Fp)[:, :F]


@partial(jax.jit, static_argnames=("n_selected", "n_important"))
def select_features(
    gr: jnp.ndarray, rng: jax.Array, *, n_selected: int, n_important: int
) -> jnp.ndarray:
    """Alg. 3.1 steps 10-19: top-k_imp by VI + uniform (m - k_imp) of the rest.

    Args:  gr [k, F].  Returns: mask [k, F] bool with exactly m True per tree.
    """
    k, F = gr.shape
    vi = variable_importance(gr)                          # Eq. (7)
    # Deterministic top-k_imp: rank by VI (desc).
    vi_rank = jnp.argsort(jnp.argsort(-vi, axis=-1), axis=-1)   # rank of each feature
    top_mask = vi_rank < n_important

    # Uniform (m - k_imp) of the remainder: random keys, masked ranking.
    u = jax.random.uniform(rng, (k, F))
    u = jnp.where(top_mask, -jnp.inf, u)                  # exclude the top features
    u_rank = jnp.argsort(jnp.argsort(-u, axis=-1), axis=-1)
    rest_mask = u_rank < (n_selected - n_important)
    return top_mask | rest_mask


@partial(jax.jit, static_argnames=("n_trees", "n_features", "n_selected"))
def random_feature_mask(
    rng: jax.Array, *, n_trees: int, n_features: int, n_selected: int
) -> jnp.ndarray:
    """Breiman-RF feature selection (paper §3.1 step 2): m uniform per tree."""
    u = jax.random.uniform(rng, (n_trees, n_features))
    rank = jnp.argsort(jnp.argsort(-u, axis=-1), axis=-1)
    return rank < n_selected


def dimension_reduction(
    x_binned: jnp.ndarray,
    y: jnp.ndarray,
    weights: jnp.ndarray,
    config: ForestConfig,
    rng: jax.Array,
) -> jnp.ndarray:
    """Full Alg. 3.1. Returns per-tree feature mask [k, F]."""
    cfg = config.resolved(x_binned.shape[1])
    gr = root_gain_ratios(x_binned, y, weights, cfg)
    return select_features(
        gr, rng, n_selected=cfg.n_selected, n_important=cfg.n_important
    )


@partial(jax.jit, static_argnames=("n_bins", "backend"))
def _root_hist_block(hist_acc, xb_b, base_b, w_b, *, n_bins, backend):
    slot0 = jnp.zeros_like(w_b, dtype=jnp.int32)
    return hist_acc + level_histograms(
        xb_b, base_b, w_b, slot0, n_slots=1, n_bins=n_bins, backend=backend,
    )


def dimension_reduction_streamed(
    x_binned,
    y: jnp.ndarray,
    weights: jnp.ndarray,
    config: ForestConfig,
    rng: jax.Array,
    *,
    prefetch: int = 2,
) -> jnp.ndarray:
    """Alg. 3.1 over host sample blocks (the streaming data plane).

    The root histogram is a sum over samples, so it accumulates block by
    block exactly like the growth histograms — DSI counts are integer-
    valued, the accumulation is bit-exact, and the resulting mask equals
    the resident ``dimension_reduction`` mask bitwise (the gain ratio is
    per-feature, so full-F scoring of the accumulated histogram matches
    the resident slab sweep). The sweep's own working set is one block,
    its [k, Nb] weight slice, and the [k, 1, F, B, C] root histogram —
    the [N, F] matrix is never device-resident (the caller's [k, N]
    DSI weights are, as everywhere on the streaming plane).
    """
    from ..data.pipeline import BlockFeeder, stream_blocks

    y_np = np.asarray(y)
    w_np = np.asarray(weights, dtype=np.float32)
    blocks = stream_blocks(
        x_binned, config.sample_block, what="dimension_reduction_streamed",
        n_y=y_np.shape[0], n_w=w_np.shape[1],
    )
    feeder = BlockFeeder(blocks, prefetch=prefetch)
    F = feeder.blocks[0].shape[1]
    cfg = config.resolved(F)
    k = weights.shape[0]
    hist = jnp.zeros((k, 1, F, cfg.n_bins, cfg.n_classes), jnp.float32)
    o = 0
    for xb_b in feeder.sweep():
        n = xb_b.shape[0]
        base_b = class_channels(feeder.pin(y_np[o:o + n]), cfg.n_classes)
        hist = _root_hist_block(
            hist, xb_b, base_b, feeder.pin(w_np[:, o:o + n]),
            n_bins=cfg.n_bins, backend=cfg.hist_backend,
        )
        o += n
    gr = multiway_gain_ratio(hist[:, 0])                 # [k, F]
    return select_features(
        gr, rng, n_selected=cfg.n_selected, n_important=cfg.n_important
    )
