"""Distributed PRF — vertical data-partitioning on a device mesh (paper §4).

Sharding layout (the paper's data-parallel optimization, §4.1):

  x_binned [N, F] : P(sample_axes, feature_axis)   <- vertical partitioning:
                    features pinned to `model` shards, samples to `data`
  y        [N]    : P(sample_axes)
  weights  [k, N] : P(None, sample_axes)           <- DSI counts, §4.1.2
  forest          : replicated (small)

Communication structure (== the paper's task DAG, §4.2):

  T_GR   per-device histograms over its (sample x feature) block, then one
         ``psum`` over the sample axes — the *only* large collective.
         Features never move; gain-ratio math is local to feature shards
         (paper: "tasks dispatched to the slaves where the subset is
         located", LocalScheduler).
  T_NS   each shard scores its own post-combine feature slice with the
         split backend selected by ``config.split_backend`` (the fused
         pallas split-scan kernel on TPU — histogram slabs consumed in
         VMEM, only per-(tree, slot) winners emerge), then winners are
         argmax-merged across shards: an ``all_gather`` of the [k, S]
         per-shard best gain ratios + masked ``psum``s of the tiny
         O(k*S) winner descriptors and the per-sample go-left/right bits
         (paper: ClusterScheduler synchronization point). Histogram
         slabs are never shipped to a central scorer.

Bootstrap is *stratified per sample-shard* (each shard draws N_local of
its own N_local rows): the Spark implementation samples globally; the
stratified variant has identical marginal statistics, lower variance, and
needs no cross-shard index exchange. Noted as an adaptation in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: `jax.shard_map(check_vma=)` on
    >= 0.6, `jax.experimental.shard_map.shard_map(check_rep=)` before."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm

    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)

from .dsi import bootstrap_counts
from .engine import (
    CollectivePlane, _gather_feature_bins, _safe_mean, finalize_forest, grow,
    init_forest, init_growth_state, init_hist_cache, level_step,
    next_frontier, plan_level, resolve_hist_reuse, reuse_expand_scores,
    stream_block_step, write_level,
)
from .types import GrowthState
from .gain import (
    SplitScores, level_scores, multiway_gain_ratio, resolve_split_backend,
    sibling_plan,
)
from .histograms import class_channels, level_histograms, regression_channels
from .types import Forest, ForestConfig


def _axis_size(a: str) -> int:
    """`jax.lax.axis_size` compat (absent before jax 0.5): psum of the
    literal 1 over a named axis constant-folds to the axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    return fn(a) if fn is not None else jax.lax.psum(1, a)


def _multi_axis_index(axes: Sequence[str]) -> jnp.ndarray:
    """Linearized index over possibly-multiple mesh axes (row-major)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def _masked_psum(val, mine, axis):
    """Select `val` from the shard where `mine` is True; result on all shards."""
    return jax.lax.psum(jnp.where(mine, val, jnp.zeros_like(val)), axis)


def _global_best_splits(
    scores: SplitScores, n_node, axes, f_global_local: jnp.ndarray,
    n_bins: int,
):
    """T_NS across shards: gather per-shard leaders, pick the winner.

    ``axes``: mesh axes the candidate splits are sharded over — just the
    feature axis in the paper-faithful layout, or (data, feature) when
    the histogram combine is a reduce-scatter (§Perf).
    ``f_global_local``: this shard's features mapped to global ids.

    Equal-gain ties are broken on the smallest global
    ``(feature, threshold)`` key — the order the single-host flat argmax
    uses — NOT on gather order: under the reduce-scatter layout the
    shards' feature ranges interleave over the data axis, so gather
    order disagrees with global feature order and tie-breaking on it
    made ``psum_scatter`` forests diverge from every other plane (the
    paper-faithful psum layout gathers shards in feature order, where
    the two rules coincide). This keeps all planes bit-identical.
    """
    axes = tuple(axes)
    my = _multi_axis_index(axes)
    gr_all = jax.lax.all_gather(scores.gain_ratio, axes)            # [P, k, S]
    best_gr = jnp.max(gr_all, axis=0)
    key = f_global_local * n_bins + scores.threshold                # [k, S]
    key_all = jax.lax.all_gather(key, axes)                         # [P, k, S]
    key_all = jnp.where(gr_all == best_gr, key_all, jnp.iinfo(jnp.int32).max)
    win = jnp.argmin(key_all, axis=0)                               # [k, S]
    mine = win == my
    f_global = _masked_psum(f_global_local, mine, axes)
    thr = _masked_psum(scores.threshold, mine, axes)
    lcnt = _masked_psum(scores.left_counts, mine[..., None], axes)
    rcnt = _masked_psum(scores.right_counts, mine[..., None], axes)
    n_node = _masked_psum(n_node, mine, axes)
    return SplitScores(best_gr, f_global, thr, lcnt, rcnt), n_node, mine


class MeshPlane(CollectivePlane):
    """The engine's collective plane for the vertical-partition mesh.

    T_GR combine strategy (``combine_hist``): plain psum (paper-faithful:
    every sample shard ends with the full feature-shard histogram) or
    reduce-scatter (§Perf: histogram shards over (sample x feature) —
    half the wire bytes, 1/P_data of the redundant gain-ratio compute).
    ``merge_winners`` is the T_NS cross-shard argmax merge
    (``_global_best_splits``), mapping per-shard feature ids to global
    ids first. ``broadcast_route``: the winning feature lives on exactly
    one feature shard; it computes the go-right bit, a masked psum
    broadcasts it (the paper's "result distributed to all slaves").
    """

    def __init__(
        self, config: ForestConfig, n_local_features: int, mask_loc,
        *, sample_axes, feature_axis,
    ):
        self.sample_axes = tuple(sample_axes)
        self.feature_axis = feature_axis
        self.n_bins = config.n_bins
        self.Fl = Fl = n_local_features
        self.midx = jax.lax.axis_index(feature_axis)
        self.use_rs = (
            config.hist_reduce == "psum_scatter"
            and len(self.sample_axes) == 1
            and Fl % _axis_size(self.sample_axes[0]) == 0
        )
        if self.use_rs:
            self.didx = jax.lax.axis_index(self.sample_axes[0])
            self.fl_sub = Fl // _axis_size(self.sample_axes[0])
            mask_src = (
                mask_loc if mask_loc is not None
                else jnp.ones((config.n_trees, Fl), jnp.bool_)
            )
            # Post-scatter each shard scores its (data, feature) slice.
            self.level_mask = jax.lax.dynamic_slice_in_dim(
                mask_src, self.didx * self.fl_sub, self.fl_sub, 1
            )
            self.combine_hist = lambda h: jax.lax.psum_scatter(
                h, self.sample_axes[0], scatter_dimension=2, tiled=True
            )
        else:
            self.level_mask = mask_loc
            self.combine_hist = lambda h: jax.lax.psum(h, self.sample_axes)

    def reduce_root(self, root_counts):
        return jax.lax.psum(root_counts, self.sample_axes)

    def merge_winners(self, scores, n_node):
        if self.use_rs:
            f_glob = scores.feature + self.midx * self.Fl + self.didx * self.fl_sub
            axes = (self.sample_axes[0], self.feature_axis)
        else:
            f_glob = scores.feature + self.midx * self.Fl
            axes = (self.feature_axis,)
        scores, n_node, _ = _global_best_splits(
            scores, n_node, axes, f_glob, self.n_bins
        )
        return scores, n_node

    def hist_width(self, n_features: int) -> int:
        # The hist_reuse cache stores POST-combine histograms: the full
        # local feature shard under psum, only the post-scatter slice
        # under reduce-scatter (the cache never widens the rs layout).
        return self.fl_sub if self.use_rs else n_features

    def broadcast_route(self, xb_loc, f_i, thr_i):
        f_shard = f_i // self.Fl                                 # global ids
        f_here = jnp.where(f_shard == self.midx, f_i - self.midx * self.Fl, 0)
        bins_i = _gather_feature_bins(xb_loc, f_here)            # [k, Nl]
        go_loc = jnp.where(
            f_shard == self.midx, (bins_i > thr_i).astype(jnp.int32), 0
        )
        return jax.lax.psum(go_loc, self.feature_axis)


def _grow_sharded(
    xb_loc, base_loc, w_loc, mask_loc, config: ForestConfig,
    *, sample_axes, feature_axis,
):
    """Level-synchronous growth on one device's (sample x feature) block
    — a thin entry point over the unified engine (core/engine.py)."""
    plane = MeshPlane(
        config, xb_loc.shape[1], mask_loc,
        sample_axes=sample_axes, feature_axis=feature_axis,
    )
    return grow(xb_loc, base_loc, w_loc, config, plane)


# ---------------------------------------------------------------------------
# Mesh x streaming: host sample blocks fed into the collective plane
# ---------------------------------------------------------------------------


def _pad_rows(a: np.ndarray, pad: int, fill=0):
    if pad == 0:
        return np.ascontiguousarray(a)
    width = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, width, constant_values=fill)


def grow_sharded_checkpointed(
    x_binned,
    y: np.ndarray,
    weights: np.ndarray,
    config: ForestConfig,
    mesh: Mesh,
    feature_mask: Optional[np.ndarray] = None,
    *,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    manager=None,
    resume_from: Optional[str] = None,
    on_level=None,
) -> Forest:
    """Resident mesh growth with per-level checkpointing / crash resume.

    The mesh analogue of ``engine.grow_checkpointed``: a host-driven
    loop over ONE jitted ``shard_map`` call wrapping the engine's
    ``level_step`` on ``MeshPlane`` — the identical traced level-step of
    ``_grow_sharded``'s ``lax.while_loop``, so the forest is
    bit-identical to the uninterrupted trainer. Between levels the full
    ``GrowthState`` carry is handed to ``manager.maybe_save``; on
    resume the carry is restored with its original mesh shardings (the
    per-sample slot table goes back to ``P(None, sample_axes)``, the
    rest replicated). Rows are padded to the data-axis size with
    zero-weight samples, invisible to histograms and root counts.
    """
    sample_axes = tuple(sample_axes)
    from .api import _channels

    x_np = np.asarray(x_binned)
    y_np = np.asarray(y)
    w_np = np.asarray(weights, np.float32)
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    pad = (-x_np.shape[0]) % D
    k, F = config.n_trees, x_np.shape[1]

    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    row_sh = NamedSharding(mesh, P(sample_axes))
    kn_sh = NamedSharding(mesh, P(None, sample_axes))

    xb = jax.device_put(_pad_rows(x_np, pad), x_sh)
    base_dev = _channels(jax.device_put(_pad_rows(y_np, pad), row_sh), config)
    w_dev = jax.device_put(_pad_rows(w_np.T, pad).T, kn_sh)
    mask_np = (
        np.ones((k, F), bool) if feature_mask is None
        else np.asarray(feature_mask, bool)
    )
    mask_dev = jax.device_put(mask_np, NamedSharding(mesh, P(None, feature_axis)))

    def make_plane(mask_loc):
        return MeshPlane(
            config, mask_loc.shape[1], mask_loc,
            sample_axes=sample_axes, feature_axis=feature_axis,
        )

    # The hist_reuse cache joins the carry (and therefore every
    # checkpoint): resolved host-side from the LOCAL feature width so it
    # matches what init_growth_state builds inside the shard_map. Its
    # histogram is feature-sharded (post-psum each feature shard keeps
    # its own slice; under reduce-scatter the slice is further split
    # over the data axis); the small index tables are replicated.
    Fl = F // int(mesh.shape[feature_axis])
    use_rs = (
        config.hist_reduce == "psum_scatter"
        and len(sample_axes) == 1 and Fl % D == 0
    )
    reuse = resolve_hist_reuse(config, Fl)
    cache_specs = None
    if reuse:
        hist_axes = (feature_axis, sample_axes[0]) if use_rs else feature_axis
        cache_specs = {
            "hist": P(None, None, hist_axes),
            "perm": P(), "parent": P(), "small_right": P(),
        }

    def init_kernel(base_loc, w_loc, mask_loc):
        st = init_growth_state(
            base_loc, w_loc, config, make_plane(mask_loc),
            n_features=Fl if reuse else None,
        )
        return st.forest, st.slot_node, st.sample_slot, st.rng, st.level, \
            st.hist_cache

    state_specs = (P(), P(), P(None, sample_axes), P(), P(), cache_specs)
    init_fn = jax.jit(_shard_map(
        init_kernel, mesh=mesh,
        in_specs=(P(sample_axes), P(None, sample_axes), P(None, feature_axis)),
        out_specs=state_specs,
    ))

    def step_kernel(xb_loc, base_loc, w_loc, mask_loc, forest, slot_node,
                    slot_loc, rng, level, cache):
        st = level_step(
            xb_loc, base_loc, w_loc,
            GrowthState(
                forest=forest, slot_node=slot_node, sample_slot=slot_loc,
                rng=rng, level=level, hist_cache=cache,
            ),
            config, make_plane(mask_loc),
        )
        return st.forest, st.slot_node, st.sample_slot, st.rng, st.level, \
            st.hist_cache

    step_fn = jax.jit(_shard_map(
        step_kernel, mesh=mesh,
        in_specs=(
            P(sample_axes, feature_axis), P(sample_axes),
            P(None, sample_axes), P(None, feature_axis),
        ) + state_specs,
        out_specs=state_specs,
    ))

    state = init_fn(base_dev, w_dev, mask_dev)
    if resume_from is not None:
        from ..checkpoint.checkpoint import restore_latest_valid

        shardings = jax.tree_util.tree_map(lambda a: a.sharding, state)
        restored = restore_latest_valid(
            state, resume_from, shardings
        )
        if restored is not None:
            state, _ = restored
    forest, slot_node, slot_loc, rng, level, cache = state
    while (
        int(level) < config.max_depth
        and bool(np.any(np.asarray(slot_node) >= 0))
    ):
        forest, slot_node, slot_loc, rng, level, cache = step_fn(
            xb, base_dev, w_dev, mask_dev,
            forest, slot_node, slot_loc, rng, level, cache,
        )
        if manager is not None:
            manager.maybe_save(
                (forest, slot_node, slot_loc, rng, level, cache), int(level)
            )
        if on_level is not None:
            on_level(int(level), forest)
    return finalize_forest(forest)




def grow_forest_streamed_sharded(
    x_binned,
    y: np.ndarray,
    weights: np.ndarray,
    config: ForestConfig,
    mesh: Mesh,
    feature_mask: Optional[np.ndarray] = None,
    *,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    prefetch: int = 2,
    manager=None,
    resume_from: Optional[str] = None,
    on_level=None,
    feeder_opts: Optional[dict] = None,
    quarantined: Sequence[int] = (),
    runtime=None,
    block_sizes: Optional[Sequence[int]] = None,
) -> Forest:
    """Out-of-core growth on the **mesh** plane — the streaming data
    plane composed with ``MeshPlane``'s collectives, lifting the
    per-host memory cap on the distributed path too.

    Per (block, level), ONE jitted ``shard_map`` call runs
    ``engine.stream_block_step`` on every device: each shard routes its
    (sample x feature) slice of the block (the winning feature's
    go-right bit broadcast by ``MeshPlane.broadcast_route``'s masked
    psum) and folds it into its **local** histogram partial — the
    ``combine_hist`` collective (psum or psum_scatter, per
    ``config.hist_reduce``) runs once per level in the plan step, not
    once per block, so streaming adds zero extra collective traffic.
    The per-shard partials live in a ``[D, k, S, F, B, C]`` carry
    sharded ``P(sample_axes, ..., feature_axis)`` (each data shard owns
    its row), and the per-sample slot table stays device-resident
    sharded ``P(None, sample_axes)``.

    Blocks are padded host-side to a multiple of the data-axis size
    with parked samples (``slot = -1``, zero weight) — invisible to
    histograms, routing, and root counts — so any block split shards.
    The result is bit-identical to resident ``_grow_sharded`` growth
    and to the local planes (the engine parity matrix).

    **Checkpointing** mirrors ``grow_forest_streamed``: ``manager``
    saves the driver's full inter-level carry (forest, frontier, level
    plan, per-block slot tables) after each level; ``resume_from``
    restores the latest carry — slot tables back to their
    ``P(None, sample_axes)`` sharding — and the level loop continues
    where it stopped, bit-identically. ``feeder_opts`` forwards
    retry/backoff/fault-injection knobs to the ``BlockFeeder``;
    ``quarantined`` block indices are dropped from every sweep.

    **Multi-process plane.** With ``runtime`` (a
    ``launch.multiproc.MultiHostMesh``) the same driver runs across
    ``jax.distributed`` processes: ``x_binned`` is then the list of
    per-block **host-local padded row slices** (each process holds only
    its own rows — see ``MultiHostMesh.local_row_range``), and
    ``block_sizes`` gives the global unpadded block sizes the local
    slices came from. Every device array is constructed through the
    runtime's addressable-slice ``put`` — blocks via a shard-aware
    feeder placement, carries via ``zeros`` — so no host ever
    materializes a global row range, while the jitted kernels (and
    therefore the forest, bitwise) are identical to the single-process
    mesh. Checkpoints go through the multi-process manager/restore
    (process-0 manifest, per-host shard leaves).
    """
    from .api import _stream_setup

    sample_axes = tuple(sample_axes)
    if runtime is not None:
        if block_sizes is None:
            raise ValueError(
                "grow_forest_streamed_sharded(runtime=...) needs "
                "block_sizes — the global unpadded sizes the host-local "
                "block slices were cut from"
            )
        sizes = [int(n) for n in block_sizes]
        offsets = np.concatenate([[0], np.cumsum(sizes)])
        y_np = np.asarray(y)
        if config.regression:
            y_np = y_np.astype(np.float32)
        w_np = np.asarray(weights, dtype=np.float32)
        local_blocks = list(x_binned)
        F = local_blocks[0].shape[1]
    else:
        feeder0, y_np, w_np, sizes, offsets = _stream_setup(
            x_binned, y, weights, config, prefetch
        )
        F = feeder0.blocks[0].shape[1]
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    k, S = config.n_trees, config.frontier
    B = config.n_bins
    C = 3 if config.regression else config.n_classes

    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    row_sh = NamedSharding(mesh, P(sample_axes))
    kn_sh = NamedSharding(mesh, P(None, sample_axes))
    rep_sh = NamedSharding(mesh, P())
    hist_spec = P(sample_axes, None, None, feature_axis)

    # Sibling-subtraction reuse (config.hist_reuse): per-block partials
    # scatter into R rank segments instead of S slots — the [D, k, R,
    # F, B, C] carry AND the per-level combine halve — and the plan
    # step reconstructs large children from the durable cache. The
    # cache histogram is feature-sharded exactly like the checkpointed
    # resident path's.
    Fl = F // int(mesh.shape[feature_axis])
    use_rs = (
        config.hist_reduce == "psum_scatter"
        and len(sample_axes) == 1 and Fl % D == 0
    )
    reuse = resolve_hist_reuse(config, Fl)
    n_rows = config.max_splits_per_level if reuse else S
    cache_sh = None
    if reuse:
        hist_axes = (feature_axis, sample_axes[0]) if use_rs else feature_axis
        cache_sh = {
            "hist": NamedSharding(mesh, P(None, None, hist_axes)),
            "perm": rep_sh, "parent": rep_sh, "small_right": rep_sh,
        }
        cache_specs = {
            "hist": P(None, None, hist_axes),
            "perm": P(), "parent": P(), "small_right": P(),
        }

    from ..data.pipeline import BlockFeeder

    pads = [(-n) % D for n in sizes]
    ms = [n + p for n, p in zip(sizes, pads)]       # padded global rows
    from .api import _channels

    base_dev, w_dev, slot_dev = [], [], []
    if runtime is not None:
        x_spec = P(sample_axes, feature_axis)
        feeder = BlockFeeder(
            local_blocks,
            placement=runtime.block_placement(ms, F, x_spec),
            prefetch=prefetch, quarantined=quarantined,
            **(feeder_opts or {}),
        )
        for i, m in enumerate(ms):
            o0 = offsets[i]
            lo, hi = runtime.local_row_range(m)
            nreal = max(min(hi, sizes[i]) - lo, 0)   # local non-pad rows
            yb = np.zeros((hi - lo,), y_np.dtype)
            yb[:nreal] = y_np[o0 + lo:o0 + lo + nreal]
            # Channels on the local rows only — _channels is row-wise,
            # so this is the row slice of the single-process build.
            ch = np.asarray(_channels(jnp.asarray(yb), config))
            base_dev.append(runtime.put(
                ch, (m,) + ch.shape[1:], P(sample_axes),
                box=[(lo, hi)] + [(0, s) for s in ch.shape[1:]],
            ))
            wb = np.zeros((k, hi - lo), np.float32)
            wb[:, :nreal] = w_np[:, o0 + lo:o0 + lo + nreal]
            w_dev.append(runtime.put(
                wb, (k, m), P(None, sample_axes), box=[(0, k), (lo, hi)],
            ))
            slot0 = np.zeros((k, hi - lo), np.int32)
            slot0[:, max(sizes[i] - lo, 0):] = -1    # pad rows stay parked
            slot_dev.append(runtime.put(
                slot0, (k, m), P(None, sample_axes), box=[(0, k), (lo, hi)],
            ))
    else:
        feeder = BlockFeeder(
            [_pad_rows(b, p) for b, p in zip(feeder0.blocks, pads)],
            placement=x_sh, prefetch=prefetch, quarantined=quarantined,
            **(feeder_opts or {}),
        )
        for i, p in enumerate(pads):
            o0, o1 = offsets[i], offsets[i + 1]
            # Channels built on device by the same _channels every other
            # plane uses; pad rows are zero-weight + parked, so their
            # channel content is irrelevant.
            base_dev.append(_channels(
                jax.device_put(_pad_rows(y_np[o0:o1], p), row_sh), config,
            ))
            w_dev.append(
                jax.device_put(_pad_rows(w_np[:, o0:o1].T, p).T, kn_sh)
            )
            slot0 = np.zeros((k, sizes[i] + p), np.int32)
            slot0[:, sizes[i]:] = -1                # pad rows stay parked
            slot_dev.append(jax.device_put(slot0, kn_sh))

    mask_np = (
        np.ones((k, F), bool) if feature_mask is None
        else np.asarray(feature_mask, bool)
    )
    mask_dev = (
        runtime.put_full(mask_np, P(None, feature_axis))
        if runtime is not None
        else jax.device_put(mask_np, NamedSharding(mesh, P(None, feature_axis)))
    )

    def make_plane(Fl, mask_loc=None):
        return MeshPlane(
            config, Fl, mask_loc,
            sample_axes=sample_axes, feature_axis=feature_axis,
        )

    def step_kernel_route(hist_part, xb_loc, base_loc, w_loc, slot_loc,
                          slot_node, split_rank, scores, small_right=None):
        h, slot_loc = stream_block_step(
            hist_part[0], xb_loc, base_loc, w_loc, slot_loc, slot_node,
            split_rank, scores, config, make_plane(xb_loc.shape[1]),
            route=True, small_right=small_right,
        )
        return h[None], slot_loc

    def step_kernel_first(hist_part, xb_loc, base_loc, w_loc, slot_loc,
                          slot_node, small_right=None):
        h, slot_loc = stream_block_step(
            hist_part[0], xb_loc, base_loc, w_loc, slot_loc, slot_node,
            None, None, config, make_plane(xb_loc.shape[1]), route=False,
            small_right=small_right,
        )
        return h[None], slot_loc

    data_specs = (hist_spec, P(sample_axes, feature_axis), P(sample_axes),
                  P(None, sample_axes), P(None, sample_axes), P())
    sr_specs = (P(),) if reuse else ()
    step_route = jax.jit(_shard_map(
        step_kernel_route, mesh=mesh,
        in_specs=data_specs + (P(), P()) + sr_specs,
        out_specs=(hist_spec, P(None, sample_axes)),
    ))
    step_first = jax.jit(_shard_map(
        step_kernel_first, mesh=mesh,
        in_specs=data_specs + sr_specs,
        out_specs=(hist_spec, P(None, sample_axes)),
    ))

    split_be = resolve_split_backend(config.split_backend)

    def _root_init(forest, hist_c):
        # Root counts: any feature's bin marginal of the level-0
        # histogram (slot/rank row 0) sums to the [k, C] root class
        # counts (identical on every shard — exact integer sums).
        root = hist_c[:, 0, 0].sum(axis=1)
        forest = dataclasses.replace(
            forest, class_counts=forest.class_counts.at[:, 0].set(root),
        )
        if config.regression:
            forest = dataclasses.replace(
                forest, value=forest.value.at[:, 0].set(_safe_mean(root)),
            )
        return forest

    def make_plan(init: bool):
        def plan_kernel(hist_part, forest, slot_node, level, mask_loc):
            plane = make_plane(hist_part.shape[3], mask_loc)
            hist_c = plane.combine_hist(hist_part[0])
            if init:
                forest = _root_init(forest, hist_c)
            scores_loc, n_loc = level_scores(
                hist_c, plane.level_mask, regression=config.regression,
                backend=split_be,
            )
            scores, n_node = plane.merge_winners(scores_loc, n_loc)
            split_rank, is_split, child_base = plan_level(
                scores, n_node, slot_node, config, level
            )
            forest = write_level(
                forest, slot_node, split_rank, is_split, child_base, scores,
                config,
            )
            return (
                forest, scores, split_rank,
                next_frontier(is_split, child_base, config.frontier),
            )

        def plan_kernel_reuse(hist_part, forest, slot_node, level, mask_loc,
                              cache):
            plane = make_plane(hist_part.shape[3], mask_loc)
            hist_c = plane.combine_hist(hist_part[0])   # packed: half the wire
            if init:
                forest = _root_init(forest, hist_c)
            scores, n_node, hist2, perm = reuse_expand_scores(
                hist_c, cache, plane.level_mask, config
            )
            scores, n_node = plane.merge_winners(scores, n_node)
            split_rank, is_split, child_base = plan_level(
                scores, n_node, slot_node, config, level
            )
            forest = write_level(
                forest, slot_node, split_rank, is_split, child_base, scores,
                config,
            )
            parent, small_right = sibling_plan(
                scores, split_rank, is_split,
                n_ranks=config.max_splits_per_level,
                regression=config.regression,
            )
            return (
                forest, scores, split_rank,
                next_frontier(is_split, child_base, config.frontier),
                {"hist": hist2, "perm": perm,
                 "parent": parent, "small_right": small_right},
            )

        if reuse:
            return jax.jit(_shard_map(
                plan_kernel_reuse, mesh=mesh,
                in_specs=(hist_spec, P(), P(), P(), P(None, feature_axis),
                          cache_specs),
                out_specs=(P(), P(), P(), P(), cache_specs),
            ))
        return jax.jit(_shard_map(
            plan_kernel, mesh=mesh,
            in_specs=(hist_spec, P(), P(), P(), P(None, feature_axis)),
            out_specs=(P(), P(), P(), P()),
        ))

    plan_init, plan_next = make_plan(True), make_plan(False)

    hist0 = (
        runtime.zeros((D, k, n_rows, F, B, C), hist_spec, jnp.float32)
        if runtime is not None
        else jax.device_put(
            jnp.zeros((D, k, n_rows, F, B, C), jnp.float32),
            NamedSharding(mesh, hist_spec),
        )
    )

    state = None
    if resume_from is not None:
        from ..checkpoint.checkpoint import restore_latest_valid
        from .api import _stream_state_like

        # The like-template is GLOBAL-shaped: cache width F (the mesh
        # shards its feature dim per cache_sh on restore).
        like = _stream_state_like(
            [n + p for n, p in zip(sizes, pads)], config,
            F if reuse else 0,
        )
        shardings = jax.tree_util.tree_map(lambda _: rep_sh, like)
        shardings["slots"] = [kn_sh for _ in like["slots"]]
        if reuse:
            shardings["hist_cache"] = cache_sh
        if runtime is not None:
            from ..launch.multiproc import restore_latest_valid_multiproc

            restored = restore_latest_valid_multiproc(
                like, resume_from, shardings, runtime
            )
        else:
            restored = restore_latest_valid(like, resume_from, shardings)
        if restored is not None:
            state, _ = restored
    if state is not None:
        forest, slot_node = state["forest"], state["slot_node"]
        scores, split_rank = state["scores"], state["split_rank"]
        slot_dev, start = list(state["slots"]), int(np.asarray(state["level"]))
        cache = state.get("hist_cache") if reuse else None
    else:
        slot0_np = np.full((k, S), -1, np.int32)
        slot0_np[:, 0] = 0
        slot_node = (
            runtime.put_full(slot0_np, P()) if runtime is not None
            else jax.device_put(jnp.asarray(slot0_np), rep_sh)
        )
        forest, scores, split_rank = None, None, None
        start = 0
        # Global cache width F — sharded per cache_sh (dim 2).
        if reuse:
            cache0 = init_hist_cache(config, F)
            cache = (
                {n: runtime.put_full(np.asarray(v), cache_specs[n])
                 for n, v in cache0.items()}
                if runtime is not None
                else jax.device_put(cache0, cache_sh)
            )
        else:
            cache = None

    def level_sweep(route: bool):
        hist = hist0
        sr = ((cache["small_right"],) if reuse else ())
        for i, xb_b in zip(feeder.live_blocks, feeder.sweep()):
            if route:
                hist, slot_dev[i] = step_route(
                    hist, xb_b, base_dev[i], w_dev[i], slot_dev[i],
                    slot_node, split_rank, scores, *sr,
                )
            else:
                hist, slot_dev[i] = step_first(
                    hist, xb_b, base_dev[i], w_dev[i], slot_dev[i], slot_node,
                    *sr,
                )
        return hist

    try:
        for level in range(start, config.max_depth):
            if not np.any(np.asarray(slot_node) >= 0):
                break
            hist = level_sweep(route=level > 0)
            plan = plan_next if forest is not None else plan_init
            if forest is None:
                f0 = init_forest(config)
                forest = (
                    jax.tree_util.tree_map(
                        lambda a: runtime.put_full(np.asarray(a), P()), f0
                    )
                    if runtime is not None else jax.device_put(f0, rep_sh)
                )
            if reuse:
                forest, scores, split_rank, slot_node, cache = plan(
                    hist, forest, slot_node, np.int32(level),
                    mask_dev, cache,
                )
            else:
                forest, scores, split_rank, slot_node = plan(
                    hist, forest, slot_node, np.int32(level),
                    mask_dev,
                )
            if manager is not None:
                manager.maybe_save({
                    "forest": forest, "slot_node": slot_node,
                    "scores": scores, "split_rank": split_rank,
                    "slots": slot_dev, "hist_cache": cache,
                    "level": np.int32(level + 1),
                }, level + 1)
            if on_level is not None:
                on_level(level + 1, forest)

        if forest is None:          # max_depth == 0: root node only
            def root_kernel(hist_part):
                plane = make_plane(hist_part.shape[3])
                hist_c = plane.combine_hist(hist_part[0])
                return hist_c[:, 0, 0].sum(axis=1)

            root_fn = jax.jit(_shard_map(
                root_kernel, mesh=mesh, in_specs=(hist_spec,), out_specs=P(),
            ))
            # Host round-trip: the replicated root counts fetch cleanly
            # on every process, and the .at[].set below then runs on
            # purely local arrays (eager ops on multi-process global
            # arrays would raise).
            root = jnp.asarray(np.asarray(jax.device_get(
                root_fn(level_sweep(route=False))
            )))
            forest = init_forest(config)
            forest = dataclasses.replace(
                forest, class_counts=forest.class_counts.at[:, 0].set(root)
            )
            if config.regression:
                forest = dataclasses.replace(
                    forest, value=forest.value.at[:, 0].set(_safe_mean(root))
                )
    finally:
        feeder.close()
    if runtime is not None:
        # Forest leaves are fully replicated — pull them host-side so
        # finalize_forest (eager jnp) runs on local arrays.
        forest = jax.tree_util.tree_map(
            lambda a: jnp.asarray(np.asarray(jax.device_get(a))), forest
        )
    return finalize_forest(forest)


def oob_accuracy_streamed_sharded(
    forest: Forest,
    x_binned,
    y: np.ndarray,
    weights: np.ndarray,
    mesh: Mesh,
    *,
    sample_block: int = 0,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    prefetch: int = 2,
    feeder_opts: Optional[dict] = None,
    quarantined: Sequence[int] = (),
    runtime=None,
    block_sizes: Optional[Sequence[int]] = None,
    invalid_masks: Optional[dict] = None,
) -> jnp.ndarray:
    """Eq. (8) over host sample blocks on the mesh — per block, each
    shard routes its slice and psums its [k] correct/OOB partial counts;
    the counts accumulate across blocks (exact f32 integers, so the
    result is bit-identical to resident ``_oob_weights_sharded`` /
    single-host ``oob_accuracy``). Padded rows are masked via an
    explicit validity channel (their zero weight would otherwise read
    as OOB).

    With ``runtime`` (``launch.multiproc.MultiHostMesh``) ``x_binned``
    is each process's local row window of every padded block and
    ``block_sizes`` the global unpadded sizes; labels/weights/validity
    are placed as addressable slices and the replicated count outputs
    accumulate host-side. ``invalid_masks[i]`` (a local bool mask over
    block *i*'s window) zeroes extra rows out of the validity channel —
    exact-integer sums make that bitwise identical to dropping those
    rows, which is how the single-host path excludes imputed-label
    samples."""
    from ..data.pipeline import BlockFeeder, stream_blocks

    sample_axes = tuple(sample_axes)
    y_np = np.asarray(y)
    w_np = np.asarray(weights, dtype=np.float32)
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    if runtime is not None:
        if block_sizes is None:
            raise ValueError(
                "oob_accuracy_streamed_sharded(runtime=...) needs "
                "block_sizes — the global unpadded sizes the host-local "
                "block slices were cut from"
            )
        blocks = list(x_binned)
        sizes = [int(n) for n in block_sizes]
    else:
        blocks = stream_blocks(
            x_binned, sample_block, what="oob_accuracy_streamed_sharded",
            n_y=y_np.shape[0], n_w=w_np.shape[1],
        )
        sizes = [b.shape[0] for b in blocks]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    pads = [(-n) % D for n in sizes]
    ms = [n + p for n, p in zip(sizes, pads)]

    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    row_sh = NamedSharding(mesh, P(sample_axes))
    kn_sh = NamedSharding(mesh, P(None, sample_axes))
    if runtime is not None:
        F = blocks[0].shape[1]
        feeder = BlockFeeder(
            blocks,
            placement=runtime.block_placement(
                ms, F, P(sample_axes, feature_axis)
            ),
            prefetch=prefetch, quarantined=quarantined,
            **(feeder_opts or {}),
        )
    else:
        feeder = BlockFeeder(
            [_pad_rows(np.asarray(b), p) for b, p in zip(blocks, pads)],
            placement=x_sh, prefetch=prefetch, quarantined=quarantined,
            **(feeder_opts or {}),
        )

    def kernel(xb_loc, y_loc, w_loc, valid_loc):
        leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
        counts = jnp.take_along_axis(
            forest.class_counts, leaves[..., None], axis=1
        )
        pred = jnp.argmax(counts, axis=-1)                       # [k, Nl]
        oob = (w_loc == 0.0).astype(jnp.float32) * valid_loc[None]
        correct = jax.lax.psum(
            jnp.sum(oob * (pred == y_loc[None]).astype(jnp.float32), 1),
            sample_axes,
        )
        total = jax.lax.psum(jnp.sum(oob, 1), sample_axes)
        return correct, total

    fn = jax.jit(_shard_map(
        kernel, mesh=mesh,
        in_specs=(P(sample_axes, feature_axis), P(sample_axes),
                  P(None, sample_axes), P(sample_axes)),
        out_specs=(P(), P()),
    ))

    k = w_np.shape[0]
    try:
        if runtime is not None:
            # Replicated count outputs fetch cleanly on every process;
            # accumulating them host-side in f32 keeps the exact-integer
            # sums bitwise identical to the on-device accumulation.
            correct = np.zeros((k,), np.float32)
            total = np.zeros((k,), np.float32)
            for i, xb_b in zip(feeder.live_blocks, feeder.sweep()):
                o0, m = offsets[i], ms[i]
                lo, hi = runtime.local_row_range(m)
                nreal = max(min(hi, sizes[i]) - lo, 0)
                yb = np.zeros((hi - lo,), y_np.dtype)
                yb[:nreal] = y_np[o0 + lo:o0 + lo + nreal]
                wb = np.zeros((k, hi - lo), np.float32)
                wb[:, :nreal] = w_np[:, o0 + lo:o0 + lo + nreal]
                valid = np.zeros(hi - lo, np.float32)
                valid[:nreal] = 1.0
                if invalid_masks and i in invalid_masks:
                    valid[np.asarray(invalid_masks[i], bool)] = 0.0
                c, t = fn(
                    xb_b,
                    runtime.put(yb, (m,), P(sample_axes), box=[(lo, hi)]),
                    runtime.put(wb, (k, m), P(None, sample_axes),
                                box=[(0, k), (lo, hi)]),
                    runtime.put(valid, (m,), P(sample_axes),
                                box=[(lo, hi)]),
                )
                correct = correct + np.asarray(jax.device_get(c))
                total = total + np.asarray(jax.device_get(t))
            return jnp.asarray(np.where(
                total > 0, correct / np.maximum(total, np.float32(1.0)),
                np.float32(0.5),
            ).astype(np.float32))

        correct = jnp.zeros((k,), jnp.float32)
        total = jnp.zeros((k,), jnp.float32)
        for i, xb_b in zip(feeder.live_blocks, feeder.sweep()):
            o0, o1 = offsets[i], offsets[i + 1]
            valid = np.zeros(sizes[i] + pads[i], np.float32)
            valid[:sizes[i]] = 1.0
            c, t = fn(
                xb_b,
                jax.device_put(_pad_rows(y_np[o0:o1], pads[i]), row_sh),
                jax.device_put(_pad_rows(w_np[:, o0:o1].T, pads[i]).T, kn_sh),
                jax.device_put(valid, row_sh),
            )
            correct, total = correct + c, total + t
    finally:
        feeder.close()
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def predict_streamed_sharded(
    forest: Forest,
    x_binned,
    mesh: Mesh,
    *,
    sample_block: int = 0,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    prefetch: int = 2,
    feeder_opts: Optional[dict] = None,
) -> np.ndarray:
    """Distributed Eq. (10) prediction over host sample blocks — labels
    are per-sample, so the blocked sweep is bit-identical to
    ``predict_sharded`` on the full matrix; only one padded block is
    device-resident at a time. Returns [N] labels (host array)."""
    from ..data.pipeline import BlockFeeder, stream_blocks

    sample_axes = tuple(sample_axes)
    blocks = stream_blocks(
        x_binned, sample_block, what="predict_streamed_sharded"
    )
    sizes = [b.shape[0] for b in blocks]
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    pads = [(-n) % D for n in sizes]
    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    feeder = BlockFeeder(
        [_pad_rows(np.asarray(b), p) for b, p in zip(blocks, pads)],
        placement=x_sh, prefetch=prefetch, **(feeder_opts or {}),
    )
    fn = jax.jit(_shard_map(
        partial(_vote_labels_kernel, forest, feature_axis=feature_axis),
        mesh=mesh,
        in_specs=(P(sample_axes, feature_axis),),
        out_specs=P(sample_axes),
    ))
    try:
        out = [
            np.asarray(fn(xb_b))[:sizes[i]]
            for i, xb_b in enumerate(feeder.sweep())
        ]
    finally:
        feeder.close()
    return np.concatenate(out)


def _route_sharded(forest: Forest, xb_loc, *, feature_axis: str):
    """route_to_leaves when features are sharded over `feature_axis`."""
    k = forest.feature.shape[0]
    Nl, Fl = xb_loc.shape
    depth = forest.config.max_depth
    midx = jax.lax.axis_index(feature_axis)
    xb = xb_loc.astype(jnp.int32)

    def step(node, _):
        f = jnp.take_along_axis(forest.feature, node, 1)             # [k, Nl]
        leaf = f < 0
        f_shard = jnp.where(leaf, -1, f // Fl)
        f_here = jnp.where(f_shard == midx, f - midx * Fl, 0)
        b = _gather_feature_bins(xb, f_here)
        thr = jnp.take_along_axis(forest.threshold, node, 1)
        go_loc = jnp.where(f_shard == midx, (b > thr).astype(jnp.int32), 0)
        go = jax.lax.psum(go_loc, feature_axis)
        lc = jnp.take_along_axis(forest.left_child, node, 1)
        return jnp.where(leaf, node, lc + go), None

    node0 = jnp.zeros((k, Nl), jnp.int32)
    leaves, _ = jax.lax.scan(step, node0, None, length=depth)
    return leaves


def _dimred_sharded(xb_loc, base_loc, w_loc, config, key, *, sample_axes, feature_axis):
    """Distributed Alg. 3.1: local GR + global VI ranking."""
    k, Nl = w_loc.shape
    Fl = xb_loc.shape[1]
    slot0 = jnp.zeros((k, Nl), jnp.int32)
    hist = level_histograms(
        xb_loc, base_loc, w_loc, slot0, n_slots=1, n_bins=config.n_bins,
        backend=config.hist_backend,
    )
    hist = jax.lax.psum(hist, sample_axes)
    gr_loc = multiway_gain_ratio(hist[:, 0])                         # [k, Fl]
    gr = jax.lax.all_gather(gr_loc, feature_axis, axis=1, tiled=True)  # [k, F]
    from .dimred import select_features

    cfg = config.resolved(gr.shape[1])
    mask = select_features(
        gr, key, n_selected=cfg.n_selected, n_important=cfg.n_important
    )
    midx = jax.lax.axis_index(feature_axis)
    return jax.lax.dynamic_slice_in_dim(mask, midx * Fl, Fl, axis=1)


def _oob_weights_sharded(forest, xb_loc, y_loc, w_loc, *, sample_axes, feature_axis):
    """Eq. (8) with samples and features sharded."""
    leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
    counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
    pred = jnp.argmax(counts, axis=-1)                               # [k, Nl]
    oob = (w_loc == 0.0).astype(jnp.float32)
    correct = jax.lax.psum(
        jnp.sum(oob * (pred == y_loc[None]).astype(jnp.float32), 1), sample_axes
    )
    total = jax.lax.psum(jnp.sum(oob, 1), sample_axes)
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def make_prf_train_fn(
    config: ForestConfig,
    mesh: Mesh,
    *,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
):
    """Build the jit'd distributed PRF trainer for `mesh`.

    Returns (train_fn, in_shardings): ``train_fn(x_binned, y, seed_key)``
    -> Forest (replicated). This is the function the multi-pod dry-run
    lowers and compiles.
    """
    sample_axes = tuple(sample_axes)
    x_spec = P(sample_axes, feature_axis)
    y_spec = P(sample_axes)

    def train(x_binned, y, key):
        def kernel(xb_loc, y_loc, key):
            k_boot, k_dim = jax.random.split(
                jax.random.fold_in(key, _multi_axis_index(sample_axes))
            )
            Nl = xb_loc.shape[0]
            base_loc = (
                regression_channels(y_loc)
                if config.regression
                else class_channels(y_loc, config.n_classes)
            )
            # Stratified DSI bootstrap (see module docstring).
            w_loc = bootstrap_counts(k_boot, config.n_trees, Nl)

            mask_loc = None
            if config.feature_mode == "importance" and not config.regression:
                # identical key across shards => identical global mask
                k_dim_g = jax.random.fold_in(key, 7)
                mask_loc = _dimred_sharded(
                    xb_loc, base_loc, w_loc, config, k_dim_g,
                    sample_axes=sample_axes, feature_axis=feature_axis,
                )
            elif config.feature_mode == "random":
                from .dimred import random_feature_mask

                cfg = config.resolved(x_binned.shape[1])
                mask = random_feature_mask(
                    jax.random.fold_in(key, 7),
                    n_trees=config.n_trees,
                    n_features=x_binned.shape[1],
                    n_selected=cfg.n_selected,
                )
                midx = jax.lax.axis_index(feature_axis)
                Fl = xb_loc.shape[1]
                mask_loc = jax.lax.dynamic_slice_in_dim(mask, midx * Fl, Fl, 1)

            forest = _grow_sharded(
                xb_loc, base_loc, w_loc, mask_loc, config,
                sample_axes=sample_axes, feature_axis=feature_axis,
            )
            if config.weighted_voting and not config.regression:
                w = _oob_weights_sharded(
                    forest, xb_loc, y_loc, w_loc,
                    sample_axes=sample_axes, feature_axis=feature_axis,
                )
                forest = dataclasses.replace(forest, tree_weight=w)
            return forest

        return _shard_map(
            kernel,
            mesh=mesh,
            in_specs=(x_spec, y_spec, P()),
            out_specs=P(),
        )(x_binned, y, key)

    in_shardings = (
        NamedSharding(mesh, x_spec),
        NamedSharding(mesh, y_spec),
        NamedSharding(mesh, P()),
    )
    return jax.jit(train, in_shardings=in_shardings), in_shardings


def _vote_labels_kernel(forest: Forest, xb_loc, *, feature_axis: str):
    """Per-device Eq. (10) voting over a feature-sharded block — the ONE
    kernel behind both the resident ``predict_sharded`` and the
    mesh-streamed ``predict_streamed_sharded`` sweeps."""
    leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
    counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
    probs = counts / jnp.maximum(counts.sum(-1, keepdims=True), 1e-38)
    w = (
        forest.tree_weight
        if forest.config.weighted_voting
        else jnp.ones_like(forest.tree_weight)
    )
    from .voting import weighted_vote

    scores = weighted_vote(probs, w, soft=forest.config.soft_voting)
    return jnp.argmax(scores, -1)


def predict_sharded(forest: Forest, x_binned, mesh, *,
                    sample_axes=("data",), feature_axis="model"):
    """Distributed weighted-voting prediction (Eq. 10). Returns [N] labels."""
    sample_axes = tuple(sample_axes)
    fn = _shard_map(
        partial(_vote_labels_kernel, forest, feature_axis=feature_axis),
        mesh=mesh,
        in_specs=(P(sample_axes, feature_axis),),
        out_specs=P(sample_axes),
    )
    return jax.jit(fn)(x_binned)


# ---------------------------------------------------------------------------
# Distributed bin-edge fitting (blocked quantile sketch over the mesh)
# ---------------------------------------------------------------------------


def fit_bins_sharded(
    x,
    n_bins: int,
    mesh: Mesh,
    *,
    sample_block: int,
    sample_axes: Sequence[str] = ("data",),
    max_size: Optional[int] = None,
    exclude_masks=None,
    runtime=None,
) -> np.ndarray:
    """Distributed bin-edge fitting: one quantile sketch per data shard,
    exchanged through the collective plane, merged host-side.

    The block list (``sample_blocks`` views of the source — typically an
    ``np.memmap``) is partitioned contiguously over the ``sample_axes``
    shards; each shard folds only its own blocks into a
    ``StreamingQuantileSketch``, so per-shard memory stays O(block) +
    O(F * max_size) — in a multi-process mesh each host would feed its
    local shard of the file. The per-feature summaries then cross the
    mesh as raw float64 **bit patterns** (uint32 words) through one
    ``all_gather`` over ``sample_axes`` — exact regardless of jax's x64
    mode — and are merged in shard order on the host. The result is
    deterministic, and while every summary is uncompressed it is bitwise
    identical to single-host ``fit_bins_blocked`` over the same blocks
    (and therefore to the resident ``fit_bins`` at that scale). Wire
    cost: ``D * F * 2 * max_size * 16`` bytes on the gather.

    ``exclude_masks`` (sequence, dict keyed by global block index, or a
    callable ``exclude_masks(i) -> mask | None`` for masks a multi-host
    caller recomputes lazily) carries the validator's imputed-cell
    masks, exactly as in ``fit_bins_blocked``.

    With ``runtime`` (``launch.multiproc.MultiHostMesh``) each process
    sketches only the block subsets of its own device shards — the
    block partition over shards is identical to the single-process
    call, so a memmap source pages in only the owning host's blocks —
    and the per-feature counts/compression/dtype metadata ride the
    payload's extra row so every host can reconstruct every shard's
    state from the gather alone. The merged edges are bitwise identical
    either way.
    """
    from ..data.pipeline import stream_blocks
    from .binning import (
        DEFAULT_SKETCH_SIZE, StreamingQuantileSketch, validate_n_bins,
    )

    n_bins = validate_n_bins(n_bins)
    if max_size is None:
        max_size = DEFAULT_SKETCH_SIZE
    blocks = stream_blocks(x, sample_block, what="fit_bins_sharded")
    n_features = int(np.asarray(blocks[0]).shape[1])
    axes = tuple(sample_axes)
    n_shards = 1
    for a in axes:
        n_shards *= int(mesh.shape[a])
    parts = np.array_split(np.arange(len(blocks)), n_shards)
    mine = (
        range(runtime.shard_lo, runtime.shard_hi) if runtime is not None
        else range(n_shards)
    )

    # Summaries never exceed 2 * max_size points (the sketch recompresses
    # past that), so every shard ships the same fixed-width payload. One
    # extra metadata row per feature carries [count_lo, count_hi,
    # compressed, dtype_char] so remote shards' states reconstruct from
    # the gather alone.
    width = 2 * max_size
    payloads = np.zeros((len(mine), n_features, width + 1, 4), np.uint32)
    for row, d in enumerate(mine):
        sk = StreamingQuantileSketch(n_features, max_size=max_size)
        for i in parts[d]:
            i = int(i)
            if exclude_masks is None:
                mask = None
            elif isinstance(exclude_masks, dict):
                mask = exclude_masks.get(i)
            elif callable(exclude_masks):
                mask = exclude_masks(i)
            else:
                mask = exclude_masks[i]
            sk.update(np.asarray(blocks[i]), exclude=mask)
        st = sk.state(pad_to=width)
        packed = np.ascontiguousarray(
            np.stack([st["values"], st["weights"]], axis=-1)
        )  # [F, width, 2] float64
        payloads[row, :, :width] = packed.view(np.uint32).reshape(
            n_features, width, 4
        )
        cnt = np.asarray(st["count"], np.uint64)
        payloads[row, :, width, 0] = (cnt & np.uint64(0xFFFFFFFF)).astype(
            np.uint32
        )
        payloads[row, :, width, 1] = (cnt >> np.uint64(32)).astype(np.uint32)
        payloads[row, :, width, 2] = np.asarray(st["compressed"], np.uint32)
        payloads[row, :, width, 3] = np.uint32(
            ord(np.dtype(st["value_dtype"]).char)
        )

    def _exchange(p_loc):
        g = p_loc  # [1, F, width + 1, 4] per shard
        for a in reversed(axes):
            g = jax.lax.all_gather(g, a, axis=0, tiled=True)
        return g

    gshape = (n_shards, n_features, width + 1, 4)
    p_dev = (
        runtime.put(
            payloads, gshape, P(axes),
            box=[(runtime.shard_lo, runtime.shard_hi)]
            + [(0, s) for s in gshape[1:]],
        )
        if runtime is not None else jnp.asarray(payloads)
    )
    gathered = jax.jit(_shard_map(
        _exchange, mesh=mesh,
        in_specs=(P(axes),),
        out_specs=P(),
    ))(p_dev)
    gathered = np.ascontiguousarray(np.asarray(jax.device_get(gathered)))

    merged = None
    for d in range(n_shards):
        meta = gathered[d, :, width]
        unpacked = np.ascontiguousarray(gathered[d, :, :width]).view(
            np.float64
        ).reshape(n_features, width, 2)
        st = {
            "values": unpacked[..., 0],
            "weights": unpacked[..., 1],
            "count": (
                meta[:, 0].astype(np.uint64)
                | (meta[:, 1].astype(np.uint64) << np.uint64(32))
            ).astype(np.int64),
            "compressed": meta[:, 2].astype(np.bool_),
            "value_dtype": np.dtype(chr(int(meta[0, 3]))).str,
            "max_size": max_size,
        }
        sk_d = StreamingQuantileSketch.from_state(st)
        merged = sk_d if merged is None else merged.merge(sk_d)
    return merged.edges(n_bins)


# ---------------------------------------------------------------------------
# Multi-process training plane (launch.multiproc runtime)
# ---------------------------------------------------------------------------


def _dimred_streamed_multiproc(
    local_blocks, y_np, w_np, config, rng, runtime, *,
    sizes, quarantined=(), prefetch=2, feeder_opts=None,
    sample_axes=("data",), feature_axis="model",
):
    """``dimension_reduction_streamed`` on the multi-process plane.

    Each process folds only its local rows of every block into a
    ``[D, k, 1, F, B, C]`` histogram carry (same ``hist_spec`` layout as
    the growth driver); the final kernel psums across the sample shards
    — exact-integer DSI counts, so the accumulated root histogram, the
    gain ratios, and therefore the ``select_features`` mask are bitwise
    identical to the single-host sweep. The mask comes back replicated
    and is re-derived host-locally on every process.
    """
    from ..data.pipeline import BlockFeeder
    from .dimred import select_features

    sample_axes = tuple(sample_axes)
    mesh = runtime.mesh
    D = runtime.n_data_shards
    F = local_blocks[0].shape[1]
    cfg = config.resolved(F)
    k = w_np.shape[0]
    B, C = cfg.n_bins, cfg.n_classes
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    pads = [(-n) % D for n in sizes]
    ms = [n + p for n, p in zip(sizes, pads)]
    hist_spec = P(sample_axes, None, None, feature_axis)

    feeder = BlockFeeder(
        local_blocks,
        placement=runtime.block_placement(
            ms, F, P(sample_axes, feature_axis)
        ),
        prefetch=prefetch, quarantined=quarantined, **(feeder_opts or {}),
    )

    def acc_kernel(hist_part, xb_loc, base_loc, w_loc):
        slot0 = jnp.zeros_like(w_loc, dtype=jnp.int32)
        h = hist_part[0] + level_histograms(
            xb_loc, base_loc, w_loc, slot0, n_slots=1, n_bins=B,
            backend=cfg.hist_backend,
        )
        return h[None]

    acc = jax.jit(_shard_map(
        acc_kernel, mesh=mesh,
        in_specs=(hist_spec, P(sample_axes, feature_axis), P(sample_axes),
                  P(None, sample_axes)),
        out_specs=hist_spec,
    ))

    def final_kernel(hist_part):
        h = jax.lax.psum(hist_part[0], sample_axes)      # [k, 1, Fl, B, C]
        gr = multiway_gain_ratio(h[:, 0])                # [k, Fl]
        return jax.lax.all_gather(gr, feature_axis, axis=1, tiled=True)

    final = jax.jit(_shard_map(
        final_kernel, mesh=mesh, in_specs=(hist_spec,), out_specs=P(),
    ))

    hist = runtime.zeros((D, k, 1, F, B, C), hist_spec, jnp.float32)
    try:
        for i, xb_b in zip(feeder.live_blocks, feeder.sweep()):
            o0, m = offsets[i], ms[i]
            lo, hi = runtime.local_row_range(m)
            nreal = max(min(hi, sizes[i]) - lo, 0)
            yb = np.zeros((hi - lo,), y_np.dtype)
            yb[:nreal] = y_np[o0 + lo:o0 + lo + nreal]
            ch = np.asarray(class_channels(jnp.asarray(yb), C))
            wb = np.zeros((k, hi - lo), np.float32)
            wb[:, :nreal] = w_np[:, o0 + lo:o0 + lo + nreal]
            hist = acc(
                hist, xb_b,
                runtime.put(ch, (m, C), P(sample_axes),
                            box=[(lo, hi), (0, C)]),
                runtime.put(wb, (k, m), P(None, sample_axes),
                            box=[(0, k), (lo, hi)]),
            )
    finally:
        feeder.close()
    gr = jnp.asarray(np.asarray(jax.device_get(final(hist))))
    return np.asarray(select_features(
        gr, rng, n_selected=cfg.n_selected, n_important=cfg.n_important
    ))


def train_prf_multiproc(
    x, y, config: ForestConfig, seed: int = 0, *,
    runtime=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    checkpoint_keep: int = 3,
    resume_from: Optional[str] = None,
    on_level=None,
    feeder_opts: Optional[dict] = None,
    bad_block_policy: Optional[str] = "raise",
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    sketch_max_size: Optional[int] = None,
):
    """End-to-end ``train_prf`` across ``jax.distributed`` processes.

    The whole pipeline — integrity screen, bin-edge fitting, binning,
    DSI bootstrap, dimension reduction, growth, OOB weighting — runs
    with every process touching only the rows its sample-axis shards
    own (``x`` is typically an ``np.memmap``; remote rows are never
    paged in, except that edge fitting reads the full blocks of this
    process's shard *subset* — the same block partition as
    ``fit_bins_sharded``). The trained model is **bitwise identical**
    to the single-process ``train_prf`` on the same ``(x, y, config,
    seed)``:

    * the per-block validator scans local rows and union-reduces the
      per-(block, column) bad-cell counts through one exact integer
      ``psum_hosts``, so every process reaches the same verdict (and
      the same typed ``DataIntegrityError`` under ``"raise"``); label
      screening runs on the globally-resident ``y`` identically
      everywhere;
    * edges come from per-shard quantile sketches merged bit-exactly;
    * the bootstrap/feature-mask PRNG draws are process-independent
      functions of ``seed``;
    * growth/dimred/OOB accumulate exact integer-valued f32 sums, so
      shard-order never matters.

    ``checkpoint_dir``/``resume_from`` go through the multi-process
    checkpoint protocol (process-0 manifest, per-host shard leaves);
    resuming under a different process count raises
    ``CheckpointTopologyError``. Regression with ``weighted_voting``
    is not wired on this plane yet and raises ``NotImplementedError``.
    ``sketch_max_size`` caps the per-shard quantile summary (wire and
    host cost of edge fitting scale with it; below the compression
    threshold edges are exact).
    """
    from ..data.pipeline import (
        BlockIssue, BlockValidator, DataIntegrityError, QuarantineReport,
    )
    from ..launch.multiproc import MultiHostMesh, MultiprocCheckpointManager
    from .api import PRFModel
    from .binning import apply_bins
    from .dimred import random_feature_mask

    config = config.resolved(x.shape[1])
    if config.sample_block <= 0:
        raise ValueError(
            "train_prf_multiproc needs config.sample_block > 0 — the "
            "multi-process plane is streaming-only (each process feeds "
            "its local rows of every sample block)"
        )
    if getattr(x, "ndim", None) != 2:
        raise ValueError(
            "train_prf_multiproc needs a 2-D [N, F] array-like source "
            "(np.memmap / np.ndarray) so every process can slice its own "
            f"rows; got {type(x).__name__}"
        )
    if runtime is None:
        runtime = MultiHostMesh(
            sample_axes=sample_axes, feature_axis=feature_axis
        )
    mesh = runtime.mesh
    sample_axes = tuple(sample_axes)
    D = runtime.n_data_shards
    N, F = int(x.shape[0]), int(x.shape[1])
    nb = config.sample_block
    sizes = [min(nb, N - o) for o in range(0, N, nb)]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    n_blocks = len(sizes)
    ms = [n + ((-n) % D) for n in sizes]
    windows = [runtime.local_row_range(m) for m in ms]
    y_host = np.asarray(y)

    def _local_view(i):
        """(view of x's local real rows of block i, their count)."""
        lo, hi = windows[i]
        o0 = offsets[i]
        nreal = max(min(hi, sizes[i]) - lo, 0)
        return x[o0 + lo:o0 + lo + nreal], nreal

    # ---- integrity screen (union-reduced across processes) ------------
    report = None
    cell_cols = None
    label_masks = {}
    quar = frozenset()
    if bad_block_policy not in (None, "off"):
        validator = BlockValidator(
            bad_block_policy, n_features=F,
            n_classes=None if config.regression else config.n_classes,
            regression=config.regression,
        )
        counts = np.zeros((n_blocks, F), np.int64)
        if np.issubdtype(np.asarray(x[:0]).dtype, np.inexact):
            for i in range(n_blocks):
                view, nreal = _local_view(i)
                if nreal:
                    counts[i] = (~np.isfinite(np.asarray(view))).sum(axis=0)
        cell_cols = runtime.psum_hosts(counts.ravel()).reshape(n_blocks, F)
        for i in range(n_blocks):
            lm = validator._label_mask(y_host[offsets[i]:offsets[i + 1]])
            if lm.any():
                label_masks[i] = lm
        report = QuarantineReport(
            policy=bad_block_policy, blocks_checked=n_blocks,
        )
        for i in range(n_blocks):
            bad_cells = int(cell_cols[i].sum())
            bad_labels = int(label_masks[i].sum()) if i in label_masks else 0
            if not bad_cells and not bad_labels:
                continue
            issue = BlockIssue(
                index=i, reason="nonfinite" if bad_cells else "label",
                columns=tuple(int(c) for c in np.flatnonzero(cell_cols[i])),
                bad_cells=bad_cells, bad_labels=bad_labels,
            )
            report.issues.append(issue)
            if bad_block_policy == "raise":
                raise DataIntegrityError(
                    issue.describe(), block_index=i,
                    columns=issue.columns, reason=issue.reason,
                )
            report.sanitized_cells += bad_cells
            report.sanitized_labels += bad_labels
            if bad_block_policy == "quarantine":
                report.quarantined.append(i)
        quar = frozenset(report.quarantined)
        if len(quar) == n_blocks:
            raise DataIntegrityError(
                f"every block quarantined ({n_blocks} of {n_blocks}) — "
                "nothing left to train on",
                reason="quarantine",
            )
        if label_masks:
            y_host = y_host.copy()
            for i, lm in label_masks.items():
                y_host[offsets[i]:offsets[i + 1]][lm] = 0
    good = [i for i in range(n_blocks) if i not in quar]
    flagged = (
        set() if cell_cols is None
        else {i for i in range(n_blocks) if cell_cols[i].any()}
    )

    # ---- bin edges (per-shard sketches over the good blocks) ----------
    good_views = [x[offsets[i]:offsets[i + 1]] for i in good]

    def _exclude(j):
        # Lazily recompute the imputed-cell mask of the j-th good block —
        # only the sketching shard ever pages the full block in.
        i = good[j]
        if i not in flagged:
            return None
        return ~np.isfinite(np.asarray(good_views[j]))

    edges = fit_bins_sharded(
        good_views, config.n_bins, mesh,
        sample_block=nb, sample_axes=sample_axes,
        max_size=sketch_max_size,
        exclude_masks=_exclude if flagged else None,
        runtime=runtime,
    )
    edges_dev = jnp.asarray(edges)

    # ---- bin the local rows of every block ----------------------------
    xb_local = []
    for i in range(n_blocks):
        lo, hi = windows[i]
        xbl = np.zeros((hi - lo, F), np.uint8)
        if i in quar:
            xb_local.append(xbl)             # placeholder, never swept
            continue
        view, nreal = _local_view(i)
        if nreal:
            xb = np.array(apply_bins(jnp.asarray(np.asarray(view)),
                                     edges_dev))
            if i in flagged:
                # apply_bins is element-wise, so binning the local row
                # slice matches the full-block binning bitwise; imputed
                # cells are forced to bin 0 exactly like the single-host
                # trainer.
                xb[~np.isfinite(np.asarray(view))] = 0
            xbl[:nreal] = xb
        xb_local.append(xbl)

    # ---- DSI bootstrap + feature selection (same PRNG everywhere) -----
    key = jax.random.PRNGKey(seed)
    k_boot, k_dim = jax.random.split(key)
    w_np = np.asarray(bootstrap_counts(k_boot, config.n_trees, N))
    if label_masks:
        bad_rows = np.zeros(N, dtype=bool)
        for i, lm in label_masks.items():
            bad_rows[offsets[i]:offsets[i + 1]][lm] = True
        w_np = np.where(bad_rows[None, :], 0, w_np)

    feature_mask = None
    if config.feature_mode == "importance" and not config.regression:
        feature_mask = _dimred_streamed_multiproc(
            xb_local, y_host, w_np, config, k_dim, runtime,
            sizes=sizes, quarantined=sorted(quar), feeder_opts=feeder_opts,
            sample_axes=sample_axes, feature_axis=feature_axis,
        )
    elif config.feature_mode == "random":
        feature_mask = np.asarray(random_feature_mask(
            k_dim, n_trees=config.n_trees, n_features=F,
            n_selected=config.n_selected,
        ))

    # ---- growth (the runtime-threaded mesh streamed driver) -----------
    manager = None
    if checkpoint_dir is not None:
        manager = MultiprocCheckpointManager(
            checkpoint_dir, keep=checkpoint_keep,
            save_interval=checkpoint_every, runtime=runtime,
        )
    y_grow = y_host if not config.regression else y_host.astype(np.float32)
    forest = grow_forest_streamed_sharded(
        xb_local, y_grow, w_np, config, mesh, feature_mask,
        sample_axes=sample_axes, feature_axis=feature_axis,
        manager=manager, resume_from=resume_from, on_level=on_level,
        feeder_opts=feeder_opts, quarantined=sorted(quar),
        runtime=runtime, block_sizes=sizes,
    )

    if config.weighted_voting:
        if config.regression:
            raise NotImplementedError(
                "weighted_voting for regression (OOB R^2) is not wired "
                "on the multi-process plane yet — set "
                "weighted_voting=False, or train single-process"
            )
        invalid = {}
        for i, lm in label_masks.items():
            if i in quar:
                continue
            lo, hi = windows[i]
            nreal = max(min(hi, sizes[i]) - lo, 0)
            m = np.zeros(hi - lo, bool)
            m[:nreal] = lm[lo:lo + nreal]
            if m.any():
                invalid[i] = m
        w = oob_accuracy_streamed_sharded(
            forest, xb_local, y_host, w_np, mesh,
            sample_axes=sample_axes, feature_axis=feature_axis,
            feeder_opts=feeder_opts, quarantined=sorted(quar),
            runtime=runtime, block_sizes=sizes,
            invalid_masks=invalid or None,
        )
        forest = dataclasses.replace(forest, tree_weight=w)

    return PRFModel(forest=forest, bin_edges=edges, quarantine=report)
