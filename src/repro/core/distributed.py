"""Distributed PRF — vertical data-partitioning on a device mesh (paper §4).

Sharding layout (the paper's data-parallel optimization, §4.1):

  x_binned [N, F] : P(sample_axes, feature_axis)   <- vertical partitioning:
                    features pinned to `model` shards, samples to `data`
  y        [N]    : P(sample_axes)
  weights  [k, N] : P(None, sample_axes)           <- DSI counts, §4.1.2
  forest          : replicated (small)

Communication structure (== the paper's task DAG, §4.2):

  T_GR   per-device histograms over its (sample x feature) block, then one
         ``psum`` over the sample axes — the *only* large collective.
         Features never move; gain-ratio math is local to feature shards
         (paper: "tasks dispatched to the slaves where the subset is
         located", LocalScheduler).
  T_NS   each shard scores its own post-combine feature slice with the
         split backend selected by ``config.split_backend`` (the fused
         pallas split-scan kernel on TPU — histogram slabs consumed in
         VMEM, only per-(tree, slot) winners emerge), then winners are
         argmax-merged across shards: an ``all_gather`` of the [k, S]
         per-shard best gain ratios + masked ``psum``s of the tiny
         O(k*S) winner descriptors and the per-sample go-left/right bits
         (paper: ClusterScheduler synchronization point). Histogram
         slabs are never shipped to a central scorer.

Bootstrap is *stratified per sample-shard* (each shard draws N_local of
its own N_local rows): the Spark implementation samples globally; the
stratified variant has identical marginal statistics, lower variance, and
needs no cross-shard index exchange. Noted as an adaptation in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: `jax.shard_map(check_vma=)` on
    >= 0.6, `jax.experimental.shard_map.shard_map(check_rep=)` before."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm

    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)

from .dsi import bootstrap_counts
from .engine import (
    CollectivePlane, _gather_feature_bins, _safe_mean, finalize_forest, grow,
    init_forest, init_growth_state, init_hist_cache, level_step,
    next_frontier, plan_level, resolve_hist_reuse, reuse_expand_scores,
    stream_block_step, write_level,
)
from .types import GrowthState
from .gain import (
    SplitScores, level_scores, multiway_gain_ratio, resolve_split_backend,
    sibling_plan,
)
from .histograms import class_channels, level_histograms, regression_channels
from .types import Forest, ForestConfig


def _axis_size(a: str) -> int:
    """`jax.lax.axis_size` compat (absent before jax 0.5): psum of the
    literal 1 over a named axis constant-folds to the axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    return fn(a) if fn is not None else jax.lax.psum(1, a)


def _multi_axis_index(axes: Sequence[str]) -> jnp.ndarray:
    """Linearized index over possibly-multiple mesh axes (row-major)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def _masked_psum(val, mine, axis):
    """Select `val` from the shard where `mine` is True; result on all shards."""
    return jax.lax.psum(jnp.where(mine, val, jnp.zeros_like(val)), axis)


def _global_best_splits(
    scores: SplitScores, n_node, axes, f_global_local: jnp.ndarray,
    n_bins: int,
):
    """T_NS across shards: gather per-shard leaders, pick the winner.

    ``axes``: mesh axes the candidate splits are sharded over — just the
    feature axis in the paper-faithful layout, or (data, feature) when
    the histogram combine is a reduce-scatter (§Perf).
    ``f_global_local``: this shard's features mapped to global ids.

    Equal-gain ties are broken on the smallest global
    ``(feature, threshold)`` key — the order the single-host flat argmax
    uses — NOT on gather order: under the reduce-scatter layout the
    shards' feature ranges interleave over the data axis, so gather
    order disagrees with global feature order and tie-breaking on it
    made ``psum_scatter`` forests diverge from every other plane (the
    paper-faithful psum layout gathers shards in feature order, where
    the two rules coincide). This keeps all planes bit-identical.
    """
    axes = tuple(axes)
    my = _multi_axis_index(axes)
    gr_all = jax.lax.all_gather(scores.gain_ratio, axes)            # [P, k, S]
    best_gr = jnp.max(gr_all, axis=0)
    key = f_global_local * n_bins + scores.threshold                # [k, S]
    key_all = jax.lax.all_gather(key, axes)                         # [P, k, S]
    key_all = jnp.where(gr_all == best_gr, key_all, jnp.iinfo(jnp.int32).max)
    win = jnp.argmin(key_all, axis=0)                               # [k, S]
    mine = win == my
    f_global = _masked_psum(f_global_local, mine, axes)
    thr = _masked_psum(scores.threshold, mine, axes)
    lcnt = _masked_psum(scores.left_counts, mine[..., None], axes)
    rcnt = _masked_psum(scores.right_counts, mine[..., None], axes)
    n_node = _masked_psum(n_node, mine, axes)
    return SplitScores(best_gr, f_global, thr, lcnt, rcnt), n_node, mine


class MeshPlane(CollectivePlane):
    """The engine's collective plane for the vertical-partition mesh.

    T_GR combine strategy (``combine_hist``): plain psum (paper-faithful:
    every sample shard ends with the full feature-shard histogram) or
    reduce-scatter (§Perf: histogram shards over (sample x feature) —
    half the wire bytes, 1/P_data of the redundant gain-ratio compute).
    ``merge_winners`` is the T_NS cross-shard argmax merge
    (``_global_best_splits``), mapping per-shard feature ids to global
    ids first. ``broadcast_route``: the winning feature lives on exactly
    one feature shard; it computes the go-right bit, a masked psum
    broadcasts it (the paper's "result distributed to all slaves").
    """

    def __init__(
        self, config: ForestConfig, n_local_features: int, mask_loc,
        *, sample_axes, feature_axis,
    ):
        self.sample_axes = tuple(sample_axes)
        self.feature_axis = feature_axis
        self.n_bins = config.n_bins
        self.Fl = Fl = n_local_features
        self.midx = jax.lax.axis_index(feature_axis)
        self.use_rs = (
            config.hist_reduce == "psum_scatter"
            and len(self.sample_axes) == 1
            and Fl % _axis_size(self.sample_axes[0]) == 0
        )
        if self.use_rs:
            self.didx = jax.lax.axis_index(self.sample_axes[0])
            self.fl_sub = Fl // _axis_size(self.sample_axes[0])
            mask_src = (
                mask_loc if mask_loc is not None
                else jnp.ones((config.n_trees, Fl), jnp.bool_)
            )
            # Post-scatter each shard scores its (data, feature) slice.
            self.level_mask = jax.lax.dynamic_slice_in_dim(
                mask_src, self.didx * self.fl_sub, self.fl_sub, 1
            )
            self.combine_hist = lambda h: jax.lax.psum_scatter(
                h, self.sample_axes[0], scatter_dimension=2, tiled=True
            )
        else:
            self.level_mask = mask_loc
            self.combine_hist = lambda h: jax.lax.psum(h, self.sample_axes)

    def reduce_root(self, root_counts):
        return jax.lax.psum(root_counts, self.sample_axes)

    def merge_winners(self, scores, n_node):
        if self.use_rs:
            f_glob = scores.feature + self.midx * self.Fl + self.didx * self.fl_sub
            axes = (self.sample_axes[0], self.feature_axis)
        else:
            f_glob = scores.feature + self.midx * self.Fl
            axes = (self.feature_axis,)
        scores, n_node, _ = _global_best_splits(
            scores, n_node, axes, f_glob, self.n_bins
        )
        return scores, n_node

    def hist_width(self, n_features: int) -> int:
        # The hist_reuse cache stores POST-combine histograms: the full
        # local feature shard under psum, only the post-scatter slice
        # under reduce-scatter (the cache never widens the rs layout).
        return self.fl_sub if self.use_rs else n_features

    def broadcast_route(self, xb_loc, f_i, thr_i):
        f_shard = f_i // self.Fl                                 # global ids
        f_here = jnp.where(f_shard == self.midx, f_i - self.midx * self.Fl, 0)
        bins_i = _gather_feature_bins(xb_loc, f_here)            # [k, Nl]
        go_loc = jnp.where(
            f_shard == self.midx, (bins_i > thr_i).astype(jnp.int32), 0
        )
        return jax.lax.psum(go_loc, self.feature_axis)


def _grow_sharded(
    xb_loc, base_loc, w_loc, mask_loc, config: ForestConfig,
    *, sample_axes, feature_axis,
):
    """Level-synchronous growth on one device's (sample x feature) block
    — a thin entry point over the unified engine (core/engine.py)."""
    plane = MeshPlane(
        config, xb_loc.shape[1], mask_loc,
        sample_axes=sample_axes, feature_axis=feature_axis,
    )
    return grow(xb_loc, base_loc, w_loc, config, plane)


# ---------------------------------------------------------------------------
# Mesh x streaming: host sample blocks fed into the collective plane
# ---------------------------------------------------------------------------


def _pad_rows(a: np.ndarray, pad: int, fill=0):
    if pad == 0:
        return np.ascontiguousarray(a)
    width = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, width, constant_values=fill)


def grow_sharded_checkpointed(
    x_binned,
    y: np.ndarray,
    weights: np.ndarray,
    config: ForestConfig,
    mesh: Mesh,
    feature_mask: Optional[np.ndarray] = None,
    *,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    manager=None,
    resume_from: Optional[str] = None,
    on_level=None,
) -> Forest:
    """Resident mesh growth with per-level checkpointing / crash resume.

    The mesh analogue of ``engine.grow_checkpointed``: a host-driven
    loop over ONE jitted ``shard_map`` call wrapping the engine's
    ``level_step`` on ``MeshPlane`` — the identical traced level-step of
    ``_grow_sharded``'s ``lax.while_loop``, so the forest is
    bit-identical to the uninterrupted trainer. Between levels the full
    ``GrowthState`` carry is handed to ``manager.maybe_save``; on
    resume the carry is restored with its original mesh shardings (the
    per-sample slot table goes back to ``P(None, sample_axes)``, the
    rest replicated). Rows are padded to the data-axis size with
    zero-weight samples, invisible to histograms and root counts.
    """
    sample_axes = tuple(sample_axes)
    from .api import _channels

    x_np = np.asarray(x_binned)
    y_np = np.asarray(y)
    w_np = np.asarray(weights, np.float32)
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    pad = (-x_np.shape[0]) % D
    k, F = config.n_trees, x_np.shape[1]

    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    row_sh = NamedSharding(mesh, P(sample_axes))
    kn_sh = NamedSharding(mesh, P(None, sample_axes))

    xb = jax.device_put(_pad_rows(x_np, pad), x_sh)
    base_dev = _channels(jax.device_put(_pad_rows(y_np, pad), row_sh), config)
    w_dev = jax.device_put(_pad_rows(w_np.T, pad).T, kn_sh)
    mask_np = (
        np.ones((k, F), bool) if feature_mask is None
        else np.asarray(feature_mask, bool)
    )
    mask_dev = jax.device_put(mask_np, NamedSharding(mesh, P(None, feature_axis)))

    def make_plane(mask_loc):
        return MeshPlane(
            config, mask_loc.shape[1], mask_loc,
            sample_axes=sample_axes, feature_axis=feature_axis,
        )

    # The hist_reuse cache joins the carry (and therefore every
    # checkpoint): resolved host-side from the LOCAL feature width so it
    # matches what init_growth_state builds inside the shard_map. Its
    # histogram is feature-sharded (post-psum each feature shard keeps
    # its own slice; under reduce-scatter the slice is further split
    # over the data axis); the small index tables are replicated.
    Fl = F // int(mesh.shape[feature_axis])
    use_rs = (
        config.hist_reduce == "psum_scatter"
        and len(sample_axes) == 1 and Fl % D == 0
    )
    reuse = resolve_hist_reuse(config, Fl)
    cache_specs = None
    if reuse:
        hist_axes = (feature_axis, sample_axes[0]) if use_rs else feature_axis
        cache_specs = {
            "hist": P(None, None, hist_axes),
            "perm": P(), "parent": P(), "small_right": P(),
        }

    def init_kernel(base_loc, w_loc, mask_loc):
        st = init_growth_state(
            base_loc, w_loc, config, make_plane(mask_loc),
            n_features=Fl if reuse else None,
        )
        return st.forest, st.slot_node, st.sample_slot, st.rng, st.level, \
            st.hist_cache

    state_specs = (P(), P(), P(None, sample_axes), P(), P(), cache_specs)
    init_fn = jax.jit(_shard_map(
        init_kernel, mesh=mesh,
        in_specs=(P(sample_axes), P(None, sample_axes), P(None, feature_axis)),
        out_specs=state_specs,
    ))

    def step_kernel(xb_loc, base_loc, w_loc, mask_loc, forest, slot_node,
                    slot_loc, rng, level, cache):
        st = level_step(
            xb_loc, base_loc, w_loc,
            GrowthState(
                forest=forest, slot_node=slot_node, sample_slot=slot_loc,
                rng=rng, level=level, hist_cache=cache,
            ),
            config, make_plane(mask_loc),
        )
        return st.forest, st.slot_node, st.sample_slot, st.rng, st.level, \
            st.hist_cache

    step_fn = jax.jit(_shard_map(
        step_kernel, mesh=mesh,
        in_specs=(
            P(sample_axes, feature_axis), P(sample_axes),
            P(None, sample_axes), P(None, feature_axis),
        ) + state_specs,
        out_specs=state_specs,
    ))

    state = init_fn(base_dev, w_dev, mask_dev)
    if resume_from is not None:
        from ..checkpoint.checkpoint import restore_latest_valid

        shardings = jax.tree_util.tree_map(lambda a: a.sharding, state)
        restored = restore_latest_valid(
            state, resume_from, shardings
        )
        if restored is not None:
            state, _ = restored
    forest, slot_node, slot_loc, rng, level, cache = state
    while (
        int(level) < config.max_depth
        and bool(np.any(np.asarray(slot_node) >= 0))
    ):
        forest, slot_node, slot_loc, rng, level, cache = step_fn(
            xb, base_dev, w_dev, mask_dev,
            forest, slot_node, slot_loc, rng, level, cache,
        )
        if manager is not None:
            manager.maybe_save(
                (forest, slot_node, slot_loc, rng, level, cache), int(level)
            )
        if on_level is not None:
            on_level(int(level), forest)
    return finalize_forest(forest)




def grow_forest_streamed_sharded(
    x_binned,
    y: np.ndarray,
    weights: np.ndarray,
    config: ForestConfig,
    mesh: Mesh,
    feature_mask: Optional[np.ndarray] = None,
    *,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    prefetch: int = 2,
    manager=None,
    resume_from: Optional[str] = None,
    on_level=None,
    feeder_opts: Optional[dict] = None,
) -> Forest:
    """Out-of-core growth on the **mesh** plane — the streaming data
    plane composed with ``MeshPlane``'s collectives, lifting the
    per-host memory cap on the distributed path too.

    Per (block, level), ONE jitted ``shard_map`` call runs
    ``engine.stream_block_step`` on every device: each shard routes its
    (sample x feature) slice of the block (the winning feature's
    go-right bit broadcast by ``MeshPlane.broadcast_route``'s masked
    psum) and folds it into its **local** histogram partial — the
    ``combine_hist`` collective (psum or psum_scatter, per
    ``config.hist_reduce``) runs once per level in the plan step, not
    once per block, so streaming adds zero extra collective traffic.
    The per-shard partials live in a ``[D, k, S, F, B, C]`` carry
    sharded ``P(sample_axes, ..., feature_axis)`` (each data shard owns
    its row), and the per-sample slot table stays device-resident
    sharded ``P(None, sample_axes)``.

    Blocks are padded host-side to a multiple of the data-axis size
    with parked samples (``slot = -1``, zero weight) — invisible to
    histograms, routing, and root counts — so any block split shards.
    The result is bit-identical to resident ``_grow_sharded`` growth
    and to the local planes (the engine parity matrix).

    **Checkpointing** mirrors ``grow_forest_streamed``: ``manager``
    saves the driver's full inter-level carry (forest, frontier, level
    plan, per-block slot tables) after each level; ``resume_from``
    restores the latest carry — slot tables back to their
    ``P(None, sample_axes)`` sharding — and the level loop continues
    where it stopped, bit-identically. ``feeder_opts`` forwards
    retry/backoff/fault-injection knobs to the ``BlockFeeder``.
    """
    from .api import _stream_setup

    sample_axes = tuple(sample_axes)
    feeder0, y_np, w_np, sizes, offsets = _stream_setup(
        x_binned, y, weights, config, prefetch
    )
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    k, S = config.n_trees, config.frontier
    F = feeder0.blocks[0].shape[1]
    B = config.n_bins
    C = 3 if config.regression else config.n_classes

    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    row_sh = NamedSharding(mesh, P(sample_axes))
    kn_sh = NamedSharding(mesh, P(None, sample_axes))
    rep_sh = NamedSharding(mesh, P())
    hist_spec = P(sample_axes, None, None, feature_axis)

    # Sibling-subtraction reuse (config.hist_reuse): per-block partials
    # scatter into R rank segments instead of S slots — the [D, k, R,
    # F, B, C] carry AND the per-level combine halve — and the plan
    # step reconstructs large children from the durable cache. The
    # cache histogram is feature-sharded exactly like the checkpointed
    # resident path's.
    Fl = F // int(mesh.shape[feature_axis])
    use_rs = (
        config.hist_reduce == "psum_scatter"
        and len(sample_axes) == 1 and Fl % D == 0
    )
    reuse = resolve_hist_reuse(config, Fl)
    n_rows = config.max_splits_per_level if reuse else S
    cache_sh = None
    if reuse:
        hist_axes = (feature_axis, sample_axes[0]) if use_rs else feature_axis
        cache_sh = {
            "hist": NamedSharding(mesh, P(None, None, hist_axes)),
            "perm": rep_sh, "parent": rep_sh, "small_right": rep_sh,
        }
        cache_specs = {
            "hist": P(None, None, hist_axes),
            "perm": P(), "parent": P(), "small_right": P(),
        }

    from ..data.pipeline import BlockFeeder

    pads = [(-n) % D for n in sizes]
    feeder = BlockFeeder(
        [_pad_rows(b, p) for b, p in zip(feeder0.blocks, pads)],
        placement=x_sh, prefetch=prefetch, **(feeder_opts or {}),
    )

    from .api import _channels

    base_dev, w_dev, slot_dev = [], [], []
    for i, p in enumerate(pads):
        o0, o1 = offsets[i], offsets[i + 1]
        # Channels built on device by the same _channels every other
        # plane uses; pad rows are zero-weight + parked, so their
        # channel content is irrelevant.
        base_dev.append(_channels(
            jax.device_put(_pad_rows(y_np[o0:o1], p), row_sh), config,
        ))
        w_dev.append(jax.device_put(_pad_rows(w_np[:, o0:o1].T, p).T, kn_sh))
        slot0 = np.zeros((k, sizes[i] + p), np.int32)
        slot0[:, sizes[i]:] = -1                    # pad rows stay parked
        slot_dev.append(jax.device_put(slot0, kn_sh))

    mask_np = (
        np.ones((k, F), bool) if feature_mask is None
        else np.asarray(feature_mask, bool)
    )
    mask_dev = jax.device_put(mask_np, NamedSharding(mesh, P(None, feature_axis)))

    def make_plane(Fl, mask_loc=None):
        return MeshPlane(
            config, Fl, mask_loc,
            sample_axes=sample_axes, feature_axis=feature_axis,
        )

    def step_kernel_route(hist_part, xb_loc, base_loc, w_loc, slot_loc,
                          slot_node, split_rank, scores, small_right=None):
        h, slot_loc = stream_block_step(
            hist_part[0], xb_loc, base_loc, w_loc, slot_loc, slot_node,
            split_rank, scores, config, make_plane(xb_loc.shape[1]),
            route=True, small_right=small_right,
        )
        return h[None], slot_loc

    def step_kernel_first(hist_part, xb_loc, base_loc, w_loc, slot_loc,
                          slot_node, small_right=None):
        h, slot_loc = stream_block_step(
            hist_part[0], xb_loc, base_loc, w_loc, slot_loc, slot_node,
            None, None, config, make_plane(xb_loc.shape[1]), route=False,
            small_right=small_right,
        )
        return h[None], slot_loc

    data_specs = (hist_spec, P(sample_axes, feature_axis), P(sample_axes),
                  P(None, sample_axes), P(None, sample_axes), P())
    sr_specs = (P(),) if reuse else ()
    step_route = jax.jit(_shard_map(
        step_kernel_route, mesh=mesh,
        in_specs=data_specs + (P(), P()) + sr_specs,
        out_specs=(hist_spec, P(None, sample_axes)),
    ))
    step_first = jax.jit(_shard_map(
        step_kernel_first, mesh=mesh,
        in_specs=data_specs + sr_specs,
        out_specs=(hist_spec, P(None, sample_axes)),
    ))

    split_be = resolve_split_backend(config.split_backend)

    def _root_init(forest, hist_c):
        # Root counts: any feature's bin marginal of the level-0
        # histogram (slot/rank row 0) sums to the [k, C] root class
        # counts (identical on every shard — exact integer sums).
        root = hist_c[:, 0, 0].sum(axis=1)
        forest = dataclasses.replace(
            forest, class_counts=forest.class_counts.at[:, 0].set(root),
        )
        if config.regression:
            forest = dataclasses.replace(
                forest, value=forest.value.at[:, 0].set(_safe_mean(root)),
            )
        return forest

    def make_plan(init: bool):
        def plan_kernel(hist_part, forest, slot_node, level, mask_loc):
            plane = make_plane(hist_part.shape[3], mask_loc)
            hist_c = plane.combine_hist(hist_part[0])
            if init:
                forest = _root_init(forest, hist_c)
            scores_loc, n_loc = level_scores(
                hist_c, plane.level_mask, regression=config.regression,
                backend=split_be,
            )
            scores, n_node = plane.merge_winners(scores_loc, n_loc)
            split_rank, is_split, child_base = plan_level(
                scores, n_node, slot_node, config, level
            )
            forest = write_level(
                forest, slot_node, split_rank, is_split, child_base, scores,
                config,
            )
            return (
                forest, scores, split_rank,
                next_frontier(is_split, child_base, config.frontier),
            )

        def plan_kernel_reuse(hist_part, forest, slot_node, level, mask_loc,
                              cache):
            plane = make_plane(hist_part.shape[3], mask_loc)
            hist_c = plane.combine_hist(hist_part[0])   # packed: half the wire
            if init:
                forest = _root_init(forest, hist_c)
            scores, n_node, hist2, perm = reuse_expand_scores(
                hist_c, cache, plane.level_mask, config
            )
            scores, n_node = plane.merge_winners(scores, n_node)
            split_rank, is_split, child_base = plan_level(
                scores, n_node, slot_node, config, level
            )
            forest = write_level(
                forest, slot_node, split_rank, is_split, child_base, scores,
                config,
            )
            parent, small_right = sibling_plan(
                scores, split_rank, is_split,
                n_ranks=config.max_splits_per_level,
                regression=config.regression,
            )
            return (
                forest, scores, split_rank,
                next_frontier(is_split, child_base, config.frontier),
                {"hist": hist2, "perm": perm,
                 "parent": parent, "small_right": small_right},
            )

        if reuse:
            return jax.jit(_shard_map(
                plan_kernel_reuse, mesh=mesh,
                in_specs=(hist_spec, P(), P(), P(), P(None, feature_axis),
                          cache_specs),
                out_specs=(P(), P(), P(), P(), cache_specs),
            ))
        return jax.jit(_shard_map(
            plan_kernel, mesh=mesh,
            in_specs=(hist_spec, P(), P(), P(), P(None, feature_axis)),
            out_specs=(P(), P(), P(), P()),
        ))

    plan_init, plan_next = make_plan(True), make_plan(False)

    hist0 = jax.device_put(
        jnp.zeros((D, k, n_rows, F, B, C), jnp.float32),
        NamedSharding(mesh, hist_spec),
    )

    state = None
    if resume_from is not None:
        from ..checkpoint.checkpoint import restore_latest_valid
        from .api import _stream_state_like

        # The like-template is GLOBAL-shaped: cache width F (the mesh
        # shards its feature dim per cache_sh on restore).
        like = _stream_state_like(
            [n + p for n, p in zip(sizes, pads)], config,
            F if reuse else 0,
        )
        shardings = jax.tree_util.tree_map(lambda _: rep_sh, like)
        shardings["slots"] = [kn_sh for _ in like["slots"]]
        if reuse:
            shardings["hist_cache"] = cache_sh
        restored = restore_latest_valid(like, resume_from, shardings)
        if restored is not None:
            state, _ = restored
    if state is not None:
        forest, slot_node = state["forest"], state["slot_node"]
        scores, split_rank = state["scores"], state["split_rank"]
        slot_dev, start = list(state["slots"]), int(state["level"])
        cache = state.get("hist_cache") if reuse else None
    else:
        slot_node = jax.device_put(
            jnp.full((k, S), -1, jnp.int32).at[:, 0].set(0), rep_sh
        )
        forest, scores, split_rank = None, None, None
        start = 0
        # Global cache width F — device_put shards dim 2 per cache_sh.
        cache = (
            jax.device_put(init_hist_cache(config, F), cache_sh)
            if reuse else None
        )

    def level_sweep(route: bool):
        hist = hist0
        sr = ((cache["small_right"],) if reuse else ())
        for i, xb_b in enumerate(feeder.sweep()):
            if route:
                hist, slot_dev[i] = step_route(
                    hist, xb_b, base_dev[i], w_dev[i], slot_dev[i],
                    slot_node, split_rank, scores, *sr,
                )
            else:
                hist, slot_dev[i] = step_first(
                    hist, xb_b, base_dev[i], w_dev[i], slot_dev[i], slot_node,
                    *sr,
                )
        return hist

    try:
        for level in range(start, config.max_depth):
            if not np.any(np.asarray(slot_node) >= 0):
                break
            hist = level_sweep(route=level > 0)
            plan = plan_next if forest is not None else plan_init
            if forest is None:
                forest = jax.device_put(init_forest(config), rep_sh)
            if reuse:
                forest, scores, split_rank, slot_node, cache = plan(
                    hist, forest, slot_node, jnp.asarray(level, jnp.int32),
                    mask_dev, cache,
                )
            else:
                forest, scores, split_rank, slot_node = plan(
                    hist, forest, slot_node, jnp.asarray(level, jnp.int32),
                    mask_dev,
                )
            if manager is not None:
                manager.maybe_save({
                    "forest": forest, "slot_node": slot_node,
                    "scores": scores, "split_rank": split_rank,
                    "slots": slot_dev, "hist_cache": cache,
                    "level": jnp.asarray(level + 1, jnp.int32),
                }, level + 1)
            if on_level is not None:
                on_level(level + 1, forest)

        if forest is None:          # max_depth == 0: root node only
            def root_kernel(hist_part):
                plane = make_plane(hist_part.shape[3])
                hist_c = plane.combine_hist(hist_part[0])
                return hist_c[:, 0, 0].sum(axis=1)

            root_fn = jax.jit(_shard_map(
                root_kernel, mesh=mesh, in_specs=(hist_spec,), out_specs=P(),
            ))
            root = root_fn(level_sweep(route=False))
            forest = init_forest(config)
            forest = dataclasses.replace(
                forest, class_counts=forest.class_counts.at[:, 0].set(root)
            )
            if config.regression:
                forest = dataclasses.replace(
                    forest, value=forest.value.at[:, 0].set(_safe_mean(root))
                )
    finally:
        feeder.close()
    return finalize_forest(forest)


def oob_accuracy_streamed_sharded(
    forest: Forest,
    x_binned,
    y: np.ndarray,
    weights: np.ndarray,
    mesh: Mesh,
    *,
    sample_block: int = 0,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    prefetch: int = 2,
) -> jnp.ndarray:
    """Eq. (8) over host sample blocks on the mesh — per block, each
    shard routes its slice and psums its [k] correct/OOB partial counts;
    the counts accumulate across blocks (exact f32 integers, so the
    result is bit-identical to resident ``_oob_weights_sharded`` /
    single-host ``oob_accuracy``). Padded rows are masked via an
    explicit validity channel (their zero weight would otherwise read
    as OOB)."""
    from ..data.pipeline import BlockFeeder, stream_blocks

    sample_axes = tuple(sample_axes)
    y_np = np.asarray(y)
    w_np = np.asarray(weights, dtype=np.float32)
    blocks = stream_blocks(
        x_binned, sample_block, what="oob_accuracy_streamed_sharded",
        n_y=y_np.shape[0], n_w=w_np.shape[1],
    )
    sizes = [b.shape[0] for b in blocks]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    pads = [(-n) % D for n in sizes]

    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    row_sh = NamedSharding(mesh, P(sample_axes))
    kn_sh = NamedSharding(mesh, P(None, sample_axes))
    feeder = BlockFeeder(
        [_pad_rows(np.asarray(b), p) for b, p in zip(blocks, pads)],
        placement=x_sh, prefetch=prefetch,
    )

    def kernel(xb_loc, y_loc, w_loc, valid_loc):
        leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
        counts = jnp.take_along_axis(
            forest.class_counts, leaves[..., None], axis=1
        )
        pred = jnp.argmax(counts, axis=-1)                       # [k, Nl]
        oob = (w_loc == 0.0).astype(jnp.float32) * valid_loc[None]
        correct = jax.lax.psum(
            jnp.sum(oob * (pred == y_loc[None]).astype(jnp.float32), 1),
            sample_axes,
        )
        total = jax.lax.psum(jnp.sum(oob, 1), sample_axes)
        return correct, total

    fn = jax.jit(_shard_map(
        kernel, mesh=mesh,
        in_specs=(P(sample_axes, feature_axis), P(sample_axes),
                  P(None, sample_axes), P(sample_axes)),
        out_specs=(P(), P()),
    ))

    k = w_np.shape[0]
    correct = jnp.zeros((k,), jnp.float32)
    total = jnp.zeros((k,), jnp.float32)
    for i, xb_b in enumerate(feeder.sweep()):
        o0, o1 = offsets[i], offsets[i + 1]
        valid = np.zeros(sizes[i] + pads[i], np.float32)
        valid[:sizes[i]] = 1.0
        c, t = fn(
            xb_b,
            jax.device_put(_pad_rows(y_np[o0:o1], pads[i]), row_sh),
            jax.device_put(_pad_rows(w_np[:, o0:o1].T, pads[i]).T, kn_sh),
            jax.device_put(valid, row_sh),
        )
        correct, total = correct + c, total + t
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def predict_streamed_sharded(
    forest: Forest,
    x_binned,
    mesh: Mesh,
    *,
    sample_block: int = 0,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
    prefetch: int = 2,
) -> np.ndarray:
    """Distributed Eq. (10) prediction over host sample blocks — labels
    are per-sample, so the blocked sweep is bit-identical to
    ``predict_sharded`` on the full matrix; only one padded block is
    device-resident at a time. Returns [N] labels (host array)."""
    from ..data.pipeline import BlockFeeder, stream_blocks

    sample_axes = tuple(sample_axes)
    blocks = stream_blocks(
        x_binned, sample_block, what="predict_streamed_sharded"
    )
    sizes = [b.shape[0] for b in blocks]
    D = int(np.prod([mesh.shape[a] for a in sample_axes]))
    pads = [(-n) % D for n in sizes]
    x_sh = NamedSharding(mesh, P(sample_axes, feature_axis))
    feeder = BlockFeeder(
        [_pad_rows(np.asarray(b), p) for b, p in zip(blocks, pads)],
        placement=x_sh, prefetch=prefetch,
    )
    fn = jax.jit(_shard_map(
        partial(_vote_labels_kernel, forest, feature_axis=feature_axis),
        mesh=mesh,
        in_specs=(P(sample_axes, feature_axis),),
        out_specs=P(sample_axes),
    ))
    out = [
        np.asarray(fn(xb_b))[:sizes[i]] for i, xb_b in enumerate(feeder.sweep())
    ]
    return np.concatenate(out)


def _route_sharded(forest: Forest, xb_loc, *, feature_axis: str):
    """route_to_leaves when features are sharded over `feature_axis`."""
    k = forest.feature.shape[0]
    Nl, Fl = xb_loc.shape
    depth = forest.config.max_depth
    midx = jax.lax.axis_index(feature_axis)
    xb = xb_loc.astype(jnp.int32)

    def step(node, _):
        f = jnp.take_along_axis(forest.feature, node, 1)             # [k, Nl]
        leaf = f < 0
        f_shard = jnp.where(leaf, -1, f // Fl)
        f_here = jnp.where(f_shard == midx, f - midx * Fl, 0)
        b = _gather_feature_bins(xb, f_here)
        thr = jnp.take_along_axis(forest.threshold, node, 1)
        go_loc = jnp.where(f_shard == midx, (b > thr).astype(jnp.int32), 0)
        go = jax.lax.psum(go_loc, feature_axis)
        lc = jnp.take_along_axis(forest.left_child, node, 1)
        return jnp.where(leaf, node, lc + go), None

    node0 = jnp.zeros((k, Nl), jnp.int32)
    leaves, _ = jax.lax.scan(step, node0, None, length=depth)
    return leaves


def _dimred_sharded(xb_loc, base_loc, w_loc, config, key, *, sample_axes, feature_axis):
    """Distributed Alg. 3.1: local GR + global VI ranking."""
    k, Nl = w_loc.shape
    Fl = xb_loc.shape[1]
    slot0 = jnp.zeros((k, Nl), jnp.int32)
    hist = level_histograms(
        xb_loc, base_loc, w_loc, slot0, n_slots=1, n_bins=config.n_bins,
        backend=config.hist_backend,
    )
    hist = jax.lax.psum(hist, sample_axes)
    gr_loc = multiway_gain_ratio(hist[:, 0])                         # [k, Fl]
    gr = jax.lax.all_gather(gr_loc, feature_axis, axis=1, tiled=True)  # [k, F]
    from .dimred import select_features

    cfg = config.resolved(gr.shape[1])
    mask = select_features(
        gr, key, n_selected=cfg.n_selected, n_important=cfg.n_important
    )
    midx = jax.lax.axis_index(feature_axis)
    return jax.lax.dynamic_slice_in_dim(mask, midx * Fl, Fl, axis=1)


def _oob_weights_sharded(forest, xb_loc, y_loc, w_loc, *, sample_axes, feature_axis):
    """Eq. (8) with samples and features sharded."""
    leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
    counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
    pred = jnp.argmax(counts, axis=-1)                               # [k, Nl]
    oob = (w_loc == 0.0).astype(jnp.float32)
    correct = jax.lax.psum(
        jnp.sum(oob * (pred == y_loc[None]).astype(jnp.float32), 1), sample_axes
    )
    total = jax.lax.psum(jnp.sum(oob, 1), sample_axes)
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def make_prf_train_fn(
    config: ForestConfig,
    mesh: Mesh,
    *,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
):
    """Build the jit'd distributed PRF trainer for `mesh`.

    Returns (train_fn, in_shardings): ``train_fn(x_binned, y, seed_key)``
    -> Forest (replicated). This is the function the multi-pod dry-run
    lowers and compiles.
    """
    sample_axes = tuple(sample_axes)
    x_spec = P(sample_axes, feature_axis)
    y_spec = P(sample_axes)

    def train(x_binned, y, key):
        def kernel(xb_loc, y_loc, key):
            k_boot, k_dim = jax.random.split(
                jax.random.fold_in(key, _multi_axis_index(sample_axes))
            )
            Nl = xb_loc.shape[0]
            base_loc = (
                regression_channels(y_loc)
                if config.regression
                else class_channels(y_loc, config.n_classes)
            )
            # Stratified DSI bootstrap (see module docstring).
            w_loc = bootstrap_counts(k_boot, config.n_trees, Nl)

            mask_loc = None
            if config.feature_mode == "importance" and not config.regression:
                # identical key across shards => identical global mask
                k_dim_g = jax.random.fold_in(key, 7)
                mask_loc = _dimred_sharded(
                    xb_loc, base_loc, w_loc, config, k_dim_g,
                    sample_axes=sample_axes, feature_axis=feature_axis,
                )
            elif config.feature_mode == "random":
                from .dimred import random_feature_mask

                cfg = config.resolved(x_binned.shape[1])
                mask = random_feature_mask(
                    jax.random.fold_in(key, 7),
                    n_trees=config.n_trees,
                    n_features=x_binned.shape[1],
                    n_selected=cfg.n_selected,
                )
                midx = jax.lax.axis_index(feature_axis)
                Fl = xb_loc.shape[1]
                mask_loc = jax.lax.dynamic_slice_in_dim(mask, midx * Fl, Fl, 1)

            forest = _grow_sharded(
                xb_loc, base_loc, w_loc, mask_loc, config,
                sample_axes=sample_axes, feature_axis=feature_axis,
            )
            if config.weighted_voting and not config.regression:
                w = _oob_weights_sharded(
                    forest, xb_loc, y_loc, w_loc,
                    sample_axes=sample_axes, feature_axis=feature_axis,
                )
                forest = dataclasses.replace(forest, tree_weight=w)
            return forest

        return _shard_map(
            kernel,
            mesh=mesh,
            in_specs=(x_spec, y_spec, P()),
            out_specs=P(),
        )(x_binned, y, key)

    in_shardings = (
        NamedSharding(mesh, x_spec),
        NamedSharding(mesh, y_spec),
        NamedSharding(mesh, P()),
    )
    return jax.jit(train, in_shardings=in_shardings), in_shardings


def _vote_labels_kernel(forest: Forest, xb_loc, *, feature_axis: str):
    """Per-device Eq. (10) voting over a feature-sharded block — the ONE
    kernel behind both the resident ``predict_sharded`` and the
    mesh-streamed ``predict_streamed_sharded`` sweeps."""
    leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
    counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
    probs = counts / jnp.maximum(counts.sum(-1, keepdims=True), 1e-38)
    w = (
        forest.tree_weight
        if forest.config.weighted_voting
        else jnp.ones_like(forest.tree_weight)
    )
    from .voting import weighted_vote

    scores = weighted_vote(probs, w, soft=forest.config.soft_voting)
    return jnp.argmax(scores, -1)


def predict_sharded(forest: Forest, x_binned, mesh, *,
                    sample_axes=("data",), feature_axis="model"):
    """Distributed weighted-voting prediction (Eq. 10). Returns [N] labels."""
    sample_axes = tuple(sample_axes)
    fn = _shard_map(
        partial(_vote_labels_kernel, forest, feature_axis=feature_axis),
        mesh=mesh,
        in_specs=(P(sample_axes, feature_axis),),
        out_specs=P(sample_axes),
    )
    return jax.jit(fn)(x_binned)


# ---------------------------------------------------------------------------
# Distributed bin-edge fitting (blocked quantile sketch over the mesh)
# ---------------------------------------------------------------------------


def fit_bins_sharded(
    x,
    n_bins: int,
    mesh: Mesh,
    *,
    sample_block: int,
    sample_axes: Sequence[str] = ("data",),
    max_size: Optional[int] = None,
    exclude_masks=None,
) -> np.ndarray:
    """Distributed bin-edge fitting: one quantile sketch per data shard,
    exchanged through the collective plane, merged host-side.

    The block list (``sample_blocks`` views of the source — typically an
    ``np.memmap``) is partitioned contiguously over the ``sample_axes``
    shards; each shard folds only its own blocks into a
    ``StreamingQuantileSketch``, so per-shard memory stays O(block) +
    O(F * max_size) — in a multi-process mesh each host would feed its
    local shard of the file. The per-feature summaries then cross the
    mesh as raw float64 **bit patterns** (uint32 words) through one
    ``all_gather`` over ``sample_axes`` — exact regardless of jax's x64
    mode — and are merged in shard order on the host. The result is
    deterministic, and while every summary is uncompressed it is bitwise
    identical to single-host ``fit_bins_blocked`` over the same blocks
    (and therefore to the resident ``fit_bins`` at that scale). Wire
    cost: ``D * F * 2 * max_size * 16`` bytes on the gather.

    ``exclude_masks`` (sequence or dict keyed by global block index)
    carries the validator's imputed-cell masks, exactly as in
    ``fit_bins_blocked``. Per-shard sample counts and compression flags
    are host-side bookkeeping only — edges depend solely on the gathered
    summaries.
    """
    from ..data.pipeline import stream_blocks
    from .binning import (
        DEFAULT_SKETCH_SIZE, StreamingQuantileSketch, validate_n_bins,
    )

    n_bins = validate_n_bins(n_bins)
    if max_size is None:
        max_size = DEFAULT_SKETCH_SIZE
    blocks = stream_blocks(x, sample_block, what="fit_bins_sharded")
    n_features = int(np.asarray(blocks[0]).shape[1])
    axes = tuple(sample_axes)
    n_shards = 1
    for a in axes:
        n_shards *= int(mesh.shape[a])
    parts = np.array_split(np.arange(len(blocks)), n_shards)

    # Summaries never exceed 2 * max_size points (the sketch recompresses
    # past that), so every shard ships the same fixed-width payload.
    width = 2 * max_size
    payloads = np.zeros((n_shards, n_features, width, 4), np.uint32)
    states = []
    for d in range(n_shards):
        sk = StreamingQuantileSketch(n_features, max_size=max_size)
        for i in parts[d]:
            i = int(i)
            if exclude_masks is None:
                mask = None
            elif isinstance(exclude_masks, dict):
                mask = exclude_masks.get(i)
            else:
                mask = exclude_masks[i]
            sk.update(np.asarray(blocks[i]), exclude=mask)
        st = sk.state(pad_to=width)
        packed = np.ascontiguousarray(
            np.stack([st["values"], st["weights"]], axis=-1)
        )  # [F, width, 2] float64
        payloads[d] = packed.view(np.uint32).reshape(n_features, width, 4)
        states.append(st)

    def _exchange(p_loc):
        g = p_loc  # [1, F, width, 4] per shard
        for a in reversed(axes):
            g = jax.lax.all_gather(g, a, axis=0, tiled=True)
        return g

    gathered = jax.jit(_shard_map(
        _exchange, mesh=mesh,
        in_specs=(P(axes),),
        out_specs=P(),
    ))(jnp.asarray(payloads))
    gathered = np.ascontiguousarray(np.asarray(jax.device_get(gathered)))

    merged = None
    for d in range(n_shards):
        unpacked = gathered[d].view(np.float64).reshape(n_features, width, 2)
        st = dict(states[d])
        st["values"] = unpacked[..., 0]
        st["weights"] = unpacked[..., 1]
        sk_d = StreamingQuantileSketch.from_state(st)
        merged = sk_d if merged is None else merged.merge(sk_d)
    return merged.edges(n_bins)
