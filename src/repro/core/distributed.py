"""Distributed PRF — vertical data-partitioning on a device mesh (paper §4).

Sharding layout (the paper's data-parallel optimization, §4.1):

  x_binned [N, F] : P(sample_axes, feature_axis)   <- vertical partitioning:
                    features pinned to `model` shards, samples to `data`
  y        [N]    : P(sample_axes)
  weights  [k, N] : P(None, sample_axes)           <- DSI counts, §4.1.2
  forest          : replicated (small)

Communication structure (== the paper's task DAG, §4.2):

  T_GR   per-device histograms over its (sample x feature) block, then one
         ``psum`` over the sample axes — the *only* large collective.
         Features never move; gain-ratio math is local to feature shards
         (paper: "tasks dispatched to the slaves where the subset is
         located", LocalScheduler).
  T_NS   each shard scores its own post-combine feature slice with the
         split backend selected by ``config.split_backend`` (the fused
         pallas split-scan kernel on TPU — histogram slabs consumed in
         VMEM, only per-(tree, slot) winners emerge), then winners are
         argmax-merged across shards: an ``all_gather`` of the [k, S]
         per-shard best gain ratios + masked ``psum``s of the tiny
         O(k*S) winner descriptors and the per-sample go-left/right bits
         (paper: ClusterScheduler synchronization point). Histogram
         slabs are never shipped to a central scorer.

Bootstrap is *stratified per sample-shard* (each shard draws N_local of
its own N_local rows): the Spark implementation samples globally; the
stratified variant has identical marginal statistics, lower variance, and
needs no cross-shard index exchange. Noted as an adaptation in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: `jax.shard_map(check_vma=)` on
    >= 0.6, `jax.experimental.shard_map.shard_map(check_rep=)` before."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm

    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)

from .dsi import bootstrap_counts
from .engine import CollectivePlane, _gather_feature_bins, grow
from .gain import SplitScores, multiway_gain_ratio
from .histograms import class_channels, level_histograms, regression_channels
from .types import Forest, ForestConfig


def _axis_size(a: str) -> int:
    """`jax.lax.axis_size` compat (absent before jax 0.5): psum of the
    literal 1 over a named axis constant-folds to the axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    return fn(a) if fn is not None else jax.lax.psum(1, a)


def _multi_axis_index(axes: Sequence[str]) -> jnp.ndarray:
    """Linearized index over possibly-multiple mesh axes (row-major)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def _masked_psum(val, mine, axis):
    """Select `val` from the shard where `mine` is True; result on all shards."""
    return jax.lax.psum(jnp.where(mine, val, jnp.zeros_like(val)), axis)


def _global_best_splits(
    scores: SplitScores, n_node, axes, f_global_local: jnp.ndarray,
    n_bins: int,
):
    """T_NS across shards: gather per-shard leaders, pick the winner.

    ``axes``: mesh axes the candidate splits are sharded over — just the
    feature axis in the paper-faithful layout, or (data, feature) when
    the histogram combine is a reduce-scatter (§Perf).
    ``f_global_local``: this shard's features mapped to global ids.

    Equal-gain ties are broken on the smallest global
    ``(feature, threshold)`` key — the order the single-host flat argmax
    uses — NOT on gather order: under the reduce-scatter layout the
    shards' feature ranges interleave over the data axis, so gather
    order disagrees with global feature order and tie-breaking on it
    made ``psum_scatter`` forests diverge from every other plane (the
    paper-faithful psum layout gathers shards in feature order, where
    the two rules coincide). This keeps all planes bit-identical.
    """
    axes = tuple(axes)
    my = _multi_axis_index(axes)
    gr_all = jax.lax.all_gather(scores.gain_ratio, axes)            # [P, k, S]
    best_gr = jnp.max(gr_all, axis=0)
    key = f_global_local * n_bins + scores.threshold                # [k, S]
    key_all = jax.lax.all_gather(key, axes)                         # [P, k, S]
    key_all = jnp.where(gr_all == best_gr, key_all, jnp.iinfo(jnp.int32).max)
    win = jnp.argmin(key_all, axis=0)                               # [k, S]
    mine = win == my
    f_global = _masked_psum(f_global_local, mine, axes)
    thr = _masked_psum(scores.threshold, mine, axes)
    lcnt = _masked_psum(scores.left_counts, mine[..., None], axes)
    rcnt = _masked_psum(scores.right_counts, mine[..., None], axes)
    n_node = _masked_psum(n_node, mine, axes)
    return SplitScores(best_gr, f_global, thr, lcnt, rcnt), n_node, mine


class MeshPlane(CollectivePlane):
    """The engine's collective plane for the vertical-partition mesh.

    T_GR combine strategy (``combine_hist``): plain psum (paper-faithful:
    every sample shard ends with the full feature-shard histogram) or
    reduce-scatter (§Perf: histogram shards over (sample x feature) —
    half the wire bytes, 1/P_data of the redundant gain-ratio compute).
    ``merge_winners`` is the T_NS cross-shard argmax merge
    (``_global_best_splits``), mapping per-shard feature ids to global
    ids first. ``broadcast_route``: the winning feature lives on exactly
    one feature shard; it computes the go-right bit, a masked psum
    broadcasts it (the paper's "result distributed to all slaves").
    """

    def __init__(
        self, config: ForestConfig, n_local_features: int, mask_loc,
        *, sample_axes, feature_axis,
    ):
        self.sample_axes = tuple(sample_axes)
        self.feature_axis = feature_axis
        self.n_bins = config.n_bins
        self.Fl = Fl = n_local_features
        self.midx = jax.lax.axis_index(feature_axis)
        self.use_rs = (
            config.hist_reduce == "psum_scatter"
            and len(self.sample_axes) == 1
            and Fl % _axis_size(self.sample_axes[0]) == 0
        )
        if self.use_rs:
            self.didx = jax.lax.axis_index(self.sample_axes[0])
            self.fl_sub = Fl // _axis_size(self.sample_axes[0])
            mask_src = (
                mask_loc if mask_loc is not None
                else jnp.ones((config.n_trees, Fl), jnp.bool_)
            )
            # Post-scatter each shard scores its (data, feature) slice.
            self.level_mask = jax.lax.dynamic_slice_in_dim(
                mask_src, self.didx * self.fl_sub, self.fl_sub, 1
            )
            self.combine_hist = lambda h: jax.lax.psum_scatter(
                h, self.sample_axes[0], scatter_dimension=2, tiled=True
            )
        else:
            self.level_mask = mask_loc
            self.combine_hist = lambda h: jax.lax.psum(h, self.sample_axes)

    def reduce_root(self, root_counts):
        return jax.lax.psum(root_counts, self.sample_axes)

    def merge_winners(self, scores, n_node):
        if self.use_rs:
            f_glob = scores.feature + self.midx * self.Fl + self.didx * self.fl_sub
            axes = (self.sample_axes[0], self.feature_axis)
        else:
            f_glob = scores.feature + self.midx * self.Fl
            axes = (self.feature_axis,)
        scores, n_node, _ = _global_best_splits(
            scores, n_node, axes, f_glob, self.n_bins
        )
        return scores, n_node

    def broadcast_route(self, xb_loc, f_i, thr_i):
        f_shard = f_i // self.Fl                                 # global ids
        f_here = jnp.where(f_shard == self.midx, f_i - self.midx * self.Fl, 0)
        bins_i = _gather_feature_bins(xb_loc, f_here)            # [k, Nl]
        go_loc = jnp.where(
            f_shard == self.midx, (bins_i > thr_i).astype(jnp.int32), 0
        )
        return jax.lax.psum(go_loc, self.feature_axis)


def _grow_sharded(
    xb_loc, base_loc, w_loc, mask_loc, config: ForestConfig,
    *, sample_axes, feature_axis,
):
    """Level-synchronous growth on one device's (sample x feature) block
    — a thin entry point over the unified engine (core/engine.py)."""
    plane = MeshPlane(
        config, xb_loc.shape[1], mask_loc,
        sample_axes=sample_axes, feature_axis=feature_axis,
    )
    return grow(xb_loc, base_loc, w_loc, config, plane)


def _route_sharded(forest: Forest, xb_loc, *, feature_axis: str):
    """route_to_leaves when features are sharded over `feature_axis`."""
    k = forest.feature.shape[0]
    Nl, Fl = xb_loc.shape
    depth = forest.config.max_depth
    midx = jax.lax.axis_index(feature_axis)
    xb = xb_loc.astype(jnp.int32)

    def step(node, _):
        f = jnp.take_along_axis(forest.feature, node, 1)             # [k, Nl]
        leaf = f < 0
        f_shard = jnp.where(leaf, -1, f // Fl)
        f_here = jnp.where(f_shard == midx, f - midx * Fl, 0)
        b = _gather_feature_bins(xb, f_here)
        thr = jnp.take_along_axis(forest.threshold, node, 1)
        go_loc = jnp.where(f_shard == midx, (b > thr).astype(jnp.int32), 0)
        go = jax.lax.psum(go_loc, feature_axis)
        lc = jnp.take_along_axis(forest.left_child, node, 1)
        return jnp.where(leaf, node, lc + go), None

    node0 = jnp.zeros((k, Nl), jnp.int32)
    leaves, _ = jax.lax.scan(step, node0, None, length=depth)
    return leaves


def _dimred_sharded(xb_loc, base_loc, w_loc, config, key, *, sample_axes, feature_axis):
    """Distributed Alg. 3.1: local GR + global VI ranking."""
    k, Nl = w_loc.shape
    Fl = xb_loc.shape[1]
    slot0 = jnp.zeros((k, Nl), jnp.int32)
    hist = level_histograms(
        xb_loc, base_loc, w_loc, slot0, n_slots=1, n_bins=config.n_bins,
        backend=config.hist_backend,
    )
    hist = jax.lax.psum(hist, sample_axes)
    gr_loc = multiway_gain_ratio(hist[:, 0])                         # [k, Fl]
    gr = jax.lax.all_gather(gr_loc, feature_axis, axis=1, tiled=True)  # [k, F]
    from .dimred import select_features

    cfg = config.resolved(gr.shape[1])
    mask = select_features(
        gr, key, n_selected=cfg.n_selected, n_important=cfg.n_important
    )
    midx = jax.lax.axis_index(feature_axis)
    return jax.lax.dynamic_slice_in_dim(mask, midx * Fl, Fl, axis=1)


def _oob_weights_sharded(forest, xb_loc, y_loc, w_loc, *, sample_axes, feature_axis):
    """Eq. (8) with samples and features sharded."""
    leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
    counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
    pred = jnp.argmax(counts, axis=-1)                               # [k, Nl]
    oob = (w_loc == 0.0).astype(jnp.float32)
    correct = jax.lax.psum(
        jnp.sum(oob * (pred == y_loc[None]).astype(jnp.float32), 1), sample_axes
    )
    total = jax.lax.psum(jnp.sum(oob, 1), sample_axes)
    return jnp.where(total > 0, correct / jnp.maximum(total, 1.0), 0.5)


def make_prf_train_fn(
    config: ForestConfig,
    mesh: Mesh,
    *,
    sample_axes: Sequence[str] = ("data",),
    feature_axis: str = "model",
):
    """Build the jit'd distributed PRF trainer for `mesh`.

    Returns (train_fn, in_shardings): ``train_fn(x_binned, y, seed_key)``
    -> Forest (replicated). This is the function the multi-pod dry-run
    lowers and compiles.
    """
    sample_axes = tuple(sample_axes)
    x_spec = P(sample_axes, feature_axis)
    y_spec = P(sample_axes)

    def train(x_binned, y, key):
        def kernel(xb_loc, y_loc, key):
            k_boot, k_dim = jax.random.split(
                jax.random.fold_in(key, _multi_axis_index(sample_axes))
            )
            Nl = xb_loc.shape[0]
            base_loc = (
                regression_channels(y_loc)
                if config.regression
                else class_channels(y_loc, config.n_classes)
            )
            # Stratified DSI bootstrap (see module docstring).
            w_loc = bootstrap_counts(k_boot, config.n_trees, Nl)

            mask_loc = None
            if config.feature_mode == "importance" and not config.regression:
                # identical key across shards => identical global mask
                k_dim_g = jax.random.fold_in(key, 7)
                mask_loc = _dimred_sharded(
                    xb_loc, base_loc, w_loc, config, k_dim_g,
                    sample_axes=sample_axes, feature_axis=feature_axis,
                )
            elif config.feature_mode == "random":
                from .dimred import random_feature_mask

                cfg = config.resolved(x_binned.shape[1])
                mask = random_feature_mask(
                    jax.random.fold_in(key, 7),
                    n_trees=config.n_trees,
                    n_features=x_binned.shape[1],
                    n_selected=cfg.n_selected,
                )
                midx = jax.lax.axis_index(feature_axis)
                Fl = xb_loc.shape[1]
                mask_loc = jax.lax.dynamic_slice_in_dim(mask, midx * Fl, Fl, 1)

            forest = _grow_sharded(
                xb_loc, base_loc, w_loc, mask_loc, config,
                sample_axes=sample_axes, feature_axis=feature_axis,
            )
            if config.weighted_voting and not config.regression:
                w = _oob_weights_sharded(
                    forest, xb_loc, y_loc, w_loc,
                    sample_axes=sample_axes, feature_axis=feature_axis,
                )
                forest = dataclasses.replace(forest, tree_weight=w)
            return forest

        return _shard_map(
            kernel,
            mesh=mesh,
            in_specs=(x_spec, y_spec, P()),
            out_specs=P(),
        )(x_binned, y, key)

    in_shardings = (
        NamedSharding(mesh, x_spec),
        NamedSharding(mesh, y_spec),
        NamedSharding(mesh, P()),
    )
    return jax.jit(train, in_shardings=in_shardings), in_shardings


def predict_sharded(forest: Forest, x_binned, mesh, *,
                    sample_axes=("data",), feature_axis="model"):
    """Distributed weighted-voting prediction (Eq. 10). Returns [N] labels."""
    sample_axes = tuple(sample_axes)

    def kernel(xb_loc):
        leaves = _route_sharded(forest, xb_loc, feature_axis=feature_axis)
        counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
        probs = counts / jnp.maximum(counts.sum(-1, keepdims=True), 1e-38)
        w = (
            forest.tree_weight
            if forest.config.weighted_voting
            else jnp.ones_like(forest.tree_weight)
        )
        from .voting import weighted_vote

        scores = weighted_vote(probs, w, soft=forest.config.soft_voting)
        return jnp.argmax(scores, -1)

    fn = _shard_map(
        kernel, mesh=mesh,
        in_specs=(P(sample_axes, feature_axis),),
        out_specs=P(sample_axes),
    )
    return jax.jit(fn)(x_binned)
