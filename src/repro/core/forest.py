"""Level-synchronous PRF training & prediction (paper Alg. 4.2, TPU-native).

The paper's task DAG (Fig. 6) maps onto arrays:

* DAG stage  -> one iteration of a ``lax.scan`` over tree depth;
* T_GR tasks -> the [k trees x S frontier slots x F features] histogram +
                gain-ratio tensor computed in one fused step (dual
                parallelism of §4.2.1: trees AND features concurrently);
* T_NS tasks -> the argmax over (feature, threshold) + child allocation.

Trees live in a flat node pool; level L allocates children inside band
``[1 + 2*S*L, 1 + 2*S*(L+1))`` so allocation is pure index math. A beam
limit (``max_frontier``) turns growth into LightGBM-style best-first
expansion and bounds histogram memory at any scale; ``tree_chunk`` bounds
it in the ensemble direction (trees processed in chunks per level — the
paper's "tasks of different trees dispatched in groups").
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .gain import SplitScores, level_scores, node_counts, resolve_split_backend
from .histograms import (
    class_channels, hist_feature_slab, level_histograms, regression_channels,
)
from .types import Forest, ForestConfig


def init_forest(config: ForestConfig) -> Forest:
    k, P = config.n_trees, config.max_nodes + 1  # +1 pad slot
    C = 3 if config.regression else config.n_classes
    return Forest(
        feature=jnp.full((k, P), -1, jnp.int32),
        threshold=jnp.zeros((k, P), jnp.int32),
        left_child=jnp.full((k, P), -1, jnp.int32),
        class_counts=jnp.zeros((k, P, C), jnp.float32),
        value=jnp.zeros((k, P), jnp.float32),
        tree_weight=jnp.ones((k,), jnp.float32),
        config=config,
    )


def _safe_mean(counts: jnp.ndarray) -> jnp.ndarray:
    """Weighted mean ``sum / count`` of [..., C>=2] regression channels,
    0 when the count is 0.

    ``sum / maximum(count, 1e-38)`` is NOT safe here: 1e-38 is a
    subnormal float32, which XLA flushes to zero on CPU/TPU, so
    zero-count slots (every non-split frontier slot writes the pad
    node) silently became 0/0 = NaN. Harmless to the gather-based
    predict path (the pad slot is unreachable), but the fused traversal
    kernel reads every pool row through a one-hot matmul and 0 * NaN
    poisons the scores.
    """
    return jnp.where(
        counts[..., 0] > 0,
        counts[..., 1] / jnp.maximum(counts[..., 0], 1e-38),
        0.0,
    )


def _gather_feature_bins(xb: jnp.ndarray, f: jnp.ndarray) -> jnp.ndarray:
    """bins[t, i] = xb[i, f[t, i]] as ONE flattened gather.

    Replaces the per-tree ``vmap(take_along_axis)`` that re-materialized
    a [k, N] int32 gather per call site per level: broadcasting the row
    index over the tree axis lowers to a single gather of [k, N] pairs.
    """
    return xb.astype(jnp.int32)[jnp.arange(xb.shape[0])[None, :], f]


def _rank_splits(gain: jnp.ndarray, valid: jnp.ndarray, n_max: int) -> jnp.ndarray:
    """Beam selection: rank valid slots by gain, admit top n_max.

    Returns split_rank [k, S] int32 in [0, n_max) for admitted slots, -1 else.
    """
    score = jnp.where(valid, gain, -jnp.inf)
    order = jnp.argsort(-score, axis=-1)
    pos = jnp.argsort(order, axis=-1).astype(jnp.int32)        # rank of each slot
    admitted = valid & (pos < n_max)
    return jnp.where(admitted, pos, -1)


def fused_level_scores(
    x_binned: jnp.ndarray,       # [N, F] uint8
    base_channels: jnp.ndarray,  # [N, C]
    weights: jnp.ndarray,        # [tc, N]
    sample_slot: jnp.ndarray,    # [tc, N]
    feature_mask: Optional[jnp.ndarray],  # [tc, F] bool or None
    config: ForestConfig,
):
    """Fully-fused T_GR -> T_NS: histogram kernel -> split-scan kernel
    per feature slab; the ``[tc, S, F, B, C]`` histogram never exists in
    HBM. Peak histogram footprint is one ``[tc, S, W, B, C]`` slab,
    where ``W = hist_feature_slab(...)`` is the hist kernel's own
    feature block — so per-slab pallas histograms are bit-identical to
    slices of the unfused call, and so are the resulting forests.

    The T_NS argmax rides along as the split-scan kernel's running-best
    carry, threaded through the slab loop; only O(tc*S) descriptors
    survive. Returns (SplitScores, n_node [tc, S]).
    """
    from ..kernels.gain_ratio.kernel import _round_up
    from ..kernels.split_scan.kernel import init_carry, split_scan_block

    tc = weights.shape[0]
    N, F = x_binned.shape
    S, B = config.frontier, config.n_bins
    C = base_channels.shape[-1]
    packed = config.packed_hist and not config.regression
    W = hist_feature_slab(N, F, S, B, C, packed=packed)
    Fp = _round_up(F, W)
    xb = jnp.pad(x_binned, ((0, 0), (0, Fp - F)))
    mask = (
        feature_mask if feature_mask is not None else jnp.ones((tc, F), jnp.bool_)
    )
    mask = jnp.pad(mask, ((0, 0), (0, Fp - F)))   # padded features masked out
    interpret = jax.default_backend() != "tpu"

    def slab(j, carry):
        f0 = j * W
        xb_s = jax.lax.dynamic_slice_in_dim(xb, f0, W, axis=1)
        mask_s = jax.lax.dynamic_slice_in_dim(mask, f0, W, axis=1)
        hist = level_histograms(
            xb_s, base_channels, weights, sample_slot,
            n_slots=S, n_bins=B, packed=packed, backend=config.hist_backend,
        )
        return split_scan_block(
            hist, mask_s, carry, f0,
            regression=config.regression, interpret=interpret,
        )

    carry = jax.lax.fori_loop(0, Fp // W, slab, init_carry(tc, S, C))
    scores = SplitScores(*carry)
    return scores, node_counts(scores, regression=config.regression)


def chunked_level_scores(
    x_binned: jnp.ndarray,       # [N, F] uint8 (local shard in distributed mode)
    base_channels: jnp.ndarray,  # [N, C]
    weights: jnp.ndarray,        # [k, N]
    sample_slot: jnp.ndarray,    # [k, N]
    feature_mask: Optional[jnp.ndarray],  # [k, F] bool or None
    config: ForestConfig,
    *,
    hist_reduce=None,            # optional fn(hist) -> hist (e.g. psum over 'data')
):
    """T_GR + T_NS-stage-1 for all k trees, chunked over the tree axis.

    The histogram tensor only ever exists for ``tree_chunk`` trees at a
    time; only the O(k*S) split descriptors survive the chunk loop.
    With ``split_backend="pallas"`` on the single-host path
    (``hist_reduce is None``) the chunk runs ``fused_level_scores`` and
    the histogram never exists at all beyond one feature slab; the
    distributed path still combines full feature-shard histograms
    (psum / psum_scatter) and applies the fused scorer post-combine.
    Returns (SplitScores [k, S, ...], n_node [k, S]).
    """
    k = config.n_trees
    S = config.frontier
    tc = config.tree_chunk if config.tree_chunk > 0 else k
    tc = min(tc, k)

    packed = config.packed_hist and not config.regression
    split_be = resolve_split_backend(config.split_backend)

    def score_chunk(w_c, slot_c, mask_c):
        if hist_reduce is None and split_be == "pallas":
            return fused_level_scores(
                x_binned, base_channels, w_c, slot_c, mask_c, config
            )
        hist = level_histograms(
            x_binned, base_channels, w_c, slot_c,
            n_slots=S, n_bins=config.n_bins, packed=packed,
            backend=config.hist_backend,
        )
        if hist_reduce is not None:
            hist = hist_reduce(hist)     # psum over the sample axis (T_GR combine)
        return level_scores(
            hist, mask_c, regression=config.regression, backend=split_be
        )

    if tc >= k:
        return score_chunk(weights, sample_slot, feature_mask)

    if k % tc != 0:
        raise ValueError(f"n_trees={k} must be divisible by tree_chunk={tc}")
    nc = k // tc
    # NOTE: the mask's feature dim may be narrower than x_binned's when
    # the histogram reduce scatters features (psum_scatter path).
    mask = (
        feature_mask
        if feature_mask is not None
        else jnp.ones((k, x_binned.shape[1]), jnp.bool_)
    )
    scores, n_node = jax.lax.map(
        lambda args: score_chunk(*args),
        (
            weights.reshape(nc, tc, -1),
            sample_slot.reshape(nc, tc, -1),
            mask.reshape(nc, tc, mask.shape[-1]),
        ),
    )
    scores = jax.tree_util.tree_map(lambda a: a.reshape(k, *a.shape[2:]), scores)
    return scores, n_node.reshape(k, S)


def grow_forest(
    x_binned: jnp.ndarray,          # [N, F] uint8
    y: jnp.ndarray,                 # [N] int32 labels (float for regression)
    weights: jnp.ndarray,           # [k, N] in-bag multiplicities (DSI counts)
    config: ForestConfig,
    feature_mask: Optional[jnp.ndarray] = None,   # [k, F] bool (dim-reduction)
) -> Forest:
    """Train k trees level-synchronously. Pure function of its inputs."""
    return _grow_forest_impl(x_binned, y, weights, config, feature_mask)


@partial(jax.jit, static_argnames=("config",))
def _grow_forest_impl(x_binned, y, weights, config, feature_mask):
    N, F = x_binned.shape
    k, S, B = config.n_trees, config.frontier, config.n_bins
    depth = config.max_depth
    n_max = max(S // 2, 1)
    pad = config.max_nodes          # scatter dump index

    base = (
        regression_channels(y)
        if config.regression
        else class_channels(y, config.n_classes)
    )

    forest = init_forest(config)
    root_counts = jnp.einsum("kn,nc->kc", weights, base)
    forest = dataclasses.replace(
        forest, class_counts=forest.class_counts.at[:, 0].set(root_counts)
    )
    if config.regression:
        forest = dataclasses.replace(
            forest,
            value=forest.value.at[:, 0].set(_safe_mean(root_counts)),
        )

    slot_node = jnp.full((k, S), -1, jnp.int32).at[:, 0].set(0)
    sample_slot = jnp.zeros((k, N), jnp.int32)
    t_idx = jnp.arange(k)[:, None]

    def level_step(carry, level):
        forest, slot_node, sample_slot = carry

        scores, n_node = chunked_level_scores(
            x_binned, base, weights, sample_slot, feature_mask, config
        )

        active = slot_node >= 0
        valid = (
            active
            & (scores.gain_ratio > config.min_gain)
            & (n_node >= config.min_samples_split)
        )
        split_rank = _rank_splits(scores.gain_ratio, valid, n_max)    # [k, S]
        is_split = split_rank >= 0

        child_base = 1 + 2 * n_max * level
        left_id = child_base + 2 * split_rank
        node_or_pad = jnp.where(is_split, slot_node, pad)

        feature = forest.feature.at[t_idx, node_or_pad].set(
            jnp.where(is_split, scores.feature, -1)
        )
        threshold = forest.threshold.at[t_idx, node_or_pad].set(scores.threshold)
        left_child = forest.left_child.at[t_idx, node_or_pad].set(left_id)

        lid = jnp.where(is_split, left_id, pad)
        rid = jnp.where(is_split, left_id + 1, pad)
        class_counts = forest.class_counts.at[t_idx, lid].set(scores.left_counts)
        class_counts = class_counts.at[t_idx, rid].set(scores.right_counts)
        if config.regression:
            lval = _safe_mean(scores.left_counts)
            rval = _safe_mean(scores.right_counts)
            value = forest.value.at[t_idx, lid].set(lval).at[t_idx, rid].set(rval)
        else:
            value = forest.value

        forest = dataclasses.replace(
            forest,
            feature=feature,
            threshold=threshold,
            left_child=left_child,
            class_counts=class_counts,
            value=value,
        )

        # --- route samples to child slots (the paper's "distribute the
        # data-index list of {v01, v02, ...} to the slaves") -------------
        live = sample_slot >= 0
        s_safe = jnp.where(live, sample_slot, 0)
        rank_i = jnp.take_along_axis(split_rank, s_safe, 1)            # [k, N]
        f_i = jnp.take_along_axis(scores.feature, s_safe, 1)
        thr_i = jnp.take_along_axis(scores.threshold, s_safe, 1)
        bins_i = _gather_feature_bins(x_binned, f_i)                   # [k, N]
        go_right = (bins_i > thr_i).astype(jnp.int32)
        new_slot = jnp.where(live & (rank_i >= 0), 2 * rank_i + go_right, -1)

        # --- next level's frontier --------------------------------------
        j = jnp.arange(S)[None, :]
        n_children = 2 * is_split.sum(-1, keepdims=True)
        new_slot_node = jnp.where(j < n_children, child_base + j, -1).astype(jnp.int32)

        return (forest, new_slot_node, new_slot), None

    (forest, _, _), _ = jax.lax.scan(
        level_step, (forest, slot_node, sample_slot), jnp.arange(depth)
    )
    return forest


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------


@jax.jit
def route_to_leaves(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """Leaf pool-id of every sample under every tree. Returns [k, N] int32."""
    k = forest.feature.shape[0]
    N = x_binned.shape[0]
    depth = forest.config.max_depth
    xb = x_binned.astype(jnp.int32)

    def step(node, _):
        f = jnp.take_along_axis(forest.feature, node, 1)               # [k, N]
        leaf = f < 0
        f_safe = jnp.where(leaf, 0, f)
        b = _gather_feature_bins(xb, f_safe)
        thr = jnp.take_along_axis(forest.threshold, node, 1)
        lc = jnp.take_along_axis(forest.left_child, node, 1)
        nxt = lc + (b > thr).astype(jnp.int32)
        return jnp.where(leaf, node, nxt), None

    node0 = jnp.zeros((k, N), jnp.int32)
    leaves, _ = jax.lax.scan(step, node0, None, length=depth)
    return leaves


def predict_proba_trees(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """Per-tree class distributions h_i(x). Returns [k, N, C]."""
    leaves = route_to_leaves(forest, x_binned)
    counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
    return counts / jnp.maximum(counts.sum(-1, keepdims=True), 1e-38)


def predict_value_trees(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """Per-tree regression outputs h_i(x). Returns [k, N]."""
    leaves = route_to_leaves(forest, x_binned)
    return jnp.take_along_axis(forest.value, leaves, axis=1)


@jax.jit
def fused_vote_scores(
    forest: Forest,
    x_binned: jnp.ndarray,      # [N, F] uint8
    payload: jnp.ndarray,       # [k, P, C] weighted per-node vote vectors
) -> jnp.ndarray:
    """Weighted-vote scores via the fused traversal kernel. Returns [N, C].

    The predict-side analogue of ``fused_level_scores``: trees are
    processed in ``tree_chunk`` groups, each chunk's ``pallas_call``
    walking the depth loop in VMEM and folding its votes into the
    ``[N, C]`` score carry threaded through the chunk loop — the
    ``[k, N, C]`` per-tree tensor of the xla path
    (``predict_proba_trees`` -> ``weighted_vote``) never exists
    (jaxpr-verified by tests/test_predict_backends.py). Chunking is
    exact (each tree contributes an exact payload row), so any chunk
    size — including a non-divisible final remainder — gives the same
    scores.
    """
    from ..kernels.tree_traverse.kernel import default_interpret, traverse_block

    k = forest.feature.shape[0]
    config = forest.config
    tc = config.tree_chunk if config.tree_chunk > 0 else k
    tc = min(tc, k)
    interpret = default_interpret()

    carry = None
    for c0 in range(0, k, tc):
        c1 = min(c0 + tc, k)
        carry = traverse_block(
            x_binned,
            forest.feature[c0:c1],
            forest.threshold[c0:c1],
            forest.left_child[c0:c1],
            payload[c0:c1],
            carry,
            depth=config.max_depth,
            interpret=interpret,
        )
    return carry
