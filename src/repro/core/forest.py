"""Single-host PRF training & prediction entry points (paper Alg. 4.2).

Training is one thin call into the unified task-DAG growth engine
(``core/engine.py``): ``grow_forest`` builds a ``LocalPlane`` (identity
collectives — the whole ``[N, F]`` block lives on one device) and runs
the engine's ``lax.while_loop`` level-step. The mesh-sharded trainer
(``core/distributed.py``) and the host-streaming out-of-core driver
(``core.api.grow_forest_streamed``) run the exact same level-step over
their own planes, so the growth logic exists once.

The T_GR/T_NS chunking machinery (``chunked_level_scores``,
``fused_level_scores``) and the shared node-pool helpers live in
``core/engine.py`` and are re-exported here for compatibility.

Prediction (``route_to_leaves`` + the fused traversal path) stays here.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .engine import (  # noqa: F401  (re-exported: training internals)
    LocalPlane, _gather_feature_bins, _rank_splits, _safe_mean,
    chunked_level_scores, fused_level_scores, fused_reuse_level_scores,
    grow, grow_checkpointed, init_forest, resolve_hist_reuse,
    reuse_level_task_group,
)
from .histograms import class_channels, regression_channels
from .types import Forest, ForestConfig


def grow_forest(
    x_binned: jnp.ndarray,          # [N, F] uint8
    y: jnp.ndarray,                 # [N] int32 labels (float for regression)
    weights: jnp.ndarray,           # [k, N] in-bag multiplicities (DSI counts)
    config: ForestConfig,
    feature_mask: Optional[jnp.ndarray] = None,   # [k, F] bool (dim-reduction)
) -> Forest:
    """Train k trees level-synchronously. Pure function of its inputs."""
    return _grow_forest_impl(x_binned, y, weights, config, feature_mask)


def grow_forest_checkpointed(
    x_binned: jnp.ndarray,
    y: jnp.ndarray,
    weights: jnp.ndarray,
    config: ForestConfig,
    feature_mask: Optional[jnp.ndarray] = None,
    *,
    manager=None,
    resume_from: Optional[str] = None,
    on_level=None,
) -> Forest:
    """``grow_forest`` with per-level checkpointing / crash resume.

    A host-driven loop over the engine's jitted ``level_step`` (see
    ``engine.grow_checkpointed``): the forest is bit-identical to
    ``grow_forest``, and a run restored from any level-boundary
    checkpoint finishes with the same trees an uninterrupted run grows
    (tests/test_fault.py kills it at every boundary to pin this).
    """
    base = (
        regression_channels(y)
        if config.regression
        else class_channels(y, config.n_classes)
    )
    return grow_checkpointed(
        x_binned, base, weights, config, LocalPlane(feature_mask),
        manager=manager, resume_from=resume_from, on_level=on_level,
    )


@partial(jax.jit, static_argnames=("config",))
def _grow_forest_impl(x_binned, y, weights, config, feature_mask):
    base = (
        regression_channels(y)
        if config.regression
        else class_channels(y, config.n_classes)
    )
    return grow(x_binned, base, weights, config, LocalPlane(feature_mask))


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------


@jax.jit
def route_to_leaves(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """Leaf pool-id of every sample under every tree. Returns [k, N] int32."""
    k = forest.feature.shape[0]
    N = x_binned.shape[0]
    depth = forest.config.max_depth
    xb = x_binned.astype(jnp.int32)

    def step(node, _):
        f = jnp.take_along_axis(forest.feature, node, 1)               # [k, N]
        leaf = f < 0
        f_safe = jnp.where(leaf, 0, f)
        b = _gather_feature_bins(xb, f_safe)
        thr = jnp.take_along_axis(forest.threshold, node, 1)
        lc = jnp.take_along_axis(forest.left_child, node, 1)
        nxt = lc + (b > thr).astype(jnp.int32)
        return jnp.where(leaf, node, nxt), None

    node0 = jnp.zeros((k, N), jnp.int32)
    leaves, _ = jax.lax.scan(step, node0, None, length=depth)
    return leaves


def predict_proba_trees(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """Per-tree class distributions h_i(x). Returns [k, N, C]."""
    leaves = route_to_leaves(forest, x_binned)
    counts = jnp.take_along_axis(forest.class_counts, leaves[..., None], axis=1)
    return counts / jnp.maximum(counts.sum(-1, keepdims=True), 1e-38)


def predict_value_trees(forest: Forest, x_binned: jnp.ndarray) -> jnp.ndarray:
    """Per-tree regression outputs h_i(x). Returns [k, N]."""
    leaves = route_to_leaves(forest, x_binned)
    return jnp.take_along_axis(forest.value, leaves, axis=1)


@jax.jit
def fused_vote_scores(
    forest: Forest,
    x_binned: jnp.ndarray,      # [N, F] uint8
    payload: jnp.ndarray,       # [k, P, C] weighted per-node vote vectors
) -> jnp.ndarray:
    """Weighted-vote scores via the fused traversal kernel. Returns [N, C].

    The predict-side analogue of ``fused_level_scores``: trees are
    processed in ``tree_chunk`` groups, each chunk's ``pallas_call``
    walking the depth loop in VMEM and folding its votes into the
    ``[N, C]`` score carry threaded through the chunk loop — the
    ``[k, N, C]`` per-tree tensor of the xla path
    (``predict_proba_trees`` -> ``weighted_vote``) never exists
    (jaxpr-verified by tests/test_predict_backends.py). Chunking is
    exact (each tree contributes an exact payload row), so any chunk
    size — including a non-divisible final remainder — gives the same
    scores.
    """
    from ..kernels.tree_traverse.kernel import default_interpret, traverse_block

    k = forest.feature.shape[0]
    config = forest.config
    tc = config.tree_chunk if config.tree_chunk > 0 else k
    tc = min(tc, k)
    interpret = default_interpret()

    carry = None
    for c0 in range(0, k, tc):
        c1 = min(c0 + tc, k)
        carry = traverse_block(
            x_binned,
            forest.feature[c0:c1],
            forest.threshold[c0:c1],
            forest.left_child[c0:c1],
            payload[c0:c1],
            carry,
            depth=config.max_depth,
            interpret=interpret,
        )
    return carry
