"""Public PRF API — train / predict, paper-faithful pipeline.

    bin -> DSI bootstrap -> dimension reduction (Alg. 3.1)
        -> level-synchronous growth (Alg. 4.2) -> OOB weights (Eq. 8)

``train_prf`` is the single-host path; ``repro.core.distributed`` offers
the mesh-sharded version with identical semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .binning import bin_dataset, apply_bins
from .dimred import dimension_reduction, random_feature_mask
from .dsi import bootstrap_counts
from .forest import grow_forest
from .types import Forest, ForestConfig
from .voting import (
    oob_accuracy, oob_r2, predict, predict_regression, predict_scores,
)


@dataclasses.dataclass
class PRFModel:
    """Trained model + the binning transform needed at inference.

    Prediction honors ``forest.config.predict_backend`` ("auto" |
    "pallas" | "xla"): the pallas backend runs the fused
    traversal+voting kernel (``kernels/tree_traverse``) that never
    materializes the ``[k, N, C]`` per-tree tensor; labels are
    identical across backends. For serving (batch bucketing, request
    aggregation, tree-sharded multi-device voting) wrap the model in
    ``repro.serving.PRFService``.
    """

    forest: Forest
    bin_edges: np.ndarray

    def predict(self, x: np.ndarray) -> np.ndarray:
        xb = apply_bins(jnp.asarray(x), jnp.asarray(self.bin_edges))
        if self.forest.config.regression:
            return np.asarray(predict_regression(self.forest, xb))
        return np.asarray(predict(self.forest, xb))

    def predict_scores(self, x: np.ndarray) -> np.ndarray:
        """Weighted-vote class scores [N, C] (classification only)."""
        if self.forest.config.regression:
            raise ValueError(
                "predict_scores is classification-only; use predict() for "
                "regression models"
            )
        xb = apply_bins(jnp.asarray(x), jnp.asarray(self.bin_edges))
        return np.asarray(predict_scores(self.forest, xb))

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == np.asarray(y)))

    def with_predict_backend(self, backend: str) -> "PRFModel":
        """Same model, different prediction backend (config is static)."""
        cfg = dataclasses.replace(self.forest.config, predict_backend=backend)
        return PRFModel(
            forest=dataclasses.replace(self.forest, config=cfg),
            bin_edges=self.bin_edges,
        )


def train_prf(
    x: np.ndarray,
    y: np.ndarray,
    config: ForestConfig,
    seed: int = 0,
) -> PRFModel:
    """End-to-end PRF training on host data (paper §3 + §4 semantics)."""
    config = config.resolved(x.shape[1])
    xb_np, edges = bin_dataset(x, config.n_bins)
    xb = jnp.asarray(xb_np)
    y = jnp.asarray(y)
    key = jax.random.PRNGKey(seed)
    k_boot, k_dim = jax.random.split(key)

    weights = bootstrap_counts(k_boot, config.n_trees, x.shape[0])     # DSI §4.1.2

    feature_mask = None
    if config.feature_mode == "importance" and not config.regression:
        feature_mask = dimension_reduction(xb, y, weights, config, k_dim)  # §3.2
    elif config.feature_mode == "random":
        feature_mask = random_feature_mask(
            k_dim, n_trees=config.n_trees, n_features=x.shape[1],
            n_selected=config.n_selected,
        )                                                              # §3.1 RF

    forest = grow_forest(
        xb, y if not config.regression else y.astype(jnp.float32),
        weights, config, feature_mask
    )                                                                  # §4.2

    if config.weighted_voting:                                         # §3.3
        w = (
            oob_r2(forest, xb, y.astype(jnp.float32), weights)
            if config.regression
            else oob_accuracy(forest, xb, y, weights)
        )
        forest = dataclasses.replace(forest, tree_weight=w)

    return PRFModel(forest=forest, bin_edges=edges)
