"""Public PRF API — train / predict, paper-faithful pipeline.

    bin -> DSI bootstrap -> dimension reduction (Alg. 3.1)
        -> level-synchronous growth (Alg. 4.2) -> OOB weights (Eq. 8)

``train_prf`` is the single-host path; ``repro.core.distributed`` offers
the mesh-sharded version with identical semantics, and
``grow_forest_streamed`` the host-streaming out-of-core growth driver
(sample blocks fed from a NumPy/memmap source — the full ``[N, F]``
matrix is never passed to one device call).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .binning import bin_dataset, apply_bins, fit_bins, fit_bins_blocked
from .dimred import (
    dimension_reduction, dimension_reduction_streamed, random_feature_mask,
)
from .dsi import bootstrap_counts
from .engine import (
    LocalPlane, _safe_mean, finalize_forest, init_forest, init_hist_cache,
    next_frontier, plan_level, resolve_hist_reuse, reuse_expand_scores,
    stream_block_step, write_level,
)
from .forest import grow_forest, grow_forest_checkpointed
from .gain import SplitScores, level_scores, resolve_split_backend, sibling_plan
from .histograms import class_channels, regression_channels
from .types import Forest, ForestConfig
from .voting import (
    oob_accuracy, oob_accuracy_streamed, oob_r2, oob_r2_streamed, predict,
    predict_regression, predict_scores,
)


@dataclasses.dataclass
class PRFModel:
    """Trained model + the binning transform needed at inference.

    Prediction honors ``forest.config.predict_backend`` ("auto" |
    "pallas" | "xla"): the pallas backend runs the fused
    traversal+voting kernel (``kernels/tree_traverse``) that never
    materializes the ``[k, N, C]`` per-tree tensor; labels are
    identical across backends. For serving (batch bucketing, request
    aggregation, tree-sharded multi-device voting) wrap the model in
    ``repro.serving.PRFService``.

    ``quarantine`` is the data-integrity report of the training run
    (``data.pipeline.QuarantineReport``) when ``train_prf`` ran with a
    ``bad_block_policy``; ``None`` when validation was off. A clean
    report (``quarantine.clean``) certifies validation changed nothing.
    """

    forest: Forest
    bin_edges: np.ndarray
    quarantine: Optional[object] = None

    def _streams(self, x: np.ndarray) -> bool:
        """Out-of-core models (``config.sample_block > 0``) also predict
        per sample block — prediction is per-sample, so the blocked
        sweep is bit-identical to the resident call."""
        nb = self.forest.config.sample_block
        return nb > 0 and x.shape[0] > nb

    def _predict_blocks(self, x: np.ndarray, fn) -> np.ndarray:
        """Bin + evaluate one ``sample_block`` at a time: each binned
        block is consumed by ``fn`` before the next is built, so the
        full ``[N, F]`` matrix never becomes device-resident — only the
        per-sample outputs survive the sweep."""
        edges = jnp.asarray(self.bin_edges)
        nb = self.forest.config.sample_block
        return np.concatenate([
            np.asarray(
                fn(apply_bins(jnp.asarray(np.asarray(x[i:i + nb])), edges))
            )
            for i in range(0, x.shape[0], nb)
        ])

    def predict(self, x: np.ndarray) -> np.ndarray:
        regression = self.forest.config.regression
        if self._streams(x):
            fn = predict_regression if regression else predict
            return self._predict_blocks(x, partial(fn, self.forest))
        xb = apply_bins(jnp.asarray(np.asarray(x)), jnp.asarray(self.bin_edges))
        if regression:
            return np.asarray(predict_regression(self.forest, xb))
        return np.asarray(predict(self.forest, xb))

    def predict_scores(self, x: np.ndarray) -> np.ndarray:
        """Weighted-vote class scores [N, C] (classification only)."""
        if self.forest.config.regression:
            raise ValueError(
                "predict_scores is classification-only; use predict() for "
                "regression models"
            )
        if self._streams(x):
            return self._predict_blocks(x, partial(predict_scores, self.forest))
        xb = apply_bins(jnp.asarray(np.asarray(x)), jnp.asarray(self.bin_edges))
        return np.asarray(predict_scores(self.forest, xb))

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == np.asarray(y)))

    def with_predict_backend(self, backend: str) -> "PRFModel":
        """Same model, different prediction backend (config is static)."""
        cfg = dataclasses.replace(self.forest.config, predict_backend=backend)
        return PRFModel(
            forest=dataclasses.replace(self.forest, config=cfg),
            bin_edges=self.bin_edges,
            quarantine=self.quarantine,
        )


def _checkpoint_manager(
    checkpoint_dir: Optional[str], checkpoint_every: int, checkpoint_keep: int
):
    if checkpoint_dir is None:
        return None
    from ..checkpoint.checkpoint import CheckpointManager

    return CheckpointManager(
        checkpoint_dir, keep=checkpoint_keep, save_interval=checkpoint_every
    )


def train_prf(
    x: np.ndarray,
    y: np.ndarray,
    config: ForestConfig,
    seed: int = 0,
    *,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    checkpoint_keep: int = 3,
    resume_from: Optional[str] = None,
    on_level=None,
    feeder_opts: Optional[dict] = None,
    bad_block_policy: Optional[str] = "raise",
) -> PRFModel:
    """End-to-end PRF training on host data (paper §3 + §4 semantics).

    With ``config.sample_block > 0`` the whole pipeline — binning, DSI
    bootstrap, dimension reduction, growth, OOB weights — runs through
    the streaming data plane (``grow_forest_streamed`` and the blocked
    OOB/dimred carriers): ``x`` may be an ``np.memmap`` far larger than
    device memory, the full ``[N, F]`` matrix is never device-resident,
    and the resulting model is bit-identical to the resident path for
    classification (regression channels agree to float rounding).

    **Crash resume.** ``checkpoint_dir`` turns on per-level growth
    checkpointing (every ``checkpoint_every`` levels, ``checkpoint_keep``
    rotated atomic-rename checkpoints); ``resume_from`` restores the
    latest growth carry from that directory and continues. Everything
    before growth — binning, the DSI bootstrap, dimension reduction —
    is a deterministic function of ``(x, y, config, seed)`` and is
    recomputed on resume, so only the growth carry needs to be durable,
    and the resumed run's model is **bit-identical** to an
    uninterrupted one (tests/test_fault.py). An empty ``resume_from``
    directory means "no progress yet": training starts from scratch,
    so a crash-retry wrapper can always pass both knobs.
    ``on_level(level, _)`` fires after each completed (checkpointed)
    level; ``feeder_opts`` forwards retry/fault-injection knobs to the
    streamed path's ``BlockFeeder``. A corrupted or torn newest
    checkpoint in ``resume_from`` is skipped (CRC-verified restore walks
    back to the newest valid step) — resume still lands bit-identical.

    **Data integrity.** ``bad_block_policy`` runs a deterministic
    per-block validator (NaN/Inf cells, out-of-range labels, shape
    drift) over the training source before anything is binned:
    ``"raise"`` (default) fails fast with a typed ``DataIntegrityError``
    naming the block and columns; ``"sanitize"`` deterministically
    imputes (bad cells to bin 0, bad labels neutralized via zero DSI
    weight and excluded from OOB); ``"quarantine"`` drops poisoned
    blocks from every sweep (streamed path only — the resident dataset
    is one block) and records them in ``model.quarantine``; ``None`` /
    ``"off"`` disables validation. On clean data the returned model is
    **bitwise identical** with validation on or off.
    """
    config = config.resolved(x.shape[1])
    if jax.process_count() > 1:
        # Multi-process runtime (launch.multiproc.initialize was called):
        # every process runs the same train_prf call collectively, each
        # feeding only its local rows. Bitwise identical to the
        # single-process planes.
        from .distributed import train_prf_multiproc

        return train_prf_multiproc(
            x, y, config, seed,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            checkpoint_keep=checkpoint_keep, resume_from=resume_from,
            on_level=on_level, feeder_opts=feeder_opts,
            bad_block_policy=bad_block_policy,
        )
    if config.sample_block > 0:
        return _train_prf_streamed(
            x, y, config, seed,
            checkpoint=_checkpoint_manager(
                checkpoint_dir, checkpoint_every, checkpoint_keep
            ),
            resume_from=resume_from, on_level=on_level,
            feeder_opts=feeder_opts, bad_block_policy=bad_block_policy,
        )
    report, cell_mask, label_mask = None, None, None
    if bad_block_policy not in (None, "off"):
        from ..data.pipeline import DataIntegrityError, screen_blocks

        blocks1, y_clean, cmasks, lmasks, report = screen_blocks(
            [np.asarray(x)], np.asarray(y), policy=bad_block_policy,
            n_features=x.shape[1],
            n_classes=None if config.regression else config.n_classes,
            regression=config.regression,
        )
        if not report.clean:
            if bad_block_policy == "quarantine":
                raise DataIntegrityError(
                    "bad_block_policy='quarantine' on the resident path "
                    "would drop the entire dataset (it is a single block) "
                    "— stream it with config.sample_block > 0, or use "
                    "'sanitize'",
                    block_index=0, reason="quarantine",
                )
            x, y = blocks1[0], y_clean
            cell_mask, label_mask = cmasks.get(0), lmasks.get(0)
    if config.resolved_bin_fit() == "blocked":
        # Blocked edge fitting on the resident path (bin_fit="blocked"):
        # same sketch as the streamed trainer, fed with views of x. The
        # validator's imputed cells are excluded from the sketch rather
        # than contributing their imputation constant.
        from ..data.pipeline import sample_blocks

        nb_fit = config.sample_block if config.sample_block > 0 else 65536
        edges = fit_bins_blocked(
            sample_blocks(x, nb_fit), config.n_bins,
            exclude_masks=(
                None if cell_mask is None else sample_blocks(cell_mask, nb_fit)
            ),
        )
        xb_np = np.asarray(apply_bins(jnp.asarray(x), jnp.asarray(edges)))
    else:
        xb_np, edges = bin_dataset(x, config.n_bins)
    if cell_mask is not None:
        xb_np = xb_np.copy()
        xb_np[cell_mask] = 0                 # imputed cells -> bin 0
    xb = jnp.asarray(xb_np)
    y = jnp.asarray(y)
    key = jax.random.PRNGKey(seed)
    k_boot, k_dim = jax.random.split(key)

    weights = bootstrap_counts(k_boot, config.n_trees, x.shape[0])     # DSI §4.1.2
    if label_mask is not None:
        # Imputed-label samples get neutral (zero) weight in every tree.
        weights = jnp.where(jnp.asarray(label_mask)[None, :], 0, weights)

    feature_mask = None
    if config.feature_mode == "importance" and not config.regression:
        feature_mask = dimension_reduction(xb, y, weights, config, k_dim)  # §3.2
    elif config.feature_mode == "random":
        feature_mask = random_feature_mask(
            k_dim, n_trees=config.n_trees, n_features=x.shape[1],
            n_selected=config.n_selected,
        )                                                              # §3.1 RF

    y_grow = y if not config.regression else y.astype(jnp.float32)
    if checkpoint_dir is not None or resume_from is not None:
        forest = grow_forest_checkpointed(
            xb, y_grow, weights, config, feature_mask,
            manager=_checkpoint_manager(
                checkpoint_dir, checkpoint_every, checkpoint_keep
            ),
            resume_from=resume_from, on_level=on_level,
        )                                                              # §4.2
    else:
        forest = grow_forest(xb, y_grow, weights, config, feature_mask)  # §4.2

    if config.weighted_voting:                                         # §3.3
        xb_o, y_o, w_o = xb, y, weights
        if label_mask is not None:
            # Zero-weight == out-of-bag, so imputed-label samples would
            # otherwise score every tree against a made-up label — drop
            # them from the Eq. 8 evaluation entirely.
            kidx = jnp.asarray(np.flatnonzero(~label_mask))
            xb_o = jnp.take(xb, kidx, axis=0)
            y_o = jnp.take(y, kidx, axis=0)
            w_o = jnp.take(weights, kidx, axis=1)
        w = (
            oob_r2(forest, xb_o, y_o.astype(jnp.float32), w_o)
            if config.regression
            else oob_accuracy(forest, xb_o, y_o, w_o)
        )
        forest = dataclasses.replace(forest, tree_weight=w)

    return PRFModel(forest=forest, bin_edges=edges, quarantine=report)


# ---------------------------------------------------------------------------
# Host-streaming out-of-core training (the streaming data plane)
# ---------------------------------------------------------------------------


def _channels(y: jnp.ndarray, config: ForestConfig) -> jnp.ndarray:
    return (
        regression_channels(y)
        if config.regression
        else class_channels(y, config.n_classes)
    )


def _train_prf_streamed(
    x: np.ndarray, y: np.ndarray, config: ForestConfig, seed: int,
    *,
    checkpoint=None,
    resume_from: Optional[str] = None,
    on_level=None,
    feeder_opts: Optional[dict] = None,
    bad_block_policy: Optional[str] = "raise",
) -> PRFModel:
    """``train_prf`` over the streaming data plane (never re-validates
    shapes against a device-resident ``[N, F]`` matrix — there is none).

    Binning edges are fit out-of-core too (``bin_fit="auto"`` resolves
    to the blocked path here): per-block sorted summaries merge in a
    ``StreamingQuantileSketch``, so edge fitting costs O(block) +
    O(F * sketch) host memory and never materializes the raw source —
    bitwise identical to the resident ``np.quantile`` below the sketch's
    compression threshold. Everything downstream — the binned blocks,
    dimension reduction, growth, OOB weights, and the model's own
    predictions — moves per ``sample_block`` rows.

    **Integrity screen.** With ``bad_block_policy`` set, every raw block
    is validated *before* edge fitting (one NaN would otherwise poison
    every ``np.quantile`` edge): sanitized cells are imputed then forced
    to bin 0, sanitized labels get zero DSI weight and are excluded from
    OOB, and quarantined blocks are excluded from edge fitting, dimred,
    the growth sweep (the feeder never transfers them), and OOB — all
    decided once, deterministically, so rerunning reproduces the same
    model. When the screen finds nothing, every downstream input is the
    untouched original — bitwise identical to validation off.
    """
    nb = config.sample_block
    N = x.shape[0]
    raw_blocks = [np.asarray(x[i:i + nb]) for i in range(0, N, nb)]
    y_host = np.asarray(y)
    report = None
    cell_masks, label_masks = {}, {}
    quar = frozenset()
    if bad_block_policy not in (None, "off"):
        from ..data.pipeline import DataIntegrityError, screen_blocks

        raw_blocks, y_host, cell_masks, label_masks, report = screen_blocks(
            raw_blocks, y_host, policy=bad_block_policy,
            n_features=x.shape[1],
            n_classes=None if config.regression else config.n_classes,
            regression=config.regression,
        )
        quar = frozenset(report.quarantined)
        if len(quar) == len(raw_blocks):
            raise DataIntegrityError(
                f"every block quarantined ({len(raw_blocks)} of "
                f"{len(raw_blocks)}) — nothing left to train on",
                reason="quarantine",
            )
    dirty = report is not None and not report.clean
    good = [i for i in range(len(raw_blocks)) if i not in quar]

    if config.resolved_bin_fit() == "blocked":
        # Out-of-core edge fitting (the default whenever sample_block > 0):
        # per-block sorted summaries merged in a StreamingQuantileSketch —
        # O(block) + O(F * sketch) host memory, never a full pass over the
        # raw source. Quarantined blocks never enter the sketch, and
        # sanitized blocks contribute only their finite original cells
        # (the validator's imputed-cell masks become exclusion masks
        # instead of a full np.concatenate of the good blocks).
        edges = fit_bins_blocked(
            (raw_blocks[i] for i in good), config.n_bins,
            exclude_masks={
                j: cell_masks[i] for j, i in enumerate(good) if i in cell_masks
            },
        )
    elif dirty:
        # bin_fit="exact" on dirty data: edges from screened data only —
        # this is the one remaining full-pass concatenate, kept verbatim
        # for strict compatibility with the pre-sketch behavior.
        edges = fit_bins(
            np.concatenate([raw_blocks[i] for i in good]), config.n_bins
        )
    else:
        edges = fit_bins(x, config.n_bins)
    edges_dev = jnp.asarray(edges)
    # Binned uint8 blocks stay HOST-resident (4-8x smaller than the raw
    # floats); each level sweep feeds them to the device one at a time.
    xb_blocks = []
    for i, rb in enumerate(raw_blocks):
        xb = np.asarray(apply_bins(jnp.asarray(rb), edges_dev))
        if i in cell_masks:
            xb = np.array(xb)
            xb[cell_masks[i]] = 0            # imputed cells -> bin 0
        xb_blocks.append(xb)
    y = jnp.asarray(y_host)
    key = jax.random.PRNGKey(seed)
    k_boot, k_dim = jax.random.split(key)

    weights = bootstrap_counts(k_boot, config.n_trees, N)          # DSI §4.1.2
    if label_masks:
        # Imputed-label samples get neutral (zero) weight in every tree.
        bad_rows = np.zeros(N, dtype=bool)
        for i, m in label_masks.items():
            bad_rows[i * nb:i * nb + m.shape[0]][m] = True
        weights = jnp.where(jnp.asarray(bad_rows)[None, :], 0, weights)

    def _drop_quarantined(blocks, y_dev, w_dev):
        """Filter quarantined blocks out of a (blocks, y, weights) feed,
        keeping labels/weights aligned with the surviving blocks."""
        if not quar:
            return blocks, y_dev, w_dev
        ys = jnp.concatenate(
            [y_dev[i * nb:i * nb + blocks[i].shape[0]] for i in good]
        )
        ws = jnp.concatenate(
            [w_dev[:, i * nb:i * nb + blocks[i].shape[0]] for i in good],
            axis=1,
        )
        return [blocks[i] for i in good], ys, ws

    feature_mask = None
    if config.feature_mode == "importance" and not config.regression:
        dr_blocks, dr_y, dr_w = _drop_quarantined(xb_blocks, y, weights)
        feature_mask = dimension_reduction_streamed(                   # §3.2
            dr_blocks, dr_y, dr_w, config, k_dim
        )
    elif config.feature_mode == "random":
        feature_mask = random_feature_mask(
            k_dim, n_trees=config.n_trees, n_features=x.shape[1],
            n_selected=config.n_selected,
        )                                                              # §3.1 RF

    y = y if not config.regression else y.astype(jnp.float32)
    forest = grow_forest_streamed(
        xb_blocks, y, weights, config, feature_mask,
        manager=checkpoint, resume_from=resume_from, on_level=on_level,
        feeder_opts=feeder_opts, quarantined=sorted(quar),
    )                                                                  # §4.2

    if config.weighted_voting:                                         # §3.3
        if dirty:
            # OOB over surviving blocks and rows only: quarantined
            # blocks are gone, and imputed-label rows (zero weight ==
            # out-of-bag everywhere) must not score trees against a
            # made-up label.
            w_host = np.asarray(weights)
            y_oob = y_host if not config.regression else \
                y_host.astype(np.float32)
            o_blocks, o_y, o_w = [], [], []
            for i in good:
                o0, n_i = i * nb, xb_blocks[i].shape[0]
                keep = (
                    ~label_masks[i] if i in label_masks
                    else np.ones(n_i, dtype=bool)
                )
                if not keep.any():
                    continue
                o_blocks.append(xb_blocks[i][keep])
                o_y.append(y_oob[o0:o0 + n_i][keep])
                o_w.append(w_host[:, o0:o0 + n_i][:, keep])
            oy = jnp.asarray(np.concatenate(o_y))
            ow = jnp.asarray(np.concatenate(o_w, axis=1))
            w = (
                oob_r2_streamed(forest, o_blocks, oy.astype(jnp.float32), ow)
                if config.regression
                else oob_accuracy_streamed(forest, o_blocks, oy, ow)
            )
        else:
            w = (
                oob_r2_streamed(
                    forest, xb_blocks, y.astype(jnp.float32), weights
                )
                if config.regression
                else oob_accuracy_streamed(forest, xb_blocks, y, weights)
            )
        forest = dataclasses.replace(forest, tree_weight=w)

    return PRFModel(forest=forest, bin_edges=edges, quarantine=report)


@partial(jax.jit, static_argnames=("config",))
def _stream_init(level0_hist, config):
    """Root node from the accumulated level-0 histogram: at level 0
    every sample sits in slot 0, so one feature's bin marginal IS the
    [k, C] root class counts — no extra pass over the blocks."""
    root_counts = level0_hist[:, 0, 0].sum(axis=1)
    forest = init_forest(config)
    forest = dataclasses.replace(
        forest, class_counts=forest.class_counts.at[:, 0].set(root_counts)
    )
    if config.regression:
        forest = dataclasses.replace(
            forest, value=forest.value.at[:, 0].set(_safe_mean(root_counts))
        )
    return forest


@partial(jax.jit, static_argnames=("config", "route"))
def _stream_block_step(
    hist_acc, xb_b, base_b, w_b, slot_b, slot_node, split_rank, scores,
    config, route, small_right=None,
):
    """The fused route+histogram pass for one block on the local plane —
    see ``engine.stream_block_step``. ONE jitted call, ONE read of the
    block per level. ``small_right`` switches the block into the packed
    sibling-subtraction histogram (``config.hist_reuse``)."""
    return stream_block_step(
        hist_acc, xb_b, base_b, w_b, slot_b, slot_node, split_rank, scores,
        config, LocalPlane(), route=route, small_right=small_right,
    )


@partial(jax.jit, static_argnames=("config",))
def _stream_plan_write(forest, slot_node, hist, feature_mask, level, config):
    """T_NS + node writes for one level, from the accumulated histogram.
    Runs the same plan/write/frontier pieces as the resident engine."""
    scores, n_node = level_scores(
        hist, feature_mask, regression=config.regression,
        backend=resolve_split_backend(config.split_backend),
    )
    split_rank, is_split, child_base = plan_level(
        scores, n_node, slot_node, config, level
    )
    forest = write_level(
        forest, slot_node, split_rank, is_split, child_base, scores, config
    )
    new_slot_node = next_frontier(is_split, child_base, config.frontier)
    return forest, scores, split_rank, new_slot_node


@partial(jax.jit, static_argnames=("config",))
def _stream_plan_write_reuse(
    forest, slot_node, packed_h, cache, feature_mask, level, config,
):
    """Reuse-mode ``_stream_plan_write``: the level's accumulated packed
    (small-child) histogram is expanded against the cache
    (``parent - small``), scored in paired-row order, permuted back to
    slots, and the refreshed cache — this level's paired tensor plus the
    next level's small-side plan — rides out with the level plan."""
    scores, n_node, hist2, perm = reuse_expand_scores(
        packed_h, cache, feature_mask, config
    )
    split_rank, is_split, child_base = plan_level(
        scores, n_node, slot_node, config, level
    )
    forest = write_level(
        forest, slot_node, split_rank, is_split, child_base, scores, config
    )
    new_slot_node = next_frontier(is_split, child_base, config.frontier)
    parent, small_right = sibling_plan(
        scores, split_rank, is_split,
        n_ranks=config.max_splits_per_level, regression=config.regression,
    )
    new_cache = {
        "hist": hist2, "perm": perm,
        "parent": parent, "small_right": small_right,
    }
    return forest, scores, split_rank, new_slot_node, new_cache


def _stream_setup(
    x_binned, y, weights, config: ForestConfig, prefetch: int,
    feeder_opts: Optional[dict] = None,
    quarantined: Sequence[int] = (),
):
    """Shared host-side setup of the streaming growth drivers: validated
    block list and a ``BlockFeeder`` over the blocks. ``feeder_opts``
    forwards retry/backoff/fault-injection/validator knobs to the
    feeder; ``quarantined`` block indices are dropped from every sweep
    (never transferred to a device)."""
    from ..data.pipeline import BlockFeeder, stream_blocks

    y_np = np.asarray(y)
    w_np = np.asarray(weights, dtype=np.float32)
    blocks = stream_blocks(
        x_binned, config.sample_block, what="grow_forest_streamed",
        n_y=y_np.shape[0], n_w=w_np.shape[1],
    )
    sizes = [b.shape[0] for b in blocks]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    if config.regression:
        y_np = y_np.astype(np.float32)
    feeder = BlockFeeder(
        blocks, prefetch=prefetch, quarantined=quarantined,
        **(feeder_opts or {}),
    )
    return feeder, y_np, w_np, sizes, offsets


def _stream_state_like(sizes, config: ForestConfig, hist_width: int = 0):
    """Structure template for the streamed growth checkpoint: the
    host-driven driver's full inter-level carry. ``scores``/``split_rank``
    must be part of it — the streaming plane fuses each level's routing
    into the NEXT level's block sweep, so resuming at level L+1 needs
    level L's plan, not just the forest and frontier.

    ``hist_width > 0`` adds the sibling-subtraction cache (the plane's
    post-combine feature width) — the reuse carry must be durable or a
    resumed run would lose the subtraction baseline. With reuse off the
    entry is ``None``, an *empty* pytree child, so off-mode templates
    (and therefore existing checkpoints) are byte-compatible."""
    k, S = config.n_trees, config.frontier
    C = 3 if config.regression else config.n_classes
    return {
        "forest": init_forest(config),
        "slot_node": jnp.zeros((k, S), jnp.int32),
        "scores": SplitScores(
            jnp.zeros((k, S), jnp.float32),
            jnp.zeros((k, S), jnp.int32),
            jnp.zeros((k, S), jnp.int32),
            jnp.zeros((k, S, C), jnp.float32),
            jnp.zeros((k, S, C), jnp.float32),
        ),
        "split_rank": jnp.zeros((k, S), jnp.int32),
        "slots": [jnp.zeros((k, n), jnp.int32) for n in sizes],
        "level": jnp.asarray(0, jnp.int32),
        "hist_cache": (
            init_hist_cache(config, hist_width) if hist_width > 0 else None
        ),
    }


def grow_forest_streamed(
    x_binned: Union[np.ndarray, Sequence[np.ndarray]],
    y: np.ndarray,
    weights: np.ndarray,
    config: ForestConfig,
    feature_mask: Optional[np.ndarray] = None,
    *,
    prefetch: int = 2,
    manager=None,
    resume_from: Optional[str] = None,
    on_level=None,
    feeder_opts: Optional[dict] = None,
    quarantined: Sequence[int] = (),
) -> Forest:
    """Out-of-core ``grow_forest`` over the async streaming data plane.

    ``x_binned`` is either a host array / ``np.memmap`` of binned
    features ``[N, F]`` (sliced into ``config.sample_block``-row views —
    no copy; ``sample_block > 0`` is required so the full matrix can
    never silently become one device block) or an explicit sequence of
    ``[Nb, F]`` blocks.

    Data-plane accounting (each device call only ever sees one block):

    * **one read per level** — per block per level, ONE jitted call
      (``engine.stream_block_step``) routes the block's samples from
      the previous level's frontier and immediately folds them into
      this level's histogram carry, so the route and histogram passes
      share a single host->device feed of the block;
    * **async double-buffering** — a ``BlockFeeder`` thread keeps
      ``prefetch`` block copies in flight, so block ``i+1``'s
      host->device transfer overlaps block ``i``'s histogram
      (``prefetch=0`` restores the synchronous feed);
    * **pinned per-block constants** — label channels and DSI weights
      are uploaded once for the whole growth, not once per level, and
      the per-sample slot table stays device-resident across levels
      (no host round-trip per block per level).

    Per level, one jitted call then scores + writes the level with the
    engine's shared ``plan_level`` / ``write_level`` / ``next_frontier``
    pieces. Root class counts come for free from the level-0 histogram
    (every sample sits in slot 0). Device memory: the ``[N, F]`` bin
    matrix — the dominant term for realistic F — is never resident
    (one ``sample_block * F`` block at a time, plus the
    ``k*S*F*B*C`` histogram carry), but the pinned weight/channel/slot
    operands DO scale with N: ``(2k + C) * N`` f32/int32 words stay on
    device for the whole growth (the price of feeding them zero times
    per level instead of twice). With k trees per host ≪ F features
    that is a small fraction of the streamed data; for very large
    ensembles, shard trees across hosts before streaming.

    DSI counts are integer-valued, so the blocked accumulation is
    bit-exact for classification: the result equals the resident
    ``grow_forest`` forest array for array (tests/test_engine.py pins
    this across >= 4 blocks, with and without prefetch). Regression
    channels agree to float rounding. Host-side early exit stops the
    level loop as soon as every tree's frontier is empty (always on —
    the loop is host-driven and the forests are identical either way;
    ``config.early_exit`` only gates the device-side ``lax.while_loop``).

    **Checkpointing** mirrors ``grow_forest_checkpointed``: ``manager``
    saves the driver's full inter-level carry (forest, frontier, level
    plan, per-block slot tables — see ``_stream_state_like``) after
    each level; ``resume_from`` restores the newest *CRC-verified*
    carry (``checkpoint.restore_latest_valid`` — a corrupted or torn
    newest step is skipped, costing recompute of the affected levels,
    never a poisoned model) and the level loop continues where it
    stopped, producing the bit-identical forest.
    ``on_level(level, forest)`` fires after each completed level's
    checkpoint.

    **Quarantine.** ``quarantined`` block indices (plus any the feeder's
    own ``validator`` flags — forward one via ``feeder_opts``) are
    dropped from every level sweep: never transferred, never routed,
    never histogrammed. Their slot-table entries stay as zeros in the
    checkpoint carry, so the carry structure — and therefore resume —
    is independent of which blocks were quarantined.
    """
    feeder, y_np, w_np, sizes, offsets = _stream_setup(
        x_binned, y, weights, config, prefetch, feeder_opts, quarantined
    )

    k, S = config.n_trees, config.frontier
    F = feeder.blocks[0].shape[1]
    B = config.n_bins
    C = 3 if config.regression else config.n_classes
    mask_dev = None if feature_mask is None else jnp.asarray(feature_mask)
    # Sibling-subtraction reuse: blocks scatter into R rank segments
    # instead of S slots (the per-level carry is half the tensor) and
    # the plan step subtracts large children from the durable cache.
    reuse = resolve_hist_reuse(config, F)
    n_rows = config.max_splits_per_level if reuse else S

    # Per-block constants: pinned on device ONCE for the whole growth.
    # Quarantined blocks get no pins — nothing of theirs ever lands on
    # a device.
    live = set(feeder.live_blocks)
    base_dev, w_dev = [], []
    for i in range(len(feeder)):
        if i not in live:
            base_dev.append(None)
            w_dev.append(None)
            continue
        o0, o1 = offsets[i], offsets[i + 1]
        base_dev.append(_channels(feeder.pin(y_np[o0:o1]), config))
        w_dev.append(feeder.pin(w_np[:, o0:o1]))

    state = None
    if resume_from is not None:
        from ..checkpoint.checkpoint import restore_latest_valid

        restored = restore_latest_valid(
            _stream_state_like(sizes, config, F if reuse else 0), resume_from
        )
        if restored is not None:
            state, _ = restored
    if state is not None:
        forest, slot_node = state["forest"], state["slot_node"]
        scores, split_rank = state["scores"], state["split_rank"]
        slot_dev, start = list(state["slots"]), int(state["level"])
        cache = state["hist_cache"]
    else:
        # The per-sample frontier table: device-resident across levels.
        slot_dev = [jnp.zeros((k, n), jnp.int32) for n in sizes]
        slot_node = jnp.full((k, S), -1, jnp.int32).at[:, 0].set(0)
        forest, scores, split_rank = None, None, None
        cache = init_hist_cache(config, F) if reuse else None
        start = 0

    def level_sweep(route: bool):
        hist = jnp.zeros((k, n_rows, F, B, C), jnp.float32)
        for i, xb_b in zip(feeder.live_blocks, feeder.sweep()):
            hist, slot_dev[i] = _stream_block_step(
                hist, xb_b, base_dev[i], w_dev[i], slot_dev[i], slot_node,
                split_rank if route else None, scores if route else None,
                config, route,
                cache["small_right"] if reuse else None,
            )
        return hist

    try:
        for level in range(start, config.max_depth):
            if not np.any(np.asarray(slot_node) >= 0):
                break                               # every frontier is empty
            hist = level_sweep(route=level > 0)
            if forest is None:
                forest = _stream_init(hist, config)  # root node, free at level 0
            if reuse:
                forest, scores, split_rank, slot_node, cache = (
                    _stream_plan_write_reuse(
                        forest, slot_node, hist, cache, mask_dev,
                        jnp.asarray(level, jnp.int32), config,
                    )
                )
            else:
                forest, scores, split_rank, slot_node = _stream_plan_write(
                    forest, slot_node, hist, mask_dev,
                    jnp.asarray(level, jnp.int32), config,
                )
            if manager is not None:
                manager.maybe_save({
                    "forest": forest, "slot_node": slot_node,
                    "scores": scores, "split_rank": split_rank,
                    "slots": slot_dev,
                    "level": jnp.asarray(level + 1, jnp.int32),
                    "hist_cache": cache,
                }, level + 1)
            if on_level is not None:
                on_level(level + 1, forest)

        if forest is None:          # max_depth == 0: root node only
            forest = _stream_init(level_sweep(route=False), config)
    finally:
        feeder.close()
    return finalize_forest(forest)
