"""Public PRF API — train / predict, paper-faithful pipeline.

    bin -> DSI bootstrap -> dimension reduction (Alg. 3.1)
        -> level-synchronous growth (Alg. 4.2) -> OOB weights (Eq. 8)

``train_prf`` is the single-host path; ``repro.core.distributed`` offers
the mesh-sharded version with identical semantics, and
``grow_forest_streamed`` the host-streaming out-of-core growth driver
(sample blocks fed from a NumPy/memmap source — the full ``[N, F]``
matrix is never passed to one device call).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .binning import bin_dataset, apply_bins
from .dimred import dimension_reduction, random_feature_mask
from .dsi import bootstrap_counts
from .engine import (
    LocalPlane, _safe_mean, finalize_forest, init_forest, next_frontier,
    plan_level, route_level, write_level,
)
from .forest import grow_forest
from .gain import level_scores, resolve_split_backend
from .histograms import class_channels, level_histograms, regression_channels
from .types import Forest, ForestConfig
from .voting import (
    oob_accuracy, oob_r2, predict, predict_regression, predict_scores,
)


@dataclasses.dataclass
class PRFModel:
    """Trained model + the binning transform needed at inference.

    Prediction honors ``forest.config.predict_backend`` ("auto" |
    "pallas" | "xla"): the pallas backend runs the fused
    traversal+voting kernel (``kernels/tree_traverse``) that never
    materializes the ``[k, N, C]`` per-tree tensor; labels are
    identical across backends. For serving (batch bucketing, request
    aggregation, tree-sharded multi-device voting) wrap the model in
    ``repro.serving.PRFService``.
    """

    forest: Forest
    bin_edges: np.ndarray

    def predict(self, x: np.ndarray) -> np.ndarray:
        xb = apply_bins(jnp.asarray(x), jnp.asarray(self.bin_edges))
        if self.forest.config.regression:
            return np.asarray(predict_regression(self.forest, xb))
        return np.asarray(predict(self.forest, xb))

    def predict_scores(self, x: np.ndarray) -> np.ndarray:
        """Weighted-vote class scores [N, C] (classification only)."""
        if self.forest.config.regression:
            raise ValueError(
                "predict_scores is classification-only; use predict() for "
                "regression models"
            )
        xb = apply_bins(jnp.asarray(x), jnp.asarray(self.bin_edges))
        return np.asarray(predict_scores(self.forest, xb))

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == np.asarray(y)))

    def with_predict_backend(self, backend: str) -> "PRFModel":
        """Same model, different prediction backend (config is static)."""
        cfg = dataclasses.replace(self.forest.config, predict_backend=backend)
        return PRFModel(
            forest=dataclasses.replace(self.forest, config=cfg),
            bin_edges=self.bin_edges,
        )


def train_prf(
    x: np.ndarray,
    y: np.ndarray,
    config: ForestConfig,
    seed: int = 0,
) -> PRFModel:
    """End-to-end PRF training on host data (paper §3 + §4 semantics)."""
    config = config.resolved(x.shape[1])
    xb_np, edges = bin_dataset(x, config.n_bins)
    xb = jnp.asarray(xb_np)
    y = jnp.asarray(y)
    key = jax.random.PRNGKey(seed)
    k_boot, k_dim = jax.random.split(key)

    weights = bootstrap_counts(k_boot, config.n_trees, x.shape[0])     # DSI §4.1.2

    feature_mask = None
    if config.feature_mode == "importance" and not config.regression:
        feature_mask = dimension_reduction(xb, y, weights, config, k_dim)  # §3.2
    elif config.feature_mode == "random":
        feature_mask = random_feature_mask(
            k_dim, n_trees=config.n_trees, n_features=x.shape[1],
            n_selected=config.n_selected,
        )                                                              # §3.1 RF

    forest = grow_forest(
        xb, y if not config.regression else y.astype(jnp.float32),
        weights, config, feature_mask
    )                                                                  # §4.2

    if config.weighted_voting:                                         # §3.3
        w = (
            oob_r2(forest, xb, y.astype(jnp.float32), weights)
            if config.regression
            else oob_accuracy(forest, xb, y, weights)
        )
        forest = dataclasses.replace(forest, tree_weight=w)

    return PRFModel(forest=forest, bin_edges=edges)


# ---------------------------------------------------------------------------
# Host-streaming out-of-core growth (sample-block streaming)
# ---------------------------------------------------------------------------


def _channels(y: jnp.ndarray, config: ForestConfig) -> jnp.ndarray:
    return (
        regression_channels(y)
        if config.regression
        else class_channels(y, config.n_classes)
    )


@partial(jax.jit, static_argnames=("config",))
def _stream_init(level0_hist, config):
    """Root node from the accumulated level-0 histogram: at level 0
    every sample sits in slot 0, so one feature's bin marginal IS the
    [k, C] root class counts — no extra pass over the blocks."""
    root_counts = level0_hist[:, 0, 0].sum(axis=1)
    forest = init_forest(config)
    forest = dataclasses.replace(
        forest, class_counts=forest.class_counts.at[:, 0].set(root_counts)
    )
    if config.regression:
        forest = dataclasses.replace(
            forest, value=forest.value.at[:, 0].set(_safe_mean(root_counts))
        )
    return forest


@partial(jax.jit, static_argnames=("config",))
def _stream_hist(hist_acc, xb_b, y_b, w_b, slot_b, slot_node, config):
    """Fold one sample block into the level histogram carry — the host
    side of the resumable T_GR accumulation. Trees whose frontiers died
    contribute zero-weight (masked) work, exactly as in the engine."""
    tree_live = jnp.any(slot_node >= 0, axis=1)
    w_lvl = w_b * tree_live[:, None].astype(w_b.dtype)
    h = level_histograms(
        xb_b, _channels(y_b, config), w_lvl, slot_b,
        n_slots=config.frontier, n_bins=config.n_bins,
        packed=config.packed_hist and not config.regression,
        backend=config.hist_backend,
    )
    return hist_acc + h


@partial(jax.jit, static_argnames=("config",))
def _stream_plan_write(forest, slot_node, hist, feature_mask, level, config):
    """T_NS + node writes for one level, from the accumulated histogram.
    Runs the same plan/write/frontier pieces as the resident engine."""
    scores, n_node = level_scores(
        hist, feature_mask, regression=config.regression,
        backend=resolve_split_backend(config.split_backend),
    )
    split_rank, is_split, child_base = plan_level(
        scores, n_node, slot_node, config, level
    )
    forest = write_level(
        forest, slot_node, split_rank, is_split, child_base, scores, config
    )
    new_slot_node = next_frontier(is_split, child_base, config.frontier)
    return forest, scores, split_rank, new_slot_node


@jax.jit
def _stream_route(xb_b, slot_b, split_rank, scores):
    return route_level(xb_b, slot_b, split_rank, scores, LocalPlane())


def grow_forest_streamed(
    x_binned: Union[np.ndarray, Sequence[np.ndarray]],
    y: np.ndarray,
    weights: np.ndarray,
    config: ForestConfig,
    feature_mask: Optional[np.ndarray] = None,
) -> Forest:
    """Out-of-core ``grow_forest``: train from host-resident sample blocks.

    ``x_binned`` is either a host array / ``np.memmap`` of binned
    features ``[N, F]`` (sliced into ``config.sample_block``-row views —
    no copy; ``sample_block > 0`` is required so the full matrix can
    never silently become one device block) or an explicit sequence of
    ``[Nb, F]`` blocks. Each device call only ever sees one block: per
    level, one pass accumulates the ``[k, S, F, B, C]`` level histogram
    block by block (the resumable T_GR carry), one jitted call scores +
    writes the level with the engine's shared ``plan_level`` /
    ``write_level`` / ``next_frontier`` pieces, and a second pass routes
    each block's samples to their child slots. Root class counts come
    for free from the level-0 histogram (every sample sits in slot 0),
    so each level reads the data exactly once for histograms. The
    per-sample frontier table stays host-resident, so device memory
    holds O(sample_block * F + k*S*F*B*C) — independent of N.

    DSI counts are integer-valued, so the blocked accumulation is
    bit-exact for classification: the result equals the resident
    ``grow_forest`` forest array for array (tests/test_engine.py pins
    this across >= 4 blocks). Regression channels agree to float
    rounding. Host-side early exit stops the level loop as soon as
    every tree's frontier is empty (always on — the loop is host-driven
    and the forests are identical either way; ``config.early_exit``
    only gates the device-side ``lax.while_loop``).
    """
    from ..data.pipeline import sample_blocks

    y_np = np.asarray(y)
    w_np = np.asarray(weights, dtype=np.float32)
    if not isinstance(x_binned, (list, tuple)) and config.sample_block <= 0:
        raise ValueError(
            "grow_forest_streamed with an array/memmap source needs "
            "config.sample_block > 0 — sample_block=0 would feed the whole "
            "[N, F] matrix as one device block, which is exactly what this "
            "path exists to avoid (pass an explicit block list to stream "
            "from a custom source)"
        )
    blocks = sample_blocks(x_binned, config.sample_block)
    sizes = [b.shape[0] for b in blocks]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    if offsets[-1] != y_np.shape[0] or offsets[-1] != w_np.shape[1]:
        raise ValueError(
            f"blocks cover {offsets[-1]} samples, but y has {y_np.shape[0]} "
            f"and weights {w_np.shape[1]}"
        )
    if config.regression:
        y_np = y_np.astype(np.float32)

    k, S = config.n_trees, config.frontier
    F = blocks[0].shape[1]
    B = config.n_bins
    C = 3 if config.regression else config.n_classes
    mask_dev = None if feature_mask is None else jnp.asarray(feature_mask)

    def block_args(i):
        o0, o1 = offsets[i], offsets[i + 1]
        return jnp.asarray(blocks[i]), jnp.asarray(y_np[o0:o1]), \
            jnp.asarray(w_np[:, o0:o1])

    slot_node = jnp.full((k, S), -1, jnp.int32).at[:, 0].set(0)
    slot_blocks = [np.zeros((k, n), np.int32) for n in sizes]

    def level_hist():
        hist = jnp.zeros((k, S, F, B, C), jnp.float32)
        for i in range(len(blocks)):
            xb_b, y_b, w_b = block_args(i)
            hist = _stream_hist(
                hist, xb_b, y_b, w_b, jnp.asarray(slot_blocks[i]),
                slot_node, config,
            )
        return hist

    forest = None
    for level in range(config.max_depth):
        if not np.any(np.asarray(slot_node) >= 0):
            break                                   # every frontier is empty
        hist = level_hist()
        if forest is None:
            forest = _stream_init(hist, config)     # root node, free at level 0
        forest, scores, split_rank, slot_node = _stream_plan_write(
            forest, slot_node, hist, mask_dev, jnp.asarray(level, jnp.int32),
            config,
        )
        for i in range(len(blocks)):
            slot_blocks[i] = np.asarray(_stream_route(
                jnp.asarray(blocks[i]), jnp.asarray(slot_blocks[i]),
                split_rank, scores,
            ))

    if forest is None:              # max_depth == 0: root node only
        forest = _stream_init(level_hist(), config)
    return finalize_forest(forest)
