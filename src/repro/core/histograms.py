"""Weighted class-histogram construction — the T_GR workhorse (paper §4.2.1).

``level_histograms`` is the single entry point for every histogram the
trainer builds (single-host ``grow_forest``, the sharded
``_grow_sharded`` path, and dimension reduction). It dispatches between
two backends, selected by ``ForestConfig.hist_backend``:

* ``"segment_sum"`` — a per-tree, per-feature ``jax.ops.segment_sum``
  vmap. XLA-native scatter; the portable oracle.
* ``"pallas"`` — the fused MXU one-hot-matmul kernel
  (``kernels/gain_ratio``): one ``pallas_call`` emits the whole
  ``[tc, S, F, B, C]`` tensor for a chunk of trees, with the per-tree
  DSI weight multiply fused into the kernel and padding/masking for
  arbitrary ``N``/``F``. Runs in ``interpret`` mode off-TPU so the same
  code path is testable on CPU.
* ``"auto"`` — ``pallas`` when the default JAX backend is TPU, else
  ``segment_sum``.

Both backends apply the per-tree weight *inside* the per-tree step so
the ``[k, N, C]`` weighted-channel tensor is never materialized —
ensemble growth costs k*N weights, not k*N*C activations (the DSI
data-multiplexing property). ``packed=True`` (classification-shaped
one-hot channels only) additionally folds the class index into the
scatter/one-hot index, so the inner loop reads the ``[N]`` weight vector
instead of the ``[N, C]`` channel matrix — a C-fold cut of T_GR's
dominant memory traffic (§Perf log, PERF.md).

The distributed path (core/distributed.py) calls the same function on
each device's (sample-shard x feature-shard) block and psums over the
sample axis.

The fused T_GR->T_NS path (core/engine.fused_level_scores and the
blocked dimension-reduction sweep in core/dimred.py) calls
``level_histograms`` on one ``hist_feature_slab``-wide column slice at a
time, so the full ``[tc, S, F, B, C]`` tensor never reaches HBM;
``blocked_level_histograms`` is the sample-axis analogue (a resumable
device-side accumulation over ``[sample_block, F]`` row blocks, used by
``ForestConfig.sample_block`` on the resident path). The host-streaming
data plane (``core.api.grow_forest_streamed`` and the mesh-composed
``core.distributed.grow_forest_streamed_sharded``) runs the same
accumulation across HOST-fed blocks instead: one ``level_histograms``
call per block per level inside ``engine.stream_block_step``, summed
into a device-resident carry. Both orders are exact for integer-valued
DSI counts (every partial sum is an exact f32 integer below 2**24), so
resident, device-blocked, and host-streamed training agree bitwise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..kernels.gain_ratio.kernel import multi_tree_hist_pallas

BACKENDS = ("auto", "pallas", "segment_sum")


def resolve_backend(backend: str) -> str:
    """'auto' -> 'pallas' on TPU, 'segment_sum' elsewhere."""
    if backend not in BACKENDS:
        raise ValueError(f"hist_backend={backend!r} not in {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "segment_sum"
    return backend


def hist_feature_slab(
    N: int, F: int, S: int, B: int, C: int, *, packed: bool = False
) -> int:
    """Feature-slab width for blocked histogram consumption.

    This is exactly the pallas hist kernel's own ``f_blk`` for the
    *full-F* problem, so per-slab histograms are bit-identical to
    column slices of the one-shot call: the kernel sees the same
    ``(n_blk, f_blk)`` blocks in the same order, just one
    feature-block-column at a time. (``segment_sum`` is per-feature
    independent, so it is trivially slab-invariant.)
    """
    from ..kernels.gain_ratio.kernel import choose_blocks

    return choose_blocks(N, F, S, B, C, packed=packed)[1]


@partial(
    jax.jit,
    static_argnames=("n_slots", "n_bins", "packed", "backend", "interpret"),
)
def level_histograms(
    x_binned: jnp.ndarray,      # [N, F] uint8
    base_channels: jnp.ndarray, # [N, C] per-sample channel data (unweighted)
    weights: jnp.ndarray,       # [k, N] per-tree in-bag weights (DSI counts)
    sample_slot: jnp.ndarray,   # [k, N] int32, -1 = parked
    *,
    n_slots: int,
    n_bins: int,
    packed: bool = False,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """hist[t,s,f,b,c] = sum_i w[t,i] * base[i,c] * [slot_i = s] * [x_if = b].

    ``base_channels`` is ``onehot(y)`` for classification or
    ``[1, y, y^2]`` for regression — same kernel either way (``packed``
    requires the classification-shaped one-hot form).

    ``interpret`` only affects the pallas backend; ``None`` means
    interpret off-TPU, compiled on TPU.

    Returns: [k, S, F, B, C] float32.
    """
    backend = resolve_backend(backend)
    if backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return multi_tree_hist_pallas(
            x_binned, base_channels, weights, sample_slot,
            n_slots=n_slots, n_bins=n_bins, packed=packed,
            interpret=interpret,
        )

    N, F = x_binned.shape
    C = base_channels.shape[-1]
    S, B = n_slots, n_bins

    if packed:
        cls = jnp.argmax(base_channels, axis=-1).astype(jnp.int32)   # [N]
        wcls = base_channels.max(axis=-1)                            # per-sample scale

        def per_tree_packed(w, slot):
            wv = w * wcls
            base = jnp.where(slot >= 0, slot, S) * (B * C)

            def per_feature(bins_f):
                seg = base + bins_f.astype(jnp.int32) * C + cls
                out = jax.ops.segment_sum(wv, seg, num_segments=S * B * C + B * C)
                return out[: S * B * C].reshape(S, B, C)

            return jax.vmap(per_feature, in_axes=1)(x_binned)

        hist = jax.vmap(per_tree_packed)(weights, sample_slot)
        return jnp.transpose(hist, (0, 2, 1, 3, 4))

    def per_tree(w, slot):                        # w [N], slot [N]
        ch = w[:, None] * base_channels           # fused by XLA
        base = jnp.where(slot >= 0, slot, S) * B  # parked -> dump segment

        def per_feature(bins_f):                  # [N] uint8
            seg = base + bins_f
            out = jax.ops.segment_sum(ch, seg, num_segments=S * B + B)
            return out[: S * B].reshape(S, B, C)

        return jax.vmap(per_feature, in_axes=1)(x_binned)   # [F, S, B, C]

    hist = jax.vmap(per_tree)(weights, sample_slot)         # [k, F, S, B, C]
    return jnp.transpose(hist, (0, 2, 1, 3, 4))


def blocked_level_histograms(
    x_binned: jnp.ndarray,      # [N, F] uint8
    base_channels: jnp.ndarray, # [N, C]
    weights: jnp.ndarray,       # [k, N]
    sample_slot: jnp.ndarray,   # [k, N] int32, -1 = parked
    *,
    n_slots: int,
    n_bins: int,
    sample_block: int,
    packed: bool = False,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """``level_histograms`` accumulated over ``[sample_block, F]`` row
    blocks — the resumable sample-axis carry of the T_GR stage.

    The histogram is a sum over samples, so feeding the kernel one row
    block at a time and adding the partial tensors is exact whenever the
    weighted counts are integer-valued (classification with DSI
    multiplicities — every partial sum stays an exact f32 integer below
    2**24), and agrees to float rounding for regression channels. The
    trailing remainder block is padded with parked samples
    (``slot = -1`` -> the kernels' dump segment), so any ``N`` works.

    Bounds the per-call sample working set to ``sample_block`` rows —
    the device-side half of the sample-block streaming path
    (``ForestConfig.sample_block``); the host-side half is
    ``core.api.grow_forest_streamed``.
    """
    N, F = x_binned.shape
    k = weights.shape[0]
    C = base_channels.shape[-1]
    nb = -(-N // sample_block)
    pad = nb * sample_block - N
    if pad:
        x_binned = jnp.pad(x_binned, ((0, pad), (0, 0)))
        base_channels = jnp.pad(base_channels, ((0, pad), (0, 0)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
        sample_slot = jnp.pad(
            sample_slot, ((0, 0), (0, pad)), constant_values=-1
        )

    def body(i, acc):
        r0 = i * sample_block
        h = level_histograms(
            jax.lax.dynamic_slice_in_dim(x_binned, r0, sample_block, 0),
            jax.lax.dynamic_slice_in_dim(base_channels, r0, sample_block, 0),
            jax.lax.dynamic_slice_in_dim(weights, r0, sample_block, 1),
            jax.lax.dynamic_slice_in_dim(sample_slot, r0, sample_block, 1),
            n_slots=n_slots, n_bins=n_bins, packed=packed,
            backend=backend, interpret=interpret,
        )
        return acc + h

    init = jnp.zeros((k, n_slots, F, n_bins, C), jnp.float32)
    return jax.lax.fori_loop(0, nb, body, init)


# ---------------------------------------------------------------------------
# Sibling-subtraction histogram reuse (ForestConfig.hist_reuse)
# ---------------------------------------------------------------------------
#
# ``hist(parent) = hist(left) + hist(right)`` holds *bitwise* for the
# integer DSI counts (every partial sum is an exact f32 integer below
# 2**24 — the same argument that makes blocked accumulation exact), so a
# level's T_GR only needs to histogram the samples routed to the
# *smaller* child of each split; the sibling is ``parent - small``.
#
# Layout: splits admitted at the previous level carry dense ranks
# ``r in [0, n_splits)`` (``engine._rank_splits``) and their children
# occupy frontier slots ``2r`` / ``2r + 1``. The reuse path histograms
# into R = max_splits_per_level **rank segments** (samples in large
# slots are parked to the dump segment — the same masking machinery the
# early-exit scheduler uses for dead trees), which
#
# * halves the one-hot matmul width of the pallas T_GR kernel,
# * halves the scatter segment count of the segment_sum backend, and
# * halves the tensor the mesh plane's psum / psum_scatter moves
#   (``sibling_expand`` runs post-combine, so all shards agree).
#
# ``sibling_expand`` then rebuilds a full S-row tensor in *rank-paired*
# row order — rows [0, R) are the small children, rows [R, 2R) their
# subtraction-reconstructed siblings — NOT slot order: reordering the
# O(k*S) split descriptors after scoring (``sibling_perm``) is free,
# reordering the [k, S, F, B, C] tensor is a full extra memory pass.
# Unoccupied rows are exactly zero (invalid ranks contribute no samples
# and force ``large = 0``), matching what direct histogramming produces
# for empty slots — which is why reuse-on forests are bit-identical to
# reuse-off on every plane.


def sibling_segments(
    sample_slot: jnp.ndarray,    # [k, N] int32 frontier slots, -1 parked
    small_right: jnp.ndarray,    # [k, R] int32, 1 = right child is smaller
) -> jnp.ndarray:
    """Rank segment of each sample: ``slot // 2`` when the sample's slot
    is the *small* child of its pair, -1 (dump) otherwise.

    At level 0 the init cache (``small_right = 0``) makes slot 0 the
    "small" side of rank 0, so the whole dataset lands in segment 0 —
    the root histogram needs no special case.
    """
    R = small_right.shape[1]
    live = sample_slot >= 0
    s = jnp.where(live, sample_slot, 0)
    r = s // 2
    side = s - 2 * r
    sr = jnp.take_along_axis(small_right, jnp.minimum(r, R - 1), axis=1)
    keep = live & (side == sr) & (r < R)
    return jnp.where(keep, r, -1).astype(jnp.int32)


def sibling_perm(small_right: jnp.ndarray, n_slots: int) -> jnp.ndarray:
    """Slot -> paired-row permutation [k, S]: slot ``2r + side`` reads
    row ``r`` (small) or ``R + r`` (large); slots past ``2R`` read
    themselves (their rows are zero either way)."""
    k, R = small_right.shape
    s = jnp.arange(n_slots, dtype=jnp.int32)[None, :]
    r = jnp.minimum(s // 2, R - 1)
    side = s - 2 * r
    sr = jnp.take_along_axis(small_right, r, axis=1)
    pair = jnp.where(side == sr, r, R + r)
    return jnp.where(s < 2 * R, pair, s).astype(jnp.int32)


def sibling_expand(
    packed: jnp.ndarray,        # [k, R, F, B, C] small-child histograms
    cache_hist: jnp.ndarray,    # [k, S, F, B, C] previous level, paired rows
    cache_perm: jnp.ndarray,    # [k, S] previous level's slot -> row map
    parent: jnp.ndarray,        # [k, R] parent *slot* of each rank, -1 invalid
    n_slots: int,
) -> jnp.ndarray:
    """Rebuild the full level histogram from small-child segments:
    rows [0, R) = ``packed``, rows [R, 2R) = ``parent - packed`` (the
    large siblings), rows [2R, S) = zero. Returns [k, S, F, B, C] in
    rank-paired row order (see module comment; ``sibling_perm`` maps
    slots to rows)."""
    k, R = parent.shape
    valid = parent >= 0
    rows = jnp.take_along_axis(cache_perm, jnp.where(valid, parent, 0), axis=1)
    parent_h = jnp.take_along_axis(
        cache_hist, rows[:, :, None, None, None], axis=1
    )
    large = jnp.where(
        valid[:, :, None, None, None], parent_h - packed, 0.0
    )
    hist = jnp.concatenate([packed, large], axis=1)
    if 2 * R < n_slots:
        hist = jnp.pad(hist, ((0, 0), (0, n_slots - 2 * R)) + ((0, 0),) * 3)
    return hist[:, :n_slots]


def class_channels(y: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    """onehot(y) -> [N, C] float32."""
    return jax.nn.one_hot(y, n_classes, dtype=jnp.float32)


def regression_channels(y: jnp.ndarray) -> jnp.ndarray:
    """[1, y, y^2] -> [N, 3] float32."""
    y = y.astype(jnp.float32)
    return jnp.stack([jnp.ones_like(y), y, y * y], axis=-1)
