"""Token data pipeline with DSI-style multiplexed sampling (paper §4.1.2).

The paper's data-multiplexing idea applied to LM training: the tokenized
corpus is materialized ONCE (shared, read-only); every epoch/replica is
just an *index table* over it. Shuffling, repeats, and replica splits
never copy token data — the same flat-in-k volume property as the PRF
DSI table. Synthetic corpus here (Zipf-ish token stream with injected
bigram structure so loss visibly decreases); swap `corpus` for a memmap
of real tokens in production.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import (
    Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple, Union,
)

import numpy as np


def sample_blocks(
    x: Union[np.ndarray, Sequence[np.ndarray]], block_rows: int = 0,
    row_range: Optional[Tuple[int, int]] = None,
) -> List[np.ndarray]:
    """Zero-copy ``[Nb, F]`` row views over a host array / ``np.memmap``.

    The block-feed API of the out-of-core trainer
    (``repro.core.api.grow_forest_streamed``): an array source is
    sliced into ``block_rows``-row views (no copy — memmap blocks are
    only paged in when a block is fed to the device). An explicit
    list/tuple of blocks passes through with ndarray blocks (memmap
    views included) kept **by identity** — only non-array entries
    (e.g. nested lists) are materialized, once, here — so callers can
    stream from any host source that yields row blocks.
    ``block_rows <= 0`` means one block (the degenerate resident feed).

    ``row_range=(lo, hi)`` restricts each block to its intersection with
    the **global** row interval ``[lo, hi)`` — the shard-aware feed of
    the multi-process plane (``launch.multiproc.MultiHostMesh``): block
    boundaries stay where a single-process sweep would put them, but
    each process's views cover only its own rows of the memmap, so only
    those pages are ever read. Blocks that fall entirely outside the
    range become empty ``[0, F]`` views (block indexing stays global).
    """
    if isinstance(x, (list, tuple)):
        blocks = [b if isinstance(b, np.ndarray) else np.asarray(b) for b in x]
        if row_range is None:
            return blocks
        lo, hi = row_range
        out, off = [], 0
        for b in blocks:
            b0, b1 = off, off + b.shape[0]
            out.append(b[max(lo - b0, 0):max(min(hi, b1) - b0, 0)])
            off = b1
        return out
    src = np.asarray(x)
    nb = block_rows if block_rows > 0 else src.shape[0]
    if row_range is None:
        return [src[i:i + nb] for i in range(0, src.shape[0], nb)]
    lo, hi = row_range
    return [
        src[min(max(lo, i), i + nb):min(max(hi, i), i + nb)]
        for i in range(0, src.shape[0], nb)
    ]


def stream_blocks(
    x: Union[np.ndarray, Sequence[Any]],
    sample_block: Optional[int],
    *,
    what: str,
    n_y: Optional[int] = None,
    n_w: Optional[int] = None,
) -> List[Any]:
    """The ONE block-list constructor + validator of the streaming data
    plane (growth, dimred, OOB, prediction — local and mesh).

    An explicit block sequence passes through (device arrays included);
    an array/memmap source is sliced per ``sample_block``, which must be
    > 0 so the full ``[N, F]`` matrix can never silently become one
    device block. Rejects empty block sequences, and — when the caller
    supplies its label/weight lengths — blocks that do not cover them.
    """
    if isinstance(x, (list, tuple)):
        blocks = list(x)
    else:
        if sample_block is None or sample_block <= 0:
            raise ValueError(
                f"{what} with an array/memmap source needs sample_block > 0 "
                "— sample_block=0 would feed the whole [N, F] matrix as one "
                "device block, which is exactly what the streaming plane "
                "exists to avoid (pass an explicit block list to stream "
                "from a custom source)"
            )
        blocks = sample_blocks(x, sample_block)
    if not blocks:
        raise ValueError(
            f"{what} got an empty block sequence — the data source yielded "
            "no [Nb, F] sample blocks (empty block list, or an array source "
            "with 0 rows)"
        )
    if n_y is not None or n_w is not None:
        covered = sum(int(b.shape[0]) for b in blocks)
        if (n_y is not None and covered != n_y) or (
            n_w is not None and covered != n_w
        ):
            raise ValueError(
                f"{what}: blocks cover {covered} samples, but y has {n_y} "
                f"and weights {n_w}"
            )
    return blocks


class FeedError(RuntimeError):
    """A block feed failed permanently (retry budget exhausted, a
    non-retryable error, or a producer thread that would not stop)."""


class DataIntegrityError(ValueError):
    """A sample block failed integrity validation (non-finite features,
    out-of-range labels, or shape drift). Carries the offending block
    index, columns, and reason so operators can find the bad shard."""

    def __init__(
        self, message: str, *,
        block_index: Optional[int] = None,
        columns: Sequence[int] = (),
        reason: str = "",
    ):
        super().__init__(message)
        self.block_index = block_index
        self.columns = tuple(int(c) for c in columns)
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class BlockIssue:
    """One validation finding for one sample block."""

    index: int                    # block index in the sweep order
    reason: str                   # "nonfinite" | "label" | "shape"
    columns: Tuple[int, ...]      # offending feature columns ((): n/a)
    bad_cells: int = 0            # non-finite feature cells
    bad_labels: int = 0           # out-of-range / non-finite labels

    def describe(self) -> str:
        if self.reason == "shape":
            return f"block {self.index}: shape drift"
        if self.reason == "label":
            return f"block {self.index}: {self.bad_labels} bad label(s)"
        return (
            f"block {self.index}: {self.bad_cells} non-finite cell(s) in "
            f"columns {list(self.columns)}"
        )


@dataclasses.dataclass
class QuarantineReport:
    """What the block validator found and did — attached to the trained
    model (``PRFModel.quarantine``) and surfaced by serving ``health()``.

    ``quarantined`` lists blocks dropped from every sweep;
    ``sanitized_cells`` / ``sanitized_labels`` count deterministic
    imputations. ``clean`` is True when nothing was found, which is the
    guarantee that validation was a bitwise no-op on the model.
    """

    policy: str
    blocks_checked: int = 0
    quarantined: List[int] = dataclasses.field(default_factory=list)
    sanitized_cells: int = 0
    sanitized_labels: int = 0
    issues: List[BlockIssue] = dataclasses.field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not self.issues

    def counters(self) -> Dict[str, int]:
        return {
            "blocks_checked": self.blocks_checked,
            "blocks_quarantined": len(self.quarantined),
            "sanitized_cells": self.sanitized_cells,
            "sanitized_labels": self.sanitized_labels,
        }


class BlockValidator:
    """Deterministic per-block integrity validator of the data plane.

    Checks each ``[Nb, F]`` block for NaN/Inf cells, shape drift against
    the expected feature count, and (when labels are supplied)
    out-of-range or non-finite labels. ``policy`` decides what a finding
    does:

    * ``"raise"`` — typed :class:`DataIntegrityError` naming the block
      index and offending columns; nothing trains on poisoned data.
    * ``"sanitize"`` — deterministic imputation: bad feature cells are
      zeroed (the trainer maps them to bin 0), bad labels are imputed to
      0 and the sample's DSI weights neutralized — the model is
      reproducible run-to-run.
    * ``"quarantine"`` — the block is dropped from every sweep and
      recorded in the :class:`QuarantineReport`.

    Validation is pure numpy over host blocks (memmap pages are touched
    once, before any device transfer), and on clean data it mutates
    nothing — the trained model is bitwise identical with validation on
    or off.
    """

    POLICIES = ("raise", "sanitize", "quarantine")

    def __init__(
        self, policy: str = "raise", *,
        n_features: Optional[int] = None,
        n_classes: Optional[int] = None,
        regression: bool = False,
    ):
        if policy not in self.POLICIES:
            raise ValueError(
                f"bad_block_policy must be one of {self.POLICIES} (or None "
                f"to disable validation), got {policy!r}"
            )
        self.policy = policy
        self.n_features = n_features
        self.n_classes = n_classes
        self.regression = regression

    def check(
        self, block: np.ndarray, index: int,
        y_block: Optional[np.ndarray] = None,
    ) -> Optional[BlockIssue]:
        """Inspect one block (and its label slice); return the finding."""
        b = np.asarray(block)
        n_feat = self.n_features
        if b.ndim != 2 or (n_feat is not None and b.shape[1] != n_feat):
            return BlockIssue(index=index, reason="shape", columns=())
        bad_cells = 0
        cols: Tuple[int, ...] = ()
        if np.issubdtype(b.dtype, np.inexact):
            finite = np.isfinite(b)
            if not finite.all():
                bad = ~finite
                bad_cells = int(bad.sum())
                cols = tuple(int(c) for c in np.flatnonzero(bad.any(axis=0)))
        bad_labels = 0
        if y_block is not None:
            yb = np.asarray(y_block)
            bad_y = np.zeros(yb.shape[0], dtype=bool)
            if np.issubdtype(yb.dtype, np.inexact):
                bad_y |= ~np.isfinite(yb)
            if not self.regression and self.n_classes is not None:
                with np.errstate(invalid="ignore"):
                    bad_y |= (yb < 0) | (yb >= self.n_classes)
            bad_labels = int(bad_y.sum())
        if bad_cells or bad_labels:
            reason = "nonfinite" if bad_cells else "label"
            return BlockIssue(
                index=index, reason=reason, columns=cols,
                bad_cells=bad_cells, bad_labels=bad_labels,
            )
        return None

    def _label_mask(self, y_block: np.ndarray) -> np.ndarray:
        yb = np.asarray(y_block)
        bad = np.zeros(yb.shape[0], dtype=bool)
        if np.issubdtype(yb.dtype, np.inexact):
            bad |= ~np.isfinite(yb)
        if not self.regression and self.n_classes is not None:
            with np.errstate(invalid="ignore"):
                bad |= (yb < 0) | (yb >= self.n_classes)
        return bad

    def screen(
        self,
        blocks: Sequence[np.ndarray],
        y: Optional[np.ndarray] = None,
    ):
        """Validate every block and apply the policy.

        Returns ``(blocks, y, cell_masks, label_masks, report)`` —
        blocks/y are the originals when clean (bitwise no-op), imputed
        copies where sanitization touched them; ``cell_masks[i]`` /
        ``label_masks[i]`` are boolean masks of the imputed feature
        cells / labels of block ``i`` (the trainer forces masked cells
        to bin 0 and zeroes masked samples' weights); quarantined block
        indices are listed in ``report.quarantined``.
        """
        blocks = list(blocks)
        y_out = None if y is None else np.asarray(y)
        report = QuarantineReport(policy=self.policy, blocks_checked=len(blocks))
        cell_masks: Dict[int, np.ndarray] = {}
        label_masks: Dict[int, np.ndarray] = {}
        n_feat = self.n_features
        if n_feat is None:
            for b in blocks:
                bb = np.asarray(b)
                if bb.ndim == 2:
                    n_feat = int(bb.shape[1])
                    break
        offset = 0
        for i, b in enumerate(blocks):
            bb = np.asarray(b)
            rows = int(bb.shape[0]) if bb.ndim >= 1 else 0
            yb = None if y_out is None else y_out[offset:offset + rows]
            issue = None
            if bb.ndim != 2 or (n_feat is not None and bb.shape[1] != n_feat):
                issue = BlockIssue(index=i, reason="shape", columns=())
                if self.policy != "quarantine" or y_out is not None:
                    # A drifted block can't be sanitized, and with labels
                    # present its row count can't be reconciled against y.
                    raise DataIntegrityError(
                        f"block {i} drifted in shape: expected [Nb, "
                        f"{n_feat}], got {list(bb.shape)}",
                        block_index=i, reason="shape",
                    )
            else:
                issue = self.check(bb, i, yb)
            if issue is None:
                offset += rows
                continue
            report.issues.append(issue)
            if self.policy == "raise":
                raise DataIntegrityError(
                    issue.describe(), block_index=i,
                    columns=issue.columns, reason=issue.reason,
                )
            if issue.reason == "shape":
                report.quarantined.append(i)
                offset += rows
                continue
            # sanitize and quarantine both impute, so every downstream
            # consumer (bin-edge fitting included) sees finite data; a
            # quarantined block additionally drops out of every sweep.
            if issue.bad_cells:
                mask = ~np.isfinite(bb)
                fixed = bb.copy()
                fixed[mask] = 0.0
                blocks[i] = fixed
                cell_masks[i] = mask
                report.sanitized_cells += issue.bad_cells
            if issue.bad_labels:
                lmask = self._label_mask(yb)
                if y_out is y:
                    y_out = y_out.copy()
                y_out[offset:offset + rows][lmask] = 0
                label_masks[i] = lmask
                report.sanitized_labels += issue.bad_labels
            if self.policy == "quarantine":
                report.quarantined.append(i)
            offset += rows
        return blocks, y_out, cell_masks, label_masks, report


def screen_blocks(
    blocks: Sequence[np.ndarray],
    y: Optional[np.ndarray] = None,
    *,
    policy: str,
    n_features: Optional[int] = None,
    n_classes: Optional[int] = None,
    regression: bool = False,
):
    """Module-level convenience around :meth:`BlockValidator.screen`."""
    validator = BlockValidator(
        policy, n_features=n_features, n_classes=n_classes,
        regression=regression,
    )
    return validator.screen(blocks, y)


class _Sweep:
    """One prefetching pass over a feeder's blocks.

    A real iterator object (not a generator) so the background thread
    has an owner with a deterministic ``close()``: a producer-side
    exception is re-raised from the consumer's next ``__next__`` *after*
    the thread is joined, and early consumer exit (``break``, an
    exception in the loop body, or context-manager ``__exit__``) cancels
    the producer, drains its in-flight device buffers, and joins —
    never a leaked thread or a hung ``queue.put``.
    """

    def __init__(self, feeder: "BlockFeeder"):
        self._feeder = feeder
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=feeder.prefetch)
        self._stop = object()
        self._cancel = threading.Event()
        self._closed = False
        self._thread = threading.Thread(
            target=self._produce, daemon=True, name="prf-block-feeder"
        )
        self._thread.start()

    def _put_item(self, item) -> bool:
        """Enqueue with cancel polling so a gone consumer can't wedge us."""
        while not self._cancel.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self):
        try:
            for i in self._feeder.live_blocks:
                if self._cancel.is_set():
                    return
                b = self._feeder.blocks[i]
                if not self._put_item(self._feeder._put(b, f"block[{i}]", i)):
                    return
            self._put_item(self._stop)
        except BaseException as e:  # re-raised on the consumer side
            self._put_item(e)

    def __iter__(self) -> "_Sweep":
        return self

    def __next__(self):
        if self._closed:
            raise StopIteration
        item = self._q.get()
        if item is self._stop:
            self.close()
            raise StopIteration
        if isinstance(item, BaseException):
            self.close()
            raise item
        return item

    def close(self) -> None:
        """Cancel the producer, drain queued buffers, join the thread.

        A producer that fails to stop within ``feeder.join_timeout``
        seconds is a wedged device transfer — escalated to
        :class:`FeedError` (naming the last feed site) instead of
        silently leaking a live thread.
        """
        if self._closed:
            return
        self._closed = True
        self._cancel.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=self._feeder.join_timeout)
        self._feeder._sweeps.discard(self)
        if self._thread.is_alive():
            try:
                import jax

                proc = int(jax.process_index())
            except Exception:
                proc = 0
            raise FeedError(
                f"feeder thread {self._thread.name!r} on process {proc} "
                f"failed to stop within {self._feeder.join_timeout}s — a "
                f"transfer is wedged at site {self._feeder._last_site!r}"
            )

    def __enter__(self) -> "_Sweep":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class BlockFeeder:
    """Async double-buffered host->device feed of the streaming data plane.

    One feeder owns the host-side sample blocks for a whole training /
    evaluation run. Two jobs:

    * ``pin(a)`` — one-shot ``jax.device_put`` of a per-block constant
      (``y``, DSI weights, channel matrices): uploaded ONCE and kept
      device-resident for every subsequent level sweep, instead of
      re-fed per level.
    * ``sweep()`` — yield device copies of the blocks in order, with a
      background thread running block ``i+1``'s host->device copy while
      block ``i``'s histogram/route call executes (``prefetch`` copies
      in flight; ``prefetch=0`` degrades to the synchronous feed). JAX
      dispatch is async, so the consumer's device work and the
      producer's ``device_put`` genuinely overlap.

    ``placement`` is anything ``jax.device_put`` accepts as a target —
    a device for the single-host driver, or a ``NamedSharding`` so each
    mesh shard receives its (sample x feature) slice of every block
    (the mesh-streamed path, ``distributed.grow_forest_streamed_sharded``).

    **Fault tolerance.** Every host->device transfer (``pin`` and each
    sweep block) runs through a bounded retry loop: a ``retryable``
    exception (default ``OSError`` — flaky memmap page-ins — and
    ``RuntimeError``, which covers transient device_put failures and
    ``launch.fault.SimulatedFailure``) is retried up to ``max_retries``
    times with exponential backoff (``backoff * backoff_factor**i``,
    capped at ``max_backoff`` seconds); exhaustion raises
    :class:`FeedError` from the last error. ``fault_hook`` is a
    deterministic chaos hook called before every transfer (see
    ``launch.fault.FaultInjector``) so injected-failure tests are
    reproducible. ``retries`` counts the retried attempts.

    A feeder is a context manager: ``close()`` (or ``__exit__``) shuts
    down any live sweep threads deterministically.
    """

    def __init__(
        self,
        blocks: Sequence[Any],
        *,
        placement: Any = None,
        prefetch: int = 2,
        max_retries: int = 3,
        backoff: float = 0.05,
        backoff_factor: float = 2.0,
        max_backoff: float = 2.0,
        retryable: Tuple[type, ...] = (OSError, RuntimeError),
        fault_hook: Optional[Callable[[str], None]] = None,
        validator: Optional[BlockValidator] = None,
        quarantined: Sequence[int] = (),
        join_timeout: float = 10.0,
    ):
        self.blocks = list(blocks)
        if not self.blocks:
            raise ValueError(
                "BlockFeeder needs at least one sample block — got an empty "
                "block sequence"
            )
        # Eager integrity screen: quarantine decisions are made ONCE at
        # construction (before any pin or sweep), so every level sweep
        # of a run sees the same live-block set deterministically.
        self.report: Optional[QuarantineReport] = None
        quar = {int(i) for i in quarantined}
        if validator is not None:
            self.blocks, _, _, _, self.report = validator.screen(self.blocks)
            quar |= set(self.report.quarantined)
        out_of_range = [i for i in quar if not 0 <= i < len(self.blocks)]
        if out_of_range:
            raise ValueError(
                f"quarantined block indices out of range: {sorted(out_of_range)}"
            )
        self.quarantined = tuple(sorted(quar))
        self.live_blocks = tuple(
            i for i in range(len(self.blocks)) if i not in quar
        )
        if not self.live_blocks:
            raise DataIntegrityError(
                f"every block quarantined ({len(self.blocks)} of "
                f"{len(self.blocks)}) — nothing left to train on",
                reason="quarantine",
            )
        self.placement = placement
        self.prefetch = int(prefetch)
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if backoff < 0 or max_backoff < 0 or backoff_factor < 1.0:
            raise ValueError(
                "backoff/max_backoff must be >= 0 and backoff_factor >= 1"
            )
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.backoff_factor = float(backoff_factor)
        self.max_backoff = float(max_backoff)
        self.retryable = tuple(retryable)
        self.fault_hook = fault_hook
        if join_timeout <= 0:
            raise ValueError(f"join_timeout must be > 0, got {join_timeout}")
        self.join_timeout = float(join_timeout)
        self.retries = 0                     # total retried attempts
        self._last_site: Optional[str] = None
        self._sweeps: set = set()

    def __len__(self) -> int:
        return len(self.blocks)

    def _put(self, host_array, site: str, index: Optional[int] = None):
        """One host->device transfer under the bounded retry policy."""
        import jax

        self._last_site = site
        attempt = 0
        while True:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(site)
                if callable(self.placement):
                    # Multi-process placement: a callback building the
                    # global device array from this process's host-local
                    # rows (needs the block index for its row offset).
                    return self.placement(host_array, index)
                if self.placement is None:
                    return jax.device_put(host_array)
                return jax.device_put(host_array, self.placement)
            except self.retryable as e:
                attempt += 1
                if attempt > self.max_retries:
                    raise FeedError(
                        f"feed of {site} failed permanently after "
                        f"{self.max_retries} retries: {e}"
                    ) from e
                self.retries += 1
                time.sleep(min(
                    self.backoff * self.backoff_factor ** (attempt - 1),
                    self.max_backoff,
                ))

    def pin(self, host_array):
        """Pin one host array on device (respecting ``placement``)."""
        return self._put(host_array, "pin")

    def sweep(self) -> Iterator[Any]:
        """Yield the *live* blocks as device arrays, prefetch-deep.

        Quarantined blocks are skipped entirely — never transferred,
        never histogrammed. Zip with ``live_blocks`` to recover the
        original block index of each yielded buffer.
        """
        if self.prefetch <= 0:
            def sync():
                for i in self.live_blocks:
                    yield self._put(self.blocks[i], f"block[{i}]", i)
            return sync()
        s = _Sweep(self)
        self._sweeps.add(s)
        return s

    def close(self) -> None:
        """Shut down any live sweep threads (idempotent)."""
        for s in list(self._sweeps):
            s.close()

    def __enter__(self) -> "BlockFeeder":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    n_docs: int = 2048
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipf marginals + deterministic bigram transitions => learnable.
        probs = 1.0 / np.arange(1, self.vocab_size + 1) ** 1.1
        probs /= probs.sum()
        succ = rng.integers(0, self.vocab_size, self.vocab_size)
        toks = rng.choice(self.vocab_size, (self.n_docs, self.seq_len + 1), p=probs)
        follow = rng.random((self.n_docs, self.seq_len)) < 0.5
        for t in range(1, self.seq_len + 1):
            toks[:, t] = np.where(follow[:, t - 1], succ[toks[:, t - 1]], toks[:, t])
        self.corpus = toks.astype(np.int32)          # the single shared copy

    def dsi_epoch(self, epoch: int, batch: int, steps: int) -> np.ndarray:
        """Index table [steps, batch] — the DSI analogue (no data copied)."""
        rng = np.random.default_rng(self.seed * 1000 + epoch)
        return rng.integers(0, self.n_docs, (steps, batch)).astype(np.int32)

    def batch(self, dsi_row: np.ndarray) -> Dict[str, np.ndarray]:
        docs = self.corpus[dsi_row]                  # gather through the DSI
        return {"tokens": docs[:, :-1], "targets": docs[:, 1:]}

    def batches(self, batch: int, steps: int, *, epoch: int = 0,
                n_micro: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        table = self.dsi_epoch(epoch, batch, steps)
        for s in range(steps):
            b = self.batch(table[s])
            if n_micro > 1:
                b = {
                    k: v.reshape(n_micro, batch // n_micro, *v.shape[1:])
                    for k, v in b.items()
                }
            else:
                b = {k: v[None] for k, v in b.items()}
            yield b
