"""Token data pipeline with DSI-style multiplexed sampling (paper §4.1.2).

The paper's data-multiplexing idea applied to LM training: the tokenized
corpus is materialized ONCE (shared, read-only); every epoch/replica is
just an *index table* over it. Shuffling, repeats, and replica splits
never copy token data — the same flat-in-k volume property as the PRF
DSI table. Synthetic corpus here (Zipf-ish token stream with injected
bigram structure so loss visibly decreases); swap `corpus` for a memmap
of real tokens in production.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import (
    Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple, Union,
)

import numpy as np


def sample_blocks(
    x: Union[np.ndarray, Sequence[np.ndarray]], block_rows: int = 0
) -> List[np.ndarray]:
    """Zero-copy ``[Nb, F]`` row views over a host array / ``np.memmap``.

    The block-feed API of the out-of-core trainer
    (``repro.core.api.grow_forest_streamed``): an array source is
    sliced into ``block_rows``-row views (no copy — memmap blocks are
    only paged in when a block is fed to the device), and an explicit
    sequence of blocks passes through unchanged, so callers can stream
    from any host source that yields row blocks. ``block_rows <= 0``
    means one block (the degenerate resident feed).
    """
    if isinstance(x, (list, tuple)):
        return [np.asarray(b) for b in x]
    src = np.asarray(x)
    nb = block_rows if block_rows > 0 else src.shape[0]
    return [src[i:i + nb] for i in range(0, src.shape[0], nb)]


def stream_blocks(
    x: Union[np.ndarray, Sequence[Any]],
    sample_block: Optional[int],
    *,
    what: str,
    n_y: Optional[int] = None,
    n_w: Optional[int] = None,
) -> List[Any]:
    """The ONE block-list constructor + validator of the streaming data
    plane (growth, dimred, OOB, prediction — local and mesh).

    An explicit block sequence passes through (device arrays included);
    an array/memmap source is sliced per ``sample_block``, which must be
    > 0 so the full ``[N, F]`` matrix can never silently become one
    device block. Rejects empty block sequences, and — when the caller
    supplies its label/weight lengths — blocks that do not cover them.
    """
    if isinstance(x, (list, tuple)):
        blocks = list(x)
    else:
        if sample_block is None or sample_block <= 0:
            raise ValueError(
                f"{what} with an array/memmap source needs sample_block > 0 "
                "— sample_block=0 would feed the whole [N, F] matrix as one "
                "device block, which is exactly what the streaming plane "
                "exists to avoid (pass an explicit block list to stream "
                "from a custom source)"
            )
        blocks = sample_blocks(x, sample_block)
    if not blocks:
        raise ValueError(
            f"{what} got an empty block sequence — the data source yielded "
            "no [Nb, F] sample blocks (empty block list, or an array source "
            "with 0 rows)"
        )
    if n_y is not None or n_w is not None:
        covered = sum(int(b.shape[0]) for b in blocks)
        if (n_y is not None and covered != n_y) or (
            n_w is not None and covered != n_w
        ):
            raise ValueError(
                f"{what}: blocks cover {covered} samples, but y has {n_y} "
                f"and weights {n_w}"
            )
    return blocks


class FeedError(RuntimeError):
    """A block feed failed permanently (retry budget exhausted, or a
    non-retryable error)."""


class _Sweep:
    """One prefetching pass over a feeder's blocks.

    A real iterator object (not a generator) so the background thread
    has an owner with a deterministic ``close()``: a producer-side
    exception is re-raised from the consumer's next ``__next__`` *after*
    the thread is joined, and early consumer exit (``break``, an
    exception in the loop body, or context-manager ``__exit__``) cancels
    the producer, drains its in-flight device buffers, and joins —
    never a leaked thread or a hung ``queue.put``.
    """

    def __init__(self, feeder: "BlockFeeder"):
        self._feeder = feeder
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=feeder.prefetch)
        self._stop = object()
        self._cancel = threading.Event()
        self._closed = False
        self._thread = threading.Thread(
            target=self._produce, daemon=True, name="prf-block-feeder"
        )
        self._thread.start()

    def _put_item(self, item) -> bool:
        """Enqueue with cancel polling so a gone consumer can't wedge us."""
        while not self._cancel.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self):
        try:
            for i, b in enumerate(self._feeder.blocks):
                if self._cancel.is_set():
                    return
                if not self._put_item(self._feeder._put(b, f"block[{i}]")):
                    return
            self._put_item(self._stop)
        except BaseException as e:  # re-raised on the consumer side
            self._put_item(e)

    def __iter__(self) -> "_Sweep":
        return self

    def __next__(self):
        if self._closed:
            raise StopIteration
        item = self._q.get()
        if item is self._stop:
            self.close()
            raise StopIteration
        if isinstance(item, BaseException):
            self.close()
            raise item
        return item

    def close(self) -> None:
        """Cancel the producer, drain queued buffers, join the thread."""
        if self._closed:
            return
        self._closed = True
        self._cancel.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=10)
        self._feeder._sweeps.discard(self)

    def __enter__(self) -> "_Sweep":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class BlockFeeder:
    """Async double-buffered host->device feed of the streaming data plane.

    One feeder owns the host-side sample blocks for a whole training /
    evaluation run. Two jobs:

    * ``pin(a)`` — one-shot ``jax.device_put`` of a per-block constant
      (``y``, DSI weights, channel matrices): uploaded ONCE and kept
      device-resident for every subsequent level sweep, instead of
      re-fed per level.
    * ``sweep()`` — yield device copies of the blocks in order, with a
      background thread running block ``i+1``'s host->device copy while
      block ``i``'s histogram/route call executes (``prefetch`` copies
      in flight; ``prefetch=0`` degrades to the synchronous feed). JAX
      dispatch is async, so the consumer's device work and the
      producer's ``device_put`` genuinely overlap.

    ``placement`` is anything ``jax.device_put`` accepts as a target —
    a device for the single-host driver, or a ``NamedSharding`` so each
    mesh shard receives its (sample x feature) slice of every block
    (the mesh-streamed path, ``distributed.grow_forest_streamed_sharded``).

    **Fault tolerance.** Every host->device transfer (``pin`` and each
    sweep block) runs through a bounded retry loop: a ``retryable``
    exception (default ``OSError`` — flaky memmap page-ins — and
    ``RuntimeError``, which covers transient device_put failures and
    ``launch.fault.SimulatedFailure``) is retried up to ``max_retries``
    times with exponential backoff (``backoff * backoff_factor**i``,
    capped at ``max_backoff`` seconds); exhaustion raises
    :class:`FeedError` from the last error. ``fault_hook`` is a
    deterministic chaos hook called before every transfer (see
    ``launch.fault.FaultInjector``) so injected-failure tests are
    reproducible. ``retries`` counts the retried attempts.

    A feeder is a context manager: ``close()`` (or ``__exit__``) shuts
    down any live sweep threads deterministically.
    """

    def __init__(
        self,
        blocks: Sequence[Any],
        *,
        placement: Any = None,
        prefetch: int = 2,
        max_retries: int = 3,
        backoff: float = 0.05,
        backoff_factor: float = 2.0,
        max_backoff: float = 2.0,
        retryable: Tuple[type, ...] = (OSError, RuntimeError),
        fault_hook: Optional[Callable[[str], None]] = None,
    ):
        self.blocks = list(blocks)
        if not self.blocks:
            raise ValueError(
                "BlockFeeder needs at least one sample block — got an empty "
                "block sequence"
            )
        self.placement = placement
        self.prefetch = int(prefetch)
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if backoff < 0 or max_backoff < 0 or backoff_factor < 1.0:
            raise ValueError(
                "backoff/max_backoff must be >= 0 and backoff_factor >= 1"
            )
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self.backoff_factor = float(backoff_factor)
        self.max_backoff = float(max_backoff)
        self.retryable = tuple(retryable)
        self.fault_hook = fault_hook
        self.retries = 0                     # total retried attempts
        self._sweeps: set = set()

    def __len__(self) -> int:
        return len(self.blocks)

    def _put(self, host_array, site: str):
        """One host->device transfer under the bounded retry policy."""
        import jax

        attempt = 0
        while True:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(site)
                if self.placement is None:
                    return jax.device_put(host_array)
                return jax.device_put(host_array, self.placement)
            except self.retryable as e:
                attempt += 1
                if attempt > self.max_retries:
                    raise FeedError(
                        f"feed of {site} failed permanently after "
                        f"{self.max_retries} retries: {e}"
                    ) from e
                self.retries += 1
                time.sleep(min(
                    self.backoff * self.backoff_factor ** (attempt - 1),
                    self.max_backoff,
                ))

    def pin(self, host_array):
        """Pin one host array on device (respecting ``placement``)."""
        return self._put(host_array, "pin")

    def sweep(self) -> Iterator[Any]:
        """Yield the blocks as device arrays, prefetch-deep."""
        if self.prefetch <= 0:
            def sync():
                for i, b in enumerate(self.blocks):
                    yield self._put(b, f"block[{i}]")
            return sync()
        s = _Sweep(self)
        self._sweeps.add(s)
        return s

    def close(self) -> None:
        """Shut down any live sweep threads (idempotent)."""
        for s in list(self._sweeps):
            s.close()

    def __enter__(self) -> "BlockFeeder":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    n_docs: int = 2048
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipf marginals + deterministic bigram transitions => learnable.
        probs = 1.0 / np.arange(1, self.vocab_size + 1) ** 1.1
        probs /= probs.sum()
        succ = rng.integers(0, self.vocab_size, self.vocab_size)
        toks = rng.choice(self.vocab_size, (self.n_docs, self.seq_len + 1), p=probs)
        follow = rng.random((self.n_docs, self.seq_len)) < 0.5
        for t in range(1, self.seq_len + 1):
            toks[:, t] = np.where(follow[:, t - 1], succ[toks[:, t - 1]], toks[:, t])
        self.corpus = toks.astype(np.int32)          # the single shared copy

    def dsi_epoch(self, epoch: int, batch: int, steps: int) -> np.ndarray:
        """Index table [steps, batch] — the DSI analogue (no data copied)."""
        rng = np.random.default_rng(self.seed * 1000 + epoch)
        return rng.integers(0, self.n_docs, (steps, batch)).astype(np.int32)

    def batch(self, dsi_row: np.ndarray) -> Dict[str, np.ndarray]:
        docs = self.corpus[dsi_row]                  # gather through the DSI
        return {"tokens": docs[:, :-1], "targets": docs[:, 1:]}

    def batches(self, batch: int, steps: int, *, epoch: int = 0,
                n_micro: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        table = self.dsi_epoch(epoch, batch, steps)
        for s in range(steps):
            b = self.batch(table[s])
            if n_micro > 1:
                b = {
                    k: v.reshape(n_micro, batch // n_micro, *v.shape[1:])
                    for k, v in b.items()
                }
            else:
                b = {k: v[None] for k, v in b.items()}
            yield b
