"""Token data pipeline with DSI-style multiplexed sampling (paper §4.1.2).

The paper's data-multiplexing idea applied to LM training: the tokenized
corpus is materialized ONCE (shared, read-only); every epoch/replica is
just an *index table* over it. Shuffling, repeats, and replica splits
never copy token data — the same flat-in-k volume property as the PRF
DSI table. Synthetic corpus here (Zipf-ish token stream with injected
bigram structure so loss visibly decreases); swap `corpus` for a memmap
of real tokens in production.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Union

import numpy as np


def sample_blocks(
    x: Union[np.ndarray, Sequence[np.ndarray]], block_rows: int = 0
) -> List[np.ndarray]:
    """Zero-copy ``[Nb, F]`` row views over a host array / ``np.memmap``.

    The block-feed API of the out-of-core trainer
    (``repro.core.api.grow_forest_streamed``): an array source is
    sliced into ``block_rows``-row views (no copy — memmap blocks are
    only paged in when a block is fed to the device), and an explicit
    sequence of blocks passes through unchanged, so callers can stream
    from any host source that yields row blocks. ``block_rows <= 0``
    means one block (the degenerate resident feed).
    """
    if isinstance(x, (list, tuple)):
        return [np.asarray(b) for b in x]
    src = np.asarray(x)
    nb = block_rows if block_rows > 0 else src.shape[0]
    return [src[i:i + nb] for i in range(0, src.shape[0], nb)]


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    n_docs: int = 2048
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipf marginals + deterministic bigram transitions => learnable.
        probs = 1.0 / np.arange(1, self.vocab_size + 1) ** 1.1
        probs /= probs.sum()
        succ = rng.integers(0, self.vocab_size, self.vocab_size)
        toks = rng.choice(self.vocab_size, (self.n_docs, self.seq_len + 1), p=probs)
        follow = rng.random((self.n_docs, self.seq_len)) < 0.5
        for t in range(1, self.seq_len + 1):
            toks[:, t] = np.where(follow[:, t - 1], succ[toks[:, t - 1]], toks[:, t])
        self.corpus = toks.astype(np.int32)          # the single shared copy

    def dsi_epoch(self, epoch: int, batch: int, steps: int) -> np.ndarray:
        """Index table [steps, batch] — the DSI analogue (no data copied)."""
        rng = np.random.default_rng(self.seed * 1000 + epoch)
        return rng.integers(0, self.n_docs, (steps, batch)).astype(np.int32)

    def batch(self, dsi_row: np.ndarray) -> Dict[str, np.ndarray]:
        docs = self.corpus[dsi_row]                  # gather through the DSI
        return {"tokens": docs[:, :-1], "targets": docs[:, 1:]}

    def batches(self, batch: int, steps: int, *, epoch: int = 0,
                n_micro: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        table = self.dsi_epoch(epoch, batch, steps)
        for s in range(steps):
            b = self.batch(table[s])
            if n_micro > 1:
                b = {
                    k: v.reshape(n_micro, batch // n_micro, *v.shape[1:])
                    for k, v in b.items()
                }
            else:
                b = {k: v[None] for k, v in b.items()}
            yield b
