from . import tabular  # noqa: F401
