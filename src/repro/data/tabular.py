"""Synthetic tabular classification/regression generators.

Mirrors the traits of the paper's datasets (Tables 3-4): large N, high
dimensionality M, many classes, heavy noise — without shipping UCI data.
A fraction of features is informative (class-conditional Gaussian blobs),
a fraction is redundant (linear mixes of informative ones), the rest is
pure noise; a label-noise rate flips a share of labels, reproducing the
"noisy data" regime the paper's accuracy experiments target.
"""
from __future__ import annotations

import numpy as np


def make_classification(
    n_samples: int = 4096,
    n_features: int = 64,
    n_classes: int = 4,
    n_informative: int = 12,
    n_redundant: int = 8,
    class_sep: float = 1.6,
    label_noise: float = 0.05,
    seed: int = 0,
):
    """Returns (x [N, M] float32, y [N] int32)."""
    rng = np.random.default_rng(seed)
    n_informative = min(n_informative, n_features)
    n_redundant = min(n_redundant, n_features - n_informative)

    centers = rng.normal(0.0, class_sep, (n_classes, n_informative))
    y = rng.integers(0, n_classes, n_samples)
    x_inf = centers[y] + rng.normal(0.0, 1.0, (n_samples, n_informative))

    mix = rng.normal(0.0, 1.0, (n_informative, n_redundant))
    x_red = x_inf @ mix / np.sqrt(n_informative)

    n_noise = n_features - n_informative - n_redundant
    x_noise = rng.normal(0.0, 1.0, (n_samples, n_noise))

    x = np.concatenate([x_inf, x_red, x_noise], axis=1).astype(np.float32)
    perm = rng.permutation(n_features)
    x = x[:, perm]

    flip = rng.random(n_samples) < label_noise
    y = np.where(flip, rng.integers(0, n_classes, n_samples), y)
    return x, y.astype(np.int32)


def make_regression(
    n_samples: int = 4096,
    n_features: int = 32,
    n_informative: int = 8,
    noise: float = 0.1,
    seed: int = 0,
):
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, (n_samples, n_features)).astype(np.float32)
    w = np.zeros(n_features)
    idx = rng.choice(n_features, min(n_informative, n_features), replace=False)
    w[idx] = rng.normal(0.0, 1.0, len(idx))
    y = np.tanh(x @ w) + noise * rng.normal(0.0, 1.0, n_samples)
    return x, y.astype(np.float32)


def train_test_split(x, y, test_frac=0.25, seed=0):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    perm = rng.permutation(n)
    n_test = int(n * test_frac)
    te, tr = perm[:n_test], perm[n_test:]
    return x[tr], y[tr], x[te], y[te]
